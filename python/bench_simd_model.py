#!/usr/bin/env python3
"""Numpy mirror of `blockms simd` for containers without cargo.

Generates BENCH_simd.json with the exact schema of the rust bench
(EXPERIMENTS.md §SIMD). Three kinds of numbers:

- The naive and lanes anchor timings are *measured* on the same numpy
  kernel mirror the layout model uses (fixed Lloyd iterations + final
  labeling over the real block plans, best of `samples` after one
  warmup).
- The per-level simd timings are *modeled*: lanes wall x the per-level
  simd-over-lanes scale baked into the rust cost model
  (`plan/cost.rs::SimdScale` — avx512 0.58, avx2 0.72, neon 0.82,
  portable 1.0). Numpy cannot choose its own vector ISA, so the model
  states the planner's prior rather than inventing a measurement —
  hence `"source": "python-model"`. Regenerate with `blockms simd`
  where cargo exists.
- `matches_solo` is *computed*, not assumed: the scene is quantized to
  1/8 steps so every f64 accumulation is exact and partition-
  independent, and each cell's labels are compared bitwise against a
  solo single-block naive run. The non-FMA simd path runs the same
  per-pixel op order as lanes (the rust bit-identity invariant), so
  simd rows inherit the lanes labels.

The detected level comes from /proc/cpuinfo (avx512f > avx2) or the
machine architecture (aarch64 -> neon), falling back to portable.
"""

import json
import math
import platform
import sys

import numpy as np

import bench_layout_model as L

H = W = 1024
C = 3
KS = [2, 4, 8]
ITERS = 4
SAMPLES = 2
SEED = 0x51ADBE
WORKERS = 4
STRIP_ROWS = 64

# Mirrors rust plan/cost.rs::SimdScale::default().
SIMD_SCALE = {"avx512": 0.58, "avx2": 0.72, "neon": 0.82, "portable": 1.0}


def cpu_flags():
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.startswith(("flags", "Features")):
                    return set(line.split(":", 1)[1].split())
    except OSError:
        pass
    return set()


def detect_level():
    """SimdLevel::detect() for this host."""
    machine = platform.machine()
    if machine in ("aarch64", "arm64"):
        return "neon"
    flags = cpu_flags()
    if "avx512f" in flags:
        return "avx512"
    if "avx2" in flags:
        return "avx2"
    return "portable"


def supported_levels():
    """SimdLevel::ALL filtered by SimdLevel::supported(), in ALL order."""
    detected = detect_level()
    levels = ["portable"]
    if detected == "neon":
        levels.append("neon")
    if detected in ("avx2", "avx512"):
        levels.append("avx2")
    if detected == "avx512":
        levels.append("avx512")
    return levels


def scatter_labels(plan, labels):
    """run_cell returns labels concatenated in block order; map them back
    to global row-major pixel positions (what the rust coordinator's
    assembled label image holds) so plans with different block shapes
    compare position-for-position."""
    out = np.empty(H * W, dtype=labels.dtype)
    off = 0
    for r0, c0, rows, cols in plan:
        rr, cc = np.meshgrid(
            np.arange(r0, r0 + rows), np.arange(c0, c0 + cols), indexing="ij"
        )
        n = rows * cols
        out[(rr * W + cc).ravel()] = labels[off : off + n]
        off += n
    return out


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_simd.json"
    rng = np.random.default_rng(SEED)
    # Quantize to 1/8 steps: every f64 block sum is then exact, so the
    # solo reference and every block partition agree bit for bit — the
    # same invariant the rust coordinator proves with its tests.
    img = np.round(L.synthetic_scene(rng) * 8.0) / 8.0
    flat = img.reshape(-1, C)
    passes = ITERS + 1
    detected = detect_level()
    levels = supported_levels()
    solo_plan = L.block_plan(H, W)  # one block == the solo sequential run
    cases = []
    for shape_name, br, bc in L.paper_shapes():
        plan = L.block_plan(br, bc)
        for k in KS:
            init_cen = flat[rng.choice(len(flat), size=k, replace=False)].copy()
            solo_raw, _ = L.run_cell(img, solo_plan, "interleaved", "naive", k, init_cen)
            solo_labels = scatter_labels(solo_plan, solo_raw)
            walls = {}
            for layout, kernel in [("interleaved", "naive"), ("soa", "lanes")]:
                best = math.inf
                labels = None
                for sample in range(SAMPLES + 1):
                    labels, wall = L.run_cell(img, plan, layout, kernel, k, init_cen)
                    if sample > 0:
                        best = min(best, wall)
                matches = bool(np.array_equal(scatter_labels(plan, labels), solo_labels))
                if not matches:
                    raise SystemExit(
                        f"model kernel diverged from solo: {shape_name} {kernel} k={k}"
                    )
                walls[kernel] = best
            lanes = walls["lanes"]
            rows = [("naive", None, walls["naive"]), ("lanes", None, lanes)]
            for level in levels:
                rows.append(("simd", level, lanes * SIMD_SCALE[level]))
            for kernel, level, wall in rows:
                cases.append(
                    {
                        "kernel": kernel,
                        "level": level if level is not None else "-",
                        "fma": False,
                        "shape": shape_name,
                        "k": k,
                        "wall_secs": round(wall, 6),
                        "ns_per_pixel_round": round(wall * 1e9 / (H * W * passes), 4),
                        "speedup_vs_lanes": round(lanes / wall, 4),
                        "matches_solo": True,
                    }
                )
                print(
                    f"{shape_name:>6} k={k} {kernel:>5}[{cases[-1]['level']:>8}]"
                    f" {cases[-1]['ns_per_pixel_round']:>9.3f} ns/px/round"
                    f"  x{cases[-1]['speedup_vs_lanes']:.2f} vs lanes",
                    flush=True,
                )
    doc = {
        "image": [H, W],
        "channels": C,
        "iters": ITERS,
        "samples": SAMPLES,
        "seed": SEED,
        "workers": WORKERS,
        "strip_rows": STRIP_ROWS,
        "source": "python-model",
        "detected_level": detected,
        "cases": cases,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path} ({len(cases)} cases, detected={detected})")


if __name__ == "__main__":
    main()
