#!/usr/bin/env python3
"""Schema check for BENCH_distributed.json (CI smoke + committed file).

Usage: check_distributed_schema.py <path> [--full]

Validates the document the rust `blockms distributed` bench and
`bench_distributed_model.py` both emit (EXPERIMENTS.md §Distributed):

- `matches_solo` must be true on **every** row — a fast distributed
  run that diverged from solo is a broken merge, not a result;
- `wire_bytes` and `model_wire_bytes` on every sharded row must equal
  the bytes-per-round closed form re-derived here from the document's
  own geometry (the planner prices exactly what moves);
- within each k, `model_wall_secs` must be monotone non-increasing
  from one shard through the modeled sweet spot (the argmin over the
  shard rows), and the measured wall must track it with 1.25x slack
  plus a 5 ms absolute guard (quick-geometry runs are spawn-noise
  dominated).

With --full, also requires the acceptance matrix — 1024x1024,
k in {2,4,8}, shards {0,1,2,4} — and `speedup_vs_solo >= 1.0` at each
k's modeled sweet spot: distribution must actually pay where the model
says it does.
"""

import json
import sys

META_NUM = [
    "channels",
    "iters",
    "samples",
    "seed",
    "conns_per_shard",
    "blocks",
    "wire_ns_per_byte",
]
CASE_NUM = [
    "shards",
    "k",
    "wall_secs",
    "ns_per_pixel_round",
    "speedup_vs_solo",
    "wire_bytes",
    "model_wire_bytes",
    "model_wall_secs",
]

# Frame-layout constants, mirrored from rust/src/shard/wire.rs.
WIRE_FRAME_HEADER = 20
WIRE_REGISTER_FIXED = WIRE_FRAME_HEADER + 8 + 118
WIRE_BLOCK_FIXED = WIRE_FRAME_HEADER + 34
WIRE_RESULT_FIXED = WIRE_FRAME_HEADER + 64
WIRE_PING = WIRE_FRAME_HEADER + 8

WALL_SLACK = 1.25
WALL_EPS = 0.005


def sharded_wire_bytes(h, w, c, k, rounds, blocks, conns):
    """down + up — rust plan/cost.rs::sharded_wire_bytes verbatim."""
    image_bytes = 4 * h * w * c
    centroids = 4 * k * c
    drift = 8 * k + 8
    block_frames = blocks * (rounds + 1)
    down = (
        conns * (WIRE_REGISTER_FIXED + image_bytes + WIRE_PING)
        + block_frames * (WIRE_BLOCK_FIXED + centroids)
        + blocks * rounds * drift
        + conns * WIRE_FRAME_HEADER
    )
    up = (
        conns * (WIRE_FRAME_HEADER + WIRE_PING)
        + blocks * rounds * (WIRE_RESULT_FIXED + 8 * k + 8 * k * c)
        + blocks * WIRE_RESULT_FIXED
        + 4 * h * w
    )
    return down + up


def fail(msg):
    print(f"BENCH_distributed.json schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    full = "--full" in sys.argv
    path = args[0] if args else "BENCH_distributed.json"
    with open(path) as f:
        doc = json.load(f)

    for key in META_NUM:
        if not isinstance(doc.get(key), (int, float)):
            fail(f"meta field {key!r} missing or non-numeric")
    img = doc.get("image")
    if not (isinstance(img, list) and len(img) == 2):
        fail("image must be [height, width]")
    if doc.get("source") not in ("rust", "python-model"):
        fail(f"unknown source {doc.get('source')!r}")
    h, w = img
    c = doc["channels"]
    iters = doc["iters"]
    blocks = doc["blocks"]
    conns = doc["conns_per_shard"]

    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        fail("cases missing or empty")
    by_k = {}
    for i, case in enumerate(cases):
        for key in CASE_NUM:
            if not isinstance(case.get(key), (int, float)):
                fail(f"case {i}: field {key!r} missing or non-numeric")
        if case.get("matches_solo") is not True:
            fail(
                f"case {i} (shards={case['shards']}, k={case['k']}): "
                "matches_solo != true — the distributed merge diverged from solo"
            )
        by_k.setdefault(case["k"], []).append((i, case))

    for k, rows in sorted(by_k.items()):
        if rows[0][1]["shards"] != 0:
            fail(f"k={k}: first row must be the solo anchor (shards=0)")
        i0, solo = rows[0]
        if solo["wire_bytes"] != 0 or solo["model_wire_bytes"] != 0:
            fail(f"case {i0}: solo row must report zero wire bytes")
        if abs(solo["speedup_vs_solo"] - 1.0) > 1e-6:
            fail(f"case {i0}: solo anchor must carry speedup 1.0")
        shard_rows = rows[1:]
        if not shard_rows:
            fail(f"k={k}: no sharded rows")
        prev_shards = 0
        for i, case in shard_rows:
            shards = case["shards"]
            if shards <= prev_shards:
                fail(f"case {i}: shard counts must be ascending within k={k}")
            prev_shards = shards
            want = sharded_wire_bytes(h, w, c, k, iters, blocks, shards * conns)
            if case["wire_bytes"] != want:
                fail(
                    f"case {i} ({shards} shards, k={k}): wire_bytes "
                    f"{case['wire_bytes']} != closed form {want}"
                )
            if case["model_wire_bytes"] != want:
                fail(
                    f"case {i} ({shards} shards, k={k}): model_wire_bytes "
                    f"{case['model_wire_bytes']} != closed form {want}"
                )
        # Monotone non-increasing through the modeled sweet spot: the
        # model must not claim a dip it immediately takes back, and the
        # measured wall must track the model's descent (with slack —
        # quick-geometry walls are spawn-noise dominated).
        walls = [case["wall_secs"] for _i, case in shard_rows]
        model = [case["model_wall_secs"] for _i, case in shard_rows]
        sweet = model.index(min(model))
        for j in range(sweet):
            if model[j + 1] > model[j] * (1 + 1e-9):
                fail(
                    f"k={k}: model_wall_secs rises before the sweet spot "
                    f"({model[j]:.6f} -> {model[j + 1]:.6f} at "
                    f"{shard_rows[j + 1][1]['shards']} shards)"
                )
            if walls[j + 1] > walls[j] * WALL_SLACK + WALL_EPS:
                fail(
                    f"k={k}: measured wall rises before the modeled sweet spot "
                    f"({walls[j]:.6f} -> {walls[j + 1]:.6f} at "
                    f"{shard_rows[j + 1][1]['shards']} shards)"
                )
        if full and shard_rows[sweet][1]["speedup_vs_solo"] < 1.0:
            fail(
                f"k={k}: modeled sweet spot ({shard_rows[sweet][1]['shards']} "
                f"shards) is slower than solo "
                f"(speedup {shard_rows[sweet][1]['speedup_vs_solo']})"
            )

    if full:
        if img != [1024, 1024]:
            fail(f"--full requires a 1024x1024 image, got {img}")
        if sorted(by_k) != [2, 4, 8]:
            fail(f"--full requires k in {{2,4,8}}, got {sorted(by_k)}")
        for k, rows in by_k.items():
            counts = [case["shards"] for _i, case in rows]
            if counts != [0, 1, 2, 4]:
                fail(f"--full requires shards [0,1,2,4] per k, k={k} has {counts}")

    ks = ",".join(str(k) for k in sorted(by_k))
    print(f"{path}: schema OK ({len(cases)} cases, k={{{ks}}}, source={doc['source']})")


if __name__ == "__main__":
    main()
