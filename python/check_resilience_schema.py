#!/usr/bin/env python3
"""Schema check for BENCH_resilience.json (CI smoke + committed file).

Usage: check_resilience_schema.py <path> [--full]

Validates the document the rust `blockms resilience` bench and the
python model both emit (EXPERIMENTS.md §Resilience), and gates the
fault-tolerance acceptance invariants:

- every scenario row is bitwise identical to its fault-free baseline
  (`matches_baseline`) — retries and resume may cost time, never values;
- every geometry carries all four scenarios (baseline, retry,
  checkpoint, resume);
- the retry and resume rows actually injected a fault, and the resume
  row timed a positive recovery leg;
- fault-tolerance overhead is bounded: retry and checkpoint within 50%
  of baseline (generous — CI smoke geometries are milliseconds-tall and
  noisy), resume within 150% (a kill re-does at most the round it died
  in plus the post-checkpoint tail).

With --full (the committed, full-size document), the bounds tighten —
retry/checkpoint within 10%, resume within 60% — and the paper-sized
1024x1024 geometry is required.
"""

import json
import sys

SCENARIOS = {"baseline", "retry", "checkpoint", "resume"}
META_NUM = [
    "k",
    "iters",
    "samples",
    "seed",
    "workers",
    "retries",
    "checkpoint_every",
    "channels",
]
CASE_NUM = [
    "height",
    "width",
    "wall_secs",
    "ns_per_pixel_round",
    "overhead_pct",
    "recovery_secs",
    "faults_injected",
    "retries_used",
]


def fail(msg):
    print(f"BENCH_resilience.json schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    full = "--full" in sys.argv
    path = args[0] if args else "BENCH_resilience.json"
    with open(path) as f:
        doc = json.load(f)

    for key in META_NUM:
        if not isinstance(doc.get(key), (int, float)):
            fail(f"meta field {key!r} missing or non-numeric")
    if doc.get("source") not in ("rust", "python-model"):
        fail(f"unknown source {doc.get('source')!r}")
    if doc["retries"] < 1:
        fail("the retry scenario needs a budget of at least 1")
    if doc["checkpoint_every"] < 1:
        fail("the checkpoint scenarios need a positive cadence")

    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        fail("cases missing or empty")

    retry_cap, ck_cap, resume_cap = (10.0, 10.0, 60.0) if full else (50.0, 50.0, 150.0)
    by_geom = {}
    for i, c in enumerate(cases):
        s = c.get("scenario")
        if s not in SCENARIOS:
            fail(f"case {i}: bad scenario {s!r}")
        for key in CASE_NUM:
            if not isinstance(c.get(key), (int, float)):
                fail(f"case {i}: field {key!r} missing or non-numeric")
        if c.get("matches_baseline") is not True:
            fail(
                f"case {i} ({c['width']}x{c['height']} {s}): matches_baseline is not "
                "true — fault tolerance changed the answer"
            )
        geom = (c["height"], c["width"])
        if s in by_geom.setdefault(geom, {}):
            fail(f"case {i}: duplicate scenario {s!r} for {geom}")
        by_geom[geom][s] = c

        if s == "baseline":
            if c["overhead_pct"] != 0:
                fail(f"case {i}: baseline overhead must be 0")
            if c["faults_injected"] != 0:
                fail(f"case {i}: baseline must be fault-free")
        if s == "retry" and c["faults_injected"] < 1:
            fail(f"case {i}: the retry scenario never injected a fault")
        if s == "resume":
            if c["faults_injected"] < 1:
                fail(f"case {i}: the resume scenario never killed the run")
            if c["recovery_secs"] <= 0:
                fail(f"case {i}: resume must time a positive recovery leg")
        cap = {"retry": retry_cap, "checkpoint": ck_cap, "resume": resume_cap}.get(s)
        if cap is not None and c["overhead_pct"] > cap:
            fail(
                f"case {i} ({c['width']}x{c['height']} {s}): overhead "
                f"{c['overhead_pct']:.1f}% exceeds the {cap:.0f}% bound"
            )

    for geom, rows in by_geom.items():
        missing = SCENARIOS - set(rows)
        if missing:
            fail(f"geometry {geom}: missing scenarios {sorted(missing)}")

    if full and (1024, 1024) not in by_geom:
        fail("--full requires the paper-sized 1024x1024 geometry")

    print(f"{path}: schema OK ({len(cases)} cases, source={doc['source']})")


if __name__ == "__main__":
    main()
