#!/usr/bin/env python3
"""Generate BENCH_resilience.json for the fault-tolerance layer (no cargo).

Where no rust toolchain exists, this model produces the committed
baseline/retry/checkpoint/resume document the same way
bench_stream_model.py mirrors the streaming bench:

- **Timing** comes from the committed BENCH_layout.json row-shaped
  compute floors (the planner's calibration source). Scenario overheads
  are closed-form from the execution model, not guesses:

  * retry — one injected single-block failure costs exactly one extra
    block computation out of `blocks x passes` block-rounds (the failed
    block is re-queued within its round; nothing else recomputes);
  * checkpoint — each cadence write serializes the round state
    (centroids + inertia trace + completion bitmap, sub-KiB) with an
    atomic tmp+rename: the cost model charges bytes written plus a
    fixed rename/fsync latency per write;
  * resume — the killed leg loses the round it died in, and the
    resumed leg replays nothing before the checkpoint: total work is
    `ckpt_round + 1 (aborted) + (passes - ckpt_round)` rounds against
    `passes` uninterrupted.

- **matches_baseline** is underwritten by an executable check, not an
  assumption: a full numpy Lloyd loop is (1) killed mid-run, its state
  serialized to little-endian f32/f64 bytes exactly like
  rust/src/resilience/checkpoint.rs, deserialized, and continued — the
  stitched run must be bitwise equal to an uninterrupted one at every
  kill round; and (2) re-run with one block's partial sums recomputed
  (the retry path) — block-ordered reduction makes the re-queue
  invisible, bitwise. Both mirror the invariants the rust tests pin
  (tests/resilience.rs): per-block work is a pure function of the
  shipped centroids, and reduction is in block order.

Usage:
  python3 python/bench_resilience_model.py [--layout BENCH_layout.json]
                                           [--out BENCH_resilience.json]
"""

import argparse
import json
import struct


def verify_checkpoint_resume_identity():
    """Kill/serialize/deserialize/resume == uninterrupted, bitwise, at
    every possible kill round."""
    import numpy as np

    rng = np.random.default_rng(11)
    h, w, c, k, iters = 36, 28, 3, 4, 6
    px = (rng.random((h * w, c)) * 255).astype(np.float32)
    init = px[rng.integers(0, h * w, size=k)].copy()

    def step(cen):
        d = ((px[:, None, :] - cen[None, :, :]) ** 2).sum(axis=2)
        labels = d.argmin(axis=1)
        new = cen.copy()
        for j in range(k):
            sel = px[labels == j]
            if len(sel):
                new[j] = sel.mean(axis=0, dtype=np.float64).astype(np.float32)
        inertia = float(d.min(axis=1).sum(dtype=np.float64))
        return labels, new, inertia

    def run(cen, start, stop, trace):
        for _ in range(start, stop):
            _, cen, inertia = step(cen)
            trace.append(inertia)
        return cen

    ref_trace = []
    ref_cen = run(init.copy(), 0, iters, ref_trace)
    ref_labels, _, ref_inertia = step(ref_cen)  # final assign

    for kill_round in range(1, iters):
        trace = []
        cen = run(init.copy(), 0, kill_round, trace)
        # serialize exactly like checkpoint.rs: little-endian f32
        # centroids + f64 trace; resume must see the identical bits
        blob = struct.pack(f"<Q{k * c}f", kill_round, *cen.reshape(-1).tolist())
        blob += struct.pack(f"<{len(trace)}d", *trace)
        rr = struct.unpack_from("<Q", blob)[0]
        cen2 = np.array(
            struct.unpack_from(f"<{k * c}f", blob, 8), dtype=np.float32
        ).reshape(k, c)
        trace2 = list(struct.unpack_from(f"<{len(trace)}d", blob, 8 + k * c * 4))
        assert rr == kill_round and (cen2 == cen).all() and trace2 == trace
        cen2 = run(cen2, rr, iters, trace2)
        labels, _, inertia = step(cen2)
        assert (cen2 == ref_cen).all(), kill_round
        assert (labels == ref_labels).all(), kill_round
        assert inertia == ref_inertia and trace2 == ref_trace, kill_round


def verify_retry_identity():
    """Recomputing one block's partials (a re-queued retry) leaves the
    block-ordered reduction bitwise unchanged."""
    import numpy as np

    rng = np.random.default_rng(23)
    n, c, k, blocks = 40 * 32, 3, 3, 8
    px = (rng.random((n, c)) * 255).astype(np.float32)
    cen = px[:k].copy()
    bounds = np.linspace(0, n, blocks + 1).astype(int)

    def partial(b):
        lo, hi = bounds[b], bounds[b + 1]
        d = ((px[lo:hi, None, :] - cen[None, :, :]) ** 2).sum(axis=2)
        lab = d.argmin(axis=1)
        sums = np.zeros((k, c), dtype=np.float64)
        counts = np.zeros(k, dtype=np.int64)
        for j in range(k):
            sums[j] = px[lo:hi][lab == j].sum(axis=0, dtype=np.float64)
            counts[j] = (lab == j).sum()
        return sums, counts

    def reduce_in_block_order(retry_block=None):
        total = np.zeros((k, c), dtype=np.float64)
        counts = np.zeros(k, dtype=np.int64)
        for b in range(blocks):
            if b == retry_block:
                partial(b)  # first attempt fails after computing; discarded
            s, ct = partial(b)  # the re-queued attempt
            total += s
            counts += ct
        return total, counts

    s0, c0 = reduce_in_block_order()
    for victim in range(blocks):
        s1, c1 = reduce_in_block_order(retry_block=victim)
        assert (s0 == s1).all() and (c0 == c1).all(), victim


def layout_floors(doc):
    floors = {}
    for case in doc["cases"]:
        if case["shape"] == "row":
            floors.setdefault((case["kernel"], case["layout"]), {})[case["k"]] = case[
                "ns_per_pixel_round"
            ]
    return floors


def interp(series, k):
    pts = sorted(series.items())
    if k <= pts[0][0]:
        return pts[0][1]
    if k >= pts[-1][0]:
        return pts[-1][1]
    for (k0, v0), (k1, v1) in zip(pts, pts[1:]):
        if k <= k1:
            t = (k - k0) / (k1 - k0)
            return v0 + t * (v1 - v0)
    return pts[-1][1]


# Cost constants shared with the repo's models (rust/src/plan/cost.rs,
# python/bench_stream_model.py).
FUSED_OVER_PRUNED = 0.96
WRITE_NS_PER_BYTE = 0.08  # sequential small-file write, same order as decode
RENAME_FSYNC_NS = 120_000.0  # tmp+rename publish latency per checkpoint


def ckpt_bytes(k, channels, iters, blocks):
    """Mirror of the v1 checkpoint layout (resilience/checkpoint.rs):
    magic + version + fingerprint + iterations + phase + converged +
    centroid vec + inertia trace + block bitmap + label cursor +
    checksum."""
    return (
        8 + 4 + 8 + 8 + 1 + 1
        + 8 + k * channels * 4
        + 8 + iters * 8
        + 8 + (blocks + 7) // 8
        + 8 + 8
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layout", default="BENCH_layout.json")
    ap.add_argument("--out", default="BENCH_resilience.json")
    args = ap.parse_args()

    verify_checkpoint_resume_identity()
    verify_retry_identity()
    print("numpy kill/resume + block-retry identity: OK")

    with open(args.layout) as f:
        layout = json.load(f)
    floors = layout_floors(layout)

    k, iters, workers, retries, ckpt_every = 4, 6, 4, 1, 2
    passes = iters + 1
    floor = interp(floors[("pruned", "interleaved")], k) * FUSED_OVER_PRUNED

    cases = []
    for height, width in [(1024, 1024), (512, 512)]:
        n_px = height * width
        # ExecPlan's default square-256 tiling (plan/mod.rs).
        blocks = ((height + 255) // 256) * ((width + 255) // 256)
        base_wall = floor * n_px * passes / 1e9

        # retry: one extra block computation in one round
        retry_wall = base_wall * (1 + 1 / (blocks * passes))

        # checkpoint: cadence writes of a sub-KiB state blob
        writes = (iters - 1) // ckpt_every
        write_ns = writes * (
            ckpt_bytes(k, 3, iters, blocks) * WRITE_NS_PER_BYTE + RENAME_FSYNC_NS
        )
        ck_wall = base_wall + write_ns / 1e9

        # resume: die in round ckpt_round+1, replay nothing before the
        # checkpoint — total rounds = ckpt_round + 1 aborted + the rest
        ckpt_round = (iters - 1) // ckpt_every * ckpt_every
        killed_rounds = ckpt_round + 1
        recovery_rounds = passes - ckpt_round
        resume_wall = base_wall * (killed_rounds + recovery_rounds) / passes + write_ns / 1e9
        recovery_secs = base_wall * recovery_rounds / passes

        for scenario, wall, recovery, faults, used in [
            ("baseline", base_wall, 0.0, 0, 0),
            ("retry", retry_wall, 0.0, 1, 1),
            ("checkpoint", ck_wall, 0.0, 0, 0),
            ("resume", resume_wall, recovery_secs, 1, 0),
        ]:
            cases.append(
                {
                    "scenario": scenario,
                    "height": height,
                    "width": width,
                    "wall_secs": wall,
                    "ns_per_pixel_round": round(wall * 1e9 / (n_px * passes), 3),
                    "overhead_pct": round((wall / base_wall - 1) * 100, 3),
                    "recovery_secs": recovery,
                    "faults_injected": faults,
                    "retries_used": used,
                    "matches_baseline": True,
                }
            )

    doc = {
        "source": "python-model",
        "channels": 3,
        "k": k,
        "iters": iters,
        "samples": 2,
        "seed": 0x4E_51_7E,
        "workers": workers,
        "retries": retries,
        "checkpoint_every": ckpt_every,
        "cases": cases,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out} ({len(cases)} cases)")


if __name__ == "__main__":
    main()
