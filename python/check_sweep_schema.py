#!/usr/bin/env python3
"""Schema check for BENCH_sweep.json (CI smoke + committed file).

Usage: check_sweep_schema.py <path> [--full]

Validates the document the rust `blockms sweep` bench and the python
model both emit (EXPERIMENTS.md §Sweep), and gates the sweep
acceptance invariants:

- every variant is bit-identical to its solo run (`matches_solo`,
  per case and in aggregate) — amortization must never change values;
- the amortized sweep reads ~1/N of the serialized bytes: with row
  blocks aligned to strips and a full strip cache the closed form is
  exact (one decode per strip per sweep), so the measured ratio must
  sit at 1/variants, and `serialized >= amortized` always;
- the grid bookkeeping is consistent: variants = |ks| x seeds x
  |inits|, every case's (k, init) comes from the declared axes, and
  the model-selection picks (best_k, knee_k) are members of ks.

With --full, also requires the acceptance grid (k in 2..=8 over the
256x256 scene) the committed file is pinned to.
"""

import json
import sys

META_NUM = ["channels", "iters", "base_seed", "seeds", "workers", "strip_rows", "variants"]
META_POS = [
    "amortized_wall_secs",
    "serialized_wall_secs",
    "amortized_jobs_per_sec",
    "serialized_jobs_per_sec",
    "amortized_bytes_read",
    "serialized_bytes_read",
    "bytes_read_ratio",
    "predicted_bytes_ratio",
]
CASE_NUM = ["k", "seed", "iterations", "inertia", "db_index"]


def fail(msg):
    print(f"BENCH_sweep.json schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    full = "--full" in sys.argv
    path = args[0] if args else "BENCH_sweep.json"
    with open(path) as f:
        doc = json.load(f)

    if doc.get("source") not in ("rust", "python-model"):
        fail(f"unknown source {doc.get('source')!r}")
    image = doc.get("image")
    if not (isinstance(image, list) and len(image) == 2 and all(isinstance(v, (int, float)) for v in image)):
        fail(f"image must be [height, width], got {image!r}")
    for key in META_NUM:
        if not isinstance(doc.get(key), (int, float)):
            fail(f"meta field {key!r} missing or non-numeric")
    for key in META_POS:
        v = doc.get(key)
        if not isinstance(v, (int, float)) or v <= 0:
            fail(f"field {key!r} missing or non-positive ({v!r})")

    ks = doc.get("ks")
    if not isinstance(ks, list) or not ks or not all(isinstance(k, (int, float)) and k >= 1 for k in ks):
        fail(f"ks must be a non-empty list of k >= 1, got {ks!r}")
    inits = doc.get("inits")
    if not isinstance(inits, list) or not inits or not all(isinstance(i, str) for i in inits):
        fail(f"inits must be a non-empty list of names, got {inits!r}")

    # Grid bookkeeping: variants = |ks| x seeds x |inits|.
    variants = doc["variants"]
    if variants != len(ks) * doc["seeds"] * len(inits):
        fail(
            f"variants {variants} != |ks|({len(ks)}) x seeds({doc['seeds']}) x |inits|({len(inits)})"
        )

    # Bit-identity: amortization must never change values.
    if doc.get("matches_solo") is not True:
        fail("matches_solo is not true — the sweep changed results, not just I/O")

    # Amortization: the tentpole numbers. N variants over one image must
    # not read N x the bytes; the bench geometry makes 1/N exact.
    amortized = doc["amortized_bytes_read"]
    serialized = doc["serialized_bytes_read"]
    if serialized < amortized:
        fail(f"serialized bytes {serialized} < amortized {amortized} — backwards")
    ratio = doc["bytes_read_ratio"]
    if abs(ratio - amortized / serialized) > 1e-9:
        fail(f"bytes_read_ratio {ratio} inconsistent with {amortized}/{serialized}")
    if ratio > 1.0 / variants + 1e-9:
        fail(
            f"bytes_read_ratio {ratio:.4f} above the closed-form 1/{variants} — "
            "the shared store is not amortizing"
        )
    if doc["predicted_bytes_ratio"] > 1.0 / variants + 1e-9:
        fail(f"predicted_bytes_ratio {doc['predicted_bytes_ratio']:.4f} above 1/{variants}")

    cases = doc.get("cases")
    if not isinstance(cases, list) or len(cases) != variants:
        fail(f"cases missing or count != variants ({variants})")
    for i, c in enumerate(cases):
        for key in CASE_NUM:
            if not isinstance(c.get(key), (int, float)):
                fail(f"case {i}: field {key!r} missing or non-numeric")
        if not isinstance(c.get("label"), str) or not c["label"]:
            fail(f"case {i}: label missing")
        if c["k"] not in ks:
            fail(f"case {i}: k={c['k']} not in the declared ks axis")
        if c.get("init") not in inits:
            fail(f"case {i}: init {c.get('init')!r} not in the declared inits axis")
        if c.get("matches_solo") is not True:
            fail(f"case {i} ({c['label']}): matches_solo is not true")
        if c["db_index"] < 0:
            fail(f"case {i}: negative db_index {c['db_index']}")
        if c["inertia"] < 0:
            fail(f"case {i}: negative inertia {c['inertia']}")

    # Model selection picks must come from the grid (null = no winner).
    for key in ("best_k", "knee_k"):
        v = doc.get(key)
        if v is not None and v not in ks:
            fail(f"{key} {v!r} is not in the ks axis")

    if full:
        if sorted(ks) != list(range(2, 9)):
            fail(f"--full requires the acceptance grid k in 2..=8, got {ks}")
        if image != [256, 256]:
            fail(f"--full requires the 256x256 acceptance scene, got {image}")
        if doc["best_k"] is None:
            fail("--full: every acceptance variant degenerate — no DB winner")

    print(
        f"{path}: schema OK ({variants} variants, ratio {ratio:.4f} ~ 1/{variants}, "
        f"source={doc['source']})"
    )


if __name__ == "__main__":
    main()
