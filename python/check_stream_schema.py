#!/usr/bin/env python3
"""Schema check for BENCH_stream.json (CI smoke + committed file).

Usage: check_stream_schema.py <path> [--full]

Validates the document the rust `blockms stream` bench and the python
model both emit (EXPERIMENTS.md §Streaming), and gates the two
out-of-core acceptance invariants:

- every streamed case is bitwise identical to its in-memory twin
  (`matches_in_memory`), and
- every budgeted case's audited peak resident bytes sit at or under
  its `mem_mb` budget.

With --full, also requires the acceptance geometries (1024x1024 and
the tall 4096x1024 case) and the height-independence property: the
tall streamed case — 4x the pixels — must not have a larger resident
footprint than the square one.
"""

import json
import sys

MODES = {"in-memory", "streamed"}
META_NUM = ["k", "iters", "samples", "seed", "workers", "strip_rows", "mem_mb", "channels"]
CASE_NUM = [
    "height",
    "width",
    "k",
    "wall_secs",
    "ns_per_pixel_pass",
    "peak_resident_bytes",
    "mem_mb",
]


def fail(msg):
    print(f"BENCH_stream.json schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    full = "--full" in sys.argv
    path = args[0] if args else "BENCH_stream.json"
    with open(path) as f:
        doc = json.load(f)

    for key in META_NUM:
        if not isinstance(doc.get(key), (int, float)):
            fail(f"meta field {key!r} missing or non-numeric")
    if doc.get("source") not in ("rust", "python-model"):
        fail(f"unknown source {doc.get('source')!r}")
    if doc["mem_mb"] <= 0:
        fail("the streamed matrix must run under a positive mem_mb budget")

    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        fail("cases missing or empty")
    seen = set()
    for i, c in enumerate(cases):
        if c.get("mode") not in MODES:
            fail(f"case {i}: bad mode {c.get('mode')!r}")
        for key in CASE_NUM:
            if not isinstance(c.get(key), (int, float)):
                fail(f"case {i}: field {key!r} missing or non-numeric")
        if not isinstance(c.get("file_backed"), bool):
            fail(f"case {i}: field 'file_backed' missing or non-bool")
        if c.get("matches_in_memory") is not True:
            fail(f"case {i}: matches_in_memory is not true — a broken pipeline, not a result")
        if c["mem_mb"] > 0 and c["peak_resident_bytes"] > c["mem_mb"] * (1 << 20):
            fail(
                f"case {i} ({c['width']}x{c['height']} {c['mode']}): peak resident "
                f"{c['peak_resident_bytes']} bytes exceeds the {c['mem_mb']} MiB budget"
            )
        seen.add((c["mode"], c["height"], c["width"]))

    streamed = {(c["height"], c["width"]): c for c in cases if c["mode"] == "streamed"}
    for hw in streamed:
        if ("in-memory",) + hw not in seen:
            fail(f"streamed case {hw} has no in-memory twin")
        if streamed[hw]["mem_mb"] <= 0:
            fail(f"streamed case {hw} ran without a budget")

    if full:
        for hw in [(1024, 1024), (4096, 1024)]:
            if hw not in streamed:
                fail(f"--full requires the {hw[1]}x{hw[0]} streamed case")
        square = streamed[(1024, 1024)]["peak_resident_bytes"]
        tall = streamed[(4096, 1024)]["peak_resident_bytes"]
        if tall > square:
            fail(
                f"height-independence violated: tall streamed peak {tall} > "
                f"square streamed peak {square}"
            )
        image_bytes = 4096 * 1024 * 3 * 4
        if tall * 4 > image_bytes:
            fail(f"tall streamed peak {tall} is not out-of-core vs {image_bytes} image bytes")

    print(f"{path}: schema OK ({len(cases)} cases, source={doc['source']})")


if __name__ == "__main__":
    main()
