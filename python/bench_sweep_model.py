#!/usr/bin/env python3
"""Numpy model of `blockms sweep` — generates the committed BENCH_sweep.json.

No cargo toolchain runs where this file is maintained, so the committed
sweep artifact comes from this model, exactly as BENCH_stream.json comes
from bench_stream_model.py. CI regenerates the rust-sourced file with
`blockms sweep --quick` and gates BOTH through check_sweep_schema.py;
the checker validates invariants (bit-identity, byte amortization,
grid bookkeeping), never cross-compares the two files' timings.

What is exact vs modeled:

- **bytes** are exact closed forms. The amortized sweep shares one
  strip store across all N variants with a full cache, so each strip
  decodes once: amortized_bytes = h*w*3*4 and serialized_bytes = N x
  that, giving bytes_read_ratio = 1/N — the same arithmetic the rust
  bench measures (rust/src/bench/sweep.rs, rust/tests/stripstore_io.rs).
- **clustering** is a real Lloyd run (RandomSample init via the ported
  Xoshiro256++ from bench_stream_model, f32 centroids, f64 inertia) on
  a deterministic 5-class value-noise scene that mirrors
  rust/src/image/synthetic.rs *distributionally*, not bit-exactly: the
  f32 lattice/gaussian streams were judged too fragile to port bit-for-
  bit, and nothing consumes cross-file equality. Per-variant inertia /
  db_index are therefore model-scene values with the same structure.
- **matches_solo** is underwritten the honest way available here: every
  variant runs twice from scratch and the runs must agree bitwise —
  the model's analogue of the sweep-vs-solo matrix that
  rust/tests/sweep_equivalence.rs pins on the real implementation.
- **walls** come from the committed BENCH_layout.json row floors
  (naive/interleaved, the sweep bench's pinned kernel) plus the baked
  decode term, single-stream like bench_stream_model.py.
"""

import argparse
import json

import numpy as np

from bench_stream_model import DECODE_NS_PER_BYTE, Rng, interp, layout_floors

SCENE_SEED = 0xB10C_5EED  # SyntheticOrtho default
CLASSES = 5
OCTAVES = 4
NOISE_DN = 6.0


def smooth(t):
    return t * t * (3.0 - 2.0 * t)


def synth_scene(height, width, seed=SCENE_SEED):
    """Deterministic 5-class blended scene, SyntheticOrtho-shaped.

    Multi-octave value noise picks a fractional class per pixel; pixels
    blend the two nearest class signatures and add gaussian sensor
    noise, clamped to the 8-bit DN range — same structure as
    rust/src/image/synthetic.rs, different (numpy) random streams.
    """
    rng = np.random.default_rng(seed)
    base = 30.0 + 195.0 * (np.arange(CLASSES) + 0.5) / CLASSES
    sigs = np.clip(
        base[:, None] + (rng.random((CLASSES, 3)) - 0.5) * 60.0, 0.0, 255.0
    ).astype(np.float32)

    field = np.zeros((height, width), np.float64)
    total, amp, cell = 0.0, 1.0, max(height, width)
    for _ in range(OCTAVES):
        gh, gw = height // cell + 2, width // cell + 2
        lattice = rng.random((gh, gw))
        ys, xs = np.arange(height) / cell, np.arange(width) / cell
        y0, x0 = ys.astype(int), xs.astype(int)
        fy, fx = smooth(ys - y0)[:, None], smooth(xs - x0)[None, :]
        a = lattice[np.ix_(y0, x0)]
        b = lattice[np.ix_(y0, x0 + 1)]
        c = lattice[np.ix_(y0 + 1, x0)]
        d = lattice[np.ix_(y0 + 1, x0 + 1)]
        field += amp * ((a * (1 - fx) + b * fx) * (1 - fy) + (c * (1 - fx) + d * fx) * fy)
        total += amp
        amp *= 0.55
        cell = max(cell // 2, 2)

    t = np.clip(field / total, 0.0, 1.0 - 1e-6) * CLASSES
    lo = np.minimum(t.astype(int), CLASSES - 1)
    hi = np.minimum(lo + 1, CLASSES - 1)
    frac = (t - lo)[..., None].astype(np.float32)
    px = sigs[lo] * (1.0 - frac) + sigs[hi] * frac
    px += rng.normal(0.0, NOISE_DN, px.shape)
    return np.clip(px, 0.0, 255.0).astype(np.float32).reshape(-1, 3)


def lloyd(px, k, seed, iters):
    """Fixed-iteration Lloyd mirroring the coordinator's pass structure:
    `iters` Step rounds (assign + update), then one final Assign round
    that freezes labels and computes the f64 inertia."""
    n = len(px)
    centroids = px[Rng(seed).sample_indices(n, k)].copy()
    px64 = px.astype(np.float64)
    labels = None
    for _ in range(iters):
        d = ((px[:, None, :] - centroids[None, :, :]) ** 2).sum(axis=2)
        labels = d.argmin(axis=1)
        for c in range(k):
            mask = labels == c
            if mask.any():
                centroids[c] = px64[mask].mean(axis=0).astype(np.float32)
    d = ((px64[:, None, :] - centroids[None, :, :].astype(np.float64)) ** 2).sum(axis=2)
    labels = d.argmin(axis=1)
    inertia = float(d[np.arange(n), labels].sum())
    return labels.astype(np.uint32), centroids, inertia


def davies_bouldin(px, labels, centroids, k):
    """f64 port of rust/src/metrics/quality.rs::davies_bouldin."""
    px64 = px.astype(np.float64)
    c64 = centroids.astype(np.float64)
    active, scatter = [], {}
    for c in range(k):
        mask = labels == c
        if not mask.any():
            continue
        active.append(c)
        scatter[c] = float(np.sqrt(((px64[mask] - c64[c]) ** 2).sum(axis=1)).mean())
    if len(active) <= 1:
        return 0.0
    total = 0.0
    for i in active:
        worst = 0.0
        for j in active:
            if i == j:
                continue
            dist = float(np.sqrt(((c64[i] - c64[j]) ** 2).sum()))
            if dist > 0.0:
                worst = max(worst, (scatter[i] + scatter[j]) / dist)
        total += worst
    return total / len(active)


def knee_index(values):
    """Port of rust/src/sweep/report.rs::knee_index."""
    if len(values) < 3:
        return 0
    n = len(values)
    span = values[-1] - values[0]
    if span == 0.0:
        return 0
    best, best_d = 0, float("-inf")
    for i, v in enumerate(values):
        x = i / (n - 1)
        y = (v - values[0]) / span
        d = abs(x - y)
        if d > best_d:
            best, best_d = i, d
    return best


def rank_by_db(cases):
    """Port of SweepReport::ranked_by_db: degenerate (db == 0) last,
    then db ascending, then smaller k, then submission order."""
    return sorted(
        range(len(cases)),
        key=lambda i: (
            cases[i]["db_index"] == 0.0,
            cases[i]["db_index"],
            cases[i]["k"],
            i,
        ),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layout", default="BENCH_layout.json")
    ap.add_argument("--out", default="BENCH_sweep.json")
    args = ap.parse_args()

    with open(args.layout) as f:
        floors = layout_floors(json.load(f))

    # The acceptance config `blockms sweep` defaults to.
    height = width = 256
    ks = list(range(2, 9))
    base_seed = 0x51_EEE7
    seeds, inits, iters, workers, strip_rows = 1, ["random"], 6, 4, 32
    variants = len(ks) * seeds * len(inits)

    px = synth_scene(height, width)
    n_px = height * width
    passes = iters + 1
    image_bytes = n_px * 3 * 4
    decode_secs = image_bytes * DECODE_NS_PER_BYTE / 1e9

    cases = []
    compute_secs = 0.0
    for k in ks:
        for s in range(seeds):
            seed = base_seed + s
            labels, centroids, inertia = lloyd(px, k, seed, iters)
            # matches_solo, model style: a second independent run must
            # reproduce every bit, or the variant is not deterministic.
            labels2, centroids2, inertia2 = lloyd(px, k, seed, iters)
            matches = (
                np.array_equal(labels, labels2)
                and centroids.tobytes() == centroids2.tobytes()
                and inertia == inertia2
            )
            assert matches, f"k={k} seed={seed}: rerun diverged"
            db = davies_bouldin(px, labels, centroids, k)
            compute_secs += interp(floors[("naive", "interleaved")], k) * n_px * passes / 1e9
            cases.append(
                {
                    "label": f"k{k}-s{seed}-random",
                    "k": k,
                    "seed": seed,
                    "init": "random",
                    "iterations": iters,
                    "inertia": inertia,
                    "db_index": db,
                    "matches_solo": matches,
                }
            )

    amortized_bytes = image_bytes
    serialized_bytes = variants * image_bytes
    amortized_wall = compute_secs + decode_secs
    serialized_wall = compute_secs + variants * decode_secs

    ranked = rank_by_db(cases)
    best = cases[ranked[0]]
    best_k = None if best["db_index"] == 0.0 else best["k"]
    elbow_ks = sorted({c["k"] for c in cases})
    elbow = [
        float(np.mean([c["inertia"] for c in cases if c["k"] == k])) for k in elbow_ks
    ]
    knee_k = elbow_ks[knee_index(elbow)] if elbow_ks else None

    doc = {
        "source": "python-model",
        "image": [height, width],
        "channels": 3,
        "iters": iters,
        "base_seed": base_seed,
        "seeds": seeds,
        "workers": workers,
        "strip_rows": strip_rows,
        "ks": ks,
        "inits": inits,
        "variants": variants,
        "amortized_wall_secs": amortized_wall,
        "serialized_wall_secs": serialized_wall,
        "amortized_jobs_per_sec": variants / amortized_wall,
        "serialized_jobs_per_sec": variants / serialized_wall,
        "amortized_bytes_read": amortized_bytes,
        "serialized_bytes_read": serialized_bytes,
        "bytes_read_ratio": amortized_bytes / serialized_bytes,
        "predicted_bytes_ratio": 1.0 / variants,
        "matches_solo": all(c["matches_solo"] for c in cases),
        "best_k": best_k,
        "knee_k": knee_k,
        "cases": cases,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(
        f"wrote {args.out}: {variants} variants, best_k={best_k}, knee_k={knee_k}, "
        f"ratio={doc['bytes_read_ratio']:.4f}"
    )


if __name__ == "__main__":
    main()
