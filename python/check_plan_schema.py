#!/usr/bin/env python3
"""Schema check for BENCH_plan.json (CI smoke + committed file).

Usage: check_plan_schema.py <path> [--full]

Validates the document the rust `blockms plan --out` bench and the
python model (`python/bench_plan_model.py`) both emit (EXPERIMENTS.md
section Planner). With --full (the committed / acceptance file), every
case's planner regret must sit inside the cost model's stated error
bound — that is the acceptance bar, not a style check — and the matrix
must be complete: 1024x1024, the paper's three shapes x k in {2,4,8}.
Without --full (CI quick smoke: single-sample millisecond timings),
only the schema and internal consistency are enforced; a timing-ratio
gate on a noisy shared runner would be flaky by construction.
"""

import json
import sys

KERNELS = {"naive", "pruned", "fused", "lanes"}
LAYOUTS = {"interleaved", "soa"}
SHAPES = {"row", "column", "square"}

META_NUM = [
    "iters",
    "samples",
    "seed",
    "workers",
    "strip_rows",
    "channels",
    "error_bound",
    "decode_ns_per_byte",
    "max_regret",
]
CASE_NUM = [
    "k",
    "predicted_ns_px_pass",
    "measured_ns_px_pass",
    "best_ns_px_pass",
    "regret",
    "prediction_error",
    "refined_ns_px_pass",
]


def fail(msg):
    print(f"BENCH_plan.json schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    full = "--full" in sys.argv
    path = args[0] if args else "BENCH_plan.json"
    with open(path) as f:
        doc = json.load(f)

    for key in META_NUM:
        if not isinstance(doc.get(key), (int, float)):
            fail(f"meta field {key!r} missing or non-numeric")
    img = doc.get("image")
    if not (isinstance(img, list) and len(img) == 2):
        fail("image must be [height, width]")
    if doc.get("source") not in ("rust", "python-model"):
        fail(f"unknown source {doc.get('source')!r}")
    bound = doc["error_bound"]
    if not 0.0 < bound <= 1.0:
        fail(f"error_bound {bound} outside (0, 1]")

    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        fail("cases missing or empty")
    seen = set()
    worst = 0.0
    for i, c in enumerate(cases):
        if c.get("shape") not in SHAPES:
            fail(f"case {i}: bad shape {c.get('shape')!r}")
        for key in ("picked_kernel", "best_kernel"):
            if c.get(key) not in KERNELS:
                fail(f"case {i}: bad {key} {c.get(key)!r}")
        for key in ("picked_layout", "best_layout"):
            if c.get(key) not in LAYOUTS:
                fail(f"case {i}: bad {key} {c.get(key)!r}")
        for key in CASE_NUM:
            if not isinstance(c.get(key), (int, float)):
                fail(f"case {i}: field {key!r} missing or non-numeric")
        if c["regret"] < 0:
            fail(f"case {i}: negative regret {c['regret']} (best-of-grid is a minimum)")
        if not isinstance(c.get("within_bound"), bool):
            fail(f"case {i}: within_bound missing or non-boolean")
        if c["within_bound"] != (c["regret"] <= bound):
            fail(f"case {i}: within_bound inconsistent with regret vs bound")
        # The acceptance bar (enforced on the full/committed matrix):
        # auto-selection never costs more than the model's own stated
        # uncertainty. Quick CI runs time single samples at millisecond
        # scale, where a ratio gate would be noise-flaky.
        if full and c["regret"] > bound:
            fail(
                f"case {i} ({c['shape']} k={c['k']}): regret {c['regret']:.4f} "
                f"exceeds the model's stated error bound {bound:.4f}"
            )
        worst = max(worst, c["regret"])
        seen.add((c["shape"], c["k"]))
    if abs(worst - doc["max_regret"]) > 1e-9:
        fail(f"max_regret {doc['max_regret']} != worst case regret {worst}")

    if full:
        if img != [1024, 1024]:
            fail(f"--full requires a 1024x1024 image, got {img}")
        want = {(sh, k) for sh in SHAPES for k in (2, 4, 8)}
        missing = want - seen
        if missing:
            fail(f"--full matrix incomplete: missing {sorted(missing)}")

    gate = "<=" if full else "vs"
    print(
        f"{path}: schema OK ({len(cases)} cases, source={doc['source']}, "
        f"max regret {worst:.2%} {gate} bound {bound:.0%})"
    )


if __name__ == "__main__":
    main()
