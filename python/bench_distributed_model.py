#!/usr/bin/env python3
"""Numpy mirror of `blockms distributed` for containers without cargo.

Generates BENCH_distributed.json with the exact schema of the rust
bench (EXPERIMENTS.md §Distributed). Three kinds of numbers:

- `matches_solo` is *computed*, not assumed: the sharded twin computes
  every block's f64 partial sums/counts/inertia shard-by-shard (shard-
  major execution order) and the "leader" merges the outcomes in block
  order — the same deterministic reduction the rust leader runs — then
  every round's merged accumulators, the updated centroid bits, the
  final labels, and the inertia bits are compared against a solo twin
  that accumulates in block order as it computes. A divergence aborts.
- Walls are *measured-then-modeled*: the single-lane wall is measured
  on the same numpy lanes/SoA kernel mirror the layout model uses
  (best of `samples` after one warmup), then scaled by the cost
  model's lane-saturation law (ideal 1/W clamped to the block count,
  barrier imbalance ceil(B/W)·W/B) and, for sharded rows, the wire
  term (closed-form bytes x `wire_ns_per_byte`) is added unscaled —
  numpy has no process fan-out to measure, so the model states the
  planner's law rather than inventing a measurement, hence
  `"source": "python-model"`. Regenerate with `blockms distributed`
  where cargo exists.
- `wire_bytes` and `model_wire_bytes` are both the closed form
  (`rust/src/plan/cost.rs::sharded_wire_bytes`, re-derived below):
  with no real transport there is nothing to count, and the rust bench
  proves measured == closed form; the schema gate holds the equality
  either way.
"""

import json
import math
import sys

import numpy as np

import bench_layout_model as L

C = 3
KS = [2, 4, 8]
SHARD_COUNTS = [1, 2, 4]
CONNS_PER_SHARD = 2
ITERS = 4
SAMPLES = 2
SEED = 0xD15781
GRID = 4  # the bench's 4x4 square block grid

# Mirrors rust plan/cost.rs: the baked lanes/SoA compute floors
# (ns/px/pass at the calibration ks, REF_WORKERS=4) and the wire rate.
LANES_SOA_FLOOR = {2: 27.301, 4: 54.629, 8: 74.319}
REF_WORKERS = 4
WIRE_NS_PER_BYTE = 0.15

# Frame-layout constants, mirrored from rust/src/shard/wire.rs.
WIRE_FRAME_HEADER = 20
WIRE_REGISTER_FIXED = WIRE_FRAME_HEADER + 8 + 118
WIRE_BLOCK_FIXED = WIRE_FRAME_HEADER + 34
WIRE_RESULT_FIXED = WIRE_FRAME_HEADER + 64
WIRE_PING = WIRE_FRAME_HEADER + 8


def sharded_wire_bytes(h, w, c, k, rounds, blocks, conns):
    """(down, up) — rust plan/cost.rs::sharded_wire_bytes verbatim."""
    image_bytes = 4 * h * w * c
    centroids = 4 * k * c
    drift = 8 * k + 8
    block_frames = blocks * (rounds + 1)
    down = (
        conns * (WIRE_REGISTER_FIXED + image_bytes + WIRE_PING)
        + block_frames * (WIRE_BLOCK_FIXED + centroids)
        + blocks * rounds * drift
        + conns * WIRE_FRAME_HEADER
    )
    up = (
        conns * (WIRE_FRAME_HEADER + WIRE_PING)
        + blocks * rounds * (WIRE_RESULT_FIXED + 8 * k + 8 * k * c)
        + blocks * WIRE_RESULT_FIXED
        + 4 * h * w
    )
    return down, up


def lane_scale(lanes, blocks):
    """Wall multiplier vs one lane: ideal 1/W clamped to the block
    count, corrected by per-round barrier imbalance (cost.rs law)."""
    eff = max(1, min(lanes, blocks))
    imbalance = math.ceil(blocks / eff) * eff / blocks
    return imbalance / eff


def model_wall(k, n_px, blocks, lanes, wire_bytes):
    """CostModel::predict_sharded for this bench's direct-I/O lanes/SoA
    cell: prior floor x lane scaling (relative to REF_WORKERS), zero
    excess decode, plus the unscaled wire term."""
    passes = ITERS + 1
    scale = lane_scale(lanes, blocks) / lane_scale(REF_WORKERS, blocks)
    compute = n_px * passes * LANES_SOA_FLOOR[k] * scale / 1e9
    return compute + wire_bytes * WIRE_NS_PER_BYTE / 1e9


def block_tiles(img, plan):
    """SoA tile per block (what the lanes kernel consumes)."""
    tiles = []
    for r0, c0, rows, cols in plan:
        block = img[r0 : r0 + rows, c0 : c0 + cols].reshape(-1, C)
        tiles.append(np.ascontiguousarray(block.T))
    return tiles


def block_outcome(tiles, bi, cen, k, state, drift):
    """One block's job outcome: (labels, f64 sums, counts, inertia) —
    a pure function of the round's shipped centroids (+ carried
    per-block bounds), computed identically on any worker."""
    labels, d2 = L.step_block("lanes", tiles[bi], cen, k, state, drift)
    sums, counts = L.accum(tiles[bi].T.astype(np.float64), labels, k)
    return labels, sums, counts, float(d2.astype(np.float64).sum())


def advance(cen, sums, counts):
    """Centroid update + the drift vector run_cell ships next round."""
    new = L.update_centroids(cen, sums, counts)
    per = np.sqrt(
        ((new.astype(np.float64) - cen.astype(np.float64)) ** 2).sum(axis=1)
    ) * (1 + 1e-12)
    return new, (per, per.max() if len(per) else 0.0)


def sharded_twin_matches(img, plan, k, init_cen, shards):
    """Drive a solo twin (compute + merge in block order) and a sharded
    twin (blocks computed shard-major, outcomes merged in block order)
    in lockstep; True iff every round's accumulators, centroid bits,
    and the final labels + inertia bits agree exactly."""
    blocks = len(plan)
    owner = [bi % shards for bi in range(blocks)]
    tiles = block_tiles(img, plan)
    cen_a, cen_b = init_cen.copy(), init_cen.copy()
    st_a = [L.BlockState() for _ in plan]
    st_b = [L.BlockState() for _ in plan]
    drift_a = drift_b = None
    for rnd in range(ITERS + 1):
        # Solo: accumulate as it computes, block order.
        sums_a = np.zeros((k, C), dtype=np.float64)
        counts_a = np.zeros(k, dtype=np.int64)
        inertia_a = 0.0
        labels_a = []
        for bi in range(blocks):
            labels, s, c, inert = block_outcome(tiles, bi, cen_a, k, st_a[bi], drift_a)
            sums_a += s
            counts_a += c
            inertia_a += inert
            labels_a.append(labels)
        # Sharded: every shard computes its own blocks (shard-major
        # order — arrival order in the real system is arbitrary), then
        # the leader reduces the outcomes in block order.
        outcomes = {}
        for shard in range(shards):
            for bi in (b for b in range(blocks) if owner[b] == shard):
                outcomes[bi] = block_outcome(tiles, bi, cen_b, k, st_b[bi], drift_b)
        sums_b = np.zeros((k, C), dtype=np.float64)
        counts_b = np.zeros(k, dtype=np.int64)
        inertia_b = 0.0
        labels_b = []
        for bi in range(blocks):
            labels, s, c, inert = outcomes[bi]
            sums_b += s
            counts_b += c
            inertia_b += inert
            labels_b.append(labels)
        if not (
            np.array_equal(sums_a.view(np.uint64), sums_b.view(np.uint64))
            and np.array_equal(counts_a, counts_b)
            and np.float64(inertia_a).view(np.uint64) == np.float64(inertia_b).view(np.uint64)
        ):
            return False
        if rnd < ITERS:
            cen_a, drift_a = advance(cen_a, sums_a, counts_a)
            cen_b, drift_b = advance(cen_b, sums_b, counts_b)
            if not np.array_equal(cen_a.view(np.uint32), cen_b.view(np.uint32)):
                return False
        else:
            if not np.array_equal(np.concatenate(labels_a), np.concatenate(labels_b)):
                return False
    return True


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_distributed.json"
    h, w = L.H, L.W
    n_px = h * w
    passes = ITERS + 1
    side = math.ceil(h / GRID)
    plan = L.block_plan(side, side)
    blocks = len(plan)
    rng = np.random.default_rng(SEED)
    img = L.synthetic_scene(rng)
    flat = img.reshape(-1, C)
    cases = []
    for k in KS:
        init_cen = flat[rng.choice(len(flat), size=k, replace=False)].copy()
        # Measured single-lane wall on the same kernel mirror.
        t1 = math.inf
        for sample in range(SAMPLES + 1):
            _labels, wall = L.run_cell(img, plan, "soa", "lanes", k, init_cen)
            if sample > 0:
                t1 = min(t1, wall)
        solo_wall = t1 * lane_scale(CONNS_PER_SHARD, blocks)
        for shards in [0] + SHARD_COUNTS:
            if shards == 0:
                wall, wire, matches = solo_wall, 0, True
                model = model_wall(k, n_px, blocks, CONNS_PER_SHARD, 0)
            else:
                lanes = shards * CONNS_PER_SHARD
                down, up = sharded_wire_bytes(h, w, C, k, ITERS, blocks, lanes)
                wire = down + up
                wall = t1 * lane_scale(lanes, blocks) + wire * WIRE_NS_PER_BYTE / 1e9
                model = model_wall(k, n_px, blocks, lanes, wire)
                matches = sharded_twin_matches(img, plan, k, init_cen, shards)
                if not matches:
                    raise SystemExit(f"sharded merge diverged from solo: {shards} shards k={k}")
            cases.append(
                {
                    "shards": shards,
                    "k": k,
                    "wall_secs": round(wall, 6),
                    "ns_per_pixel_round": round(wall * 1e9 / (n_px * passes), 4),
                    "speedup_vs_solo": round(solo_wall / wall, 4),
                    "matches_solo": matches,
                    "wire_bytes": wire,
                    "model_wire_bytes": wire,
                    "model_wall_secs": round(model, 6),
                }
            )
            name = "solo" if shards == 0 else f"{shards} shards"
            print(
                f"k={k} {name:>8}  {cases[-1]['wall_secs']:>9.4f} s"
                f"  x{cases[-1]['speedup_vs_solo']:.2f} vs solo"
                f"  {wire:>12} wire bytes",
                flush=True,
            )
    doc = {
        "image": [h, w],
        "channels": C,
        "iters": ITERS,
        "samples": SAMPLES,
        "seed": SEED,
        "conns_per_shard": CONNS_PER_SHARD,
        "blocks": blocks,
        "wire_ns_per_byte": WIRE_NS_PER_BYTE,
        "source": "python-model",
        "cases": cases,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path} ({len(cases)} cases)")


if __name__ == "__main__":
    main()
