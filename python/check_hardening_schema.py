#!/usr/bin/env python3
"""Schema check for BENCH_hardening.json (CI smoke + committed file).

Usage: check_hardening_schema.py <path> [--full]

Validates the document the rust `blockms hardening` bench and the
python model both emit (EXPERIMENTS.md §Hardening), and gates the
liveness-hardening acceptance invariants:

- every row is bitwise identical to its unhardened fault-free baseline
  (`matches_baseline`) — the watchdog, speculation, deadlines, and QoS
  change when work happens and who does it, never values;
- every geometry carries the baseline and hardened scenarios; the hang
  and overload drills appear at least once (they run on the first
  geometry only — stall latency is real wall-clock);
- the hardened (nothing-fails) overhead is bounded: ≤3% on the
  committed full-size document, ≤25% on the CI smoke run (smoke
  geometries are milliseconds-tall and noisy);
- every hang row parked at least one victim, timed a positive recovery,
  and recovered within the model's bound — the heartbeat timeout or
  the hang release plus slack, never an unbounded stall;
- the overload row served exactly the admission cap's worth of
  high-priority jobs and shed exactly the cap's worth of squatters.
"""

import json
import sys

REQUIRED_SCENARIOS = {"baseline", "hardened"}
META_NUM = [
    "k",
    "iters",
    "samples",
    "seed",
    "workers",
    "retries",
    "hang_ms",
    "heartbeat_timeout_ms",
    "overload_cap",
    "channels",
]
CASE_NUM = [
    "height",
    "width",
    "wall_secs",
    "ns_per_pixel_round",
    "overhead_pct",
    "recovery_secs",
    "hang_victims",
    "served",
    "shed",
]


def fail(msg):
    print(f"BENCH_hardening.json schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    full = "--full" in sys.argv
    path = args[0] if args else "BENCH_hardening.json"
    with open(path) as f:
        doc = json.load(f)

    for key in META_NUM:
        if not isinstance(doc.get(key), (int, float)):
            fail(f"meta field {key!r} missing or non-numeric")
    if doc.get("source") not in ("rust", "python-model"):
        fail(f"unknown source {doc.get('source')!r}")
    if doc["retries"] < 1:
        fail("the hang drills need a retry budget of at least 1")
    if doc["overload_cap"] < 1:
        fail("the overload drill needs a positive admission cap")

    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        fail("cases missing or empty")

    hardened_cap = 3.0 if full else 25.0
    # Recovery is bounded by whichever wakes the block first — the
    # watchdog escalating (heartbeat timeout) or the hang releasing —
    # plus generous recompute/scheduling slack.
    recovery_cap = (
        max(doc["hang_ms"], doc["heartbeat_timeout_ms"]) / 1e3 * 2.0 + 1.0
    )
    cap = int(doc["overload_cap"])

    seen_scenarios = set()
    by_geom = {}
    for i, c in enumerate(cases):
        s = c.get("scenario")
        if not isinstance(s, str) or not (
            s in ("baseline", "hardened", "overload") or s.startswith("hang_")
        ):
            fail(f"case {i}: bad scenario {s!r}")
        for key in CASE_NUM:
            if not isinstance(c.get(key), (int, float)):
                fail(f"case {i}: field {key!r} missing or non-numeric")
        if c.get("matches_baseline") is not True:
            fail(
                f"case {i} ({c['width']}x{c['height']} {s}): matches_baseline is not "
                "true — hardening changed the answer"
            )
        seen_scenarios.add("hang" if s.startswith("hang_") else s)
        geom = (c["height"], c["width"])
        if s in by_geom.setdefault(geom, {}):
            fail(f"case {i}: duplicate scenario {s!r} for {geom}")
        by_geom[geom][s] = c

        if s == "baseline":
            if c["overhead_pct"] != 0:
                fail(f"case {i}: baseline overhead must be 0")
            if c["hang_victims"] != 0:
                fail(f"case {i}: baseline must be hang-free")
        if s == "hardened" and c["overhead_pct"] > hardened_cap:
            fail(
                f"case {i} ({c['width']}x{c['height']}): hardened overhead "
                f"{c['overhead_pct']:.2f}% exceeds the {hardened_cap:.0f}% gate"
            )
        if s.startswith("hang_"):
            if c["hang_victims"] < 1:
                fail(f"case {i}: a hang drill must park at least one victim")
            if c["recovery_secs"] <= 0:
                fail(f"case {i}: a hang drill must time a positive recovery")
            if c["recovery_secs"] > recovery_cap:
                fail(
                    f"case {i} ({s}): recovery {c['recovery_secs']:.2f}s exceeds "
                    f"the {recovery_cap:.2f}s liveness bound"
                )
        if s == "overload":
            if c["served"] != cap:
                fail(
                    f"case {i}: overload served {c['served']} jobs, "
                    f"expected exactly the cap ({cap})"
                )
            if c["shed"] != cap:
                fail(
                    f"case {i}: overload shed {c['shed']} times, "
                    f"expected exactly the cap ({cap})"
                )

    for geom, rows in by_geom.items():
        missing = REQUIRED_SCENARIOS - set(rows)
        if missing:
            fail(f"geometry {geom}: missing scenarios {sorted(missing)}")
    if "hang" not in seen_scenarios:
        fail("no hang drill rows present")
    if "overload" not in seen_scenarios:
        fail("no overload drill row present")

    if full and (1024, 1024) not in by_geom:
        fail("--full requires the paper-sized 1024x1024 geometry")

    print(f"{path}: schema OK ({len(cases)} cases, source={doc['source']})")


if __name__ == "__main__":
    main()
