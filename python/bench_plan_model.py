#!/usr/bin/env python3
"""Generate BENCH_plan.json from the committed BENCH_layout.json.

An exact mirror of the rust cost model (`rust/src/plan/cost.rs`), used
where no cargo toolchain exists. The measured grid is the layout
matrix's own cells (kernel x layout per shape x k at the same 1024^2 /
strips-of-64 / 4-worker configuration, cache 0, prefetch off), so the
planner's regret is computed against real measurements:

- compute floors  = row-shaped cells (amplification 1.0);
- decode ns/byte  = least-squares fit over naive column/square cells;
- error_bound     = max(0.10, worst self-prediction over the matrix);
- picked          = argmin predicted over the measured grid, ties to
                    the earlier candidate in the rust enumeration
                    order (kernels naive,pruned,lanes x layouts
                    interleaved,soa) — fused is excluded because the
                    layout matrix carries no measured fused cell;
- regret          = measured(pick)/measured(best) - 1.

Usage:
  python3 python/bench_plan_model.py [--layout BENCH_layout.json]
                                     [--out BENCH_plan.json]
  python3 python/bench_plan_model.py --print-priors   # rust constants
"""

import argparse
import json

KERNELS = ["naive", "pruned", "lanes"]  # measured grid (no fused cell)
LAYOUTS = ["interleaved", "soa"]
SHAPES = ["row", "column", "square"]


def load_cells(doc):
    return {
        (c["kernel"], c["layout"], c["shape"], c["k"]): c for c in doc["cases"]
    }


def calibrate(doc, cells):
    """Mirror of CostModel::calibrate_from_json."""
    h, w = doc["image"]
    n_px = float(h * w)
    passes = doc["iters"] + 1.0

    floors = {}  # (kernel, layout) -> sorted [(k, ns)]
    row_bytes = {}  # layout -> bytes of one row pass
    for (kern, lay, shape, k), c in cells.items():
        if shape == "row":
            floors.setdefault((kern, lay), []).append((k, c["ns_per_pixel_round"]))
            row_bytes[lay] = c["bytes_read"]
    for series in floors.values():
        series.sort()

    num = den = 0.0
    for (kern, lay, shape, k), c in cells.items():
        if kern != "naive" or shape == "row":
            continue
        row = cells[("naive", lay, "row", k)]
        excess_ns = (c["ns_per_pixel_round"] - row["ns_per_pixel_round"]) * n_px * passes
        excess_bytes = c["bytes_read"] - row["bytes_read"]
        num += excess_ns * excess_bytes
        den += excess_bytes * excess_bytes
    decode = max(0.0, num / den) if den > 0 else 0.0

    def floor_of(kern, lay, k):
        series = floors[(kern, lay)]
        ks = [p[0] for p in series]
        if k <= ks[0]:
            return series[0][1]
        if k >= ks[-1]:
            return series[-1][1]
        for (k0, v0), (k1, v1) in zip(series, series[1:]):
            if k <= k1:
                t = (k - k0) / (k1 - k0)
                return v0 + t * (v1 - v0)
        return series[-1][1]

    def predict(kern, lay, shape, k):
        # bytes depend on (layout, shape) only; excess vs the row pass
        b = cells[("naive", lay, shape, k)]["bytes_read"]
        br = cells[("naive", lay, "row", k)]["bytes_read"]
        return floor_of(kern, lay, k) + max(0, b - br) * decode / (n_px * passes)

    worst = 0.10
    for (kern, lay, shape, k), c in cells.items():
        m = c["ns_per_pixel_round"]
        if m > 0:
            worst = max(worst, abs(predict(kern, lay, shape, k) - m) / m)

    return floor_of, predict, decode, worst


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layout", default="BENCH_layout.json")
    ap.add_argument("--out", default="BENCH_plan.json")
    ap.add_argument("--print-priors", action="store_true")
    args = ap.parse_args()

    with open(args.layout) as f:
        doc = json.load(f)
    cells = load_cells(doc)
    floor_of, predict, decode, bound = calibrate(doc, cells)

    if args.print_priors:
        print("// CostModel::baked() constants (from", args.layout, ")")
        for kern in KERNELS:
            for lay in LAYOUTS:
                ns = [round(floor_of(kern, lay, k), 3) for k in (2, 4, 8)]
                print(f"({kern}, {lay}): {ns}")
        print(f"decode_ns_per_byte: {decode:.5f}")
        print(f"worst self-prediction error: {bound:.4f}")
        return

    cases = []
    for shape in SHAPES:
        for k in (2, 4, 8):
            grid = [(kern, lay) for kern in KERNELS for lay in LAYOUTS]
            # deterministic argmin: strictly-less keeps the earlier candidate
            picked, picked_pred = None, float("inf")
            for kern, lay in grid:
                p = predict(kern, lay, shape, k)
                if p < picked_pred:
                    picked, picked_pred = (kern, lay), p
            measured = {
                g: cells[(g[0], g[1], shape, k)]["ns_per_pixel_round"] for g in grid
            }
            best = min(grid, key=lambda g: (measured[g], grid.index(g)))
            m_pick, m_best = measured[picked], measured[best]
            regret = m_pick / m_best - 1.0
            # one EWMA feedback step, as CostModel::refine does
            refined = 0.5 * floor_of(picked[0], picked[1], k) + 0.5 * m_pick
            cases.append(
                {
                    "shape": shape,
                    "k": k,
                    "picked_kernel": picked[0],
                    "picked_layout": picked[1],
                    "predicted_ns_px_pass": round(picked_pred, 4),
                    "measured_ns_px_pass": round(m_pick, 4),
                    "best_kernel": best[0],
                    "best_layout": best[1],
                    "best_ns_px_pass": round(m_best, 4),
                    "regret": round(regret, 6),
                    "prediction_error": round(abs(picked_pred - m_pick) / m_pick, 6),
                    "refined_ns_px_pass": round(refined, 4),
                    "within_bound": regret <= bound,
                }
            )

    max_regret = max(c["regret"] for c in cases)
    out = {
        "image": doc["image"],
        "channels": doc["channels"],
        "iters": doc["iters"],
        "samples": doc["samples"],
        "seed": doc["seed"],
        "workers": doc["workers"],
        "strip_rows": doc["strip_rows"],
        "error_bound": round(bound, 6),
        "decode_ns_per_byte": round(decode, 6),
        "max_regret": max_regret,
        "source": "python-model",
        "cases": cases,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    print(
        f"wrote {args.out}: {len(cases)} cases, max regret {max_regret:.2%} "
        f"(bound {bound:.0%})"
    )


if __name__ == "__main__":
    main()
