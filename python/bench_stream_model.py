#!/usr/bin/env python3
"""Generate BENCH_stream.json for the out-of-core pipeline (no cargo).

Where no rust toolchain exists, this model produces the committed
streamed-vs-in-memory document the same way bench_plan_model.py mirrors
the planner bench:

- **Timing** comes from the committed BENCH_layout.json row-shaped
  compute floors (the planner's own calibration source) for the kernel
  the budget-constrained planner picks (fused/interleaved: SoA arenas
  and lane scratch tiles are infeasible under the 8 MiB budget), plus
  the exact ingest-decode term (`decode_ns_per_byte` x one image pass).

- **Resident accounting** is closed-form and exact: it mirrors the
  runtime's ResidentGauge bookkeeping (rust/src/stripstore/store.rs,
  reader.rs) — file ingest holds 2 strips (decoded f32 + encode bytes);
  each worker's reader holds one decoded strip, the 64 KiB raw-decode
  chunk, and its block crop buffer; the streaming row shape makes the
  block one strip tall. Nothing scales with image height, which is the
  whole point.

- **matches_in_memory** is underwritten by an executable check, not an
  assumption: the streamed pipeline differs from the in-memory one
  ONLY in (a) how pixels reach the strip store (an identity copy,
  pinned byte-for-byte by rust unit tests) and (b) how the init draw
  is made. (b) is the subtle part, so this script ports the repo's
  SplitMix64/Xoshiro256++ PRNG and verifies that the streaming sampler
  (sparse Fisher-Yates + strip-order capture) reproduces the dense
  `sample_indices` draw exactly, then runs a full numpy Lloyd loop on
  both paths of a small scene and requires bitwise-equal labels,
  centroids, and inertia.

Usage:
  python3 python/bench_stream_model.py [--layout BENCH_layout.json]
                                       [--out BENCH_stream.json]
"""

import argparse
import json

MASK = (1 << 64) - 1


class SplitMix64:
    def __init__(self, seed):
        self.state = seed & MASK

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return z ^ (z >> 31)


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """Port of rust/src/util/prng.rs (Xoshiro256++)."""

    def __init__(self, seed):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self):
        s = self.s
        result = (rotl((s[0] + s[3]) & MASK, 23) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def next_below(self, bound):
        x = self.next_u64()
        m = x * bound
        low = m & MASK
        if low < bound:
            t = (-bound) % bound
            while low < t:
                x = self.next_u64()
                m = x * bound
                low = m & MASK
        return m >> 64

    def range_usize(self, lo, hi):
        return lo + self.next_below(hi - lo)

    def sample_indices(self, n, k):
        idx = list(range(n))
        for i in range(k):
            j = self.range_usize(i, n)
            idx[i], idx[j] = idx[j], idx[i]
        return idx[:k]

    def sample_indices_sparse(self, n, k):
        displaced = {}
        out = []
        for i in range(k):
            j = self.range_usize(i, n)
            vi = displaced.get(i, i)
            vj = displaced.get(j, j)
            displaced[i] = vj
            displaced[j] = vi
            out.append(vj)
        return out


def verify_init_equivalence():
    """Dense draw == sparse draw == strip-order capture, many configs."""
    for seed in range(40):
        for n, k in [(1, 1), (10, 3), (5000, 8), (4096 * 64, 4)]:
            dense = Rng(seed).sample_indices(n, k)
            sparse = Rng(seed).sample_indices_sparse(n, k)
            assert dense == sparse, (seed, n, k)
            # strip-order capture: feeding pixels 0..n in strips fills
            # slot i with pixel dense[i], regardless of strip size
            targets = {px: slot for slot, px in enumerate(sparse)}
            captured = [None] * k
            pos = 0
            strip = 97  # deliberately unaligned
            while pos < n:
                for off in range(min(strip, n - pos)):
                    slot = targets.get(pos + off)
                    if slot is not None:
                        captured[slot] = pos + off
                pos += min(strip, n - pos)
            assert captured == dense, (seed, n, k)


def verify_pipeline_identity():
    """Full numpy Lloyd loop: streamed init vs in-memory init, bitwise."""
    import numpy as np

    rng = np.random.default_rng(7)
    h, w, c, k, iters = 40, 30, 3, 4, 5
    px = (rng.random((h * w, c)) * 255).astype(np.float32)

    def lloyd(centroids):
        cen = centroids.copy()
        for _ in range(iters + 1):
            d = ((px[:, None, :] - cen[None, :, :]) ** 2).sum(axis=2)
            labels = d.argmin(axis=1)
            for j in range(k):
                sel = px[labels == j]
                if len(sel):
                    cen[j] = sel.mean(axis=0, dtype=np.float64).astype(np.float32)
        inertia = float(d.min(axis=1).sum(dtype=np.float64))
        return labels, cen, inertia

    seed = 123
    dense_idx = Rng(seed).sample_indices(h * w, k)
    sparse_idx = Rng(seed).sample_indices_sparse(h * w, k)
    la, ca, ia = lloyd(px[dense_idx])
    lb, cb, ib = lloyd(px[sparse_idx])
    assert (la == lb).all() and (ca == cb).all() and ia == ib


def layout_floors(doc):
    """Row-shaped ns/px/pass floors: (kernel, layout) -> {k: ns}."""
    floors = {}
    for case in doc["cases"]:
        if case["shape"] == "row":
            floors.setdefault((case["kernel"], case["layout"]), {})[case["k"]] = case[
                "ns_per_pixel_round"
            ]
    return floors


def interp(series, k):
    pts = sorted(series.items())
    if k <= pts[0][0]:
        return pts[0][1]
    if k >= pts[-1][0]:
        return pts[-1][1]
    for (k0, v0), (k1, v1) in zip(pts, pts[1:]):
        if k <= k1:
            t = (k - k0) / (k1 - k0)
            return v0 + t * (v1 - v0)
    return pts[-1][1]


DECODE_NS_PER_BYTE = 0.07848  # baked fit, rust/src/plan/cost.rs
FUSED_OVER_PRUNED = 0.96
DECODE_CHUNK = 1 << 16  # StripReader::DECODE_CHUNK_BYTES


def streamed_peak(width, strip_rows, workers):
    """Gauge mirror for the budget-degraded plan (file backing, rows of
    one strip, interleaved layout, no cache, no prefetch)."""
    strip_bytes = strip_rows * width * 3 * 4
    ingest = 2 * strip_bytes
    block_bytes = strip_bytes  # rows[strip_rows] block = one strip
    chunk = min(strip_bytes, DECODE_CHUNK)
    runtime = workers * (strip_bytes + chunk + block_bytes)
    return max(ingest, runtime)


def in_memory_peak(height, width, strip_rows, workers):
    image = height * width * 3 * 4
    strip_bytes = strip_rows * width * 3 * 4
    # memory-backed readers serve strips zero-copy; only block crops
    return image + max(strip_bytes, workers * strip_bytes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layout", default="BENCH_layout.json")
    ap.add_argument("--out", default="BENCH_stream.json")
    args = ap.parse_args()

    verify_init_equivalence()
    verify_pipeline_identity()
    print("init equivalence + numpy pipeline identity: OK")

    with open(args.layout) as f:
        layout = json.load(f)
    floors = layout_floors(layout)

    k, iters, workers, strip_rows, mem_mb = 4, 6, 4, 64, 8
    # The budget-constrained planner's pick (see plan/mod.rs tests):
    # fused kernel, interleaved layout (fused floor = pruned x 0.96).
    floor = interp(floors[("pruned", "interleaved")], k) * FUSED_OVER_PRUNED

    cases = []
    for height, width in [(1024, 1024), (4096, 1024)]:
        n_px = height * width
        passes = iters + 1
        image_bytes = n_px * 3 * 4
        ingest_ns = image_bytes * DECODE_NS_PER_BYTE / (n_px * passes)
        mem_ns = floor
        stream_ns = floor + ingest_ns
        for mode, ns, peak, budget, file_backed in [
            ("in-memory", mem_ns, in_memory_peak(height, width, strip_rows, workers), 0, False),
            ("streamed", stream_ns, streamed_peak(width, strip_rows, workers), mem_mb, True),
        ]:
            if budget:
                assert peak <= budget << 20, (mode, height, width, peak)
            cases.append(
                {
                    "mode": mode,
                    "height": height,
                    "width": width,
                    "k": k,
                    "wall_secs": ns * n_px * passes / 1e9,
                    "ns_per_pixel_pass": round(ns, 3),
                    "peak_resident_bytes": peak,
                    "mem_mb": budget,
                    "file_backed": file_backed,
                    "matches_in_memory": True,
                }
            )

    doc = {
        "source": "python-model",
        "channels": 3,
        "k": k,
        "iters": iters,
        "samples": 2,
        "seed": 0x57_8EA4,
        "workers": workers,
        "strip_rows": strip_rows,
        "mem_mb": mem_mb,
        "cases": cases,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out} ({len(cases)} cases)")


if __name__ == "__main__":
    main()
