#!/usr/bin/env python3
"""Numpy mirror of `blockms layout` for containers without cargo.

Generates BENCH_layout.json with the exact schema of the rust bench
(EXPERIMENTS.md §Layout). Two kinds of numbers:

- I/O counters (`bytes_read`, `strip_reads`, cache hits/misses) are the
  *closed-form* values of the access model — identical to what the rust
  run counts: interleaved layouts read every block's strip span once
  per pass, the SoA tile arena reads it once per job.
- Timings are *measured* on a numpy mirror of the three kernels run
  with the same protocol (fixed Lloyd iterations + final labeling,
  per-block over the real block plans, best of `samples` after one
  warmup). They model relative layout/kernel behaviour, not rust
  absolute speed — hence `"source": "python-model"`. Regenerate with
  `blockms layout --scale 1` where cargo exists.

Labels are checked bit-identical across kernels (same argmin ties,
same update stream); a divergence aborts rather than emitting
`matches_naive: false`.
"""

import json
import math
import sys
import time

import numpy as np

H = W = 1024
C = 3
KS = [2, 4, 8]
ITERS = 4
SAMPLES = 2
SEED = 0x50A71E
WORKERS = 4
STRIP_ROWS = 64
CACHE_STRIPS = 0
REL_SLACK = 1e-5  # guard band, mirrors kernel.rs

LAYOUT_CELLS = [
    ("interleaved", "naive"),
    ("interleaved", "pruned"),
    ("interleaved", "lanes"),
    ("soa", "naive"),
    ("soa", "pruned"),
    ("soa", "lanes"),
]


def paper_shapes():
    """BlockShape::paper_default for the three approaches (TARGET=5)."""
    rows = math.ceil(H / 5.0)
    cols = math.ceil(W / 5.0)
    side = math.ceil(math.sqrt(H * W / 5.0))
    return [
        ("row", rows, W),
        ("column", H, cols),
        ("square", side, side),
    ]


def block_plan(br, bc):
    regions = []
    for r0 in range(0, H, br):
        for c0 in range(0, W, bc):
            regions.append((r0, c0, min(br, H - r0), min(bc, W - c0)))
    return regions


def strip_span(r0, rows):
    return r0 // STRIP_ROWS, (r0 + rows - 1) // STRIP_ROWS


def strip_bytes(s):
    first = s * STRIP_ROWS
    rows = min(STRIP_ROWS, H - first)
    return rows * W * C * 4


def io_closed_form(plan, layout, passes):
    """(bytes_read, strip_reads) for a full drive — the numbers the rust
    AccessStats must report (static schedule, no cache, no prefetch)."""
    per_pass_reads = 0
    per_pass_bytes = 0
    for r0, _c0, rows, _cols in plan:
        lo, hi = strip_span(r0, rows)
        per_pass_reads += hi - lo + 1
        per_pass_bytes += sum(strip_bytes(s) for s in range(lo, hi + 1))
    fills = 1 if layout == "soa" else passes
    return per_pass_bytes * fills, per_pass_reads * fills


def synthetic_scene(rng):
    """A stand-in scene with cluster structure (the rust SyntheticOrtho
    generator is not ported; timings only need realistic data)."""
    base = rng.integers(0, 4, size=(H, W))
    centers = rng.uniform(20.0, 235.0, size=(4, C)).astype(np.float32)
    img = centers[base] + rng.normal(0.0, 6.0, size=(H, W, C))
    return np.clip(img, 0.0, 255.0).astype(np.float32)


def accum(px64, labels, k):
    sums = np.zeros((k, C), dtype=np.float64)
    for c in range(C):
        sums[:, c] = np.bincount(labels, weights=px64[:, c], minlength=k)
    counts = np.bincount(labels, minlength=k)
    return sums, counts


def update_centroids(cen, sums, counts):
    new = cen.copy()
    nz = counts > 0
    new[nz] = (sums[nz] / counts[nz, None]).astype(np.float32)
    return new


def dist2_all(px, cen):
    # (P, k) squared distances; argmin ties break to the lowest index,
    # like math::nearest.
    return ((px[:, None, :] - cen[None, :, :]) ** 2).sum(axis=2)


def dist2_planes(planes, cen):
    # SoA shape: accumulate per channel across all pixels of a plane.
    d = np.zeros((cen.shape[0], planes.shape[1]), dtype=np.float32)
    for c in range(C):
        t = planes[c][None, :] - cen[:, c][:, None]
        d += t * t
    return d.T


class BlockState:
    """Per-block Hamerly bounds (pruned/lanes kernels)."""

    def __init__(self):
        self.labels = None
        self.upper = None
        self.lower = None


def step_block(kernel, data, cen, k, state, drift):
    """One accumulation pass over a block; returns (labels, d2min)."""
    if kernel == "naive":
        d = dist2_all(data, cen)
        labels = d.argmin(axis=1)
        return labels, d[np.arange(len(labels)), labels]
    # pruned / lanes: full scan when no usable bounds
    soa = kernel == "lanes"
    if state.labels is None or drift is None:
        d = dist2_planes(data, cen) if soa else dist2_all(data, cen)
        labels = d.argmin(axis=1)
        part = np.partition(d, 1, axis=1)
        state.labels = labels
        state.upper = np.sqrt(part[:, 0].astype(np.float64))
        state.lower = np.sqrt(part[:, 1].astype(np.float64)) if k > 1 else np.full(len(labels), np.inf)
        return labels, d[np.arange(len(labels)), labels]
    per, dmax = drift
    u = state.upper + per[state.labels]
    low = state.lower - dmax
    if soa:
        own = np.zeros(data.shape[1], dtype=np.float32)
        for c in range(C):
            t = data[c] - cen[state.labels, c]
            own += t * t
    else:
        t = data - cen[state.labels]
        own = (t * t).sum(axis=1)
    u = np.minimum(u, np.sqrt(own.astype(np.float64)))
    skip = u * (1.0 + REL_SLACK) + 1e-12 < low
    labels = state.labels.copy()
    d2 = own.copy()
    if not skip.all():
        idx = ~skip
        sub = data[:, idx] if soa else data[idx]
        d = dist2_planes(sub, cen) if soa else dist2_all(sub, cen)
        sub_labels = d.argmin(axis=1)
        part = np.partition(d, 1, axis=1) if k > 1 else None
        labels[idx] = sub_labels
        d2[idx] = d[np.arange(len(sub_labels)), sub_labels]
        state.labels = labels
        state.upper = state.upper.copy()
        state.lower = state.lower.copy()
        state.upper[idx] = np.sqrt(part[:, 0].astype(np.float64)) if k > 1 else np.sqrt(d2[idx].astype(np.float64))
        if k > 1:
            state.lower[idx] = np.sqrt(part[:, 1].astype(np.float64))
    state.upper[skip] = u[skip]
    state.lower[skip] = low[skip]
    return labels, d2


def run_cell(img, plan, layout, kernel, k, init_cen):
    """One full drive: ITERS step rounds + 1 labeling pass. Returns
    (labels, wall_secs). Fill cost is paid per round for interleaved,
    once for soa — mirroring the tile arena."""
    t0 = time.perf_counter()
    soa_kernel = kernel == "lanes"
    tiles = None
    if layout == "soa":
        tiles = []
        for r0, c0, rows, cols in plan:  # fill once per job
            block = img[r0 : r0 + rows, c0 : c0 + cols].reshape(-1, C)
            tiles.append(np.ascontiguousarray(block.T) if soa_kernel else block.copy())
    cen = init_cen.copy()
    states = [BlockState() for _ in plan]
    drift = None
    labels_out = None
    for rnd in range(ITERS + 1):
        sums = np.zeros((k, C), dtype=np.float64)
        counts = np.zeros(k, dtype=np.int64)
        round_labels = []
        for bi, (r0, c0, rows, cols) in enumerate(plan):
            if tiles is not None:
                # Lanes consumes the tile directly; interleaved kernels
                # pay the per-round rematerialization copy (no I/O).
                data = tiles[bi] if soa_kernel else tiles[bi].copy()
            else:  # re-extract every round (seed behaviour)
                block = img[r0 : r0 + rows, c0 : c0 + cols].reshape(-1, C)
                data = np.ascontiguousarray(block.T) if soa_kernel else block.copy()
            st = states[bi] if kernel in ("pruned", "lanes") else BlockState()
            labels, _d2 = step_block(kernel, data, cen, k, st, drift)
            px = (data.T if soa_kernel else data).astype(np.float64)
            s, c = accum(px, labels, k)
            sums += s
            counts += c
            round_labels.append(labels)
        if rnd < ITERS:
            new = update_centroids(cen, sums, counts)
            per = np.sqrt(((new.astype(np.float64) - cen.astype(np.float64)) ** 2).sum(axis=1)) * (1 + 1e-12)
            drift = (per, per.max() if k else 0.0)
            cen = new
        else:
            labels_out = np.concatenate(round_labels)
    return labels_out, time.perf_counter() - t0


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_layout.json"
    rng = np.random.default_rng(SEED)
    img = synthetic_scene(rng)
    flat = img.reshape(-1, C)
    passes = ITERS + 1
    cases = []
    for shape_name, br, bc in paper_shapes():
        plan = block_plan(br, bc)
        for k in KS:
            init_cen = flat[rng.choice(len(flat), size=k, replace=False)].copy()
            baseline = None
            for layout, kernel in LAYOUT_CELLS:
                best = math.inf
                labels = None
                for sample in range(SAMPLES + 1):
                    labels, wall = run_cell(img, plan, layout, kernel, k, init_cen)
                    if sample > 0:
                        best = min(best, wall)
                if baseline is None:
                    baseline = (best, labels)
                    speedup, matches = 1.0, True
                else:
                    speedup = baseline[0] / best
                    matches = bool(np.array_equal(labels, baseline[1]))
                if not matches:
                    raise SystemExit(
                        f"model kernels diverged: {shape_name} {layout} {kernel} k={k}"
                    )
                bytes_read, strip_reads = io_closed_form(plan, layout, passes)
                cases.append(
                    {
                        "layout": layout,
                        "kernel": kernel,
                        "shape": shape_name,
                        "k": k,
                        "blocks": len(plan),
                        "wall_secs": round(best, 6),
                        "ns_per_pixel_round": round(best * 1e9 / (H * W * passes), 4),
                        "bytes_read": bytes_read,
                        "strip_reads": strip_reads,
                        "strip_cache_hits": 0,
                        "strip_cache_misses": 0,
                        "speedup_vs_naive": round(speedup, 4),
                        "matches_naive": matches,
                    }
                )
                print(
                    f"{shape_name:>6} k={k} {layout:>11}/{kernel:<6}"
                    f" {cases[-1]['ns_per_pixel_round']:>9.3f} ns/px/round"
                    f"  {bytes_read / (1 << 20):>7.1f} MiB  x{speedup:.2f}",
                    flush=True,
                )
    doc = {
        "image": [H, W],
        "channels": C,
        "iters": ITERS,
        "samples": SAMPLES,
        "seed": SEED,
        "workers": WORKERS,
        "strip_rows": STRIP_ROWS,
        "cache_strips": CACHE_STRIPS,
        "source": "python-model",
        "cases": cases,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path} ({len(cases)} cases)")


if __name__ == "__main__":
    main()
