"""L2 correctness: the AOT-able graphs vs the reference Lloyd loop."""

from __future__ import annotations

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

CHUNK = model.CHUNK


def _case(seed, p=CHUNK, k=4, c=model.CHANNELS, mask_frac=0.9):
    g = np.random.default_rng(seed)
    x = jnp.asarray((g.random((p, c)) * 255).astype(np.float32))
    m = jnp.asarray((g.random(p) < mask_frac).astype(np.float32))
    cen = jnp.asarray((g.random((k, c)) * 255).astype(np.float32))
    return x, m, cen


@pytest.mark.parametrize("k", [2, 4, 8])
def test_assign_fn_matches_ref(k):
    x, _, cen = _case(100 + k, k=k)
    l1, d1 = model.assign_fn(x, cen)
    l2, d2 = ref.assign(x, cen)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("k", [2, 4, 8])
def test_step_fn_matches_ref(k):
    x, m, cen = _case(200 + k, k=k)
    s1, n1, i1 = model.step_fn(x, m, cen)
    s2, n2, i2 = ref.step(x, m, cen)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), rtol=1e-6)
    np.testing.assert_allclose(float(i1), float(i2), rtol=1e-4)


@pytest.mark.parametrize("k", [2, 4])
def test_local_kmeans_matches_ref_loop(k):
    x, m, cen = _case(300 + k, k=k)
    c1, l1, i1 = model.local_kmeans_fn(x, m, cen)
    c2, l2, i2 = ref.local_kmeans(x, m, cen, model.LOCAL_ITERS)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-4, atol=1e-2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_allclose(float(i1), float(i2), rtol=1e-4)


def test_local_kmeans_reduces_inertia():
    """Inertia after LOCAL_ITERS iterations ≤ inertia at the init centroids."""
    x, m, cen = _case(42)
    _, _, i0 = ref.step(x, m, cen)
    _, _, i_final = model.local_kmeans_fn(x, m, cen)
    assert float(i_final) <= float(i0) + 1e-3


def test_update_empty_cluster_keeps_old_centre():
    old = jnp.asarray([[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]])
    sums = jnp.asarray([[10.0, 10.0, 10.0], [0.0, 0.0, 0.0]])
    counts = jnp.asarray([2.0, 0.0])
    new = model._update(sums, counts, old)
    np.testing.assert_allclose(np.asarray(new)[0], [5.0, 5.0, 5.0])
    np.testing.assert_allclose(np.asarray(new)[1], [4.0, 5.0, 6.0])


def test_step_is_block_associative():
    """Summing two half-chunk steps equals one full-chunk step — the exact
    property the rust leader's cross-block reduction relies on."""
    x, m, cen = _case(7)
    h = CHUNK // 2
    s_a, n_a, i_a = model.step_fn(
        jnp.concatenate([x[:h], jnp.zeros_like(x[:h])]),
        jnp.concatenate([m[:h], jnp.zeros_like(m[:h])]),
        cen,
    )
    s_b, n_b, i_b = model.step_fn(
        jnp.concatenate([x[h:], jnp.zeros_like(x[h:])]),
        jnp.concatenate([m[h:], jnp.zeros_like(m[h:])]),
        cen,
    )
    s, n, i = model.step_fn(x, m, cen)
    np.testing.assert_allclose(np.asarray(s_a + s_b), np.asarray(s), rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(n_a + n_b), np.asarray(n), rtol=1e-6)
    np.testing.assert_allclose(float(i_a + i_b), float(i), rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(k=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_hypothesis_local_monotone_under_seeds(k, seed):
    """Lloyd never increases inertia between iterations, any seed, any K."""
    x, m, cen = _case(seed, k=k)
    c = cen
    prev = float(ref.step(x, m, c)[2])
    for _ in range(4):
        s, n, _ = ref.step(x, m, c)
        c = model._update(s, n, c)
        cur = float(ref.step(x, m, c)[2])
        assert cur <= prev * (1 + 1e-5) + 1e-3
        prev = cur


def test_specs_cover_all_kinds():
    sp = model.specs(4)
    assert set(sp) == {"assign", "step", "local"}
    fn, args = sp["step"]
    assert args[0].shape == (model.CHUNK, model.CHANNELS)
    assert args[1].shape == (model.CHUNK,)
    assert args[2].shape == (4, model.CHANNELS)


def test_graphs_lower_without_python_callbacks():
    """The lowered HLO must be self-contained (no host callbacks) or the
    rust runtime could not execute it."""
    for kind, (fn, args) in model.specs(2).items():
        txt = jax.jit(fn).lower(*args).compiler_ir("stablehlo")
        assert "callback" not in str(txt), f"{kind} captured a python callback"
