"""AOT path: artifacts lower, parse, and the manifest describes them truly."""

from __future__ import annotations

import hashlib
import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Build a small-chunk artifact set once for the whole module."""
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build_artifacts(out, ks=(2, 4), chunk=1024, channels=3)
    return out, manifest


def test_manifest_lists_all_artifacts(built):
    out, manifest = built
    names = {e["name"] for e in manifest["artifacts"]}
    assert names == {
        "assign_k2", "step_k2", "local_k2",
        "assign_k4", "step_k4", "local_k4",
    }
    assert manifest["chunk"] == 1024
    assert manifest["channels"] == 3
    assert manifest["local_iters"] == model.LOCAL_ITERS


def test_files_exist_and_hash_match(built):
    out, manifest = built
    for e in manifest["artifacts"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]


def test_hlo_text_is_hlo(built):
    out, manifest = built
    for e in manifest["artifacts"]:
        text = open(os.path.join(out, e["file"])).read()
        assert text.startswith("HloModule"), e["name"]
        assert "entry_computation_layout" in text
        # No Mosaic custom-calls may leak through: interpret=True only.
        assert "tpu_custom_call" not in text, e["name"]
        assert "mosaic" not in text.lower(), e["name"]


def test_manifest_signatures_match_model(built):
    out, manifest = built
    by_name = {e["name"]: e for e in manifest["artifacts"]}
    step4 = by_name["step_k4"]
    assert step4["inputs"] == [
        {"shape": [1024, 3], "dtype": "float32"},
        {"shape": [1024], "dtype": "float32"},
        {"shape": [4, 3], "dtype": "float32"},
    ]
    assert step4["outputs"] == [
        {"shape": [4, 3], "dtype": "float32"},
        {"shape": [4], "dtype": "float32"},
        {"shape": [], "dtype": "float32"},
    ]
    assign2 = by_name["assign_k2"]
    assert assign2["outputs"][0] == {"shape": [1024], "dtype": "int32"}


def test_manifest_json_round_trips(built):
    out, manifest = built
    loaded = json.load(open(os.path.join(out, "manifest.json")))
    assert loaded == manifest


def test_entry_layout_mentions_shapes(built):
    """The HLO entry layout must carry the exact chunk shapes the rust
    runtime will feed — a mismatch here is the classic silent-garbage bug."""
    out, manifest = built
    for e in manifest["artifacts"]:
        head = open(os.path.join(out, e["file"])).readline()
        k = e["k"]
        if e["kind"] in ("step", "local"):
            assert f"f32[{k},3]" in head
            assert "f32[1024,3]" in head and "f32[1024]" in head
        else:
            assert f"f32[{k},3]" in head and "f32[1024,3]" in head
