"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Two layers of coverage:

- deterministic parametrized cases over the shapes the artifacts actually
  ship (chunk tiles, K ∈ {2,4,8}, C = 3) plus adversarial inputs
  (duplicate pixels → argmin ties, empty clusters, all-padding masks,
  huge/tiny magnitudes);
- hypothesis sweeps over random shapes/values within the kernel's shape
  contract (P a multiple of the tile).
"""

from __future__ import annotations

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import kmeans_pallas as kp
from compile.kernels import ref

TILE = 128  # small tile so tests sweep many grid steps cheaply


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def _rand_case(seed, p, k, c, mask_frac=0.8, scale=1.0):
    g = _rng(seed)
    x = jnp.asarray((g.normal(size=(p, c)) * scale).astype(np.float32))
    m = jnp.asarray((g.random(p) < mask_frac).astype(np.float32))
    cen = jnp.asarray((g.normal(size=(k, c)) * scale).astype(np.float32))
    return x, m, cen


def _assert_assign_matches(x, cen):
    """Labels must match except where two centroids are so close to
    equidistant that f32 rounding of the expanded-form distance
    (x² − 2xc + c²) legitimately flips the argmin vs the direct form."""
    l_ref, d_ref = ref.assign(x, cen)
    l_pal, d_pal = kp.assign_pallas(x, cen, tile=TILE)
    l_ref, d_ref = np.asarray(l_ref), np.asarray(d_ref)
    l_pal, d_pal = np.asarray(l_pal), np.asarray(d_pal)
    # all-pairs distances in f64 as the tie arbiter
    xs = np.asarray(x, dtype=np.float64)
    cs = np.asarray(cen, dtype=np.float64)
    d_all = ((xs[:, None, :] - cs[None, :, :]) ** 2).sum(-1)
    mism = l_ref != l_pal
    if mism.any():
        picked = d_all[np.arange(len(l_pal)), l_pal]
        best = d_all.min(axis=1)
        scale = np.maximum(best, 1e-12)
        gap = (picked - best) / scale
        assert gap[mism].max() < 1e-4, (
            f"non-tie label mismatches: worst relative gap {gap[mism].max()}"
        )
    np.testing.assert_allclose(d_ref, d_pal, rtol=1e-3, atol=1e-3)


def _assert_step_matches(x, m, cen, rtol=1e-4, atol=1e-4):
    s_ref, n_ref, i_ref = ref.step(x, m, cen)
    s_pal, n_pal, i_pal = kp.step_pallas(x, m, cen, tile=TILE)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_pal), rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(n_ref), np.asarray(n_pal), rtol=rtol)
    np.testing.assert_allclose(float(i_ref), float(i_pal), rtol=1e-3, atol=atol)


@pytest.mark.parametrize("k", [2, 4, 8])
@pytest.mark.parametrize("p", [TILE, 4 * TILE])
@pytest.mark.parametrize("c", [1, 3, 4])
def test_assign_matches_ref(k, p, c):
    x, _, cen = _rand_case(1234 + k * 17 + p + c, p, k, c)
    _assert_assign_matches(x, cen)


@pytest.mark.parametrize("k", [2, 4, 8])
@pytest.mark.parametrize("p", [TILE, 4 * TILE])
@pytest.mark.parametrize("c", [1, 3, 4])
def test_step_matches_ref(k, p, c):
    x, m, cen = _rand_case(4321 + k * 31 + p + c, p, k, c)
    _assert_step_matches(x, m, cen)


def test_argmin_tie_breaks_low_index():
    """Pixels equidistant from several centroids must pick the lowest index
    (jnp.argmin semantics) — the rust baseline mirrors this, and global-mode
    equivalence depends on it."""
    p, c = TILE, 3
    x = jnp.zeros((p, c), jnp.float32)
    # all four centroids at distance 1 from the origin
    cen = jnp.asarray(
        [[1, 0, 0], [0, 1, 0], [0, 0, 1], [-1, 0, 0]], dtype=jnp.float32
    )
    labels, _ = kp.assign_pallas(x, cen, tile=TILE)
    np.testing.assert_array_equal(np.asarray(labels), np.zeros(p, np.int32))


def test_duplicate_pixels_consistent():
    x, _, cen = _rand_case(7, TILE, 4, 3)
    x = jnp.tile(x[:1], (TILE, 1))  # every pixel identical
    labels, d2 = kp.assign_pallas(x, cen, tile=TILE)
    assert len(np.unique(np.asarray(labels))) == 1
    assert np.allclose(np.asarray(d2), np.asarray(d2)[0])


def test_step_all_padding_mask_is_zero():
    x, _, cen = _rand_case(8, 2 * TILE, 4, 3)
    m = jnp.zeros((2 * TILE,), jnp.float32)
    s, n, i = kp.step_pallas(x, m, cen, tile=TILE)
    assert np.allclose(np.asarray(s), 0.0)
    assert np.allclose(np.asarray(n), 0.0)
    assert float(i) == 0.0


def test_step_empty_cluster_contributes_zero():
    """A centroid far from every pixel gets zero count and zero sum."""
    g = _rng(9)
    x = jnp.asarray(g.normal(size=(TILE, 3)).astype(np.float32))
    m = jnp.ones((TILE,), jnp.float32)
    cen = jnp.asarray(
        np.vstack([np.zeros((1, 3)), np.full((1, 3), 1e6)]).astype(np.float32)
    )
    s, n, _ = kp.step_pallas(x, m, cen, tile=TILE)
    assert float(np.asarray(n)[1]) == 0.0
    assert np.allclose(np.asarray(s)[1], 0.0)


def test_counts_sum_to_mask_total():
    x, m, cen = _rand_case(10, 4 * TILE, 8, 3, mask_frac=0.5)
    _, n, _ = kp.step_pallas(x, m, cen, tile=TILE)
    np.testing.assert_allclose(float(np.sum(np.asarray(n))), float(jnp.sum(m)), rtol=1e-6)


def test_large_magnitudes_stable():
    """The expanded d² form loses precision at huge magnitudes; the kernel
    clamps at 0 and must still agree with ref on labels."""
    x, m, cen = _rand_case(11, TILE, 4, 3, scale=1e3)
    l_ref, _ = ref.assign(x, cen)
    l_pal, _ = kp.assign_pallas(x, cen, tile=TILE)
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_pal))


def test_pixel_scale_8bit_range():
    """Realistic image data: values in [0, 255] (the paper's 8/16-bit DNs)."""
    g = _rng(12)
    x = jnp.asarray((g.random((2 * TILE, 3)) * 255).astype(np.float32))
    cen = jnp.asarray((g.random((4, 3)) * 255).astype(np.float32))
    m = jnp.ones((2 * TILE,), jnp.float32)
    _assert_step_matches(x, m, cen, rtol=1e-3, atol=1e-2)


def test_rejects_non_multiple_tile():
    x = jnp.zeros((TILE + 1, 3), jnp.float32)
    cen = jnp.zeros((2, 3), jnp.float32)
    with pytest.raises(ValueError, match="multiple"):
        kp.assign_pallas(x, cen, tile=TILE)


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 4),
    k=st.integers(2, 8),
    c=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
    mask_frac=st.floats(0.0, 1.0),
)
def test_hypothesis_step_matches_ref(tiles, k, c, seed, mask_frac):
    x, m, cen = _rand_case(seed, tiles * TILE, k, c, mask_frac=mask_frac)
    _assert_step_matches(x, m, cen)


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 4),
    k=st.integers(2, 8),
    c=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
)
def test_hypothesis_assign_matches_ref(tiles, k, c, seed, scale):
    x, _, cen = _rand_case(seed, tiles * TILE, k, c, scale=scale)
    _assert_assign_matches(x, cen)
