#!/usr/bin/env python3
"""Schema check for BENCH_layout.json (CI smoke + committed file).

Usage: check_layout_schema.py <path> [--full]

Validates the document structure the rust `blockms layout` bench and
the python model both emit (EXPERIMENTS.md §Layout). With --full, also
requires the acceptance matrix: 1024x1024, k in {2,4,8}, the complete
layout x kernel x shape cross, and the SoA one-pass I/O invariant.
"""

import json
import sys

LAYOUTS = {"interleaved", "soa"}
KERNELS = {"naive", "pruned", "lanes"}
SHAPES = {"row", "column", "square"}

META_NUM = ["iters", "samples", "seed", "workers", "strip_rows", "cache_strips", "channels"]
CASE_NUM = [
    "k",
    "blocks",
    "wall_secs",
    "ns_per_pixel_round",
    "bytes_read",
    "strip_reads",
    "strip_cache_hits",
    "strip_cache_misses",
    "speedup_vs_naive",
]


def fail(msg):
    print(f"BENCH_layout.json schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    full = "--full" in sys.argv
    path = args[0] if args else "BENCH_layout.json"
    with open(path) as f:
        doc = json.load(f)

    for key in META_NUM:
        if not isinstance(doc.get(key), (int, float)):
            fail(f"meta field {key!r} missing or non-numeric")
    img = doc.get("image")
    if not (isinstance(img, list) and len(img) == 2):
        fail("image must be [height, width]")
    if doc.get("source") not in ("rust", "python-model"):
        fail(f"unknown source {doc.get('source')!r}")

    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        fail("cases missing or empty")
    seen = set()
    for i, c in enumerate(cases):
        if c.get("layout") not in LAYOUTS:
            fail(f"case {i}: bad layout {c.get('layout')!r}")
        if c.get("kernel") not in KERNELS:
            fail(f"case {i}: bad kernel {c.get('kernel')!r}")
        if c.get("shape") not in SHAPES:
            fail(f"case {i}: bad shape {c.get('shape')!r}")
        for key in CASE_NUM:
            if not isinstance(c.get(key), (int, float)):
                fail(f"case {i}: field {key!r} missing or non-numeric")
        if c.get("matches_naive") is not True:
            fail(f"case {i}: matches_naive is not true — broken kernel, not a result")
        seen.add((c["layout"], c["kernel"], c["shape"], c["k"]))

    if full:
        if img != [1024, 1024]:
            fail(f"--full requires a 1024x1024 image, got {img}")
        want = {
            (lay, ker, sh, k)
            for lay in LAYOUTS
            for ker in KERNELS
            for sh in SHAPES
            for k in (2, 4, 8)
        }
        missing = want - seen
        if missing:
            fail(f"--full matrix incomplete: {len(missing)} cells missing, e.g. {sorted(missing)[:3]}")
        # SoA arena invariant: one pass of bytes vs (iters + 1) passes.
        passes = doc["iters"] + 1
        by_cell = {(c["layout"], c["kernel"], c["shape"], c["k"]): c for c in cases}
        for sh in SHAPES:
            for k in (2, 4, 8):
                inter = by_cell[("interleaved", "naive", sh, k)]["bytes_read"]
                soa = by_cell[("soa", "naive", sh, k)]["bytes_read"]
                if inter != soa * passes:
                    fail(f"{sh} k={k}: interleaved bytes {inter} != soa bytes {soa} x {passes}")

    print(f"{path}: schema OK ({len(cases)} cases, source={doc['source']})")


if __name__ == "__main__":
    main()
