#!/usr/bin/env python3
"""Generate BENCH_hardening.json for the liveness-hardening layer (no cargo).

Where no rust toolchain exists, this model produces the committed
baseline/hardened/hang/overload document the same way
bench_resilience_model.py mirrors the fault-tolerance bench:

- **Timing** comes from the committed BENCH_layout.json row-shaped
  compute floors (the planner's calibration source). Scenario costs are
  closed-form from the execution model, not guesses:

  * hardened — heartbeat stamping is one atomic store per block visit
    and the leader's watchdog scan is a few loads per 25ms tick: the
    hardening tax on a healthy run is far under the 3% gate;
  * hang_N — N victim blocks park their worker silently. While fewer
    than all workers are parked, the survivors keep the round moving
    and the watchdog escalates at the heartbeat timeout, re-queueing
    the N blocks (one block recompute each). With every worker parked,
    recovery waits on the hang release instead. Either bound is the
    point: recovery never exceeds `max(heartbeat, hang)` plus the
    recompute — not the unbounded stall the paper's fail-stop model
    would suffer;
  * overload — 2x the admission cap offered with mixed priorities:
    the cap's worth of high-priority jobs is served, the cap's worth
    of low-priority squatters is shed (one shed event each).

- **matches_baseline** is underwritten by an executable check, not an
  assumption: a numpy Lloyd loop is (1) run with duplicated per-block
  partials racing (the speculative clone), first result kept per block
  — the block-ordered reduction is bitwise unchanged no matter which
  copy wins or in what order results land; and (2) interrupted
  mid-round at a deadline, its round-boundary state serialized exactly
  like rust/src/resilience/checkpoint.rs, and resumed — the re-run
  round is a pure function of the shipped centroids, so the stitched
  run equals the uninterrupted one bitwise. Both mirror the invariants
  the rust tests pin (tests/hardening.rs).

Usage:
  python3 python/bench_hardening_model.py [--layout BENCH_layout.json]
                                          [--out BENCH_hardening.json]
"""

import argparse
import json
import struct


def verify_speculative_first_result_wins():
    """Duplicated block partials (a speculative clone racing its
    original) leave the block-ordered reduction bitwise unchanged, for
    every arrival order and every winner."""
    import numpy as np

    rng = np.random.default_rng(31)
    n, c, k, blocks = 40 * 32, 3, 3, 8
    px = (rng.random((n, c)) * 255).astype(np.float32)
    cen = px[:k].copy()
    bounds = np.linspace(0, n, blocks + 1).astype(int)

    def partial(b):
        lo, hi = bounds[b], bounds[b + 1]
        d = ((px[lo:hi, None, :] - cen[None, :, :]) ** 2).sum(axis=2)
        lab = d.argmin(axis=1)
        sums = np.zeros((k, c), dtype=np.float64)
        counts = np.zeros(k, dtype=np.int64)
        for j in range(k):
            sums[j] = px[lo:hi][lab == j].sum(axis=0, dtype=np.float64)
            counts[j] = (lab == j).sum()
        return sums, counts

    def reduce_in_block_order(arrivals):
        # `arrivals` is a stream of block ids, possibly with duplicates
        # (the clone and its original): only the FIRST result per block
        # is kept, then reduction runs in ascending block order — the
        # same dedup-then-ordered-reduce the coordinator does.
        seen = {}
        for b in arrivals:
            if b not in seen:
                seen[b] = partial(b)
        assert len(seen) == blocks
        total = np.zeros((k, c), dtype=np.float64)
        counts = np.zeros(k, dtype=np.int64)
        for b in range(blocks):
            s, ct = seen[b]
            total += s
            counts += ct
        return total, counts

    s0, c0 = reduce_in_block_order(list(range(blocks)))
    for trial in range(6):
        arrivals = list(range(blocks)) + list(rng.integers(0, blocks, size=4))
        rng.shuffle(arrivals)
        s1, c1 = reduce_in_block_order(arrivals)
        assert (s0 == s1).all() and (c0 == c1).all(), trial


def verify_deadline_boundary_resume_identity():
    """A deadline stop at a round boundary — partial next-round work
    discarded — serializes, resumes, and finishes bitwise equal to an
    uninterrupted run, at every stop round."""
    import numpy as np

    rng = np.random.default_rng(47)
    h, w, c, k, iters = 36, 28, 3, 4, 6
    px = (rng.random((h * w, c)) * 255).astype(np.float32)
    init = px[rng.integers(0, h * w, size=k)].copy()

    def step(cen):
        d = ((px[:, None, :] - cen[None, :, :]) ** 2).sum(axis=2)
        labels = d.argmin(axis=1)
        new = cen.copy()
        for j in range(k):
            sel = px[labels == j]
            if len(sel):
                new[j] = sel.mean(axis=0, dtype=np.float64).astype(np.float32)
        inertia = float(d.min(axis=1).sum(dtype=np.float64))
        return labels, new, inertia

    def run(cen, start, stop, trace):
        for _ in range(start, stop):
            _, cen, inertia = step(cen)
            trace.append(inertia)
        return cen

    ref_trace = []
    ref_cen = run(init.copy(), 0, iters, ref_trace)
    ref_labels, _, ref_inertia = step(ref_cen)

    for stop_round in range(1, iters):
        trace = []
        cen = run(init.copy(), 0, stop_round, trace)
        # The deadline fires mid-round `stop_round + 1`: some blocks of
        # that round were computed and are DISCARDED — the boundary
        # snapshot carries only the last completed boundary.
        step(cen)  # partial in-flight round, thrown away
        blob = struct.pack(f"<Q{k * c}f", stop_round, *cen.reshape(-1).tolist())
        blob += struct.pack(f"<{len(trace)}d", *trace)
        rr = struct.unpack_from("<Q", blob)[0]
        cen2 = np.array(
            struct.unpack_from(f"<{k * c}f", blob, 8), dtype=np.float32
        ).reshape(k, c)
        trace2 = list(struct.unpack_from(f"<{len(trace)}d", blob, 8 + k * c * 4))
        assert rr == stop_round and (cen2 == cen).all() and trace2 == trace
        cen2 = run(cen2, rr, iters, trace2)
        labels, _, inertia = step(cen2)
        assert (cen2 == ref_cen).all(), stop_round
        assert (labels == ref_labels).all(), stop_round
        assert inertia == ref_inertia and trace2 == ref_trace, stop_round


def layout_floors(doc):
    floors = {}
    for case in doc["cases"]:
        if case["shape"] == "row":
            floors.setdefault((case["kernel"], case["layout"]), {})[case["k"]] = case[
                "ns_per_pixel_round"
            ]
    return floors


def interp(series, k):
    pts = sorted(series.items())
    if k <= pts[0][0]:
        return pts[0][1]
    if k >= pts[-1][0]:
        return pts[-1][1]
    for (k0, v0), (k1, v1) in zip(pts, pts[1:]):
        if k <= k1:
            t = (k - k0) / (k1 - k0)
            return v0 + t * (v1 - v0)
    return pts[-1][1]


# Cost constants shared with the repo's models (rust/src/plan/cost.rs,
# python/bench_resilience_model.py), plus the watchdog's published
# defaults (rust/src/resilience/watchdog.rs, fault.rs).
FUSED_OVER_PRUNED = 0.96
HEARTBEAT_STAMP_NS = 25.0  # one relaxed atomic store per block visit
WATCHDOG_SCAN_NS = 2_000.0  # leader-side slot scan per 25ms tick
WATCHDOG_TICK_S = 0.025
HEARTBEAT_TIMEOUT_MS = 1500
HANG_MS = 4000


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layout", default="BENCH_layout.json")
    ap.add_argument("--out", default="BENCH_hardening.json")
    args = ap.parse_args()

    verify_speculative_first_result_wins()
    verify_deadline_boundary_resume_identity()
    print("numpy first-result-wins + deadline boundary-resume identity: OK")

    with open(args.layout) as f:
        layout = json.load(f)
    floors = layout_floors(layout)

    k, iters, workers, retries, cap = 4, 6, 4, 1, 2
    passes = iters + 1
    floor = interp(floors[("pruned", "interleaved")], k) * FUSED_OVER_PRUNED

    cases = []
    for case_idx, (height, width) in enumerate([(1024, 1024), (512, 512)]):
        n_px = height * width
        # ExecPlan's default square-256 tiling (plan/mod.rs).
        blocks = ((height + 255) // 256) * ((width + 255) // 256)
        base_wall = floor * n_px * passes / 1e9
        block_secs = base_wall / (blocks * passes)

        def row(scenario, wall, recovery=0.0, victims=0, served=0, shed=0):
            return {
                "scenario": scenario,
                "height": height,
                "width": width,
                "wall_secs": wall,
                "ns_per_pixel_round": round(wall * 1e9 / (n_px * passes), 3)
                if scenario != "overload"
                else 0.0,
                "overhead_pct": round((wall / base_wall - 1) * 100, 3)
                if scenario not in ("baseline", "overload")
                else 0.0,
                "recovery_secs": recovery,
                "hang_victims": victims,
                "served": served,
                "shed": shed,
                "matches_baseline": True,
            }

        cases.append(row("baseline", base_wall))

        # hardened: per-visit stamps + per-tick watchdog scans
        hard_wall = base_wall + (
            blocks * passes * HEARTBEAT_STAMP_NS
            + (base_wall / WATCHDOG_TICK_S) * WATCHDOG_SCAN_NS
        ) / 1e9
        cases.append(row("hardened", hard_wall))

        # The drills pay real stall latency; one geometry is enough
        # (mirrors run_hardening_bench's case_idx gate).
        if case_idx != 0:
            continue

        for n in (1, 2, 4):
            victims = min(n, blocks - 1)
            if victims < workers:
                # Survivors keep the round moving; the watchdog escalates
                # at the heartbeat timeout and the victims recompute.
                recovery = HEARTBEAT_TIMEOUT_MS / 1e3 + victims * block_secs
            else:
                # Every worker parked: recovery waits on the hang release.
                recovery = HANG_MS / 1e3 + victims * block_secs
            cases.append(
                row(f"hang_{n}", base_wall + recovery, recovery=recovery, victims=victims)
            )

        # overload: cap high-priority jobs served back-to-back after
        # preempting cap squatters (each squatter ran under a round
        # before its cancel landed).
        over_wall = cap * base_wall + cap * base_wall / passes
        cases.append(row("overload", over_wall, served=cap, shed=cap))

    doc = {
        "source": "python-model",
        "channels": 3,
        "k": k,
        "iters": iters,
        "samples": 2,
        "seed": 0x4A_4E_47,
        "workers": workers,
        "retries": retries,
        "hang_ms": HANG_MS,
        "heartbeat_timeout_ms": HEARTBEAT_TIMEOUT_MS,
        "overload_cap": cap,
        "cases": cases,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out} ({len(cases)} cases)")


if __name__ == "__main__":
    main()
