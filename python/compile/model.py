"""L2 — JAX compute graphs lowered AOT for the rust runtime.

Each function here is a *fixed-shape* graph over one pixel chunk.  The rust
coordinator streams arbitrary-size blocks through these graphs in chunks of
``CHUNK`` pixels (zero-masking the tail), reduces the partial results, and
owns the outer Lloyd loop — so the graphs stay associative and the same
artifacts serve every block shape the paper studies.

Artifacts produced by :mod:`aot` (per K ∈ {2, 4, 8}):

- ``assign_k{K}``  — ``(pixels[P,C], centroids[K,C]) -> (labels, min_d2)``
- ``step_k{K}``    — ``(pixels, mask, centroids) -> (sums, counts, inertia)``
- ``local_k{K}``   — ``(pixels, mask, centroids) ->
                       (centroids', labels, inertia)`` — a full
  ``LOCAL_ITERS``-iteration per-block K-Means (the paper's per-block
  ``blockproc(@kmeans)`` mode) compiled into one executable.

All heavy lifting inside these graphs happens in the L1 Pallas kernels
(:mod:`kernels.kmeans_pallas`); this layer adds the centroid update and the
iteration ``scan`` — both cheap, both fusible by XLA.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import kmeans_pallas as kp

# Fixed chunk geometry shared with the rust runtime (see
# rust/src/runtime/manifest.rs).  CHUNK is the pixel count per executable
# call; CHANNELS is the band count (paper images are RGB).
CHUNK = 16384
CHANNELS = 3
KS = (2, 4, 8)
LOCAL_ITERS = 8


def assign_fn(pixels: jnp.ndarray, centroids: jnp.ndarray):
    """Chunk-level nearest-centroid assignment (labels + min d²)."""
    return kp.assign_pallas(pixels, centroids)


def step_fn(pixels: jnp.ndarray, mask: jnp.ndarray, centroids: jnp.ndarray):
    """One masked Lloyd accumulation step over a chunk."""
    return kp.step_pallas(pixels, mask, centroids)


def _update(sums: jnp.ndarray, counts: jnp.ndarray, old: jnp.ndarray):
    """Centroid update with empty-cluster carry-over (matches ref + rust)."""
    safe = jnp.maximum(counts, 1.0)[:, None]
    fresh = sums / safe
    return jnp.where(counts[:, None] > 0.0, fresh, old)


def local_kmeans_fn(pixels: jnp.ndarray, mask: jnp.ndarray, centroids: jnp.ndarray):
    """Per-block K-Means: LOCAL_ITERS Lloyd iterations + final assignment.

    ``lax.scan`` keeps the HLO compact (one loop, not LOCAL_ITERS unrolled
    copies) and lets XLA reuse the iteration buffers.
    """

    def body(c, _):
        sums, counts, _inertia = kp.step_pallas(pixels, mask, c)
        return _update(sums, counts, c), None

    final_c, _ = jax.lax.scan(body, centroids, None, length=LOCAL_ITERS)
    labels, min_d2 = kp.assign_pallas(pixels, final_c)
    inertia = jnp.sum(min_d2 * mask)
    return final_c, labels, inertia


def specs(k: int, chunk: int = CHUNK, channels: int = CHANNELS):
    """ShapeDtypeStructs for the three graphs at cluster count ``k``."""
    px = jax.ShapeDtypeStruct((chunk, channels), jnp.float32)
    msk = jax.ShapeDtypeStruct((chunk,), jnp.float32)
    cen = jax.ShapeDtypeStruct((k, channels), jnp.float32)
    return {
        "assign": (assign_fn, (px, cen)),
        "step": (step_fn, (px, msk, cen)),
        "local": (local_kmeans_fn, (px, msk, cen)),
    }
