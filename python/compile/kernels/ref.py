"""Pure-jnp reference implementations (correctness oracle).

Everything the Pallas kernels in :mod:`kmeans_pallas` compute is
re-implemented here with plain ``jax.numpy`` ops, in the most direct way
possible.  pytest (``python/tests/``) asserts the kernels match these
references over swept shapes/dtypes/masks; the rust integration tests then
assert the AOT artifacts match a rust port of the same math, closing the
loop across all three layers.

Conventions (shared with the kernels, the L2 model and the rust runtime):

- ``pixels``    f32[P, C]   — one chunk of flattened block pixels.
- ``mask``      f32[P]      — 1.0 for valid pixels, 0.0 for padding.
- ``centroids`` f32[K, C]   — current cluster centres.
- ``labels``    i32[P]      — argmin cluster index per pixel.
- ``min_d2``    f32[P]      — squared distance to the owning centre.
- ``sums``      f32[K, C]   — masked per-cluster coordinate sums.
- ``counts``    f32[K]      — masked per-cluster member counts.
- ``inertia``   f32[]       — masked sum of ``min_d2``.

Ties in the argmin resolve to the lowest cluster index (jnp.argmin
semantics); the kernels and the rust baseline must match this exactly so
that global-mode parallel K-Means is bit-identical to the sequential
baseline.
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_sqdist(pixels: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """All-pairs squared euclidean distances, f32[P, K].

    Computed the *direct* way — ``sum((x - c)^2)`` — rather than the
    expanded ``x2 - 2xc + c2`` form the kernels use, so the test catches
    algebra mistakes in the expansion.
    """
    diff = pixels[:, None, :] - centroids[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def assign(pixels: jnp.ndarray, centroids: jnp.ndarray):
    """Nearest-centroid assignment.  Returns ``(labels i32[P], min_d2 f32[P])``."""
    d2 = pairwise_sqdist(pixels, centroids)
    labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
    min_d2 = jnp.min(d2, axis=1)
    return labels, min_d2


def step(pixels: jnp.ndarray, mask: jnp.ndarray, centroids: jnp.ndarray):
    """One masked Lloyd accumulation step.

    Returns ``(sums f32[K,C], counts f32[K], inertia f32[])``.  The caller
    (leader, in rust) reduces these across chunks/blocks and divides to get
    the new centroids — that division deliberately does NOT happen here so
    the reduction stays associative across any block partition.
    """
    k = centroids.shape[0]
    labels, min_d2 = assign(pixels, centroids)
    onehot = (labels[:, None] == jnp.arange(k)[None, :]).astype(pixels.dtype)
    onehot = onehot * mask[:, None]
    sums = onehot.T @ pixels
    counts = jnp.sum(onehot, axis=0)
    inertia = jnp.sum(min_d2 * mask)
    return sums, counts, inertia


def update_centroids(
    sums: jnp.ndarray, counts: jnp.ndarray, old_centroids: jnp.ndarray
) -> jnp.ndarray:
    """Centroid update with empty-cluster carry-over.

    A cluster that captured no pixels keeps its previous centre (the same
    policy the rust sequential baseline uses), avoiding NaNs.
    """
    safe = jnp.maximum(counts, 1.0)[:, None]
    fresh = sums / safe
    return jnp.where(counts[:, None] > 0.0, fresh, old_centroids)


def local_kmeans(
    pixels: jnp.ndarray,
    mask: jnp.ndarray,
    centroids: jnp.ndarray,
    iters: int,
):
    """Full per-block Lloyd loop (reference for the ``local_k*`` artifact).

    Returns ``(centroids f32[K,C], labels i32[P], inertia f32[])`` after
    ``iters`` fixed iterations (the AOT artifact compiles the loop length
    in; convergence short-circuiting happens at the rust layer by comparing
    successive inertias).
    """
    c = centroids
    for _ in range(iters):
        sums, counts, _ = step(pixels, mask, c)
        c = update_centroids(sums, counts, c)
    labels, min_d2 = assign(pixels, c)
    inertia = jnp.sum(min_d2 * mask)
    return c, labels, inertia
