"""L1 — Pallas kernels for the per-block K-Means hot spot.

The paper's compute hot spot is the per-pixel nearest-centroid search over
every pixel of every block.  Here it is expressed as tiled Pallas kernels:

- :func:`assign_pallas`  — nearest-centroid assignment (labels + min d²),
- :func:`step_pallas`    — fused assignment + masked per-cluster partial
  sums / counts / inertia accumulation (one Lloyd accumulation step).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles for
MATLAB parpool workers; we tile for VMEM.  Pixels stream through the grid
in ``TILE×C`` tiles (12 KiB at the default tile — far under VMEM) while the
``K×C`` centroid panel stays resident, and the distance computation is
written in the expanded form

    d²(x, c) = ‖x‖² − 2·x@cᵀ + ‖c‖²

so its inner term is a ``(TILE×C)·(C×K)`` matmul that maps onto the MXU
systolic array on a real TPU.  Everything here lowers with
``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls — so these kernels are *structure-correct* TPU kernels
validated numerically on CPU (see DESIGN.md §Perf for the VMEM/MXU
estimates).

The accumulating outputs of ``step`` revisit the same output block on every
grid step (``index_map = lambda i: (0, 0)``) with a ``@pl.when(first)``
zero-init — the standard Pallas reduction idiom.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default pixel-tile length.  48 KiB of pixel data per tile at C=3/f32 —
# well under VMEM (≈16 MiB) with room for double-buffering, MXU/VPU
# friendly (multiple of 8×128 lanes when reshaped), and measured fastest
# on the CPU-interpret path too (EXPERIMENTS.md §Perf: 1024→4096 raised
# step throughput 18.8→45.3 Mpx/s; 4 grid steps per chunk keep the
# output-accumulator pattern exercised).
DEFAULT_TILE = 4096


def _sqdist_tile(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Expanded-form squared distances for one tile: f32[TILE, K].

    The ``x @ c.T`` contraction is the MXU-eligible term; the squared-norm
    rank-1 corrections ride on the VPU.  ``maximum(..., 0)`` guards the
    tiny negative residues the expansion can produce in f32.
    """
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # [TILE, 1]
    c2 = jnp.sum(c * c, axis=1)  # [K]
    d2 = x2 - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32) + c2[None, :]
    return jnp.maximum(d2, 0.0)


def _assign_kernel(x_ref, c_ref, labels_ref, mind2_ref):
    """One grid step: assign a TILE of pixels against the resident centroids."""
    x = x_ref[...]
    c = c_ref[...]
    d2 = _sqdist_tile(x, c)
    labels_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind2_ref[...] = jnp.min(d2, axis=1)


def _step_kernel(x_ref, m_ref, c_ref, sums_ref, counts_ref, inertia_ref):
    """One grid step: fused assign + masked partial-sum accumulation.

    ``sums/counts/inertia`` map every grid step onto the same output block,
    so they act as VMEM-resident accumulators across the pixel stream.
    """
    first = pl.program_id(0) == 0

    @pl.when(first)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        inertia_ref[...] = jnp.zeros_like(inertia_ref)

    x = x_ref[...]
    m = m_ref[...]
    c = c_ref[...]
    k = c.shape[0]

    d2 = _sqdist_tile(x, c)
    labels = jnp.argmin(d2, axis=1)
    min_d2 = jnp.min(d2, axis=1)

    # Masked one-hot membership, then the per-cluster reduction is another
    # MXU-shaped contraction: onehotᵀ[K,TILE] @ x[TILE,C].
    onehot = (labels[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)
    onehot = onehot * m[:, None]
    sums_ref[...] += jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(onehot, axis=0)
    inertia_ref[...] += jnp.sum(min_d2 * m, keepdims=True)[None, :]


def _effective_tile(p: int, tile: int) -> int:
    """Clamp the tile to the chunk length (small chunks = single tile)."""
    tile = min(tile, p)
    if p % tile != 0:
        raise ValueError(f"pixel count {p} must be a multiple of tile {tile}")
    return tile


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def assign_pallas(
    pixels: jnp.ndarray,
    centroids: jnp.ndarray,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
):
    """Tiled nearest-centroid assignment.

    Args:
      pixels:    f32[P, C], P a multiple of ``tile``.
      centroids: f32[K, C].
    Returns:
      ``(labels i32[P], min_d2 f32[P])`` — matching :func:`ref.assign`.
    """
    p, c_dim = pixels.shape
    k, _ = centroids.shape
    tile = _effective_tile(p, tile)
    grid = (p // tile,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, c_dim), lambda i: (i, 0)),
            pl.BlockSpec((k, c_dim), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.int32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=interpret,
    )(pixels, centroids)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def step_pallas(
    pixels: jnp.ndarray,
    mask: jnp.ndarray,
    centroids: jnp.ndarray,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
):
    """Fused Lloyd accumulation step over a pixel chunk.

    Args:
      pixels:    f32[P, C], P a multiple of ``tile``.
      mask:      f32[P] — 1.0 valid / 0.0 padding.
      centroids: f32[K, C].
    Returns:
      ``(sums f32[K,C], counts f32[K], inertia f32[])`` matching
      :func:`ref.step`.
    """
    p, c_dim = pixels.shape
    k, _ = centroids.shape
    tile = _effective_tile(p, tile)
    grid = (p // tile,)
    sums, counts, inertia = pl.pallas_call(
        _step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, c_dim), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((k, c_dim), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, c_dim), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, c_dim), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pixels, mask, centroids)
    return sums, counts, inertia[0, 0]
