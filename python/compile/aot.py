"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

Run once via ``make artifacts``; never on the request path.  Emits, per
K ∈ {2,4,8}::

    artifacts/assign_k{K}.hlo.txt
    artifacts/step_k{K}.hlo.txt
    artifacts/local_k{K}.hlo.txt

plus ``artifacts/manifest.json`` describing every artifact's I/O signature
for the rust loader (rust/src/runtime/manifest.rs).

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Graphs are lowered with ``return_tuple=True`` so every artifact returns a
tuple; the rust side unwraps with ``Literal::to_tuple``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_desc(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def _out_descs(fn, args) -> list:
    outs = jax.eval_shape(fn, *args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    return [_spec_desc(o) for o in outs]


def build_artifacts(out_dir: str, ks=model.KS, chunk: int = model.CHUNK,
                    channels: int = model.CHANNELS) -> dict:
    """Lower all graphs, write HLO text + manifest; return the manifest."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for k in ks:
        for name, (fn, args) in model.specs(k, chunk, channels).items():
            art_name = f"{name}_k{k}"
            text = to_hlo_text(jax.jit(fn).lower(*args))
            fname = f"{art_name}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            entries.append(
                {
                    "name": art_name,
                    "file": fname,
                    "kind": name,
                    "k": k,
                    "chunk": chunk,
                    "channels": channels,
                    "inputs": [_spec_desc(a) for a in args],
                    "outputs": _out_descs(fn, args),
                    "sha256": hashlib.sha256(text.encode()).hexdigest(),
                }
            )
            print(f"  wrote {fname}  ({len(text)} chars)")
    manifest = {
        "format": 1,
        "chunk": chunk,
        "channels": channels,
        "local_iters": model.LOCAL_ITERS,
        "ks": list(ks),
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(entries)} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower blockms graphs to HLO text")
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_artifacts(args.out_dir)


if __name__ == "__main__":
    main()
