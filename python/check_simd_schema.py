#!/usr/bin/env python3
"""Schema check for BENCH_simd.json (CI smoke + committed file).

Usage: check_simd_schema.py <path> [--full]

Validates the document structure the rust `blockms simd` bench and the
python model both emit (EXPERIMENTS.md §SIMD). Always required: valid
kernels/levels/shapes, a simd row at the `portable` fallback level, and
`matches_solo` true on every non-FMA row (a fast row that diverged is a
broken kernel, not a result). With --full, also requires the acceptance
matrix — 1024x1024, k in {2,4,8}, all three shapes, the anchor +
portable + detected-level rows — and `speedup_vs_lanes >= 1.0` on every
simd row at the detected level: the Simd kernel only ships where it
beats the portable lanes formulation.
"""

import json
import sys

KERNELS = {"naive", "lanes", "simd"}
LEVELS = {"portable", "neon", "avx2", "avx512"}
SHAPES = {"row", "column", "square"}

META_NUM = ["iters", "samples", "seed", "workers", "strip_rows", "channels"]
CASE_NUM = ["k", "wall_secs", "ns_per_pixel_round", "speedup_vs_lanes"]


def fail(msg):
    print(f"BENCH_simd.json schema check FAILED: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    full = "--full" in sys.argv
    path = args[0] if args else "BENCH_simd.json"
    with open(path) as f:
        doc = json.load(f)

    for key in META_NUM:
        if not isinstance(doc.get(key), (int, float)):
            fail(f"meta field {key!r} missing or non-numeric")
    img = doc.get("image")
    if not (isinstance(img, list) and len(img) == 2):
        fail("image must be [height, width]")
    if doc.get("source") not in ("rust", "python-model"):
        fail(f"unknown source {doc.get('source')!r}")
    detected = doc.get("detected_level")
    if detected not in LEVELS:
        fail(f"detected_level {detected!r} not one of {sorted(LEVELS)}")

    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        fail("cases missing or empty")
    seen = set()
    for i, c in enumerate(cases):
        kernel = c.get("kernel")
        if kernel not in KERNELS:
            fail(f"case {i}: bad kernel {kernel!r}")
        level = c.get("level")
        if kernel == "simd":
            if level not in LEVELS:
                fail(f"case {i}: simd row with bad level {level!r}")
        elif level != "-":
            fail(f"case {i}: {kernel} row must carry level '-', got {level!r}")
        if c.get("shape") not in SHAPES:
            fail(f"case {i}: bad shape {c.get('shape')!r}")
        if not isinstance(c.get("fma"), bool):
            fail(f"case {i}: fma missing or non-bool")
        for key in CASE_NUM:
            if not isinstance(c.get(key), (int, float)):
                fail(f"case {i}: field {key!r} missing or non-numeric")
        if not c["fma"] and c.get("matches_solo") is not True:
            fail(f"case {i}: non-FMA row with matches_solo != true — broken kernel")
        if kernel == "lanes" and abs(c["speedup_vs_lanes"] - 1.0) > 1e-9:
            fail(f"case {i}: lanes anchor must carry speedup 1.0, got {c['speedup_vs_lanes']}")
        seen.add((kernel, level, c["shape"], c["k"]))

    # The portable fallback row must exist on every machine — it is what
    # BLOCKMS_SIMD=off runs and what non-SIMD hosts dispatch to.
    if not any(k == "simd" and lv == "portable" for (k, lv, _s, _kk) in seen):
        fail("no simd row at the portable fallback level")

    if full:
        if img != [1024, 1024]:
            fail(f"--full requires a 1024x1024 image, got {img}")
        want = set()
        for sh in SHAPES:
            for k in (2, 4, 8):
                want.add(("naive", "-", sh, k))
                want.add(("lanes", "-", sh, k))
                want.add(("simd", "portable", sh, k))
                want.add(("simd", detected, sh, k))
        missing = want - seen
        if missing:
            fail(f"--full matrix incomplete: {len(missing)} cells missing, e.g. {sorted(missing)[:3]}")
        for i, c in enumerate(cases):
            if c["kernel"] == "simd" and c["level"] == detected and c["speedup_vs_lanes"] < 1.0:
                fail(
                    f"case {i}: simd at detected level {detected} is slower than lanes "
                    f"(speedup {c['speedup_vs_lanes']})"
                )

    print(f"{path}: schema OK ({len(cases)} cases, source={doc['source']}, detected={detected})")


if __name__ == "__main__":
    main()
