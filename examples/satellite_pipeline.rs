//! End-to-end driver: the paper's full pipeline on one scene.
//!
//! Reproduces §4's qualitative figures and headline quantitative claim on
//! a real (synthetic) workload, through **all three layers** — synthetic
//! ortho scene → strip store → rust coordinator → AOT JAX/Pallas kernels
//! via PJRT (when `artifacts/` exists; `--engine native` to force the
//! rust oracle) → label maps + speedup tables.
//!
//! Outputs (to `./pipeline_out/`):
//!   - `input.ppm`                         — Fig 3 analogue
//!   - `seq_k2.ppm` / `par_k2.ppm`         — Figs 4/5 analogues
//!   - `seq_k4.ppm` / `par_k4.ppm`         — Figs 6/7 analogues
//!   - console: per-approach speedup/efficiency at 2/4/8 workers
//!     (Tables 12–19 miniature) + the headline "column-shaped wins".
//!
//! ```sh
//! cargo run --release --offline --example satellite_pipeline -- [scale] [engine]
//! # e.g.            …satellite_pipeline -- 0.15 pjrt
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use blockms::bench::runner::{EngineChoice, ExperimentConfig, Runner};
use blockms::bench::tables::hero_shape;
use blockms::bench::workloads::{Workload, HERO_SIZE};
use blockms::blocks::ApproachKind;
use blockms::coordinator::{ClusterConfig, Coordinator, CoordinatorConfig, Engine};
use blockms::image::{write_labels_ppm, write_ppm};
use blockms::plan::ExecPlan;
use blockms::runtime::find_artifacts_dir;
use blockms::util::fmt::{duration, ratio, secs, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: f64 = args.first().map(|s| s.parse()).transpose()?.unwrap_or(0.12);
    let engine_choice: EngineChoice = match args.get(1).map(String::as_str) {
        Some(e) => e.parse().map_err(anyhow::Error::msg)?,
        None => {
            if find_artifacts_dir().is_some() {
                EngineChoice::Pjrt
            } else {
                EngineChoice::Native
            }
        }
    };
    let out_dir = PathBuf::from("pipeline_out");
    std::fs::create_dir_all(&out_dir)?;

    // ---- the scene (hero size 4656×5793, scaled) -----------------------
    let workload = Workload::new(HERO_SIZE, scale, 0xB10C);
    println!(
        "scene: {} nominal, generated {}x{} (scale {scale}), engine {engine_choice:?}",
        HERO_SIZE.label(),
        workload.width,
        workload.height
    );
    let img = Arc::new(workload.generate());
    write_ppm(&img, &out_dir.join("input.ppm"))?;

    // ---- Figs 4–7: sequential vs parallel label maps, K = 2 and 4 ------
    let engine = match engine_choice {
        EngineChoice::Native => Engine::Native,
        EngineChoice::Pjrt => Engine::Pjrt {
            artifacts_dir: None,
        },
    };
    for k in [2usize, 4] {
        let cfg = ClusterConfig {
            k,
            ..Default::default()
        };
        let coord = Coordinator::new(CoordinatorConfig {
            exec: ExecPlan::pinned(hero_shape(ApproachKind::Cols, scale)).with_workers(4),
            engine: engine.clone(),
            ..Default::default()
        });
        let seq = coord.serial(&img, &cfg)?;
        write_labels_ppm(
            &seq.labels,
            img.height(),
            img.width(),
            &out_dir.join(format!("seq_k{k}.ppm")),
        )?;
        let par = coord.cluster(&img, &cfg)?;
        write_labels_ppm(
            &par.labels,
            img.height(),
            img.width(),
            &out_dir.join(format!("par_k{k}.ppm")),
        )?;
        let agree = par
            .labels
            .iter()
            .zip(&seq.labels)
            .filter(|(a, b)| a == b)
            .count() as f64
            / par.labels.len() as f64;
        println!(
            "k={k}: sequential {} ({} iters) | parallel {} ({} blocks) | label agreement {:.3}%",
            duration(seq.total_secs),
            seq.iterations,
            duration(par.total_secs),
            par.blocks,
            agree * 100.0
        );
    }

    // ---- headline: per-approach speedups at 2/4/8 workers --------------
    println!("\nSpeedup/efficiency, measured per-block costs replayed at N workers");
    let mut runner = Runner::new();
    let mut best: Option<(&str, f64)> = None;
    for k in [2usize, 4] {
        let mut t = Table::new(format!("Cluster {k}, image {}", HERO_SIZE.label())).header(&[
            "Approach",
            "Serial",
            "T(2w)",
            "T(4w)",
            "T(8w)",
            "Speedup(4w)",
            "Eff(4w)",
        ]);
        for kind in ApproachKind::ALL {
            let shape = hero_shape(kind, scale);
            let mut cells = Vec::new();
            for workers in [2usize, 4, 8] {
                let mut cfg = ExperimentConfig::new(workload.clone(), shape, k, workers);
                cfg.engine = engine_choice;
                cfg.iters = 6;
                cells.push(runner.measure(&cfg)?);
            }
            let four = &cells[1];
            t.row(vec![
                kind.label().to_string(),
                secs(four.serial_secs),
                secs(cells[0].parallel_secs),
                secs(cells[1].parallel_secs),
                secs(cells[2].parallel_secs),
                ratio(four.speedup),
                ratio(four.efficiency),
            ]);
            if k == 2 {
                let better = match best {
                    Some((_, s)) => four.speedup > s,
                    None => true,
                };
                if better {
                    best = Some((kind.label(), four.speedup));
                }
            }
        }
        println!("{}", t.render());
    }
    if let Some((label, speedup)) = best {
        println!(
            "headline: best approach at 4 workers (k=2) is {label} with speedup {}",
            ratio(speedup)
        );
        println!("(paper finds Column-Shaped best overall — see EXPERIMENTS.md)");
    }
    println!("\nfigures written to {}", out_dir.display());
    Ok(())
}
