//! Quickstart: cluster a synthetic orthoimage with parallel block
//! processing in ~30 lines.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use std::sync::Arc;

use blockms::prelude::*;
use blockms::coordinator::CoordinatorConfig;

fn main() -> anyhow::Result<()> {
    // 1. A synthetic 1280×800 RGB aerial scene (stands in for the
    //    paper's orthoimagery; deterministic in the seed).
    let img = Arc::new(SyntheticOrtho::default().with_seed(7).generate(800, 1280));

    // 2. One resolved execution plan: a column-shaped tiling (the
    //    paper's best case) on 4 workers. Everything the run needs,
    //    in one place — `blockms cluster --auto` would let the cost
    //    model pick these knobs instead.
    let coord = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(BlockShape::Cols { band_cols: 256 }).with_workers(4),
        ..Default::default()
    });
    let plan = coord.block_plan(&img);
    println!("plan: {} blocks of {:?}", plan.len(), plan.block_dims());

    // 3. Cluster (global mode: exactly the sequential result, computed
    //    in parallel).
    let cfg = ClusterConfig {
        k: 4,
        ..Default::default()
    };
    let out = coord.cluster(&img, &cfg)?;
    println!(
        "clustered {} px into k={} in {} iterations: inertia {:.0}, {:.1} ms",
        img.pixels(),
        cfg.k,
        out.iterations,
        out.inertia,
        out.total_secs * 1e3,
    );

    // 4. Verify against the sequential baseline — identical labels.
    let serial = coord.serial(&img, &cfg)?;
    assert_eq!(out.labels, serial.labels, "global mode must equal serial");
    println!("✓ parallel labels identical to sequential K-Means");

    // 5. Write the label map for inspection.
    let path = std::env::temp_dir().join("blockms_quickstart_labels.ppm");
    blockms::image::write_labels_ppm(&out.labels, img.height(), img.width(), &path)?;
    println!("label map written to {}", path.display());
    Ok(())
}
