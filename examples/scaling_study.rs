//! Scaling & ablation study: beyond the paper's 2/4/8 sweep.
//!
//! Extends the paper's evaluation with the ablations DESIGN.md calls out:
//!
//! 1. worker scaling 1..16 for each approach (where does it flatten, and
//!    why — block-count granularity vs I/O serialization);
//! 2. static vs dynamic scheduling (the `parfor` design choice);
//! 3. serialized-disk vs parallel-filesystem I/O model;
//! 4. global vs local clustering mode cost.
//!
//! ```sh
//! cargo run --release --offline --example scaling_study -- [scale]
//! ```

use blockms::bench::runner::{ExperimentConfig, Runner};
use blockms::bench::tables::hero_shape;
use blockms::bench::workloads::{Workload, HERO_SIZE};
use blockms::blocks::ApproachKind;
use blockms::coordinator::{ClusterMode, Schedule};
use blockms::util::fmt::{ratio, secs, Table};

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.1);
    let workload = Workload::new(HERO_SIZE, scale, 42);
    let mut runner = Runner::new();

    // ---- 1. worker scaling curve per approach ---------------------------
    let mut t = Table::new(format!(
        "Worker scaling, k=4, {} at scale {scale} (speedup vs 1 worker)",
        HERO_SIZE.label()
    ))
    .header(&["Approach", "w=1", "w=2", "w=4", "w=6", "w=8", "w=16"]);
    for kind in ApproachKind::ALL {
        let shape = hero_shape(kind, scale);
        let mut cells = vec![kind.label().to_string()];
        for workers in [1usize, 2, 4, 6, 8, 16] {
            let mut cfg = ExperimentConfig::new(workload.clone(), shape, 4, workers);
            cfg.iters = 4;
            let row = runner.measure(&cfg)?;
            cells.push(ratio(row.speedup));
        }
        t.row(cells);
    }
    println!("{}", t.render());
    println!("note: ~5 blocks/plan caps useful workers at 5 — the paper's 8-core");
    println!("rows flatten for exactly this reason (granularity, not Amdahl).\n");

    // ---- 2. static vs dynamic scheduling --------------------------------
    let mut t = Table::new("Scheduling ablation (k=4, 4 workers, parallel seconds)")
        .header(&["Approach", "dynamic", "static", "static/dynamic"]);
    for kind in ApproachKind::ALL {
        let shape = hero_shape(kind, scale);
        let mut times = Vec::new();
        for schedule in [Schedule::Dynamic, Schedule::Static] {
            let mut cfg = ExperimentConfig::new(workload.clone(), shape, 4, 4);
            cfg.iters = 4;
            cfg.schedule = schedule;
            times.push(runner.measure(&cfg)?.parallel_secs);
        }
        t.row(vec![
            kind.label().to_string(),
            secs(times[0]),
            secs(times[1]),
            ratio(times[1] / times[0]),
        ]);
    }
    println!("{}", t.render());

    // ---- 3. disk model ---------------------------------------------------
    let mut t = Table::new("I/O model ablation (k=4, 4 workers, parallel seconds)")
        .header(&["Approach", "serialized disk", "parallel fs", "penalty"]);
    for kind in ApproachKind::ALL {
        let shape = hero_shape(kind, scale);
        let mut times = Vec::new();
        for disk in [true, false] {
            let mut cfg = ExperimentConfig::new(workload.clone(), shape, 4, 4);
            cfg.iters = 4;
            cfg.disk_serialized = disk;
            times.push(runner.measure(&cfg)?.parallel_secs);
        }
        t.row(vec![
            kind.label().to_string(),
            secs(times[0]),
            secs(times[1]),
            ratio(times[0] / times[1]),
        ]);
    }
    println!("{}", t.render());
    println!("column-shaped pays the largest serialized-I/O penalty (5x read");
    println!("amplification), matching the paper's Case 3 file-access analysis.\n");

    // ---- 4. global vs local mode ----------------------------------------
    let mut t = Table::new("Clustering mode (k=4, 4 workers)").header(&[
        "Mode",
        "parallel secs",
        "rounds",
    ]);
    for (label, mode) in [("global", ClusterMode::Global), ("local", ClusterMode::Local)] {
        let mut cfg = ExperimentConfig::new(
            workload.clone(),
            hero_shape(ApproachKind::Cols, scale),
            4,
            4,
        );
        cfg.iters = 4;
        cfg.mode = mode;
        let row = runner.measure(&cfg)?;
        t.row(vec![
            label.to_string(),
            secs(row.parallel_secs),
            if mode == ClusterMode::Global {
                "iters+1 barriers".into()
            } else {
                "1 barrier".into()
            },
        ]);
    }
    println!("{}", t.render());
    println!("local mode trades the per-iteration barrier for one round of");
    println!("independent block clusterings + centroid harmonization.");
    Ok(())
}
