//! The paper's §4 Cases 1–3: how block shape drives `blockproc` I/O.
//!
//! Demonstrates, with real counted strip reads, why block geometry
//! matters: square blocks re-read every strip ~4×, row-shaped blocks read
//! each strip once, column-shaped blocks read the whole file ~5× — and
//! yet column-shaped wins on wall time once compute dominates, because
//! its partial blocks balance best (the paper's §4 punchline).
//!
//! ```sh
//! cargo run --release --offline --example block_shape_analysis -- [scale]
//! ```

use std::sync::Arc;

use blockms::bench::cases::{render_cases, run_cases};
use blockms::bench::tables::{hero_shape, SweepOpts};
use blockms::bench::workloads::{Workload, HERO_SIZE};
use blockms::blocks::{ApproachKind, BlockPlan};
use blockms::coordinator::{ClusterConfig, Coordinator, CoordinatorConfig, IoMode};
use blockms::plan::ExecPlan;
use blockms::stripstore::read_amplification;
use blockms::util::fmt::{ratio, Table};

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0.1);

    // ---- closed-form geometry at FULL paper size ------------------------
    println!("Closed-form strip-read analysis at full 4656x5793 (strips of 64 rows):");
    let mut t = Table::new("").header(&[
        "Case",
        "Block size",
        "Blocks",
        "Strip reads",
        "Amplification",
    ]);
    for (case, kind) in [
        ("Case 1 (square)", ApproachKind::Square),
        ("Case 2 (row)", ApproachKind::Rows),
        ("Case 3 (column)", ApproachKind::Cols),
    ] {
        let shape = hero_shape(kind, 1.0);
        let plan = BlockPlan::new(5793, 4656, shape);
        let (reads, strips, amp) = read_amplification(&plan, 64);
        t.row(vec![
            case.to_string(),
            format!("{:?}", shape.block_dims(5793, 4656)),
            plan.len().to_string(),
            format!("{reads} (of {strips} strips)"),
            ratio(amp),
        ]);
    }
    println!("{}", t.render());
    println!("paper: square reads every strip 4x, row 1x, column reads the file 5x\n");

    // ---- measured: real strip stores + replayed elapsed times ----------
    println!("Measured (scale {scale}): strip reads counted on a real strip store,");
    println!("elapsed = measured per-block costs replayed at 2/4/8 workers:\n");
    let opts = SweepOpts {
        scale,
        ..Default::default()
    };
    let results = run_cases(&opts)?;
    print!("{}", render_cases(&results));

    // the paper's conclusion: column-shaped is the best case overall
    let col = results
        .iter()
        .find(|r| r.approach == ApproachKind::Cols)
        .unwrap();
    let fastest_4w = results
        .iter()
        .min_by(|a, b| a.elapsed[1].partial_cmp(&b.elapsed[1]).unwrap())
        .unwrap();
    println!(
        "\nfastest at 4 workers: {} ({}s); column-shaped: {}s",
        fastest_4w.label,
        ratio(fastest_4w.elapsed[1]),
        ratio(col.elapsed[1])
    );

    // ---- bonus: wall-clock of a real strip-backed run ------------------
    let workload = Workload::new(HERO_SIZE, scale, 1);
    let img = Arc::new(workload.generate());
    let coord = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(hero_shape(ApproachKind::Cols, scale)).with_workers(2),
        io: IoMode::Strips {
            strip_rows: 32,
            file_backed: true, // a real file on disk, seek+read per strip
        },
        ..Default::default()
    });
    let out = coord.cluster(&img, &ClusterConfig::default())?;
    let io = out.io_stats.unwrap();
    println!(
        "\nfile-backed run: {} blocks, {} strip reads, {:.1} MiB transferred, {:.1} ms",
        out.blocks,
        io.strip_reads,
        io.bytes_read as f64 / (1024.0 * 1024.0),
        out.total_secs * 1e3
    );
    Ok(())
}
