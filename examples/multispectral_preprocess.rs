//! Future-work extension (paper §5): multispectral classification.
//!
//! The paper's conclusion proposes applying the approach "for the
//! classification of multispectral images". This example runs the full
//! preprocessing + clustering pipeline on a **4-band** multispectral
//! scene, exercising every substrate beyond the RGB happy path:
//!
//! 1. synthesize a 4-band scene with ground truth;
//! 2. denoise it with a parallel **sliding-neighborhood** median filter
//!    (the other `blockproc` mode, §3 of the paper) over a column plan;
//! 3. min-max normalize the bands;
//! 4. cluster with parallel block K-Means (native engine — the AOT
//!    artifacts are compiled for C=3; DESIGN.md notes the C=4 variant as
//!    a one-line `aot.py` change);
//! 5. score against ground truth (purity / ARI / Davies-Bouldin) for
//!    both global and local modes.
//!
//! ```sh
//! cargo run --release --offline --example multispectral_preprocess
//! ```

use std::sync::Arc;

use blockms::blocks::sliding::{MedianFilter, PadMethod};
use blockms::blocks::{sliding_apply, BlockPlan, BlockShape};
use blockms::coordinator::{ClusterConfig, ClusterMode, Coordinator, CoordinatorConfig};
use blockms::image::{ops, SyntheticOrtho};
use blockms::metrics::quality;
use blockms::plan::ExecPlan;
use blockms::util::fmt::{duration, ratio, Table};

fn main() -> anyhow::Result<()> {
    let (h, w) = (360, 480);
    let classes = 4;

    // 1. a 4-band multispectral scene (think B/G/R/NIR) with truth
    let gen = SyntheticOrtho::default()
        .with_seed(2024)
        .with_channels(4)
        .with_classes(classes);
    let (noisy, truth) = gen.generate_with_truth(h, w);
    println!(
        "scene: {h}x{w}, {} bands, {} truth classes",
        noisy.channels(),
        classes
    );

    // 2. parallel sliding-neighborhood median denoise (3x3, symmetric pad)
    let filter_plan = BlockPlan::new(h, w, BlockShape::Cols { band_cols: w / 5 + 1 });
    let t0 = std::time::Instant::now();
    let denoised = sliding_apply(
        &noisy,
        &filter_plan,
        &MedianFilter { window: 3 },
        PadMethod::Symmetric,
        4,
    );
    println!(
        "median 3x3 over {} blocks with 4 workers: {}",
        filter_plan.len(),
        duration(t0.elapsed().as_secs_f64())
    );

    // 3. per-band min-max normalization to [0, 255]
    let prepped = Arc::new(ops::normalize(&denoised, 255.0));

    // 4 + 5. cluster in both modes and score
    let shape = BlockShape::paper_default(blockms::blocks::ApproachKind::Cols, h, w);
    let mut table = Table::new("Multispectral clustering quality (k = truth classes)").header(&[
        "Mode",
        "Purity",
        "ARI",
        "Davies-Bouldin",
        "Time",
    ]);
    let mut raw_scores = Vec::new();
    for (label, mode) in [("global", ClusterMode::Global), ("local", ClusterMode::Local)] {
        let coord = Coordinator::new(CoordinatorConfig {
            exec: ExecPlan::pinned(shape).with_workers(4),
            mode,
            ..Default::default()
        });
        let cfg = ClusterConfig {
            k: classes,
            ..Default::default()
        };
        let out = coord.cluster(&prepped, &cfg)?;
        let p = quality::purity(&out.labels, &truth);
        let ari = quality::adjusted_rand_sampled(&out.labels, &truth, 20_000);
        let db = quality::davies_bouldin(
            prepped.as_pixels(),
            &out.labels,
            &out.centroids,
            classes,
            prepped.channels(),
        );
        table.row(vec![
            label.to_string(),
            ratio(p),
            ratio(ari),
            ratio(db),
            duration(out.total_secs),
        ]);
        raw_scores.push((label, p, ari));
    }
    println!("\n{}", table.render());

    // denoising should help: compare against clustering the raw scene
    let raw = Arc::new(ops::normalize(&noisy, 255.0));
    let coord = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(shape).with_workers(4),
        ..Default::default()
    });
    let out_raw = coord.cluster(
        &raw,
        &ClusterConfig {
            k: classes,
            ..Default::default()
        },
    )?;
    let p_raw = quality::purity(&out_raw.labels, &truth);
    let (_, p_denoised, _) = raw_scores[0];
    println!(
        "denoising effect on purity: raw {} -> median-filtered {}",
        ratio(p_raw),
        ratio(p_denoised)
    );
    anyhow::ensure!(
        p_denoised >= p_raw - 0.02,
        "median filtering should not hurt purity"
    );
    println!("✓ multispectral pipeline complete");
    Ok(())
}
