//! `cargo bench` entry point (criterion is not vendored offline, so this
//! is a self-contained harness on `blockms::metrics` + `blockms::util`).
//!
//! Two tiers:
//!
//! 1. **micro** — steady-state throughput of every hot-path component
//!    (native/PJRT kernel step, block crop, strip reads, assembly,
//!    coordinator end-to-end, scene generation);
//! 2. **paper** — regenerates every table (1–19) and the Cases 1–3
//!    analysis at bench scale, printing the paper-shaped rows. These are
//!    the `cargo bench` analogues of the paper's entire evaluation
//!    section; `blockms paper-tables --scale 1` reproduces them at full
//!    size.
//!
//! Filter by substring: `cargo bench -- micro` or `cargo bench -- table12`.
//! Scale override: `BLOCKMS_BENCH_SCALE=0.25 cargo bench -- paper`.

use std::sync::Arc;

use blockms::bench::cases::{render_cases, render_kernel_cases, run_cases, run_kernel_cases};
use blockms::bench::kernels::{render_kernel_bench, write_kernel_bench, KernelBenchOpts};
use blockms::bench::tables::{all_table_ids, run_table, SweepOpts};
use blockms::blocks::{BlockPlan, BlockShape};
use blockms::coordinator::{ClusterConfig, Coordinator, CoordinatorConfig, Engine};
use blockms::image::SyntheticOrtho;
use blockms::kmeans::math;
use blockms::metrics::time_n;
use blockms::plan::ExecPlan;
use blockms::runtime::{find_artifacts_dir, ArtifactSet, KernelEngine};
use blockms::stripstore::{Backing, StripStore};
use blockms::util::prng::Rng;
use blockms::util::stats::Summary;

struct Bench {
    filter: Option<String>,
}

impl Bench {
    fn new() -> Bench {
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with("--"))
            .map(|s| s.to_lowercase());
        Bench { filter }
    }

    fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.to_lowercase().contains(f),
            None => true,
        }
    }

    /// Run `f` `samples` times after warmup; print a summary line.
    fn run(&self, name: &str, samples: usize, mut f: impl FnMut()) {
        if !self.enabled(name) {
            return;
        }
        for _ in 0..2 {
            f(); // warmup
        }
        let times = time_n(samples, &mut f);
        let s = Summary::of(&times);
        println!(
            "bench {name:<44} median {:>12} mean {:>12} ±{:>10} (n={})",
            fmt_t(s.median),
            fmt_t(s.mean),
            fmt_t(s.stddev),
            s.count
        );
    }

    /// Throughput variant: prints M items/sec based on the median.
    fn run_throughput(
        &self,
        name: &str,
        samples: usize,
        items: usize,
        unit: &str,
        mut f: impl FnMut(),
    ) {
        if !self.enabled(name) {
            return;
        }
        for _ in 0..2 {
            f();
        }
        let times = time_n(samples, &mut f);
        let s = Summary::of(&times);
        println!(
            "bench {name:<44} median {:>12} | {:>9.2} M{unit}/s (n={})",
            fmt_t(s.median),
            items as f64 / s.median / 1e6,
            s.count
        );
    }
}

fn fmt_t(secs: f64) -> String {
    blockms::util::fmt::duration(secs)
}

fn main() {
    let b = Bench::new();
    println!("== blockms bench suite (1-core container; see DESIGN.md §5) ==\n");

    micro_kernels(&b);
    kernel_matrix(&b);
    layout_matrix(&b);
    plan_matrix(&b);
    micro_substrates(&b);
    micro_coordinator(&b);
    paper_tables(&b);
    paper_cases(&b);
    paper_kernel_cases(&b);
}

// --------------------------------------------------------------------------
// tier 1: micro benches
// --------------------------------------------------------------------------

fn micro_kernels(b: &Bench) {
    let mut rng = Rng::new(42);
    let n = 1 << 17; // 131072 pixels
    let px: Vec<f32> = (0..n * 3).map(|_| rng.next_f32() * 255.0).collect();
    let cen: Vec<f32> = (0..4 * 3).map(|_| rng.next_f32() * 255.0).collect();

    b.run_throughput("micro/native_step_131k_px_k4", 15, n, "px", || {
        std::hint::black_box(math::step(&px, &cen, 4, 3));
    });

    let mut labels = Vec::new();
    b.run_throughput("micro/native_assign_131k_px_k4", 15, n, "px", || {
        std::hint::black_box(math::assign_all(&px, &cen, 4, 3, &mut labels));
    });

    // One-pass accum+labels vs the two passes above: the fused kernel
    // should land near the step cost alone, not step + assign.
    let mut fused_labels = Vec::new();
    b.run_throughput("micro/native_fused_step_assign_131k_px_k4", 15, n, "px", || {
        std::hint::black_box(blockms::kmeans::kernel::fused_step_assign(
            &px,
            &cen,
            4,
            3,
            &mut fused_labels,
        ));
    });

    if !cfg!(feature = "pjrt") {
        println!("bench micro/pjrt_* skipped (built without the `pjrt` feature)");
    } else if let Some(dir) = find_artifacts_dir() {
        let set = ArtifactSet::load(dir).expect("artifacts");
        let mut eng = KernelEngine::load(&set, 4).expect("engine");
        b.run_throughput("micro/pjrt_step_131k_px_k4", 10, n, "px", || {
            std::hint::black_box(eng.step_block(&px, &cen).unwrap());
        });
        let mut l2 = Vec::new();
        b.run_throughput("micro/pjrt_assign_131k_px_k4", 10, n, "px", || {
            std::hint::black_box(eng.assign_block(&px, &cen, &mut l2).unwrap());
        });
    } else {
        println!("bench micro/pjrt_* skipped (no artifacts; run `make artifacts`)");
    }
}

/// Naive vs pruned vs fused step-round throughput at the acceptance
/// configuration (1024×1024, k ∈ {2, 4}), written to
/// `BENCH_kernels.json` so later PRs have a trajectory to regress
/// against. `BLOCKMS_KERNEL_SIDE` overrides the image side.
fn kernel_matrix(b: &Bench) {
    let name = "kernels/naive_vs_pruned_vs_fused_1024";
    if !b.enabled(name) {
        return;
    }
    let side = std::env::var("BLOCKMS_KERNEL_SIDE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1024usize)
        .clamp(64, 8192);
    let opts = KernelBenchOpts {
        height: side,
        width: side,
        ..Default::default()
    };
    let out = std::path::Path::new("BENCH_kernels.json");
    match write_kernel_bench(out, &opts) {
        Ok(rows) => {
            println!("bench {name}:");
            print!("{}", render_kernel_bench(&opts, &rows));
            println!("wrote {}", out.display());
        }
        Err(e) => println!("bench {name} FAILED: {e:#}"),
    }
}

/// `BENCH_layout.json`: the interleaved-vs-SoA × kernel × block-shape
/// acceptance matrix at 1024² (EXPERIMENTS.md §Layout).
/// `BLOCKMS_LAYOUT_SIDE` overrides the image side.
fn layout_matrix(b: &Bench) {
    use blockms::bench::layout::{render_layout_bench, write_layout_bench, LayoutBenchOpts};
    let name = "layout/interleaved_vs_soa_1024";
    if !b.enabled(name) {
        return;
    }
    let side = std::env::var("BLOCKMS_LAYOUT_SIDE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1024usize)
        .clamp(64, 8192);
    let opts = LayoutBenchOpts {
        height: side,
        width: side,
        ..Default::default()
    };
    let out = std::path::Path::new("BENCH_layout.json");
    match write_layout_bench(out, &opts) {
        Ok(rows) => {
            println!("bench {name}:");
            print!("{}", render_layout_bench(&opts, &rows));
            println!("wrote {}", out.display());
        }
        Err(e) => println!("bench {name} FAILED: {e:#}"),
    }
}

/// `BENCH_plan.json`: planner-predicted vs measured cost and
/// pick-vs-best-of-grid regret over the paper's shapes × k ∈ {2, 4, 8}
/// at 1024² (EXPERIMENTS.md §Planner). `BLOCKMS_PLAN_SIDE` overrides
/// the image side.
fn plan_matrix(b: &Bench) {
    use blockms::bench::plan::{render_plan_bench, write_plan_bench, PlanBenchOpts};
    let name = "plan/regret_vs_best_of_grid_1024";
    if !b.enabled(name) {
        return;
    }
    let side = std::env::var("BLOCKMS_PLAN_SIDE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1024usize)
        .clamp(64, 8192);
    let opts = PlanBenchOpts {
        height: side,
        width: side,
        ..Default::default()
    };
    let out = std::path::Path::new("BENCH_plan.json");
    match write_plan_bench(out, &opts) {
        Ok((model, rows)) => {
            println!("bench {name}:");
            print!("{}", render_plan_bench(&opts, &model, &rows));
            println!("wrote {}", out.display());
        }
        Err(e) => println!("bench {name} FAILED: {e:#}"),
    }
}

fn micro_substrates(b: &Bench) {
    let img = SyntheticOrtho::default().with_seed(1).generate(1024, 1024);

    b.run("micro/synthetic_generate_512x512", 8, || {
        std::hint::black_box(SyntheticOrtho::default().with_seed(2).generate(512, 512));
    });

    let plan = BlockPlan::new(1024, 1024, BlockShape::Square { side: 256 });
    let mut buf = Vec::new();
    b.run_throughput("micro/crop_16_blocks_1Mpx", 20, 1 << 20, "px", || {
        for r in plan.iter() {
            img.crop_into(r, &mut buf);
            std::hint::black_box(buf.len());
        }
    });

    let store = StripStore::new(&img, 64, Backing::Memory).unwrap();
    let mut reader = store.reader().unwrap();
    b.run_throughput("micro/stripstore_mem_read_1Mpx", 20, 1 << 20, "px", || {
        for r in plan.iter() {
            reader.read_block(r, &mut buf).unwrap();
            std::hint::black_box(buf.len());
        }
    });

    let dir = std::env::temp_dir().join("blockms_bench_strips");
    let fstore = StripStore::new(&img, 64, Backing::File(dir)).unwrap();
    let mut freader = fstore.reader().unwrap();
    b.run_throughput("micro/stripstore_file_read_1Mpx", 10, 1 << 20, "px", || {
        for r in plan.iter() {
            freader.read_block(r, &mut buf).unwrap();
            std::hint::black_box(buf.len());
        }
    });

    use blockms::blocks::LabelAssembler;
    let block_labels: Vec<Vec<u32>> = plan.iter().map(|r| vec![1u32; r.area()]).collect();
    b.run_throughput("micro/assemble_1Mpx", 20, 1 << 20, "px", || {
        let mut asm = LabelAssembler::new(1024, 1024);
        for (r, l) in plan.iter().zip(&block_labels) {
            asm.place(r, l).unwrap();
        }
        std::hint::black_box(asm.finish().unwrap().len());
    });
}

fn micro_coordinator(b: &Bench) {
    let img = Arc::new(SyntheticOrtho::default().with_seed(3).generate(512, 512));
    let shape = BlockShape::Cols { band_cols: 103 };
    let coord = Coordinator::new(CoordinatorConfig {
        exec: ExecPlan::pinned(shape).with_workers(4),
        ..Default::default()
    });
    let cfg = ClusterConfig {
        k: 4,
        fixed_iters: Some(3),
        ..Default::default()
    };
    b.run("micro/coordinator_e2e_512px_3iters_4w", 8, || {
        std::hint::black_box(coord.cluster(&img, &cfg).unwrap());
    });

    if cfg!(feature = "pjrt") && find_artifacts_dir().is_some() {
        let coord_pjrt = Coordinator::new(CoordinatorConfig {
            exec: ExecPlan::pinned(shape).with_workers(2),
            engine: Engine::Pjrt {
                artifacts_dir: None,
            },
            ..Default::default()
        });
        b.run("micro/coordinator_e2e_pjrt_512px_3iters_2w", 3, || {
            std::hint::black_box(coord_pjrt.cluster(&img, &cfg).unwrap());
        });
    }

    b.run("micro/seq_kmeans_512px_3iters", 8, || {
        let c = coord.serial(&img, &cfg).unwrap();
        std::hint::black_box(c.inertia);
    });
}

// --------------------------------------------------------------------------
// tier 2: the paper's evaluation
// --------------------------------------------------------------------------

fn bench_scale() -> f64 {
    std::env::var("BLOCKMS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.12)
}

fn paper_tables(b: &Bench) {
    let opts = SweepOpts {
        scale: bench_scale(),
        ..Default::default()
    };
    for id in all_table_ids() {
        let name = format!("paper/table{id:02}");
        if !b.enabled(&name) {
            continue;
        }
        let t0 = std::time::Instant::now();
        match run_table(id, &opts) {
            Ok(text) => {
                println!("bench {name} ({:.2}s):", t0.elapsed().as_secs_f64());
                println!("{text}");
            }
            Err(e) => println!("bench {name} FAILED: {e:#}"),
        }
    }
}

fn paper_cases(b: &Bench) {
    let name = "paper/cases1-3";
    if !b.enabled(name) {
        return;
    }
    let opts = SweepOpts {
        scale: bench_scale(),
        ..Default::default()
    };
    match run_cases(&opts) {
        Ok(results) => {
            println!("bench {name}:");
            print!("{}", render_cases(&results));
        }
        Err(e) => println!("bench {name} FAILED: {e:#}"),
    }
}

/// Naive vs pruned vs fused through the real coordinator at the paper's
/// three block shapes (Cases 1–3 geometry).
fn paper_kernel_cases(b: &Bench) {
    let name = "paper/kernel-cases";
    if !b.enabled(name) {
        return;
    }
    let opts = SweepOpts {
        scale: bench_scale(),
        ..Default::default()
    };
    for k in [2usize, 4] {
        match run_kernel_cases(&opts, k, 4) {
            Ok(results) => {
                println!("bench {name} (k={k}):");
                print!("{}", render_kernel_cases(&results, k));
            }
            Err(e) => println!("bench {name} (k={k}) FAILED: {e:#}"),
        }
    }
}
