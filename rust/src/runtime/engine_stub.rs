//! Offline stand-in for the PJRT kernel engine (build without the
//! `pjrt` cargo feature). Mirrors the public API of `engine.rs`;
//! [`KernelEngine::load`] fails with a clear message and the type is
//! uninhabited, so every other method is statically unreachable.

use anyhow::{bail, Result};

use super::manifest::{ArtifactKind, ArtifactSet};
use crate::kmeans::math::StepAccum;

/// Uninhabited stub for the PJRT engine.
pub struct KernelEngine {
    never: std::convert::Infallible,
}

impl KernelEngine {
    pub fn load(_set: &ArtifactSet, _k: usize) -> Result<KernelEngine> {
        bail!("this build has no PJRT support (rebuild with `--features pjrt`)")
    }

    pub fn precompile(&mut self, _kinds: &[ArtifactKind]) -> Result<()> {
        match self.never {}
    }

    pub fn k(&self) -> usize {
        match self.never {}
    }

    pub fn chunk(&self) -> usize {
        match self.never {}
    }

    pub fn channels(&self) -> usize {
        match self.never {}
    }

    pub fn local_iters(&self) -> usize {
        match self.never {}
    }

    pub fn step_block(&mut self, _pixels: &[f32], _centroids: &[f32]) -> Result<StepAccum> {
        match self.never {}
    }

    pub fn assign_block(
        &mut self,
        _pixels: &[f32],
        _centroids: &[f32],
        _labels: &mut Vec<u32>,
    ) -> Result<f64> {
        match self.never {}
    }

    pub fn local_block(
        &mut self,
        _pixels: &[f32],
        _init_centroids: &[f32],
        _labels: &mut Vec<u32>,
    ) -> Result<(Vec<f32>, f64)> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let Some(dir) = super::super::find_artifacts_dir() else {
            // No artifacts anywhere: exercise the error path through a
            // manifest that cannot exist.
            return;
        };
        if let Ok(set) = ArtifactSet::load(&dir) {
            let err = KernelEngine::load(&set, 2).unwrap_err();
            assert!(err.to_string().contains("pjrt"), "{err}");
        }
    }
}
