//! Artifact manifest: what `python/compile/aot.py` produced.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};
use sha2::{Digest, Sha256};

use crate::util::json::Json;

/// One tensor's shape+dtype as declared by the manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("tensor spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// The three graph kinds the AOT path emits per K.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    Assign,
    Step,
    Local,
}

impl ArtifactKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ArtifactKind::Assign => "assign",
            ArtifactKind::Step => "step",
            ArtifactKind::Local => "local",
        }
    }

    fn parse(s: &str) -> Result<ArtifactKind> {
        match s {
            "assign" => Ok(ArtifactKind::Assign),
            "step" => Ok(ArtifactKind::Step),
            "local" => Ok(ArtifactKind::Local),
            other => bail!("unknown artifact kind {other:?}"),
        }
    }
}

/// One artifact's metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    pub k: usize,
    pub chunk: usize,
    pub channels: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: String,
}

/// The parsed `manifest.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub chunk: usize,
    pub channels: usize,
    pub local_iters: usize,
    pub ks: Vec<usize>,
    by_key: BTreeMap<(String, usize), ArtifactMeta>,
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src).context("manifest.json")?;
        let format = j
            .get("format")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing format"))?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let chunk = req_usize(&j, "chunk")?;
        let channels = req_usize(&j, "channels")?;
        let local_iters = req_usize(&j, "local_iters")?;
        let ks = j
            .get("ks")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing ks"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad k")))
            .collect::<Result<Vec<_>>>()?;
        let mut by_key = BTreeMap::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = req_str(a, "name")?.to_string();
            let meta = ArtifactMeta {
                name: name.clone(),
                file: req_str(a, "file")?.to_string(),
                kind: ArtifactKind::parse(req_str(a, "kind")?)?,
                k: req_usize(a, "k")?,
                chunk: req_usize(a, "chunk")?,
                channels: req_usize(a, "channels")?,
                inputs: specs(a, "inputs")?,
                outputs: specs(a, "outputs")?,
                sha256: req_str(a, "sha256")?.to_string(),
            };
            let key = (meta.kind.as_str().to_string(), meta.k);
            if by_key.insert(key, meta).is_some() {
                bail!("duplicate artifact for kind/k in manifest: {name}");
            }
        }
        let m = Manifest {
            chunk,
            channels,
            local_iters,
            ks,
            by_key,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        for &k in &self.ks {
            for kind in ["assign", "step", "local"] {
                let meta = self
                    .by_key
                    .get(&(kind.to_string(), k))
                    .ok_or_else(|| anyhow!("manifest missing {kind}_k{k}"))?;
                if meta.chunk != self.chunk || meta.channels != self.channels {
                    bail!("artifact {} disagrees with manifest chunk/channels", meta.name);
                }
                // input 0 is always pixels[chunk, channels]
                let px = &meta.inputs[0];
                if px.shape != [self.chunk, self.channels] {
                    bail!(
                        "artifact {}: pixels shape {:?} != [{}, {}]",
                        meta.name,
                        px.shape,
                        self.chunk,
                        self.channels
                    );
                }
            }
        }
        Ok(())
    }

    pub fn artifact(&self, kind: ArtifactKind, k: usize) -> Result<&ArtifactMeta> {
        self.by_key
            .get(&(kind.as_str().to_string(), k))
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for kind={} k={k} (have ks={:?}) — re-run `make artifacts`",
                    kind.as_str(),
                    self.ks
                )
            })
    }

    pub fn artifacts(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.by_key.values()
    }
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("manifest missing/invalid {key:?}"))
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest missing/invalid {key:?}"))
}

fn specs(j: &Json, key: &str) -> Result<Vec<TensorSpec>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("manifest missing {key:?}"))?
        .iter()
        .map(TensorSpec::from_json)
        .collect()
}

/// A manifest bound to its on-disk directory, with integrity checking.
#[derive(Clone, Debug)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl ArtifactSet {
    /// Load `dir/manifest.json` and verify every artifact file's SHA-256
    /// matches — a stale or hand-edited artifact directory fails fast
    /// instead of producing silently wrong clusters.
    pub fn load(dir: impl Into<PathBuf>) -> Result<ArtifactSet> {
        let dir = dir.into();
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`?)", path.display()))?;
        let manifest = Manifest::parse(&src)?;
        for meta in manifest.artifacts() {
            let fpath = dir.join(&meta.file);
            let text = std::fs::read(&fpath)
                .with_context(|| format!("read artifact {}", fpath.display()))?;
            let digest = hex(&Sha256::digest(&text));
            if digest != meta.sha256 {
                bail!(
                    "artifact {} is stale (sha256 {digest} != manifest {}) — re-run `make artifacts`",
                    meta.file,
                    meta.sha256
                );
            }
        }
        Ok(ArtifactSet { dir, manifest })
    }

    pub fn hlo_path(&self, kind: ArtifactKind, k: usize) -> Result<PathBuf> {
        Ok(self.dir.join(&self.manifest.artifact(kind, k)?.file))
    }
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// Locate the artifacts dir: `$BLOCKMS_ARTIFACTS`, else walk up from cwd
/// looking for `artifacts/manifest.json`.
pub fn find_artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("BLOCKMS_ARTIFACTS") {
        return Some(PathBuf::from(p));
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join(super::DEFAULT_ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "format": 1, "chunk": 64, "channels": 3, "local_iters": 8, "ks": [2],
      "artifacts": [
        {"name": "assign_k2", "file": "assign_k2.hlo.txt", "kind": "assign",
         "k": 2, "chunk": 64, "channels": 3,
         "inputs": [{"shape": [64,3], "dtype": "float32"},
                     {"shape": [2,3], "dtype": "float32"}],
         "outputs": [{"shape": [64], "dtype": "int32"},
                      {"shape": [64], "dtype": "float32"}],
         "sha256": "x"},
        {"name": "step_k2", "file": "step_k2.hlo.txt", "kind": "step",
         "k": 2, "chunk": 64, "channels": 3,
         "inputs": [{"shape": [64,3], "dtype": "float32"},
                     {"shape": [64], "dtype": "float32"},
                     {"shape": [2,3], "dtype": "float32"}],
         "outputs": [{"shape": [2,3], "dtype": "float32"},
                      {"shape": [2], "dtype": "float32"},
                      {"shape": [], "dtype": "float32"}],
         "sha256": "x"},
        {"name": "local_k2", "file": "local_k2.hlo.txt", "kind": "local",
         "k": 2, "chunk": 64, "channels": 3,
         "inputs": [{"shape": [64,3], "dtype": "float32"},
                     {"shape": [64], "dtype": "float32"},
                     {"shape": [2,3], "dtype": "float32"}],
         "outputs": [{"shape": [2,3], "dtype": "float32"},
                      {"shape": [64], "dtype": "int32"},
                      {"shape": [], "dtype": "float32"}],
         "sha256": "x"}
      ]
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.chunk, 64);
        assert_eq!(m.ks, vec![2]);
        let a = m.artifact(ArtifactKind::Step, 2).unwrap();
        assert_eq!(a.file, "step_k2.hlo.txt");
        assert_eq!(a.inputs[2].shape, vec![2, 3]);
        assert_eq!(a.outputs[2].shape, Vec::<usize>::new());
        assert_eq!(a.outputs[2].elements(), 1);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(MINI).unwrap();
        let err = m.artifact(ArtifactKind::Step, 4).unwrap_err().to_string();
        assert!(err.contains("k=4"), "{err}");
    }

    #[test]
    fn incomplete_set_rejected() {
        let broken = MINI.replace(r#""kind": "local""#, r#""kind": "step""#);
        // now two step artifacts and no local -> duplicate or missing error
        assert!(Manifest::parse(&broken).is_err());
    }

    #[test]
    fn wrong_pixel_shape_rejected() {
        let broken = MINI.replace(r#""shape": [64,3]"#, r#""shape": [32,3]"#);
        let err = Manifest::parse(&broken).unwrap_err().to_string();
        assert!(err.contains("pixels shape"), "{err}");
    }

    #[test]
    fn bad_format_rejected() {
        let broken = MINI.replace(r#""format": 1"#, r#""format": 9"#);
        assert!(Manifest::parse(&broken).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // Integration-lite: if the repo's artifacts exist, they must load.
        if let Some(dir) = find_artifacts_dir() {
            let set = ArtifactSet::load(&dir).expect("repo artifacts must validate");
            assert!(set.manifest.ks.contains(&2));
            assert_eq!(set.manifest.channels, 3);
            let p = set.hlo_path(ArtifactKind::Assign, 2).unwrap();
            assert!(p.exists());
        }
    }
}
