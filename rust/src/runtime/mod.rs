//! Runtime: load and execute the AOT artifacts via PJRT.
//!
//! `make artifacts` (python, build-time) leaves `artifacts/*.hlo.txt` and
//! a `manifest.json`. At startup the rust side:
//!
//! 1. parses the manifest ([`Manifest`]) and validates artifact hashes,
//! 2. builds a `PjRtClient::cpu()` and compiles the HLO **text** modules
//!    ([`KernelEngine`]) — text, not serialized protos, because jax ≥ 0.5
//!    emits 64-bit instruction ids that xla_extension 0.5.1 rejects,
//! 3. streams arbitrary-size pixel buffers through the fixed-shape chunk
//!    executables, zero-masking the tail chunk.
//!
//! The PJRT client is `Rc`-based (`!Send`), so every worker thread builds
//! its **own** engine from a cheap [`BackendSpec`] — exactly the MATLAB
//! parpool model the paper uses (each worker is an independent session).
//! [`ComputeBackend`] abstracts over the PJRT engine and the pure-rust
//! [`NativeBackend`] so the coordinator is engine-agnostic.
//!
//! The PJRT path needs the external `xla` bindings, which cannot be
//! fetched in offline builds, so it is gated behind the off-by-default
//! `pjrt` cargo feature. Without it, [`KernelEngine`] is an uninhabited
//! stub whose `load` fails with a clear message — everything native
//! (the default engine everywhere) is unaffected.

mod backend;
#[cfg(feature = "pjrt")]
mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
mod engine;
mod manifest;

pub use backend::{BackendSpec, ComputeBackend, NativeBackend};
pub use engine::KernelEngine;
pub use manifest::{find_artifacts_dir, ArtifactKind, ArtifactMeta, ArtifactSet, Manifest, TensorSpec};

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";
