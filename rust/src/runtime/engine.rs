//! PJRT execution of the AOT kernels: the L3→L2/L1 bridge.

use anyhow::{anyhow, Context, Result};

use super::manifest::{ArtifactKind, ArtifactSet};
use crate::kmeans::math::{self, StepAccum};

/// A compiled set of kernels for one cluster count `k`: `assign`, `step`
/// and `local`, plus the chunking logic that streams arbitrary-size
/// blocks through the fixed-shape executables.
///
/// `!Send` by construction (the PJRT client is `Rc`-based); each worker
/// thread builds its own engine — see [`super::BackendSpec`].
pub struct KernelEngine {
    client: xla::PjRtClient,
    set: ArtifactSet,
    chunk: usize,
    channels: usize,
    k: usize,
    local_iters: usize,
    /// Lazily compiled executables (indexed Assign/Step/Local): global
    /// mode never touches `local`, local mode rarely touches `assign` —
    /// compiling on first use cuts worker startup by ~1/3 per unused
    /// kind (EXPERIMENTS.md §Perf).
    exes: [Option<xla::PjRtLoadedExecutable>; 3],
    /// Scratch: padded chunk pixels / mask (reused across calls).
    px_scratch: Vec<f32>,
    mask_scratch: Vec<f32>,
    /// Cached all-ones mask device buffer — every non-tail chunk reuses
    /// it instead of re-uploading 64 KiB per call (EXPERIMENTS.md §Perf).
    ones_mask: Option<xla::PjRtBuffer>,
}

impl KernelEngine {
    /// Compile the three artifacts for cluster count `k` on a fresh CPU
    /// PJRT client.
    pub fn load(set: &ArtifactSet, k: usize) -> Result<KernelEngine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        // validate the k is served before any lazy compile can fail later
        for kind in [ArtifactKind::Assign, ArtifactKind::Step, ArtifactKind::Local] {
            set.manifest.artifact(kind, k)?;
        }
        let m = &set.manifest;
        Ok(KernelEngine {
            client,
            set: set.clone(),
            chunk: m.chunk,
            channels: m.channels,
            k,
            local_iters: m.local_iters,
            exes: [None, None, None],
            px_scratch: vec![0.0; m.chunk * m.channels],
            mask_scratch: vec![0.0; m.chunk],
            ones_mask: None,
        })
    }

    /// Get (compiling on first use) the executable for `kind`.
    fn exe(&mut self, kind: ArtifactKind) -> Result<&xla::PjRtLoadedExecutable> {
        let idx = match kind {
            ArtifactKind::Assign => 0,
            ArtifactKind::Step => 1,
            ArtifactKind::Local => 2,
        };
        if self.exes[idx].is_none() {
            let path = self.set.hlo_path(kind, self.k)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.exes[idx] = Some(
                self.client
                    .compile(&comp)
                    .with_context(|| format!("compile {}", path.display()))?,
            );
        }
        Ok(self.exes[idx].as_ref().unwrap())
    }

    /// Eagerly compile the kinds a mode will need (called under the
    /// warmup barrier so the cost lands in `spawn_secs`, not in rounds).
    pub fn precompile(&mut self, kinds: &[ArtifactKind]) -> Result<()> {
        for &kind in kinds {
            self.exe(kind)?;
        }
        Ok(())
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    pub fn local_iters(&self) -> usize {
        self.local_iters
    }

    /// Stage one chunk (pixels + mask) into the scratch buffers.
    /// `px` holds `valid` pixels (`valid <= chunk`); the tail is
    /// zero-padded with mask 0.
    fn stage_chunk(&mut self, px: &[f32], valid: usize) {
        debug_assert_eq!(px.len(), valid * self.channels);
        debug_assert!(valid <= self.chunk);
        self.px_scratch[..px.len()].copy_from_slice(px);
        self.px_scratch[px.len()..].fill(0.0);
        self.mask_scratch[..valid].fill(1.0);
        self.mask_scratch[valid..].fill(0.0);
    }

    /// Stage the scratch pixel chunk as a device buffer — a single
    /// host→device transfer (the earlier Literal path did copy-to-literal
    /// + reshape + transfer; EXPERIMENTS.md §Perf).
    fn px_buffer(&self) -> Result<xla::PjRtBuffer> {
        Ok(self
            .client
            .buffer_from_host_buffer(&self.px_scratch, &[self.chunk, self.channels], None)?)
    }

    fn mask_buffer(&self) -> Result<xla::PjRtBuffer> {
        Ok(self
            .client
            .buffer_from_host_buffer(&self.mask_scratch, &[self.chunk], None)?)
    }

    /// Make sure the shared all-ones mask buffer exists (uploaded once;
    /// every non-tail chunk reuses it).
    fn ensure_ones_mask(&mut self) -> Result<()> {
        if self.ones_mask.is_none() {
            let ones = vec![1.0f32; self.chunk];
            self.ones_mask =
                Some(self.client.buffer_from_host_buffer(&ones, &[self.chunk], None)?);
        }
        Ok(())
    }

    fn centroid_buffer(&self, centroids: &[f32]) -> Result<xla::PjRtBuffer> {
        anyhow::ensure!(
            centroids.len() == self.k * self.channels,
            "centroid buffer {} != {}x{}",
            centroids.len(),
            self.k,
            self.channels
        );
        Ok(self
            .client
            .buffer_from_host_buffer(centroids, &[self.k, self.channels], None)?)
    }

    /// One Lloyd accumulation pass over a block's pixels (any length).
    /// Equivalent to [`math::step`]; chunks are streamed through the
    /// fixed-shape `step` executable and reduced in f64.
    pub fn step_block(&mut self, pixels: &[f32], centroids: &[f32]) -> Result<StepAccum> {
        anyhow::ensure!(pixels.len() % self.channels == 0, "ragged pixel buffer");
        let mut acc = StepAccum::zeros(self.k, self.channels);
        let cen = self.centroid_buffer(centroids)?;
        let per_chunk = self.chunk * self.channels;
        let n = pixels.len() / self.channels;
        let mut off = 0;
        while off < n {
            let valid = (n - off).min(self.chunk);
            let src = &pixels[off * self.channels..][..valid * self.channels];
            let outs = if valid == self.chunk {
                // full chunk: upload straight from the caller's slice and
                // reuse the cached all-ones mask (no scratch memcpy, no
                // mask re-upload)
                self.ensure_ones_mask()?;
                let px_buf_dev = self.client.buffer_from_host_buffer(
                    src,
                    &[self.chunk, self.channels],
                    None,
                )?;
                self.exe(ArtifactKind::Step)?;
                let mask_buf = self.ones_mask.as_ref().unwrap();
                let exe = self.exes[1].as_ref().unwrap();
                let result = exe
                    .execute_b::<&xla::PjRtBuffer>(&[&px_buf_dev, mask_buf, &cen])
                    .context("execute")?;
                result[0][0]
                    .to_literal_sync()
                    .context("fetch result")?
                    .to_tuple()
                    .context("untuple result")?
            } else {
                self.stage_chunk(src, valid);
                self.exe(ArtifactKind::Step)?;
                let px_buf_dev = self.px_buffer()?;
                let mask_buf = self.mask_buffer()?;
                let exe = self.exes[1].as_ref().unwrap();
                let result = exe
                    .execute_b::<&xla::PjRtBuffer>(&[&px_buf_dev, &mask_buf, &cen])
                    .context("execute")?;
                result[0][0]
                    .to_literal_sync()
                    .context("fetch result")?
                    .to_tuple()
                    .context("untuple result")?
            };
            anyhow::ensure!(outs.len() == 3, "step returned {} outputs", outs.len());
            let sums: Vec<f32> = outs[0].to_vec()?;
            let counts: Vec<f32> = outs[1].to_vec()?;
            let inertia: f32 = outs[2].get_first_element()?;
            for (a, b) in acc.sums.iter_mut().zip(&sums) {
                *a += *b as f64;
            }
            for (a, b) in acc.counts.iter_mut().zip(&counts) {
                *a += b.round() as u64;
            }
            acc.inertia += inertia as f64;
            off += valid;
            let _ = per_chunk;
        }
        Ok(acc)
    }

    /// Assign every pixel of a block; appends labels, returns inertia.
    pub fn assign_block(
        &mut self,
        pixels: &[f32],
        centroids: &[f32],
        labels: &mut Vec<u32>,
    ) -> Result<f64> {
        anyhow::ensure!(pixels.len() % self.channels == 0, "ragged pixel buffer");
        let cen = self.centroid_buffer(centroids)?;
        let n = pixels.len() / self.channels;
        labels.clear();
        labels.reserve(n);
        let mut inertia = 0.0f64;
        let mut off = 0;
        while off < n {
            let valid = (n - off).min(self.chunk);
            let src = &pixels[off * self.channels..][..valid * self.channels];
            let px_buf_dev = if valid == self.chunk {
                // full chunk: upload straight from the caller's slice
                self.client
                    .buffer_from_host_buffer(src, &[self.chunk, self.channels], None)?
            } else {
                self.stage_chunk(src, valid);
                self.px_buffer()?
            };
            self.exe(ArtifactKind::Assign)?;
            let exe = self.exes[0].as_ref().unwrap();
            let outs = {
                let result = exe
                    .execute_b::<&xla::PjRtBuffer>(&[&px_buf_dev, &cen])
                    .context("execute")?;
                result[0][0]
                    .to_literal_sync()
                    .context("fetch result")?
                    .to_tuple()
                    .context("untuple result")?
            };
            anyhow::ensure!(outs.len() == 2, "assign returned {} outputs", outs.len());
            let chunk_labels: Vec<i32> = outs[0].to_vec()?;
            let min_d2: Vec<f32> = outs[1].to_vec()?;
            for &l in &chunk_labels[..valid] {
                anyhow::ensure!((l as usize) < self.k, "label {l} out of range");
                labels.push(l as u32);
            }
            inertia += min_d2[..valid].iter().map(|&d| d as f64).sum::<f64>();
            off += valid;
        }
        Ok(inertia)
    }

    /// Full per-block local K-Means (`local_iters` Lloyd iterations +
    /// final assignment). Blocks that fit in one chunk run entirely
    /// inside the fused `local` executable; larger blocks compose
    /// [`Self::step_block`] + [`math::update_centroids`] on the host —
    /// mathematically identical (tested).
    pub fn local_block(
        &mut self,
        pixels: &[f32],
        init_centroids: &[f32],
        labels: &mut Vec<u32>,
    ) -> Result<(Vec<f32>, f64)> {
        anyhow::ensure!(pixels.len() % self.channels == 0, "ragged pixel buffer");
        let n = pixels.len() / self.channels;
        if n <= self.chunk {
            // fused path
            self.stage_chunk(pixels, n);
            let cen = self.centroid_buffer(init_centroids)?;
            let px_buf_dev = self.px_buffer()?;
            let mask_buf = self.mask_buffer()?;
            self.exe(ArtifactKind::Local)?;
            let exe = self.exes[2].as_ref().unwrap();
            let outs = {
                let result = exe
                    .execute_b::<&xla::PjRtBuffer>(&[&px_buf_dev, &mask_buf, &cen])
                    .context("execute")?;
                result[0][0]
                    .to_literal_sync()
                    .context("fetch result")?
                    .to_tuple()
                    .context("untuple result")?
            };
            anyhow::ensure!(outs.len() == 3, "local returned {} outputs", outs.len());
            let centroids: Vec<f32> = outs[0].to_vec()?;
            let chunk_labels: Vec<i32> = outs[1].to_vec()?;
            let inertia: f32 = outs[2].get_first_element()?;
            labels.clear();
            for &l in &chunk_labels[..n] {
                anyhow::ensure!((l as usize) < self.k, "label {l} out of range");
                labels.push(l as u32);
            }
            Ok((centroids, inertia as f64))
        } else {
            // composed path: host-side Lloyd loop over chunked steps
            let mut centroids = init_centroids.to_vec();
            for _ in 0..self.local_iters {
                let acc = self.step_block(pixels, &centroids)?;
                math::update_centroids(&acc, &mut centroids, 0.0);
            }
            let inertia = self.assign_block(pixels, &centroids, labels)?;
            Ok((centroids, inertia))
        }
    }
}

#[cfg(test)]
mod tests {
    //! Cross-layer integration: the AOT artifacts must reproduce the
    //! pure-rust oracle exactly (labels) / to f32 rounding (sums).
    //! Skipped silently when `artifacts/` is absent (pre-`make artifacts`).

    use super::*;
    use crate::runtime::manifest::find_artifacts_dir;
    use crate::util::prng::Rng;

    fn engine(k: usize) -> Option<KernelEngine> {
        let dir = find_artifacts_dir()?;
        let set = ArtifactSet::load(dir).ok()?;
        Some(KernelEngine::load(&set, k).expect("engine must load"))
    }

    fn rand_pixels(n: usize, channels: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * channels).map(|_| rng.next_f32() * 255.0).collect()
    }

    #[test]
    fn step_block_matches_oracle() {
        let Some(mut eng) = engine(4) else { return };
        let c = eng.channels();
        // deliberately not a chunk multiple: exercises tail masking
        let px = rand_pixels(eng.chunk() + 777, c, 1);
        let cen = rand_pixels(4, c, 2);
        let got = eng.step_block(&px, &cen).unwrap();
        let want = math::step(&px, &cen, 4, c);
        assert_eq!(got.counts, want.counts);
        for (g, w) in got.sums.iter().zip(&want.sums) {
            assert!((g - w).abs() < 0.5 + w.abs() * 1e-4, "{g} vs {w}");
        }
        assert!(
            (got.inertia - want.inertia).abs() < want.inertia * 1e-3 + 1.0,
            "{} vs {}",
            got.inertia,
            want.inertia
        );
    }

    #[test]
    fn assign_block_matches_oracle() {
        let Some(mut eng) = engine(2) else { return };
        let c = eng.channels();
        let px = rand_pixels(5000, c, 3);
        let cen = rand_pixels(2, c, 4);
        let mut got_labels = Vec::new();
        let got_inertia = eng.assign_block(&px, &cen, &mut got_labels).unwrap();
        let mut want_labels = Vec::new();
        let want_inertia = math::assign_all(&px, &cen, 2, c, &mut want_labels);
        assert_eq!(got_labels, want_labels);
        assert!((got_inertia - want_inertia).abs() < want_inertia * 1e-3 + 1.0);
    }

    #[test]
    fn local_block_fused_and_composed_agree() {
        let Some(mut eng) = engine(2) else { return };
        let c = eng.channels();
        // small block -> fused path
        let px = rand_pixels(800, c, 5);
        let cen = rand_pixels(2, c, 6);
        let mut labels_fused = Vec::new();
        let (cen_fused, inertia_fused) =
            eng.local_block(&px, &cen, &mut labels_fused).unwrap();
        // composed path (host loop over the same math)
        let mut cen_host = cen.clone();
        for _ in 0..eng.local_iters() {
            let acc = math::step(&px, &cen_host, 2, c);
            math::update_centroids(&acc, &mut cen_host, 0.0);
        }
        let mut labels_host = Vec::new();
        let inertia_host = math::assign_all(&px, &cen_host, 2, c, &mut labels_host);
        assert_eq!(labels_fused, labels_host);
        for (a, b) in cen_fused.iter().zip(&cen_host) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
        assert!((inertia_fused - inertia_host).abs() < inertia_host * 1e-3 + 1.0);
    }

    #[test]
    fn centroid_size_mismatch_is_error() {
        let Some(mut eng) = engine(2) else { return };
        let px = rand_pixels(10, eng.channels(), 7);
        assert!(eng.step_block(&px, &[0.0; 3]).is_err());
    }
}
