//! Engine-agnostic compute interface for the coordinator.
//!
//! Worker threads cannot share a PJRT client (`Rc`-based, `!Send`), so the
//! coordinator ships each worker a cheap, `Send + Clone` [`BackendSpec`];
//! the worker *instantiates* its own [`ComputeBackend`] on its own thread
//! — the MATLAB-parpool model (independent per-worker sessions). Two
//! implementations:
//!
//! - [`KernelEngine`] (PJRT) — the real AOT-kernel path;
//! - [`NativeBackend`] — the pure-rust oracle math, used as the serial
//!   baseline's compute and for artifact-free tests. Both are verified to
//!   agree exactly on labels (see `engine.rs` tests).

use anyhow::Result;

use super::KernelEngine;
use super::manifest::ArtifactSet;
use crate::kmeans::kernel::{self, CentroidDrift, PrunedState};
use crate::kmeans::math::{self, StepAccum};
use crate::kmeans::simd::SimdMode;
use crate::kmeans::tile::SoaTile;

/// What the coordinator needs from a compute engine, per block.
pub trait ComputeBackend {
    /// One Lloyd accumulation pass over a block.
    fn step_block(&mut self, pixels: &[f32], centroids: &[f32]) -> Result<StepAccum>;

    /// Final assignment over a block; returns inertia.
    fn assign_block(
        &mut self,
        pixels: &[f32],
        centroids: &[f32],
        labels: &mut Vec<u32>,
    ) -> Result<f64>;

    /// One Lloyd accumulation pass with Hamerly pruning: `state` carries
    /// per-pixel bounds across rounds, `drift` is the movement of the
    /// update that produced `centroids`. Must return exactly what
    /// [`ComputeBackend::step_block`] would. The default implementation
    /// is the naive pass with the state invalidated — engines that
    /// cannot prune (PJRT runs fixed-shape artifacts) stay correct and
    /// simply never skip work.
    fn step_block_pruned(
        &mut self,
        pixels: &[f32],
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
    ) -> Result<StepAccum> {
        let _ = drift;
        state.clear();
        self.step_block(pixels, centroids)
    }

    /// Final assignment reusing the pruning bounds; must label exactly
    /// like [`ComputeBackend::assign_block`]. Default: the full scan.
    fn assign_block_pruned(
        &mut self,
        pixels: &[f32],
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
        labels: &mut Vec<u32>,
    ) -> Result<f64> {
        let _ = (state, drift);
        self.assign_block(pixels, centroids, labels)
    }

    /// One Lloyd accumulation pass of the lane kernel over a planar
    /// tile. Must return exactly what [`ComputeBackend::step_block`]
    /// would for the tile's interleaved view. The default rematerializes
    /// the interleaved buffer and runs the naive pass (never prunes) —
    /// engines without a planar path (PJRT artifacts are fixed-layout)
    /// stay correct and simply don't get the layout win.
    fn step_block_lanes(
        &mut self,
        tile: &SoaTile,
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
    ) -> Result<StepAccum> {
        let _ = drift;
        state.clear();
        let mut buf = Vec::new();
        tile.to_interleaved(&mut buf);
        self.step_block(&buf, centroids)
    }

    /// Final assignment of the lane kernel over a planar tile; must
    /// label exactly like [`ComputeBackend::assign_block`]. Default:
    /// rematerialize and full-scan.
    fn assign_block_lanes(
        &mut self,
        tile: &SoaTile,
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
        labels: &mut Vec<u32>,
    ) -> Result<f64> {
        let _ = drift;
        state.clear();
        let mut buf = Vec::new();
        tile.to_interleaved(&mut buf);
        self.assign_block(&buf, centroids, labels)
    }

    /// One Lloyd accumulation pass of the native-SIMD kernel at the
    /// plan's dispatched [`SimdMode`]. Contract and default mirror
    /// [`ComputeBackend::step_block_lanes`]: engines without a SIMD path
    /// rematerialize and stay correct.
    fn step_block_simd(
        &mut self,
        tile: &SoaTile,
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
        mode: SimdMode,
    ) -> Result<StepAccum> {
        let _ = (drift, mode);
        state.clear();
        let mut buf = Vec::new();
        tile.to_interleaved(&mut buf);
        self.step_block(&buf, centroids)
    }

    /// Final assignment of the native-SIMD kernel; must label exactly
    /// like [`ComputeBackend::assign_block`] when `mode.fma` is off.
    /// Default: rematerialize and full-scan.
    fn assign_block_simd(
        &mut self,
        tile: &SoaTile,
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
        labels: &mut Vec<u32>,
        mode: SimdMode,
    ) -> Result<f64> {
        let _ = (drift, mode);
        state.clear();
        let mut buf = Vec::new();
        tile.to_interleaved(&mut buf);
        self.assign_block(&buf, centroids, labels)
    }

    /// Independent per-block K-Means (`iters` fixed Lloyd iterations from
    /// `init_centroids`, then assignment). Returns `(centroids, inertia)`.
    fn local_block(
        &mut self,
        pixels: &[f32],
        init_centroids: &[f32],
        labels: &mut Vec<u32>,
    ) -> Result<(Vec<f32>, f64)>;

    /// Engine label for logs/tables.
    fn name(&self) -> &'static str;

    /// One-time startup work (e.g. compiling executables), invoked under
    /// the coordinator's warmup barrier so it lands in `spawn_secs`
    /// rather than in a timed round. `local_mode` hints which kernels the
    /// run will use.
    fn warm(&mut self, _local_mode: bool) -> Result<()> {
        Ok(())
    }
}

/// Serializable recipe for constructing a backend on a worker thread.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// Pure-rust math (no artifacts needed).
    Native { k: usize, channels: usize, local_iters: usize },
    /// PJRT engine over the AOT artifacts.
    Pjrt { artifacts_dir: std::path::PathBuf, k: usize },
}

impl BackendSpec {
    /// Instantiate on the current thread.
    pub fn build(&self) -> Result<Box<dyn ComputeBackend>> {
        match self {
            BackendSpec::Native {
                k,
                channels,
                local_iters,
            } => Ok(Box::new(NativeBackend::new(*k, *channels, *local_iters))),
            BackendSpec::Pjrt { artifacts_dir, k } => {
                let set = ArtifactSet::load(artifacts_dir)?;
                Ok(Box::new(PjrtBackend {
                    engine: KernelEngine::load(&set, *k)?,
                }))
            }
        }
    }

    pub fn k(&self) -> usize {
        match self {
            BackendSpec::Native { k, .. } => *k,
            BackendSpec::Pjrt { k, .. } => *k,
        }
    }
}

/// Pure-rust implementation (mirrors `ref.py` exactly).
#[derive(Clone, Debug)]
pub struct NativeBackend {
    k: usize,
    channels: usize,
    local_iters: usize,
}

impl NativeBackend {
    pub fn new(k: usize, channels: usize, local_iters: usize) -> NativeBackend {
        assert!(k >= 1 && channels >= 1 && local_iters >= 1);
        NativeBackend {
            k,
            channels,
            local_iters,
        }
    }
}

impl ComputeBackend for NativeBackend {
    fn step_block(&mut self, pixels: &[f32], centroids: &[f32]) -> Result<StepAccum> {
        Ok(math::step(pixels, centroids, self.k, self.channels))
    }

    fn assign_block(
        &mut self,
        pixels: &[f32],
        centroids: &[f32],
        labels: &mut Vec<u32>,
    ) -> Result<f64> {
        Ok(math::assign_all(
            pixels,
            centroids,
            self.k,
            self.channels,
            labels,
        ))
    }

    fn local_block(
        &mut self,
        pixels: &[f32],
        init_centroids: &[f32],
        labels: &mut Vec<u32>,
    ) -> Result<(Vec<f32>, f64)> {
        let mut centroids = init_centroids.to_vec();
        for _ in 0..self.local_iters {
            let acc = math::step(pixels, &centroids, self.k, self.channels);
            math::update_centroids(&acc, &mut centroids, 0.0);
        }
        let inertia = math::assign_all(pixels, &centroids, self.k, self.channels, labels);
        Ok((centroids, inertia))
    }

    fn step_block_pruned(
        &mut self,
        pixels: &[f32],
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
    ) -> Result<StepAccum> {
        Ok(kernel::step_pruned(
            pixels, centroids, self.k, self.channels, state, drift,
        ))
    }

    fn assign_block_pruned(
        &mut self,
        pixels: &[f32],
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
        labels: &mut Vec<u32>,
    ) -> Result<f64> {
        Ok(kernel::assign_pruned(
            pixels, centroids, self.k, self.channels, state, drift, labels,
        ))
    }

    fn step_block_lanes(
        &mut self,
        tile: &SoaTile,
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
    ) -> Result<StepAccum> {
        Ok(kernel::step_lanes(tile, centroids, self.k, state, drift))
    }

    fn assign_block_lanes(
        &mut self,
        tile: &SoaTile,
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
        labels: &mut Vec<u32>,
    ) -> Result<f64> {
        Ok(kernel::assign_lanes(
            tile, centroids, self.k, state, drift, labels,
        ))
    }

    fn step_block_simd(
        &mut self,
        tile: &SoaTile,
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
        mode: SimdMode,
    ) -> Result<StepAccum> {
        Ok(kernel::step_simd(tile, centroids, self.k, state, drift, mode))
    }

    fn assign_block_simd(
        &mut self,
        tile: &SoaTile,
        centroids: &[f32],
        state: &mut PrunedState,
        drift: Option<&CentroidDrift>,
        labels: &mut Vec<u32>,
        mode: SimdMode,
    ) -> Result<f64> {
        Ok(kernel::assign_simd(
            tile, centroids, self.k, state, drift, labels, mode,
        ))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

struct PjrtBackend {
    engine: KernelEngine,
}

impl ComputeBackend for PjrtBackend {
    fn step_block(&mut self, pixels: &[f32], centroids: &[f32]) -> Result<StepAccum> {
        self.engine.step_block(pixels, centroids)
    }

    fn assign_block(
        &mut self,
        pixels: &[f32],
        centroids: &[f32],
        labels: &mut Vec<u32>,
    ) -> Result<f64> {
        self.engine.assign_block(pixels, centroids, labels)
    }

    fn local_block(
        &mut self,
        pixels: &[f32],
        init_centroids: &[f32],
        labels: &mut Vec<u32>,
    ) -> Result<(Vec<f32>, f64)> {
        self.engine.local_block(pixels, init_centroids, labels)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn warm(&mut self, local_mode: bool) -> Result<()> {
        use super::manifest::ArtifactKind::{Assign, Local, Step};
        if local_mode {
            self.engine.precompile(&[Local, Step, Assign])
        } else {
            self.engine.precompile(&[Step, Assign])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn pixels(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * 3).map(|_| rng.next_f32() * 255.0).collect()
    }

    #[test]
    fn native_spec_builds_and_computes() {
        let spec = BackendSpec::Native {
            k: 2,
            channels: 3,
            local_iters: 4,
        };
        let mut be = spec.build().unwrap();
        assert_eq!(be.name(), "native");
        let px = pixels(100, 1);
        let cen = pixels(2, 2);
        let acc = be.step_block(&px, &cen).unwrap();
        assert_eq!(acc.total_count(), 100);
        let want = math::step(&px, &cen, 2, 3);
        assert_eq!(acc, want);
    }

    #[test]
    fn native_local_runs_fixed_iters() {
        let mut be = NativeBackend::new(2, 3, 8);
        let px = pixels(500, 3);
        let cen = pixels(2, 4);
        let mut labels = Vec::new();
        let (final_cen, inertia) = be.local_block(&px, &cen, &mut labels).unwrap();
        assert_eq!(final_cen.len(), 6);
        assert_eq!(labels.len(), 500);
        assert!(inertia > 0.0);
        // running it again from the same init is deterministic
        let mut labels2 = Vec::new();
        let (c2, i2) = be.local_block(&px, &cen, &mut labels2).unwrap();
        assert_eq!(final_cen, c2);
        assert_eq!(inertia, i2);
        assert_eq!(labels, labels2);
    }

    #[test]
    fn native_pruned_rounds_equal_naive_rounds() {
        use crate::kmeans::kernel::{drift_between, PrunedState};
        let mut be = NativeBackend::new(4, 3, 1);
        let px = pixels(800, 31);
        let mut cen = pixels(4, 32);
        let mut state = PrunedState::new();
        let mut drift = None;
        for _ in 0..5 {
            let want = be.step_block(&px, &cen).unwrap();
            let got = be
                .step_block_pruned(&px, &cen, &mut state, drift.as_ref())
                .unwrap();
            assert_eq!(got, want);
            let prev = cen.clone();
            math::update_centroids(&want, &mut cen, 0.0);
            drift = Some(drift_between(&prev, &cen, 4, 3));
        }
        let (mut la, mut lb) = (Vec::new(), Vec::new());
        let ia = be
            .assign_block_pruned(&px, &cen, &mut state, drift.as_ref(), &mut la)
            .unwrap();
        let ib = be.assign_block(&px, &cen, &mut lb).unwrap();
        assert_eq!(la, lb);
        assert_eq!(ia, ib);
    }

    #[test]
    fn native_lanes_rounds_equal_naive_rounds() {
        use crate::kmeans::kernel::{drift_between, PrunedState};
        let mut be = NativeBackend::new(4, 3, 1);
        let px = pixels(800, 51);
        let tile = SoaTile::from_interleaved(&px, 3);
        let mut cen = pixels(4, 52);
        let mut state = PrunedState::new();
        let mut drift = None;
        for _ in 0..5 {
            let want = be.step_block(&px, &cen).unwrap();
            let got = be
                .step_block_lanes(&tile, &cen, &mut state, drift.as_ref())
                .unwrap();
            assert_eq!(got, want);
            let prev = cen.clone();
            math::update_centroids(&want, &mut cen, 0.0);
            drift = Some(drift_between(&prev, &cen, 4, 3));
        }
        let (mut la, mut lb) = (Vec::new(), Vec::new());
        let ia = be
            .assign_block_lanes(&tile, &cen, &mut state, drift.as_ref(), &mut la)
            .unwrap();
        let ib = be.assign_block(&px, &cen, &mut lb).unwrap();
        assert_eq!(la, lb);
        assert_eq!(ia, ib);
    }

    #[test]
    fn native_simd_rounds_equal_naive_rounds() {
        use crate::kmeans::kernel::{drift_between, PrunedState};
        let mut be = NativeBackend::new(4, 3, 1);
        let px = pixels(800, 71);
        let tile = SoaTile::from_interleaved(&px, 3);
        let mut cen = pixels(4, 72);
        let mode = SimdMode::detected();
        let mut state = PrunedState::new();
        let mut drift = None;
        for _ in 0..5 {
            let want = be.step_block(&px, &cen).unwrap();
            let got = be
                .step_block_simd(&tile, &cen, &mut state, drift.as_ref(), mode)
                .unwrap();
            assert_eq!(got, want);
            let prev = cen.clone();
            math::update_centroids(&want, &mut cen, 0.0);
            drift = Some(drift_between(&prev, &cen, 4, 3));
        }
        let (mut la, mut lb) = (Vec::new(), Vec::new());
        let ia = be
            .assign_block_simd(&tile, &cen, &mut state, drift.as_ref(), &mut la, mode)
            .unwrap();
        let ib = be.assign_block(&px, &cen, &mut lb).unwrap();
        assert_eq!(la, lb);
        assert_eq!(ia, ib);
    }

    #[test]
    fn default_lanes_fallback_rematerializes_exactly() {
        // A backend that only implements the required methods must still
        // satisfy the lanes contract through the default rematerialize
        // path (this is what the PJRT engine gets).
        struct Minimal(NativeBackend);
        impl ComputeBackend for Minimal {
            fn step_block(&mut self, p: &[f32], c: &[f32]) -> Result<StepAccum> {
                self.0.step_block(p, c)
            }
            fn assign_block(
                &mut self,
                p: &[f32],
                c: &[f32],
                l: &mut Vec<u32>,
            ) -> Result<f64> {
                self.0.assign_block(p, c, l)
            }
            fn local_block(
                &mut self,
                p: &[f32],
                i: &[f32],
                l: &mut Vec<u32>,
            ) -> Result<(Vec<f32>, f64)> {
                self.0.local_block(p, i, l)
            }
            fn name(&self) -> &'static str {
                "minimal"
            }
        }
        let mut be = Minimal(NativeBackend::new(2, 3, 1));
        let px = pixels(321, 61);
        let tile = SoaTile::from_interleaved(&px, 3);
        let cen = pixels(2, 62);
        let mut state = crate::kmeans::kernel::PrunedState::new();
        let acc = be.step_block_lanes(&tile, &cen, &mut state, None).unwrap();
        assert_eq!(acc, math::step(&px, &cen, 2, 3));
        assert!(!state.ready(), "fallback must invalidate bounds");
        let mut labels = Vec::new();
        let inertia = be
            .assign_block_lanes(&tile, &cen, &mut state, None, &mut labels)
            .unwrap();
        let mut want = Vec::new();
        assert_eq!(inertia, math::assign_all(&px, &cen, 2, 3, &mut want));
        assert_eq!(labels, want);
        // and the simd defaults satisfy the same contract
        let acc = be
            .step_block_simd(&tile, &cen, &mut state, None, SimdMode::detected())
            .unwrap();
        assert_eq!(acc, math::step(&px, &cen, 2, 3));
        assert!(!state.ready(), "simd fallback must invalidate bounds");
        let mut sl = Vec::new();
        let si = be
            .assign_block_simd(&tile, &cen, &mut state, None, &mut sl, SimdMode::detected())
            .unwrap();
        assert_eq!(sl, want);
        assert_eq!(si, inertia);
    }

    #[test]
    fn spec_is_send_clone() {
        fn assert_send<T: Send + Clone>(_: &T) {}
        let spec = BackendSpec::Native {
            k: 2,
            channels: 3,
            local_iters: 1,
        };
        assert_send(&spec);
        assert_eq!(spec.k(), 2);
    }

    /// PJRT and native backends must agree bit-for-bit on labels
    /// (skipped when artifacts are absent).
    #[test]
    fn pjrt_and_native_agree() {
        let Some(dir) = super::super::manifest::find_artifacts_dir() else {
            return;
        };
        let pjrt_spec = BackendSpec::Pjrt {
            artifacts_dir: dir,
            k: 4,
        };
        let Ok(mut pjrt) = pjrt_spec.build() else { return };
        let mut native = NativeBackend::new(4, 3, 8);
        let px = pixels(3000, 9);
        let cen = pixels(4, 10);
        let (mut la, mut lb) = (Vec::new(), Vec::new());
        let ia = pjrt.assign_block(&px, &cen, &mut la).unwrap();
        let ib = native.assign_block(&px, &cen, &mut lb).unwrap();
        assert_eq!(la, lb);
        assert!((ia - ib).abs() < ib * 1e-3 + 1.0);
    }
}
