//! Discrete-event simulation of the worker pool — the speedup substrate.
//!
//! The paper measures speedup on a 4-core Xeon with 2/4/8 MATLAB workers.
//! This container has **one** CPU core, so multi-worker wall-clock cannot
//! show parallel speedup; per DESIGN.md §5 we substitute a *calibrated
//! replay*: per-block I/O and compute costs are **measured on the real
//! pipeline** (strip reads, AOT kernel execution), then replayed through
//! this deterministic list-scheduling simulator at any worker count.
//!
//! The model captures exactly the effects the paper's analysis attributes
//! timing differences to:
//!
//! - **load balance** — blocks are scheduled onto the first free worker
//!   (dynamic, like `parfor`) or round-robin (static); a plan whose block
//!   count divides the worker count poorly leaves workers idle at the
//!   tail (why 8 workers stop helping: the paper's plans have ~5 blocks);
//! - **serialized I/O** — strip reads contend on one disk: row-shaped
//!   plans read each strip once, square plans ~4×, column plans ~5×
//!   (Cases 1–3), so I/O-heavy shapes lose parallel efficiency;
//! - **serial fraction** — leader-side init / reduction / assembly time
//!   that no worker count amortizes (Amdahl).
//!
//! The simulator never *invents* parallelism: with one worker its
//! makespan equals the serial sum exactly (tested), and its makespan is
//! always bounded below by both the critical path and the work/worker
//! bound (property-tested).

use crate::coordinator::{RoundRecord, Schedule};

/// One block's replayable cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimBlock {
    pub io_secs: f64,
    pub compute_secs: f64,
}

impl SimBlock {
    pub fn total(&self) -> f64 {
        self.io_secs + self.compute_secs
    }
}

/// Simulation parameters.
#[derive(Clone, Debug)]
pub struct SimParams {
    pub workers: usize,
    pub schedule: Schedule,
    /// Whether block I/O serializes on a single disk (true reproduces
    /// `blockproc`-on-one-spindle; false models a parallel filesystem).
    pub disk_serialized: bool,
    /// Leader seconds added per round (reduction + dispatch).
    pub leader_secs_per_round: f64,
    /// Leader seconds added once per run (init + assembly).
    pub leader_secs_fixed: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            workers: 4,
            schedule: Schedule::Dynamic,
            disk_serialized: true,
            leader_secs_per_round: 0.0,
            leader_secs_fixed: 0.0,
        }
    }
}

/// Result of simulating one round.
#[derive(Clone, Debug)]
pub struct RoundSim {
    /// Barrier-to-barrier time for the round.
    pub makespan: f64,
    /// Per-worker busy time (io + compute attributed to it).
    pub busy: Vec<f64>,
    /// Total time blocks spent waiting for the disk.
    pub io_wait: f64,
}

impl RoundSim {
    /// Worker utilization: busy time / (workers × makespan).
    pub fn utilization(&self) -> f64 {
        if self.makespan == 0.0 {
            return 1.0;
        }
        self.busy.iter().sum::<f64>() / (self.busy.len() as f64 * self.makespan)
    }
}

/// The worker-pool simulator.
#[derive(Clone, Debug)]
pub struct WorkerSim {
    params: SimParams,
}

impl WorkerSim {
    pub fn new(params: SimParams) -> WorkerSim {
        assert!(params.workers > 0, "need at least one worker");
        WorkerSim { params }
    }

    pub fn params(&self) -> &SimParams {
        &self.params
    }

    /// Simulate one round (one barrier) over `blocks`, in queue order.
    pub fn round(&self, blocks: &[SimBlock]) -> RoundSim {
        let w = self.params.workers;
        let mut worker_free = vec![0.0f64; w];
        let mut busy = vec![0.0f64; w];
        let mut disk_free = 0.0f64;
        let mut io_wait = 0.0f64;

        for (i, b) in blocks.iter().enumerate() {
            // pick the worker
            let wi = match self.params.schedule {
                Schedule::Static => i % w,
                Schedule::Dynamic => {
                    // earliest-free worker; ties to lowest index
                    let mut best = 0;
                    for j in 1..w {
                        if worker_free[j] < worker_free[best] {
                            best = j;
                        }
                    }
                    best
                }
            };
            let start = worker_free[wi];
            let (io_start, io_end) = if self.params.disk_serialized {
                let s = start.max(disk_free);
                io_wait += s - start;
                disk_free = s + b.io_secs;
                (s, s + b.io_secs)
            } else {
                (start, start + b.io_secs)
            };
            let _ = io_start;
            let end = io_end + b.compute_secs;
            worker_free[wi] = end;
            busy[wi] += b.total();
        }
        RoundSim {
            makespan: worker_free.iter().cloned().fold(0.0, f64::max),
            busy,
            io_wait,
        }
    }

    /// Simulate a whole run: a sequence of rounds (each a barrier) plus
    /// leader overheads. Returns total simulated seconds.
    pub fn run(&self, rounds: &[Vec<SimBlock>]) -> f64 {
        let mut total = self.params.leader_secs_fixed;
        for blocks in rounds {
            total += self.round(blocks).makespan + self.params.leader_secs_per_round;
        }
        total
    }

    /// Replay a measured coordinator run ([`RoundRecord`]s carry real
    /// per-block costs) at this simulator's worker count.
    pub fn replay(&self, rounds: &[RoundRecord]) -> f64 {
        let sim_rounds: Vec<Vec<SimBlock>> = rounds
            .iter()
            .map(|r| {
                r.costs
                    .iter()
                    .map(|c| SimBlock {
                        io_secs: c.io_secs,
                        compute_secs: c.compute_secs,
                    })
                    .collect()
            })
            .collect();
        self.run(&sim_rounds)
    }
}

/// Serial reference time for the same blocks: one worker, no overlap.
pub fn serial_time(rounds: &[Vec<SimBlock>]) -> f64 {
    rounds
        .iter()
        .flat_map(|r| r.iter())
        .map(SimBlock::total)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blocks(costs: &[(f64, f64)]) -> Vec<SimBlock> {
        costs
            .iter()
            .map(|&(io_secs, compute_secs)| SimBlock {
                io_secs,
                compute_secs,
            })
            .collect()
    }

    #[test]
    fn one_worker_equals_serial_sum() {
        let bs = blocks(&[(0.1, 1.0), (0.2, 0.5), (0.05, 2.0)]);
        let sim = WorkerSim::new(SimParams {
            workers: 1,
            ..Default::default()
        });
        let r = sim.round(&bs);
        let serial: f64 = bs.iter().map(SimBlock::total).sum();
        assert!((r.makespan - serial).abs() < 1e-12);
        assert!((r.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn equal_blocks_scale_nearly_linearly() {
        // 8 equal compute-dominated blocks on 2/4 workers
        let bs = blocks(&[(0.001, 1.0); 8]);
        let serial: f64 = bs.iter().map(SimBlock::total).sum();
        for w in [2usize, 4] {
            let sim = WorkerSim::new(SimParams {
                workers: w,
                ..Default::default()
            });
            let r = sim.round(&bs);
            let speedup = serial / r.makespan;
            assert!(
                speedup > w as f64 * 0.95 && speedup <= w as f64 + 1e-9,
                "w={w}: speedup {speedup}"
            );
        }
    }

    #[test]
    fn tail_imbalance_caps_speedup() {
        // 5 equal blocks on 4 workers: makespan = 2 block times -> speedup 2.5
        let bs = blocks(&[(0.0, 1.0); 5]);
        let sim = WorkerSim::new(SimParams {
            workers: 4,
            ..Default::default()
        });
        let r = sim.round(&bs);
        assert!((r.makespan - 2.0).abs() < 1e-9, "makespan {}", r.makespan);
    }

    #[test]
    fn serialized_io_limits_io_bound_speedup() {
        // fully I/O-bound blocks cannot speed up at all on one disk
        let bs = blocks(&[(1.0, 0.0); 4]);
        let sim = WorkerSim::new(SimParams {
            workers: 4,
            ..Default::default()
        });
        let r = sim.round(&bs);
        assert!((r.makespan - 4.0).abs() < 1e-9);
        assert!(r.io_wait > 0.0);
        // ...but a parallel filesystem lets them overlap
        let sim_pfs = WorkerSim::new(SimParams {
            workers: 4,
            disk_serialized: false,
            ..Default::default()
        });
        assert!((sim_pfs.round(&bs).makespan - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dynamic_beats_static_on_skew() {
        // one huge block + small ones: static round-robin pins smalls
        // behind the big one on the same worker
        let bs = blocks(&[(0.0, 4.0), (0.0, 1.0), (0.0, 1.0), (0.0, 1.0), (0.0, 1.0)]);
        let dynamic = WorkerSim::new(SimParams {
            workers: 2,
            ..Default::default()
        })
        .round(&bs)
        .makespan;
        let fixed = WorkerSim::new(SimParams {
            workers: 2,
            schedule: Schedule::Static,
            ..Default::default()
        })
        .round(&bs)
        .makespan;
        assert!(dynamic <= fixed, "dynamic {dynamic} vs static {fixed}");
        assert!((dynamic - 4.0).abs() < 1e-9); // critical path = big block
        assert!((fixed - 6.0).abs() < 1e-9); // blocks 0,2,4 on worker 0
    }

    #[test]
    fn leader_overheads_added() {
        let bs = blocks(&[(0.0, 1.0)]);
        let sim = WorkerSim::new(SimParams {
            workers: 1,
            leader_secs_per_round: 0.5,
            leader_secs_fixed: 2.0,
            ..Default::default()
        });
        let total = sim.run(&[bs.clone(), bs]);
        assert!((total - (2.0 + 2.0 * 1.5)).abs() < 1e-12);
    }

    #[test]
    fn makespan_lower_bounds_hold() {
        // property: makespan >= max block total AND >= work/workers
        use crate::util::prng::Rng;
        let mut rng = Rng::new(77);
        for _ in 0..200 {
            let n = rng.range_usize(1, 20);
            let bs: Vec<SimBlock> = (0..n)
                .map(|_| SimBlock {
                    io_secs: rng.next_f64() * 0.2,
                    compute_secs: rng.next_f64(),
                })
                .collect();
            let w = rng.range_usize(1, 9);
            let sim = WorkerSim::new(SimParams {
                workers: w,
                disk_serialized: rng.next_f64() < 0.5,
                ..Default::default()
            });
            let r = sim.round(&bs);
            let work: f64 = bs.iter().map(SimBlock::total).sum();
            let cp = bs.iter().map(SimBlock::total).fold(0.0, f64::max);
            assert!(r.makespan >= cp - 1e-9, "below critical path");
            assert!(r.makespan >= work / w as f64 - 1e-9, "below work bound");
            assert!(r.makespan <= work + 1e-9, "above serial bound");
        }
    }
}
