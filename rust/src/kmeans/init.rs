//! Centroid initialization strategies.
//!
//! Both the sequential baseline and the parallel coordinator initialize
//! from the *same* deterministic draw for a given seed, so serial vs
//! parallel comparisons (every paper table) cluster identically and time
//! the same work.

use crate::util::prng::Rng;

use super::math::sqdist;

/// How initial centroids are chosen.
#[derive(Clone, Debug, PartialEq)]
pub enum InitMethod {
    /// `k` distinct pixels sampled uniformly (MATLAB `kmeans`'s 'sample').
    RandomSample,
    /// k-means++ (D² weighting) — better spreads, fewer iterations.
    PlusPlus,
    /// Explicit centroids (tests, resuming, paper-exact replication).
    Fixed(Vec<f32>),
}

impl InitMethod {
    /// Draw initial centroids from `pixels[P, C]`.
    pub fn centroids(
        &self,
        pixels: &[f32],
        k: usize,
        channels: usize,
        seed: u64,
    ) -> Vec<f32> {
        assert_eq!(pixels.len() % channels, 0);
        let n = pixels.len() / channels;
        assert!(n >= k, "cannot init {k} clusters from {n} pixels");
        match self {
            InitMethod::Fixed(c) => {
                assert_eq!(
                    c.len(),
                    k * channels,
                    "fixed centroids have wrong size: {} != {}*{}",
                    c.len(),
                    k,
                    channels
                );
                c.clone()
            }
            InitMethod::RandomSample => {
                let mut rng = Rng::new(seed);
                let idx = rng.sample_indices(n, k);
                let mut out = Vec::with_capacity(k * channels);
                for i in idx {
                    out.extend_from_slice(&pixels[i * channels..(i + 1) * channels]);
                }
                out
            }
            InitMethod::PlusPlus => plus_plus(pixels, k, channels, seed),
        }
    }
}

/// k-means++ seeding (Arthur & Vassilvitskii 2007).
fn plus_plus(pixels: &[f32], k: usize, channels: usize, seed: u64) -> Vec<f32> {
    let n = pixels.len() / channels;
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(k * channels);

    // First centre uniformly.
    let first = rng.range_usize(0, n);
    out.extend_from_slice(&pixels[first * channels..(first + 1) * channels]);

    // d2[i] = distance to nearest chosen centre.
    let mut d2: Vec<f32> = pixels
        .chunks_exact(channels)
        .map(|px| sqdist(px, &out[..channels]))
        .collect();

    for _ in 1..k {
        let total: f64 = d2.iter().map(|&d| d as f64).sum();
        let chosen = if total <= 0.0 {
            // all points coincide with chosen centres; fall back to uniform
            rng.range_usize(0, n)
        } else {
            let mut target = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &d) in d2.iter().enumerate() {
                target -= d as f64;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        let base = chosen * channels;
        let centre: Vec<f32> = pixels[base..base + channels].to_vec();
        out.extend_from_slice(&centre);
        for (i, px) in pixels.chunks_exact(channels).enumerate() {
            let d = sqdist(px, &centre);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pixels() -> Vec<f32> {
        // two tight groups around (0,0,0) and (100,100,100)
        let mut v = Vec::new();
        for i in 0..50 {
            let j = (i % 5) as f32 * 0.1;
            v.extend_from_slice(&[j, j, j]);
            v.extend_from_slice(&[100.0 + j, 100.0 + j, 100.0 + j]);
        }
        v
    }

    #[test]
    fn random_sample_is_deterministic_and_from_data() {
        let px = pixels();
        let a = InitMethod::RandomSample.centroids(&px, 4, 3, 7);
        let b = InitMethod::RandomSample.centroids(&px, 4, 3, 7);
        assert_eq!(a, b);
        for cen in a.chunks_exact(3) {
            let found = px.chunks_exact(3).any(|p| p == cen);
            assert!(found, "centroid {cen:?} not a data pixel");
        }
    }

    #[test]
    fn different_seed_different_draw() {
        let px = pixels();
        let a = InitMethod::RandomSample.centroids(&px, 4, 3, 1);
        let b = InitMethod::RandomSample.centroids(&px, 4, 3, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn plus_plus_spreads_across_groups() {
        let px = pixels();
        // with 2 centres on two far groups, ++ must pick one from each
        for seed in 0..20 {
            let c = InitMethod::PlusPlus.centroids(&px, 2, 3, seed);
            let lo = c.chunks_exact(3).filter(|p| p[0] < 50.0).count();
            assert_eq!(lo, 1, "seed {seed}: both centres in one group: {c:?}");
        }
    }

    #[test]
    fn plus_plus_handles_identical_points() {
        let px = vec![5.0f32; 30]; // 10 identical pixels
        let c = InitMethod::PlusPlus.centroids(&px, 3, 3, 1);
        assert_eq!(c.len(), 9);
        assert!(c.iter().all(|&v| v == 5.0));
    }

    #[test]
    fn fixed_passes_through() {
        let fixed = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let c = InitMethod::Fixed(fixed.clone()).centroids(&pixels(), 2, 3, 0);
        assert_eq!(c, fixed);
    }

    #[test]
    #[should_panic(expected = "wrong size")]
    fn fixed_size_checked() {
        InitMethod::Fixed(vec![1.0; 5]).centroids(&pixels(), 2, 3, 0);
    }

    #[test]
    #[should_panic(expected = "cannot init")]
    fn too_few_pixels_rejected() {
        InitMethod::RandomSample.centroids(&[1.0, 2.0, 3.0], 2, 3, 0);
    }
}
