//! Centroid initialization strategies.
//!
//! Both the sequential baseline and the parallel coordinator initialize
//! from the *same* deterministic draw for a given seed, so serial vs
//! parallel comparisons (every paper table) cluster identically and time
//! the same work.

use std::collections::HashMap;

use anyhow::{bail, ensure, Result};

use crate::util::prng::Rng;

use super::math::sqdist;

/// How initial centroids are chosen.
#[derive(Clone, Debug, PartialEq)]
pub enum InitMethod {
    /// `k` distinct pixels sampled uniformly (MATLAB `kmeans`'s 'sample').
    RandomSample,
    /// k-means++ (D² weighting) — better spreads, fewer iterations.
    PlusPlus,
    /// Explicit centroids (tests, resuming, paper-exact replication).
    Fixed(Vec<f32>),
}

impl InitMethod {
    /// Draw initial centroids from `pixels[P, C]`.
    pub fn centroids(
        &self,
        pixels: &[f32],
        k: usize,
        channels: usize,
        seed: u64,
    ) -> Vec<f32> {
        assert_eq!(pixels.len() % channels, 0);
        let n = pixels.len() / channels;
        assert!(n >= k, "cannot init {k} clusters from {n} pixels");
        match self {
            InitMethod::Fixed(c) => {
                assert_eq!(
                    c.len(),
                    k * channels,
                    "fixed centroids have wrong size: {} != {}*{}",
                    c.len(),
                    k,
                    channels
                );
                c.clone()
            }
            InitMethod::RandomSample => {
                let mut rng = Rng::new(seed);
                let idx = rng.sample_indices(n, k);
                let mut out = Vec::with_capacity(k * channels);
                for i in idx {
                    out.extend_from_slice(&pixels[i * channels..(i + 1) * channels]);
                }
                out
            }
            InitMethod::PlusPlus => plus_plus(pixels, k, channels, seed),
        }
    }
}

/// k-means++ seeding (Arthur & Vassilvitskii 2007).
fn plus_plus(pixels: &[f32], k: usize, channels: usize, seed: u64) -> Vec<f32> {
    let n = pixels.len() / channels;
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(k * channels);

    // First centre uniformly.
    let first = rng.range_usize(0, n);
    out.extend_from_slice(&pixels[first * channels..(first + 1) * channels]);

    // d2[i] = distance to nearest chosen centre.
    let mut d2: Vec<f32> = pixels
        .chunks_exact(channels)
        .map(|px| sqdist(px, &out[..channels]))
        .collect();

    for _ in 1..k {
        let total: f64 = d2.iter().map(|&d| d as f64).sum();
        let chosen = if total <= 0.0 {
            // all points coincide with chosen centres; fall back to uniform
            rng.range_usize(0, n)
        } else {
            let mut target = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &d) in d2.iter().enumerate() {
                target -= d as f64;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        let base = chosen * channels;
        let centre: Vec<f32> = pixels[base..base + channels].to_vec();
        out.extend_from_slice(&centre);
        for (i, px) in pixels.chunks_exact(channels).enumerate() {
            let d = sqdist(px, &centre);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    out
}

/// Single-pass streaming centroid initialization: the out-of-core
/// ingest feeds every decoded strip exactly once (in order) and the
/// sampler keeps only `k × channels` floats of state — `kmeans::init`
/// no longer needs the whole image resident.
///
/// Two sampling strategies:
///
/// - **Indexed** — when the pixel count is known up front (a header
///   always gives it), the [`InitMethod::RandomSample`] draw is made
///   *before* the pass ([`Rng::sample_indices_sparse`], same generator
///   calls as the dense draw) and the chosen pixels are captured as
///   they stream by. Bit-identical to the in-memory init — the root of
///   streamed-vs-in-memory run identity, pinned by tests.
/// - **Reservoir** — Algorithm R over the stream for sources whose
///   length is unknown. Deterministic in the seed, but a *different*
///   draw than `RandomSample`; only used when no header exists.
///
/// [`InitMethod::PlusPlus`] needs distances to every pixel per chosen
/// centre (k passes over the image) and is rejected for streaming;
/// [`InitMethod::Fixed`] passes through.
pub struct StreamInit {
    k: usize,
    channels: usize,
    /// Pixels consumed so far.
    seen: usize,
    kind: StreamKind,
}

enum StreamKind {
    Fixed(Vec<f32>),
    Indexed {
        /// pixel index → sample slot (distinct indices, one slot each).
        targets: HashMap<usize, usize>,
        slots: Vec<f32>,
        filled: usize,
        n: usize,
    },
    Reservoir {
        rng: Rng,
        slots: Vec<f32>,
    },
}

impl StreamInit {
    /// Build the sampler for `init`. `pixels` is the total pixel count
    /// when known (selects the bit-identical indexed strategy);
    /// `None` falls back to reservoir sampling.
    pub fn new(
        init: &InitMethod,
        k: usize,
        channels: usize,
        pixels: Option<usize>,
        seed: u64,
    ) -> Result<StreamInit> {
        ensure!(k >= 1 && channels >= 1, "degenerate init request");
        let kind = match init {
            InitMethod::Fixed(c) => {
                ensure!(
                    c.len() == k * channels,
                    "fixed centroids have wrong size: {} != {}*{}",
                    c.len(),
                    k,
                    channels
                );
                StreamKind::Fixed(c.clone())
            }
            InitMethod::RandomSample => match pixels {
                Some(n) => {
                    ensure!(n >= k, "cannot init {k} clusters from {n} pixels");
                    let idx = Rng::new(seed).sample_indices_sparse(n, k);
                    let targets = idx.into_iter().zip(0..k).collect();
                    StreamKind::Indexed {
                        targets,
                        slots: vec![0.0; k * channels],
                        filled: 0,
                        n,
                    }
                }
                None => StreamKind::Reservoir {
                    rng: Rng::new(seed),
                    slots: vec![0.0; k * channels],
                },
            },
            InitMethod::PlusPlus => bail!(
                "k-means++ needs the full image (k distance passes); \
                 use RandomSample for streaming ingestion"
            ),
        };
        Ok(StreamInit {
            k,
            channels,
            seen: 0,
            kind,
        })
    }

    /// Observe the next strip of interleaved samples (in stream order).
    pub fn feed(&mut self, strip: &[f32]) {
        assert_eq!(
            strip.len() % self.channels,
            0,
            "strip length {} not a multiple of channels={}",
            strip.len(),
            self.channels
        );
        let c = self.channels;
        match &mut self.kind {
            StreamKind::Fixed(_) => {}
            StreamKind::Indexed {
                targets,
                slots,
                filled,
                ..
            } => {
                for (off, px) in strip.chunks_exact(c).enumerate() {
                    if let Some(&slot) = targets.get(&(self.seen + off)) {
                        slots[slot * c..(slot + 1) * c].copy_from_slice(px);
                        *filled += 1;
                    }
                }
            }
            StreamKind::Reservoir { rng, slots } => {
                for (off, px) in strip.chunks_exact(c).enumerate() {
                    let m = self.seen + off;
                    if m < self.k {
                        slots[m * c..(m + 1) * c].copy_from_slice(px);
                    } else {
                        // Algorithm R: keep each prefix uniformly sampled.
                        let j = rng.range_usize(0, m + 1);
                        if j < self.k {
                            slots[j * c..(j + 1) * c].copy_from_slice(px);
                        }
                    }
                }
            }
        }
        self.seen += strip.len() / c;
    }

    /// The initial centroid table, `k × channels`.
    pub fn finish(self) -> Result<Vec<f32>> {
        match self.kind {
            StreamKind::Fixed(c) => Ok(c),
            StreamKind::Indexed {
                slots, filled, n, ..
            } => {
                ensure!(
                    self.seen == n && filled == self.k,
                    "stream ended at pixel {} of {n} with {filled}/{} samples captured",
                    self.seen,
                    self.k
                );
                Ok(slots)
            }
            StreamKind::Reservoir { slots, .. } => {
                ensure!(
                    self.seen >= self.k,
                    "cannot init {} clusters from {} streamed pixels",
                    self.k,
                    self.seen
                );
                Ok(slots)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pixels() -> Vec<f32> {
        // two tight groups around (0,0,0) and (100,100,100)
        let mut v = Vec::new();
        for i in 0..50 {
            let j = (i % 5) as f32 * 0.1;
            v.extend_from_slice(&[j, j, j]);
            v.extend_from_slice(&[100.0 + j, 100.0 + j, 100.0 + j]);
        }
        v
    }

    #[test]
    fn random_sample_is_deterministic_and_from_data() {
        let px = pixels();
        let a = InitMethod::RandomSample.centroids(&px, 4, 3, 7);
        let b = InitMethod::RandomSample.centroids(&px, 4, 3, 7);
        assert_eq!(a, b);
        for cen in a.chunks_exact(3) {
            let found = px.chunks_exact(3).any(|p| p == cen);
            assert!(found, "centroid {cen:?} not a data pixel");
        }
    }

    #[test]
    fn different_seed_different_draw() {
        let px = pixels();
        let a = InitMethod::RandomSample.centroids(&px, 4, 3, 1);
        let b = InitMethod::RandomSample.centroids(&px, 4, 3, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn plus_plus_spreads_across_groups() {
        let px = pixels();
        // with 2 centres on two far groups, ++ must pick one from each
        for seed in 0..20 {
            let c = InitMethod::PlusPlus.centroids(&px, 2, 3, seed);
            let lo = c.chunks_exact(3).filter(|p| p[0] < 50.0).count();
            assert_eq!(lo, 1, "seed {seed}: both centres in one group: {c:?}");
        }
    }

    #[test]
    fn plus_plus_handles_identical_points() {
        let px = vec![5.0f32; 30]; // 10 identical pixels
        let c = InitMethod::PlusPlus.centroids(&px, 3, 3, 1);
        assert_eq!(c.len(), 9);
        assert!(c.iter().all(|&v| v == 5.0));
    }

    #[test]
    fn fixed_passes_through() {
        let fixed = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let c = InitMethod::Fixed(fixed.clone()).centroids(&pixels(), 2, 3, 0);
        assert_eq!(c, fixed);
    }

    #[test]
    #[should_panic(expected = "wrong size")]
    fn fixed_size_checked() {
        InitMethod::Fixed(vec![1.0; 5]).centroids(&pixels(), 2, 3, 0);
    }

    #[test]
    #[should_panic(expected = "cannot init")]
    fn too_few_pixels_rejected() {
        InitMethod::RandomSample.centroids(&[1.0, 2.0, 3.0], 2, 3, 0);
    }

    fn stream_in_chunks(init: &InitMethod, px: &[f32], k: usize, c: usize, seed: u64, chunk_px: usize) -> Vec<f32> {
        let n = px.len() / c;
        let mut s = StreamInit::new(init, k, c, Some(n), seed).unwrap();
        for chunk in px.chunks(chunk_px * c) {
            s.feed(chunk);
        }
        s.finish().unwrap()
    }

    #[test]
    fn indexed_stream_init_is_bit_identical_to_random_sample() {
        let px = pixels();
        for seed in [0u64, 1, 7, 0xB10C] {
            let dense = InitMethod::RandomSample.centroids(&px, 4, 3, seed);
            for chunk in [1usize, 3, 10, 100] {
                let streamed =
                    stream_in_chunks(&InitMethod::RandomSample, &px, 4, 3, seed, chunk);
                assert_eq!(streamed, dense, "seed={seed} chunk={chunk}");
            }
        }
    }

    #[test]
    fn fixed_streams_through_and_plusplus_is_rejected() {
        let fixed = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let got = stream_in_chunks(&InitMethod::Fixed(fixed.clone()), &pixels(), 2, 3, 0, 5);
        assert_eq!(got, fixed);
        let err = StreamInit::new(&InitMethod::PlusPlus, 2, 3, Some(100), 0).unwrap_err();
        assert!(format!("{err:#}").contains("k-means++"), "{err:#}");
    }

    #[test]
    fn reservoir_is_deterministic_and_draws_data_pixels() {
        let px = pixels();
        let run = |chunk: usize| {
            let mut s = StreamInit::new(&InitMethod::RandomSample, 3, 3, None, 9).unwrap();
            for c in px.chunks(chunk * 3) {
                s.feed(c);
            }
            s.finish().unwrap()
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b, "reservoir must be deterministic in the seed");
        for cen in a.chunks_exact(3) {
            assert!(
                px.chunks_exact(3).any(|p| p == cen),
                "reservoir centroid {cen:?} not a data pixel"
            );
        }
    }

    #[test]
    fn short_stream_is_a_clean_error() {
        let mut s = StreamInit::new(&InitMethod::RandomSample, 2, 3, Some(100), 0).unwrap();
        s.feed(&[1.0; 30]); // only 10 of the promised 100 pixels
        assert!(s.finish().is_err());
        let mut s = StreamInit::new(&InitMethod::RandomSample, 4, 3, None, 0).unwrap();
        s.feed(&[1.0; 9]); // 3 pixels < k
        assert!(s.finish().is_err());
    }
}
