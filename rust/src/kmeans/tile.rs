//! Planar SoA tiles and the per-worker tile arena.
//!
//! The interleaved `pixels[P, C]` rectangle the readers produce is the
//! layout MATLAB's `blockproc` hands to `kmeans` — convenient, but the
//! worst shape for a vectorizer: every distance accumulates *across*
//! the C interleaved channels of one pixel. [`SoaTile`] deinterleaves a
//! block once into C contiguous **planes** so the lane kernels in
//! [`super::kernel`] can compute one channel's contribution for
//! [`LANES`] *pixels* at a time with unit-stride loads.
//!
//! Two layout guarantees the kernels rely on:
//!
//! - every plane starts on a **64-byte boundary** (one cache line, two
//!   AVX2 lanes) — planes live in one allocation, each padded to a
//!   whole number of cache lines;
//! - every plane is padded to a [`LANES`] multiple with zeros, so the
//!   lane loops never need a scalar remainder: the final group computes
//!   full-width and the **tail lanes are masked at emission** (their
//!   distances are computed but never written to labels, bounds, or
//!   accumulators — lanes are data-independent, so garbage-in stays
//!   contained).
//!
//! [`TileArena`] keeps tiles alive *across Lloyd rounds*: keyed by
//! `(job, block)`, filled once per job from the strip store, reused
//! every subsequent round (the seed re-read whole strip spans per block
//! per round), and LRU-evicted under a byte budget — an evicted or
//! over-budget tile simply spills back to the re-read path, trading I/O
//! for memory but never correctness.

use std::collections::HashMap;
use std::sync::Arc;

/// Fixed lane width of the array-SIMD kernels (`[f32; LANES]` = 256
/// bits — AVX2-sized, and two of them per 512-bit vector unit). Not
/// tunable at runtime: the kernels are monomorphic over it.
pub const LANES: usize = 8;

/// f32 elements per 64-byte cache line; plane lengths are padded to a
/// multiple of this so every plane in the shared allocation starts on a
/// line boundary. A multiple of [`LANES`].
const LINE_F32: usize = 16;

/// How block pixels are held across Lloyd rounds on the workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TileLayout {
    /// Interleaved `pixels[P, C]`, re-read from the block source every
    /// round (the seed behaviour; what MATLAB `blockproc` does).
    Interleaved,
    /// Planar [`SoaTile`]s in the per-worker [`TileArena`], filled once
    /// per job and reused across all rounds.
    Soa,
}

impl TileLayout {
    pub fn label(&self) -> &'static str {
        match self {
            TileLayout::Interleaved => "interleaved",
            TileLayout::Soa => "soa",
        }
    }
}

impl std::fmt::Display for TileLayout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl std::str::FromStr for TileLayout {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "interleaved" | "aos" => Ok(TileLayout::Interleaved),
            "soa" | "planar" => Ok(TileLayout::Soa),
            other => Err(format!("unknown layout {other:?} (want interleaved|soa)")),
        }
    }
}

/// One block's pixels, channel-deinterleaved into padded planes.
///
/// All planes share one `Vec<f32>`; `off` skips to the first 64-byte
/// boundary inside it (found with `align_offset` at construction — no
/// `unsafe`, no custom allocator). The 64-byte plane alignment and the
/// whole-cache-line plane stride are an **enforced invariant** (debug-
/// asserted at construction): the native-SIMD kernels rely on the
/// stride so that a [`GROUP_MAX`](super::simd::GROUP_MAX)-wide vector
/// load at any group start inside a plane is in bounds, and on the
/// alignment for full-speed AVX-512 loads. Correctness does not hinge
/// on alignment (the kernels use unaligned loads): in the theoretical
/// case where `align_offset` cannot align, the tile still works, just
/// slower — only the stride is load-bearing, and that always holds.
#[derive(Debug)]
pub struct SoaTile {
    n: usize,
    channels: usize,
    /// Plane stride: `n` rounded up to a whole number of cache lines.
    padded: usize,
    off: usize,
    buf: Vec<f32>,
}

impl SoaTile {
    /// Deinterleave `pixels[P, C]` into a fresh tile.
    pub fn from_interleaved(pixels: &[f32], channels: usize) -> SoaTile {
        assert!(channels >= 1, "channels must be >= 1");
        assert_eq!(
            pixels.len() % channels,
            0,
            "pixel buffer length {} is not a multiple of channels={channels}",
            pixels.len()
        );
        let n = pixels.len() / channels;
        let padded = n.div_ceil(LINE_F32) * LINE_F32;
        let mut buf = vec![0.0f32; padded * channels + LINE_F32];
        // `align_offset` is in units of f32 elements; 64-byte alignment
        // needs at most LINE_F32 - 1 of the over-allocated elements.
        let (off, aligned) = match buf.as_ptr().align_offset(64) {
            usize::MAX => (0, false), // cannot align here: correct, just slower
            elems => (elems, true),
        };
        debug_assert!(off < LINE_F32);
        // Enforced invariants of the plane layout (see the type docs):
        // whole-cache-line stride always; 64-byte plane starts whenever
        // the allocation could be aligned (every real target).
        debug_assert_eq!(padded % LINE_F32, 0, "plane stride must be whole cache lines");
        if aligned {
            for c in 0..channels {
                debug_assert_eq!(
                    buf[off + c * padded..].as_ptr() as usize % 64,
                    0,
                    "plane {c} must start on a 64-byte boundary"
                );
            }
        }
        for (i, px) in pixels.chunks_exact(channels).enumerate() {
            for (c, &v) in px.iter().enumerate() {
                buf[off + c * padded + i] = v;
            }
        }
        SoaTile {
            n,
            channels,
            padded,
            off,
            buf,
        }
    }

    /// Pixel count (excluding lane-tail padding).
    pub fn pixels(&self) -> usize {
        self.n
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Padded plane length (a [`LANES`] multiple; tail entries are 0.0).
    pub fn padded_len(&self) -> usize {
        self.padded
    }

    /// Channel `c` as one contiguous padded plane.
    #[inline]
    pub fn plane(&self, c: usize) -> &[f32] {
        debug_assert!(c < self.channels);
        let start = self.off + c * self.padded;
        &self.buf[start..start + self.padded]
    }

    /// Re-interleave into `pixels[P, C]` — the exact buffer the tile was
    /// built from, bit for bit (f32 moves are copies, never rounded).
    pub fn to_interleaved(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.n * self.channels);
        for i in 0..self.n {
            for c in 0..self.channels {
                out.push(self.plane(c)[i]);
            }
        }
    }

    /// Heap footprint, for the arena's byte budget.
    pub fn bytes(&self) -> usize {
        self.buf.capacity() * std::mem::size_of::<f32>()
    }
}

/// Arena access counters (monotone over the arena's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Tile served from the arena (no block-source read).
    pub hits: u64,
    /// Tile had to be (re)filled from the block source.
    pub misses: u64,
    /// Tiles LRU-evicted to stay under the byte budget.
    pub evictions: u64,
    /// Fills whose tile exceeded the whole budget and was never cached.
    pub spills: u64,
}

/// Per-worker cache of [`SoaTile`]s keyed by `(job, block)`.
///
/// One arena per worker thread serves every job the worker touches;
/// tiles of a finished job are dropped by `purge_job` (driven by the
/// pool's `Retire` message, like the pruned bounds). Budget pressure is
/// **job-scoped**: a fill may LRU-evict the owning job's own tiles but
/// never a neighbour's (see [`TileArena::insert_within`]), and a tile
/// that cannot fit is returned to the caller without being cached at
/// all (the block re-reads every round, exactly the seed behaviour).
pub struct TileArena {
    budget: usize,
    bytes: usize,
    tick: u64,
    tiles: HashMap<(u64, usize), (u64, Arc<SoaTile>)>,
    stats: ArenaStats,
}

impl TileArena {
    pub fn new(budget_bytes: usize) -> TileArena {
        TileArena {
            budget: budget_bytes,
            bytes: 0,
            tick: 0,
            tiles: HashMap::new(),
            stats: ArenaStats::default(),
        }
    }

    /// Update the byte budget. Shrinking evicts immediately.
    pub fn set_budget(&mut self, budget_bytes: usize) {
        self.budget = budget_bytes;
        self.evict_over_budget(None);
    }

    /// Raise the byte budget to at least `budget_bytes` (monotone).
    /// Jobs carry their own `arena_mb`; a shared per-worker arena takes
    /// the **high-water** of the budgets it has been asked for, so a
    /// small-budget job interleaved on the same pool can never evict a
    /// bigger job's resident tiles (its own tiles are capped at
    /// admission instead — see [`TileArena::insert_within`]).
    pub fn raise_budget(&mut self, budget_bytes: usize) {
        self.budget = self.budget.max(budget_bytes);
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Resident bytes.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.tiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tiles.is_empty()
    }

    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    /// Look up a tile, counting a hit or miss and refreshing recency.
    pub fn get(&mut self, key: (u64, usize)) -> Option<Arc<SoaTile>> {
        self.tick += 1;
        match self.tiles.get_mut(&key) {
            Some((used, tile)) => {
                *used = self.tick;
                self.stats.hits += 1;
                Some(Arc::clone(tile))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Whether a tile is resident (no recency or counter effects).
    pub fn contains(&self, key: (u64, usize)) -> bool {
        self.tiles.contains_key(&key)
    }

    /// Cache a freshly filled tile, evicting LRU entries to fit the
    /// budget. A tile larger than the whole budget is handed back
    /// uncached (a *spill*: that block refills every round).
    pub fn insert(&mut self, key: (u64, usize), tile: SoaTile) -> Arc<SoaTile> {
        let cap = self.budget;
        self.insert_within(key, tile, cap)
    }

    /// [`TileArena::insert`] with a per-job cap — the cross-job
    /// isolation contract of a shared per-worker arena. Workers pass
    /// the owning job's own `arena_bytes`:
    ///
    /// - the tile is spilled (returned uncached) when it, or the job's
    ///   total residency with it, would exceed `cap` — a job can never
    ///   occupy more of the shared arena than it asked for;
    /// - shared-budget overflow evicts the **inserting job's own** LRU
    ///   tiles only; if they cannot cover the deficit, the new tile is
    ///   withdrawn (spilled) instead. A job may thrash itself, never a
    ///   neighbour — the once-per-job fill invariant of concurrently
    ///   resident jobs survives any interleaving (tested).
    pub fn insert_within(&mut self, key: (u64, usize), tile: SoaTile, cap: usize) -> Arc<SoaTile> {
        let tile = Arc::new(tile);
        let job = key.0;
        if tile.bytes() > cap.min(self.budget) {
            self.stats.spills += 1;
            return tile;
        }
        // Per-job residency cap: make room among this job's OWN tiles
        // (LRU within the job), spilling the new tile if they cannot
        // cover it.
        let mut job_bytes: usize = self
            .tiles
            .iter()
            .filter(|(k, _)| k.0 == job && **k != key)
            .map(|(_, (_, t))| t.bytes())
            .sum();
        while job_bytes + tile.bytes() > cap {
            match self.own_lru_victim(job, key) {
                Some(v) => {
                    if let Some((_, t)) = self.tiles.remove(&v) {
                        job_bytes -= t.bytes();
                        self.bytes -= t.bytes();
                        self.stats.evictions += 1;
                    }
                }
                None => {
                    self.stats.spills += 1;
                    return tile;
                }
            }
        }
        self.tick += 1;
        if let Some((_, old)) = self.tiles.insert(key, (self.tick, Arc::clone(&tile))) {
            self.bytes -= old.bytes();
        }
        self.bytes += tile.bytes();
        // Shared-budget overflow: again only this job's own tiles are
        // eligible; withdraw the new tile when they cannot cover the
        // deficit. Neighbours' residency is never touched.
        while self.bytes > self.budget {
            match self.own_lru_victim(job, key) {
                Some(v) => {
                    if let Some((_, t)) = self.tiles.remove(&v) {
                        self.bytes -= t.bytes();
                        self.stats.evictions += 1;
                    }
                }
                None => {
                    // No own tiles left to evict: withdraw the new one.
                    if let Some((_, t)) = self.tiles.remove(&key) {
                        self.bytes -= t.bytes();
                    }
                    self.stats.spills += 1;
                    break;
                }
            }
        }
        tile
    }

    /// This job's least-recently-used tile other than `keep`.
    fn own_lru_victim(&self, job: u64, keep: (u64, usize)) -> Option<(u64, usize)> {
        self.tiles
            .iter()
            .filter(|(k, _)| k.0 == job && **k != keep)
            .min_by_key(|(_, (used, _))| *used)
            .map(|(k, _)| *k)
    }

    /// Drop one tile if resident (the worker-side failure-eviction
    /// path: a retried block must re-read and re-deinterleave rather
    /// than trust a tile that may have been mid-insert when its block
    /// failed). Returns whether a tile was actually dropped.
    pub fn remove(&mut self, key: (u64, usize)) -> bool {
        match self.tiles.remove(&key) {
            Some((_, t)) => {
                self.bytes -= t.bytes();
                true
            }
            None => false,
        }
    }

    /// Drop every tile of `job` (the worker-side `Retire` path).
    pub fn purge_job(&mut self, job: u64) {
        let mut freed = 0usize;
        self.tiles.retain(|(j, _), (_, t)| {
            if *j == job {
                freed += t.bytes();
                false
            } else {
                true
            }
        });
        self.bytes -= freed;
    }

    fn evict_over_budget(&mut self, keep: Option<(u64, usize)>) {
        while self.bytes > self.budget {
            let victim = self
                .tiles
                .iter()
                .filter(|(k, _)| Some(**k) != keep)
                .min_by_key(|(_, (used, _))| *used)
                .map(|(k, _)| *k);
            let Some(victim) = victim else { break };
            if let Some((_, t)) = self.tiles.remove(&victim) {
                self.bytes -= t.bytes();
                self.stats.evictions += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::qcheck::{forall, pair, usize_in};

    fn random_pixels(n: usize, channels: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * channels).map(|_| rng.next_f32() * 255.0).collect()
    }

    #[test]
    fn round_trip_is_bit_identical() {
        for channels in 1..=5 {
            // odd sizes, lane-exact sizes, and every tail residue
            for n in [1, 7, LANES - 1, LANES, LANES + 1, 16, 17, 127, 1021] {
                let px = random_pixels(n, channels, 3 + n as u64 * channels as u64);
                let tile = SoaTile::from_interleaved(&px, channels);
                assert_eq!(tile.pixels(), n);
                assert_eq!(tile.padded_len() % LANES, 0);
                let mut back = Vec::new();
                tile.to_interleaved(&mut back);
                assert_eq!(back, px, "C={channels} n={n}");
            }
        }
    }

    /// qcheck: odd widths, C ∈ {1..5}, every lane-tail size — the
    /// deinterleave⇄interleave pair is the identity, planes hold the
    /// right samples, and the padding tail is zeroed.
    #[test]
    fn prop_soa_round_trip_and_plane_contents() {
        let gen = pair(usize_in(1, 300), usize_in(1, 5));
        forall(301, 120, &gen, |&(n, channels)| {
            let px = random_pixels(n, channels, (n * 7 + channels) as u64);
            let tile = SoaTile::from_interleaved(&px, channels);
            let mut back = Vec::new();
            tile.to_interleaved(&mut back);
            if back != px {
                return false;
            }
            for c in 0..channels {
                let plane = tile.plane(c);
                if plane.len() != tile.padded_len() {
                    return false;
                }
                for i in 0..n {
                    if plane[i] != px[i * channels + c] {
                        return false;
                    }
                }
                if plane[n..].iter().any(|&v| v != 0.0) {
                    return false; // lane tail must be masked-safe zeros
                }
            }
            true
        });
    }

    #[test]
    fn planes_are_cache_line_aligned() {
        for n in [5, 64, 1000] {
            let tile = SoaTile::from_interleaved(&random_pixels(n, 3, 9), 3);
            for c in 0..3 {
                let addr = tile.plane(c).as_ptr() as usize;
                assert_eq!(addr % 64, 0, "plane {c} of n={n} misaligned");
            }
        }
    }

    /// The enforced invariant the native-SIMD kernels depend on: every
    /// plane starts on a 64-byte boundary, the stride is a whole number
    /// of cache lines, and a GROUP_MAX-wide group load at any group
    /// start inside the plane stays in bounds — across pixel counts
    /// straddling every tail-padding case and channel counts 1..=5.
    #[test]
    fn plane_layout_supports_full_width_group_loads() {
        use crate::kmeans::simd::GROUP_MAX;
        for channels in 1usize..=5 {
            for n in [1usize, 7, 8, 15, 16, 17, 63, 64, 65, 700] {
                let tile = SoaTile::from_interleaved(&random_pixels(n, channels, 31), channels);
                assert_eq!(tile.padded_len() % GROUP_MAX, 0, "n={n} C={channels} stride");
                for c in 0..channels {
                    let plane = tile.plane(c);
                    assert_eq!(
                        plane.as_ptr() as usize % 64,
                        0,
                        "n={n} C={channels} plane {c} misaligned"
                    );
                    // every group the scan loop can issue fits
                    let mut start = 0;
                    while start < n {
                        assert!(start + GROUP_MAX <= plane.len(), "n={n} group @{start}");
                        start += GROUP_MAX;
                    }
                    // padding beyond the pixels is zero (computed but
                    // masked lanes must not poison distances)
                    assert!(plane[n..].iter().all(|&v| v == 0.0));
                }
            }
        }
    }

    #[test]
    fn layout_parses_and_prints() {
        for l in [TileLayout::Interleaved, TileLayout::Soa] {
            assert_eq!(l.to_string().parse::<TileLayout>().unwrap(), l);
        }
        assert!("rowmajor".parse::<TileLayout>().is_err());
    }

    fn tile_of(n: usize, seed: u64) -> SoaTile {
        SoaTile::from_interleaved(&random_pixels(n, 3, seed), 3)
    }

    #[test]
    fn arena_hit_after_insert_miss_before() {
        let mut arena = TileArena::new(1 << 20);
        assert!(arena.get((1, 0)).is_none());
        let t = arena.insert((1, 0), tile_of(100, 1));
        assert_eq!(t.pixels(), 100);
        assert!(arena.get((1, 0)).is_some());
        assert!(arena.get((1, 1)).is_none());
        let s = arena.stats();
        assert_eq!((s.hits, s.misses), (1, 2));
    }

    #[test]
    fn arena_lru_evicts_under_budget() {
        let probe = tile_of(256, 0).bytes();
        let mut arena = TileArena::new(probe * 2 + probe / 2); // fits 2 tiles
        arena.insert((1, 0), tile_of(256, 1));
        arena.insert((1, 1), tile_of(256, 2));
        assert!(arena.get((1, 0)).is_some()); // 0 is now more recent than 1
        arena.insert((1, 2), tile_of(256, 3)); // evicts LRU = block 1
        assert!(arena.contains((1, 0)));
        assert!(!arena.contains((1, 1)));
        assert!(arena.contains((1, 2)));
        assert_eq!(arena.stats().evictions, 1);
        assert!(arena.bytes() <= arena.budget());
    }

    #[test]
    fn oversized_tile_spills_uncached() {
        let mut arena = TileArena::new(64); // smaller than any real tile
        let t = arena.insert((1, 0), tile_of(512, 4));
        assert_eq!(t.pixels(), 512); // caller still gets the tile
        assert!(arena.is_empty());
        assert_eq!(arena.stats().spills, 1);
        assert_eq!(arena.bytes(), 0);
    }

    #[test]
    fn small_budget_job_cannot_evict_a_bigger_jobs_tiles() {
        // Job 1 asks for a roomy arena; job 2 asks for none. Job 2's
        // fills spill (admission cap) instead of evicting job 1.
        let probe = tile_of(128, 0).bytes();
        let mut arena = TileArena::new(0);
        arena.raise_budget(probe * 4);
        arena.insert_within((1, 0), tile_of(128, 1), probe * 4);
        arena.insert_within((1, 1), tile_of(128, 2), probe * 4);
        arena.raise_budget(0); // job 2's request: monotone, no shrink
        let t = arena.insert_within((2, 0), tile_of(128, 3), 0);
        assert_eq!(t.pixels(), 128); // job 2 still gets its tile
        assert!(arena.contains((1, 0)) && arena.contains((1, 1)));
        assert!(!arena.contains((2, 0)), "capped tile must spill");
        assert_eq!(arena.stats().spills, 1);
        assert_eq!(arena.stats().evictions, 0);
    }

    #[test]
    fn budget_pressure_evicts_own_tiles_never_a_neighbours() {
        // Job 1 fills most of the shared budget; job 2 stays inside its
        // own cap but overflows the arena. Every eviction lands on job
        // 2's own tiles; when none remain, its new tile is withdrawn.
        let probe = tile_of(128, 0).bytes();
        let mut arena = TileArena::new(0);
        arena.raise_budget(probe * 4);
        for b in 0..3 {
            arena.insert_within((1, b), tile_of(128, b as u64), probe * 4);
        }
        // job 2, cap for two tiles: first two admitted (arena at 4 + 1
        // over → evicts job 2's own? no — 3+1 = 4 fits; the 5th tile
        // overflows and must cost job 2, not job 1)
        arena.insert_within((2, 0), tile_of(128, 10), probe * 2);
        assert_eq!(arena.len(), 4);
        arena.insert_within((2, 1), tile_of(128, 11), probe * 2);
        assert!(
            arena.contains((1, 0)) && arena.contains((1, 1)) && arena.contains((1, 2)),
            "neighbour tiles must survive"
        );
        // job 2 holds exactly one resident tile (own-LRU eviction or
        // withdrawal — either way it paid for the overflow itself)
        let job2 = [arena.contains((2, 0)), arena.contains((2, 1))];
        assert_eq!(job2.iter().filter(|r| **r).count(), 1, "{job2:?}");
        assert!(arena.bytes() <= arena.budget());
    }

    #[test]
    fn purge_job_is_scoped() {
        let mut arena = TileArena::new(1 << 20);
        arena.insert((1, 0), tile_of(64, 5));
        arena.insert((1, 1), tile_of(64, 6));
        arena.insert((2, 0), tile_of(64, 7));
        arena.purge_job(1);
        assert!(!arena.contains((1, 0)) && !arena.contains((1, 1)));
        assert!(arena.contains((2, 0)));
        assert_eq!(arena.bytes(), tile_of(64, 7).bytes());
    }

    #[test]
    fn reinsert_replaces_without_leaking_bytes() {
        let mut arena = TileArena::new(1 << 20);
        arena.insert((1, 0), tile_of(64, 8));
        let before = arena.bytes();
        arena.insert((1, 0), tile_of(64, 9));
        assert_eq!(arena.bytes(), before);
        assert_eq!(arena.len(), 1);
    }

    /// qcheck: random insert/get/purge sequences keep the byte
    /// accounting exact and never exceed the budget (except transiently
    /// never — checked after every op).
    #[test]
    fn prop_arena_accounting_is_exact() {
        let gen = pair(usize_in(1, 40), usize_in(256, 4096));
        forall(302, 40, &gen, |&(ops, budget_px)| {
            let budget = tile_of(budget_px, 0).bytes() * 2;
            let mut arena = TileArena::new(budget);
            let mut rng = Rng::new(ops as u64 * 31 + budget_px as u64);
            for _ in 0..ops {
                let key = (rng.range_usize(1, 3) as u64, rng.range_usize(0, 4));
                match rng.range_usize(0, 3) {
                    0 => {
                        arena.insert(key, tile_of(rng.range_usize(8, budget_px * 3), 1));
                    }
                    1 => {
                        arena.get(key);
                    }
                    _ => arena.purge_job(key.0),
                }
                let actual: usize = arena
                    .tiles
                    .values()
                    .map(|(_, t)| t.bytes())
                    .sum();
                if arena.bytes() != actual || arena.bytes() > budget {
                    return false;
                }
            }
            true
        });
    }
}
