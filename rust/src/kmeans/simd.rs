//! Runtime-dispatched native SIMD distance kernels.
//!
//! The `Lanes` kernel proved the lane-parallel formulation as portable
//! `[f32; LANES]` array code; this module implements the same hot inner
//! loop — nearest-plus-runner-up over a group of pixels, channel-outer
//! accumulation — with `std::arch` intrinsics, selected **once per run**
//! from the host's capabilities:
//!
//! - **AVX-512** (x86_64, `avx512f`): 16 pixels per vector op,
//! - **AVX2** (x86_64): 8 pixels per vector op,
//! - **NEON** (aarch64): 8 pixels as two 128-bit halves,
//! - **Portable**: the existing `lane_nearest2` array code, everywhere
//!   else.
//!
//! # Bit-identity
//!
//! Every lane of a vector is an independent pixel, and the non-FMA
//! variants execute, per pixel, the exact op sequence of
//! [`super::kernel::lane_nearest2`]: for each centroid, channel-outer
//! `t = p - c; d += t * t` in ascending channel order, then a strict-`<`
//! argmin/runner-up update. IEEE-754 makes vector `sub`/`mul`/`add`
//! bit-equal to their scalar forms, so labels, centroids, counts, and
//! inertia are bit-identical to `Lanes` (and therefore to naive) at
//! every level including the portable fallback — property-tested in
//! `tests/kernel_equivalence.rs`. Group width only changes how many
//! pixels are in flight, never any per-pixel op order.
//!
//! The opt-in **FMA** variants (`--fma`) contract `t*t + d` into a
//! fused multiply-add with a single rounding — *not* bit-identical, and
//! covered by the ULP-bounded tolerance harness in
//! `tests/simd_tolerance.rs` instead (the ROADMAP's tolerance-gated
//! equivalence mode for accelerator arithmetic).
//!
//! # Dispatch and override
//!
//! [`SimdLevel::detect`] probes the host once; the `BLOCKMS_SIMD`
//! environment variable clamps it (`off`/`portable`, `neon`, `avx2`,
//! `avx512`) so the fallback path is reachable on any machine —
//! [`resolve`] errors on levels the host lacks (a usage error, exit 2
//! at the CLI). The resolved level rides on `ExecPlan`, so the plan
//! explain table and the `ran:` summary name the code path that
//! actually executed.

use super::kernel;
use super::tile::{SoaTile, LANES};

/// Widest group any level processes per inner-loop call (AVX-512).
pub const GROUP_MAX: usize = 16;

/// Environment variable that clamps the dispatched level.
pub const SIMD_ENV: &str = "BLOCKMS_SIMD";

/// A host SIMD capability tier, ordered weakest to strongest.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SimdLevel {
    /// The `[f32; LANES]` array code — correct everywhere.
    #[default]
    Portable,
    /// aarch64 NEON, 128-bit vectors.
    Neon,
    /// x86_64 AVX2, 256-bit vectors.
    Avx2,
    /// x86_64 AVX-512F, 512-bit vectors (16 pixels per op).
    Avx512,
}

impl SimdLevel {
    pub const ALL: [SimdLevel; 4] = [
        SimdLevel::Portable,
        SimdLevel::Neon,
        SimdLevel::Avx2,
        SimdLevel::Avx512,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            SimdLevel::Portable => "portable",
            SimdLevel::Neon => "neon",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
        }
    }

    /// Pixels per inner-loop group at this level. AVX-512 runs double
    /// groups; everything else matches the portable [`LANES`] width.
    /// Tile planes are padded to a multiple of [`GROUP_MAX`] (64 bytes),
    /// so a full group load is always in bounds.
    pub fn group_width(&self) -> usize {
        match self {
            SimdLevel::Avx512 => GROUP_MAX,
            _ => LANES,
        }
    }

    /// Best level the **hardware** supports (no env override).
    pub fn detect() -> SimdLevel {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx512f") {
                return SimdLevel::Avx512;
            }
            if std::arch::is_x86_feature_detected!("avx2") {
                return SimdLevel::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return SimdLevel::Neon;
            }
        }
        SimdLevel::Portable
    }

    /// Can this host execute `level`'s kernels? (Portable always; each
    /// native tier needs its own feature bit — AVX-512 hosts also
    /// support the AVX2 tier.)
    pub fn supported(level: SimdLevel) -> bool {
        match level {
            SimdLevel::Portable => true,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx512 => std::arch::is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl std::str::FromStr for SimdLevel {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "portable" => Ok(SimdLevel::Portable),
            "neon" => Ok(SimdLevel::Neon),
            "avx2" => Ok(SimdLevel::Avx2),
            "avx512" | "avx512f" => Ok(SimdLevel::Avx512),
            other => Err(format!(
                "unknown SIMD level {other:?} (want off|portable|neon|avx2|avx512)"
            )),
        }
    }
}

/// Why [`resolve`] rejected the `BLOCKMS_SIMD` override.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SimdEnvError {
    /// The value did not parse as a level.
    Unknown { raw: String, why: String },
    /// A parseable level the host cannot execute.
    Unsupported { asked: SimdLevel, detected: SimdLevel },
}

impl std::fmt::Display for SimdEnvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimdEnvError::Unknown { raw, why } => {
                write!(f, "{SIMD_ENV}={raw:?}: {why}")
            }
            SimdEnvError::Unsupported { asked, detected } => write!(
                f,
                "{SIMD_ENV}={asked}: this host lacks {asked} (detected {detected})"
            ),
        }
    }
}

impl std::error::Error for SimdEnvError {}

/// The level a run should dispatch: hardware detection clamped by the
/// `BLOCKMS_SIMD` override. Errors (usage mistakes — unknown value, or
/// a level the host lacks) are for entry points to surface as exit-2;
/// library callers that just want *a* valid level use
/// [`SimdMode::detected`].
pub fn resolve() -> Result<SimdLevel, SimdEnvError> {
    let detected = SimdLevel::detect();
    match std::env::var(SIMD_ENV) {
        Err(_) => Ok(detected),
        Ok(raw) => {
            let asked: SimdLevel = raw.parse().map_err(|why| SimdEnvError::Unknown {
                raw: raw.clone(),
                why,
            })?;
            if !SimdLevel::supported(asked) {
                return Err(SimdEnvError::Unsupported { asked, detected });
            }
            Ok(asked)
        }
    }
}

/// The dispatch decision a run carries: which capability tier, and
/// whether the fused-multiply-add (non-bit-identical, tolerance-gated)
/// variants are enabled.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct SimdMode {
    pub level: SimdLevel,
    pub fma: bool,
}

impl SimdMode {
    /// Non-FMA mode at the host's detected level (env-clamped when the
    /// override is valid; a broken override falls back to detection —
    /// entry points surface it as a usage error via [`resolve`] first).
    pub fn detected() -> SimdMode {
        SimdMode {
            level: resolve().unwrap_or_else(|_| SimdLevel::detect()),
            fma: false,
        }
    }

    pub fn with_fma(mut self, fma: bool) -> SimdMode {
        self.fma = fma;
        self
    }

    /// Render for plan summaries: `avx2` or `avx2+fma`.
    pub fn label(&self) -> String {
        if self.fma {
            format!("{}+fma", self.level)
        } else {
            self.level.to_string()
        }
    }
}

/// The inner-loop contract: fill `labs`/`best`/`second` for the group
/// of pixels starting at `start` (group width fixed per function; only
/// the first `group_width` slots are written).
pub(crate) type GroupFn =
    fn(&SoaTile, usize, &[f32], usize, &mut [u32; GROUP_MAX], &mut [f32; GROUP_MAX], &mut [f32; GROUP_MAX]);

/// Select the inner loop for `mode` once per scan. Returns the function
/// and its group width. Levels this host (or this build's architecture)
/// cannot execute degrade to the portable path — callers that must
/// *reject* instead go through [`resolve`] first.
pub(crate) fn group_fn(mode: SimdMode) -> (GroupFn, usize) {
    let level = if SimdLevel::supported(mode.level) {
        mode.level
    } else {
        SimdLevel::Portable
    };
    match (level, mode.fma) {
        #[cfg(target_arch = "x86_64")]
        (SimdLevel::Avx512, false) => (x86::avx512_group, GROUP_MAX),
        #[cfg(target_arch = "x86_64")]
        (SimdLevel::Avx512, true) => (x86::avx512_fma_group, GROUP_MAX),
        #[cfg(target_arch = "x86_64")]
        (SimdLevel::Avx2, false) => (x86::avx2_group, LANES),
        #[cfg(target_arch = "x86_64")]
        (SimdLevel::Avx2, true) => {
            // 256-bit FMA is its own feature bit; an AVX2-without-FMA
            // host runs the portable mul_add loop (same contraction,
            // same tolerance contract).
            if std::arch::is_x86_feature_detected!("fma") {
                (x86::avx2_fma_group, LANES)
            } else {
                (portable_fma_group, LANES)
            }
        }
        #[cfg(target_arch = "aarch64")]
        (SimdLevel::Neon, false) => (neon::neon_group, LANES),
        #[cfg(target_arch = "aarch64")]
        (SimdLevel::Neon, true) => (neon::neon_fma_group, LANES),
        (_, false) => (portable_group, LANES),
        (_, true) => (portable_fma_group, LANES),
    }
}

/// Portable tier: delegate to the `Lanes` inner loop itself — one
/// source of truth for the op order every native variant must mirror.
fn portable_group(
    tile: &SoaTile,
    start: usize,
    cen: &[f32],
    k: usize,
    labs: &mut [u32; GROUP_MAX],
    best: &mut [f32; GROUP_MAX],
    second: &mut [f32; GROUP_MAX],
) {
    let (l8, b8, s8) = kernel::lane_nearest2(tile, start, cen, k);
    labs[..LANES].copy_from_slice(&l8);
    best[..LANES].copy_from_slice(&b8);
    second[..LANES].copy_from_slice(&s8);
}

/// Portable FMA tier: `lane_nearest2` with the accumulate contracted to
/// `mul_add` (one rounding), matching what the native FMA variants do.
fn portable_fma_group(
    tile: &SoaTile,
    start: usize,
    cen: &[f32],
    k: usize,
    labs: &mut [u32; GROUP_MAX],
    best: &mut [f32; GROUP_MAX],
    second: &mut [f32; GROUP_MAX],
) {
    let ch = tile.channels();
    labs[..LANES].fill(0);
    best[..LANES].fill(f32::INFINITY);
    second[..LANES].fill(f32::INFINITY);
    for ci in 0..k {
        let mut d = [0.0f32; LANES];
        for c in 0..ch {
            let cv = cen[ci * ch + c];
            let p = &tile.plane(c)[start..start + LANES];
            for l in 0..LANES {
                let t = p[l] - cv;
                d[l] = t.mul_add(t, d[l]);
            }
        }
        for l in 0..LANES {
            if d[l] < best[l] {
                second[l] = best[l];
                best[l] = d[l];
                labs[l] = ci as u32;
            } else if d[l] < second[l] {
                second[l] = d[l];
            }
        }
    }
}

/// Emit the strict-`<` argmin/runner-up update for one stored distance
/// group — shared by every native tier so the comparison order is
/// written exactly once.
#[inline]
fn fold_group<const W: usize>(
    ci: usize,
    d: &[f32; W],
    labs: &mut [u32; GROUP_MAX],
    best: &mut [f32; GROUP_MAX],
    second: &mut [f32; GROUP_MAX],
) {
    for l in 0..W {
        if d[l] < best[l] {
            second[l] = best[l];
            best[l] = d[l];
            labs[l] = ci as u32;
        } else if d[l] < second[l] {
            second[l] = d[l];
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 / AVX-512 inner loops. Safety: the `unsafe` bodies require
    //! their target feature, which [`super::group_fn`] verified via
    //! `is_x86_feature_detected!` before handing out the function; the
    //! loads stay inside `plane(c)` because planes are padded to a
    //! [`super::GROUP_MAX`] multiple (an enforced 64-byte-aligned
    //! invariant of `SoaTile` — see `tile.rs`).

    use super::{fold_group, SoaTile, GROUP_MAX, LANES};
    use std::arch::x86_64::*;

    pub(super) fn avx2_group(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        unsafe { avx2_group_impl::<false>(tile, start, cen, k, labs, best, second) }
    }

    pub(super) fn avx2_fma_group(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        unsafe { avx2_fma_group_impl(tile, start, cen, k, labs, best, second) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn avx2_group_impl<const FMA: bool>(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        let ch = tile.channels();
        labs[..LANES].fill(0);
        best[..LANES].fill(f32::INFINITY);
        second[..LANES].fill(f32::INFINITY);
        for ci in 0..k {
            let mut d = _mm256_setzero_ps();
            for c in 0..ch {
                let p = tile.plane(c);
                debug_assert!(start + LANES <= p.len());
                let v = _mm256_loadu_ps(p.as_ptr().add(start));
                let t = _mm256_sub_ps(v, _mm256_set1_ps(cen[ci * ch + c]));
                // Mirrors the scalar `d += t * t`: separate multiply
                // and add, two roundings, bit-identical to `Lanes`.
                d = _mm256_add_ps(d, _mm256_mul_ps(t, t));
            }
            let mut da = [0.0f32; LANES];
            _mm256_storeu_ps(da.as_mut_ptr(), d);
            fold_group(ci, &da, labs, best, second);
        }
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn avx2_fma_group_impl(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        let ch = tile.channels();
        labs[..LANES].fill(0);
        best[..LANES].fill(f32::INFINITY);
        second[..LANES].fill(f32::INFINITY);
        for ci in 0..k {
            let mut d = _mm256_setzero_ps();
            for c in 0..ch {
                let p = tile.plane(c);
                debug_assert!(start + LANES <= p.len());
                let v = _mm256_loadu_ps(p.as_ptr().add(start));
                let t = _mm256_sub_ps(v, _mm256_set1_ps(cen[ci * ch + c]));
                d = _mm256_fmadd_ps(t, t, d); // one rounding: tolerance-gated
            }
            let mut da = [0.0f32; LANES];
            _mm256_storeu_ps(da.as_mut_ptr(), d);
            fold_group(ci, &da, labs, best, second);
        }
    }

    pub(super) fn avx512_group(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        unsafe { avx512_group_impl(tile, start, cen, k, labs, best, second) }
    }

    pub(super) fn avx512_fma_group(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        unsafe { avx512_fma_group_impl(tile, start, cen, k, labs, best, second) }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn avx512_group_impl(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        let ch = tile.channels();
        labs.fill(0);
        best.fill(f32::INFINITY);
        second.fill(f32::INFINITY);
        for ci in 0..k {
            let mut d = _mm512_setzero_ps();
            for c in 0..ch {
                let p = tile.plane(c);
                debug_assert!(start + GROUP_MAX <= p.len());
                let v = _mm512_loadu_ps(p.as_ptr().add(start));
                let t = _mm512_sub_ps(v, _mm512_set1_ps(cen[ci * ch + c]));
                d = _mm512_add_ps(d, _mm512_mul_ps(t, t));
            }
            let mut da = [0.0f32; GROUP_MAX];
            _mm512_storeu_ps(da.as_mut_ptr(), d);
            fold_group(ci, &da, labs, best, second);
        }
    }

    #[target_feature(enable = "avx512f")]
    unsafe fn avx512_fma_group_impl(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        let ch = tile.channels();
        labs.fill(0);
        best.fill(f32::INFINITY);
        second.fill(f32::INFINITY);
        for ci in 0..k {
            let mut d = _mm512_setzero_ps();
            for c in 0..ch {
                let p = tile.plane(c);
                debug_assert!(start + GROUP_MAX <= p.len());
                let v = _mm512_loadu_ps(p.as_ptr().add(start));
                let t = _mm512_sub_ps(v, _mm512_set1_ps(cen[ci * ch + c]));
                d = _mm512_fmadd_ps(t, t, d);
            }
            let mut da = [0.0f32; GROUP_MAX];
            _mm512_storeu_ps(da.as_mut_ptr(), d);
            fold_group(ci, &da, labs, best, second);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON inner loops: 8 lanes as two 128-bit halves, per-pixel op
    //! order identical to the portable path.

    use super::{fold_group, SoaTile, GROUP_MAX, LANES};
    use std::arch::aarch64::*;

    pub(super) fn neon_group(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        unsafe { neon_group_impl::<false>(tile, start, cen, k, labs, best, second) }
    }

    pub(super) fn neon_fma_group(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        unsafe { neon_group_impl::<true>(tile, start, cen, k, labs, best, second) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn neon_group_impl<const FMA: bool>(
        tile: &SoaTile,
        start: usize,
        cen: &[f32],
        k: usize,
        labs: &mut [u32; GROUP_MAX],
        best: &mut [f32; GROUP_MAX],
        second: &mut [f32; GROUP_MAX],
    ) {
        let ch = tile.channels();
        labs[..LANES].fill(0);
        best[..LANES].fill(f32::INFINITY);
        second[..LANES].fill(f32::INFINITY);
        for ci in 0..k {
            let mut d0 = vdupq_n_f32(0.0);
            let mut d1 = vdupq_n_f32(0.0);
            for c in 0..ch {
                let p = tile.plane(c);
                debug_assert!(start + LANES <= p.len());
                let cv = vdupq_n_f32(cen[ci * ch + c]);
                let v0 = vld1q_f32(p.as_ptr().add(start));
                let v1 = vld1q_f32(p.as_ptr().add(start + 4));
                let t0 = vsubq_f32(v0, cv);
                let t1 = vsubq_f32(v1, cv);
                if FMA {
                    d0 = vfmaq_f32(d0, t0, t0);
                    d1 = vfmaq_f32(d1, t1, t1);
                } else {
                    d0 = vaddq_f32(d0, vmulq_f32(t0, t0));
                    d1 = vaddq_f32(d1, vmulq_f32(t1, t1));
                }
            }
            let mut da = [0.0f32; LANES];
            vst1q_f32(da.as_mut_ptr(), d0);
            vst1q_f32(da.as_mut_ptr().add(4), d1);
            fold_group(ci, &da, labs, best, second);
        }
    }
}

/// Startup microbench: measured simd-over-lanes wall ratio for `mode`
/// on a small synthetic tile (full-scan step rounds, min-of-3). The
/// planner's calibration hook (`CostModel::calibrate_simd`) feeds on
/// this so `--auto` picks Simd only where it is *measured* faster on
/// the actual host. Deterministic data; a few hundred microseconds.
pub fn microbench_ratio(mode: SimdMode) -> f64 {
    use std::time::Instant;
    let channels = 3;
    let k = 4;
    let n = 16 * 1024;
    let mut rng = crate::util::prng::Rng::new(0x51D_CA_11B);
    let px: Vec<f32> = (0..n * channels).map(|_| rng.next_f32() * 255.0).collect();
    let cen: Vec<f32> = (0..k * channels).map(|_| rng.next_f32() * 255.0).collect();
    let tile = SoaTile::from_interleaved(&px, channels);
    let mut time = |simd: bool| -> f64 {
        let mut best = f64::INFINITY;
        for rep in 0..4 {
            let mut state = kernel::PrunedState::new();
            let t = Instant::now();
            let acc = if simd {
                kernel::step_simd(&tile, &cen, k, &mut state, None, mode)
            } else {
                kernel::step_lanes(&tile, &cen, k, &mut state, None)
            };
            let dt = t.elapsed().as_secs_f64();
            std::hint::black_box(acc.inertia);
            if rep > 0 {
                best = best.min(dt); // rep 0 is warmup
            }
        }
        best
    };
    let lanes = time(false);
    let simd = time(true);
    if lanes > 0.0 && simd.is_finite() {
        simd / lanes
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tile(n: usize, channels: usize, seed: u64) -> (SoaTile, Vec<f32>) {
        let mut rng = crate::util::prng::Rng::new(seed);
        let px: Vec<f32> = (0..n * channels).map(|_| rng.next_f32() * 255.0).collect();
        (SoaTile::from_interleaved(&px, channels), px)
    }

    #[test]
    fn level_round_trips_and_orders() {
        for level in SimdLevel::ALL {
            assert_eq!(level.label().parse::<SimdLevel>().unwrap(), level);
        }
        assert_eq!("off".parse::<SimdLevel>().unwrap(), SimdLevel::Portable);
        assert!("sse9".parse::<SimdLevel>().is_err());
        assert!(SimdLevel::Portable < SimdLevel::Avx512);
    }

    #[test]
    fn detection_is_supported_and_portable_always_is() {
        let d = SimdLevel::detect();
        assert!(SimdLevel::supported(d), "detected level must run: {d}");
        assert!(SimdLevel::supported(SimdLevel::Portable));
        assert!(SimdLevel::supported(SimdMode::detected().level));
    }

    /// Every *supported* level's non-FMA inner loop is bit-identical to
    /// the portable `lane_nearest2` — the module's core contract,
    /// checked lane by lane including padded tails.
    #[test]
    fn native_groups_match_portable_bitwise() {
        for channels in [1usize, 3, 4, 5] {
            for k in [1usize, 2, 4, 8] {
                let (tile, _) = tile(701, channels, 0xB17 + channels as u64);
                let mut rng = crate::util::prng::Rng::new(0xCE2 + k as u64);
                let cen: Vec<f32> =
                    (0..k * channels).map(|_| rng.next_f32() * 255.0).collect();
                let (pf, pw) = group_fn(SimdMode::default());
                for level in SimdLevel::ALL {
                    if !SimdLevel::supported(level) {
                        continue;
                    }
                    let (f, w) = group_fn(SimdMode { level, fma: false });
                    let mut start = 0;
                    while start < tile.pixels() {
                        let mut a = ([0u32; GROUP_MAX], [0f32; GROUP_MAX], [0f32; GROUP_MAX]);
                        f(&tile, start, &cen, k, &mut a.0, &mut a.1, &mut a.2);
                        // cover the same pixels with the portable fn
                        let mut off = 0;
                        while off < w {
                            let mut b =
                                ([0u32; GROUP_MAX], [0f32; GROUP_MAX], [0f32; GROUP_MAX]);
                            pf(&tile, start + off, &cen, k, &mut b.0, &mut b.1, &mut b.2);
                            for l in 0..pw.min(w - off) {
                                assert_eq!(a.0[off + l], b.0[l], "{level} lab @{}", start + off + l);
                                assert_eq!(
                                    a.1[off + l].to_bits(),
                                    b.1[l].to_bits(),
                                    "{level} best @{}",
                                    start + off + l
                                );
                                assert_eq!(
                                    a.2[off + l].to_bits(),
                                    b.2[l].to_bits(),
                                    "{level} second @{}",
                                    start + off + l
                                );
                            }
                            off += pw;
                        }
                        start += w;
                    }
                }
            }
        }
    }

    /// FMA variants stay within a tight ULP band of the exact variant
    /// (they round once instead of twice per channel term).
    #[test]
    fn fma_groups_stay_within_ulp_band() {
        let channels = 3;
        let k = 4;
        let (tile, _) = tile(256, channels, 0xF3A);
        let mut rng = crate::util::prng::Rng::new(0xF3B);
        let cen: Vec<f32> = (0..k * channels).map(|_| rng.next_f32() * 255.0).collect();
        let (exact, w) = group_fn(SimdMode::default());
        let (fused, fw) = group_fn(SimdMode::default().with_fma(true));
        assert_eq!(w, fw);
        let mut start = 0;
        while start < tile.pixels() {
            let mut a = ([0u32; GROUP_MAX], [0f32; GROUP_MAX], [0f32; GROUP_MAX]);
            let mut b = ([0u32; GROUP_MAX], [0f32; GROUP_MAX], [0f32; GROUP_MAX]);
            exact(&tile, start, &cen, k, &mut a.0, &mut a.1, &mut a.2);
            fused(&tile, start, &cen, k, &mut b.0, &mut b.1, &mut b.2);
            for l in 0..w {
                let ulps = (a.1[l].to_bits() as i64 - b.1[l].to_bits() as i64).unsigned_abs();
                assert!(ulps <= 8, "best distance drifted {ulps} ulps at lane {l}");
            }
            start += w;
        }
    }

    #[test]
    fn microbench_returns_a_positive_ratio() {
        let r = microbench_ratio(SimdMode::detected());
        assert!(r.is_finite() && r > 0.0, "ratio {r}");
    }
}
