//! Core K-Means math over flat `pixels[P, C]` buffers.
//!
//! These functions are the rust mirror of `python/compile/kernels/ref.py`
//! — same accumulation order guarantees, same tie-breaking — so the
//! sequential baseline, the coordinator's reduction, and the AOT kernel
//! all agree bit-for-bit on labels and to f32-rounding on sums.
//!
//! [`step`] and [`assign_all`] define the *semantics*; their execution is
//! delegated to the width-dispatched kernels in [`super::kernel`], which
//! are bit-identical to the reference loops here (tested below and in
//! `tests/kernel_equivalence.rs`).

/// Partial accumulation state for one step: per-cluster sums, counts,
/// and the summed squared distance (inertia). Associative under
/// [`StepAccum::merge`] — the leader reduces per-block accumulators in
/// any order.
#[derive(Clone, Debug, PartialEq)]
pub struct StepAccum {
    pub k: usize,
    pub channels: usize,
    /// `sums[k * channels + c]` — f64 so cross-block reduction order
    /// cannot perturb the result (pixels are f32; the f64 sum is exact
    /// enough to be order-insensitive at image scale).
    pub sums: Vec<f64>,
    pub counts: Vec<u64>,
    pub inertia: f64,
}

impl StepAccum {
    pub fn zeros(k: usize, channels: usize) -> StepAccum {
        StepAccum {
            k,
            channels,
            sums: vec![0.0; k * channels],
            counts: vec![0; k],
            inertia: 0.0,
        }
    }

    /// Merge another accumulator into this one (associative, commutative).
    pub fn merge(&mut self, other: &StepAccum) {
        assert_eq!(self.k, other.k);
        assert_eq!(self.channels, other.channels);
        for (a, b) in self.sums.iter_mut().zip(&other.sums) {
            *a += b;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.inertia += other.inertia;
    }

    pub fn total_count(&self) -> u64 {
        self.counts.iter().sum()
    }
}

/// Squared euclidean distance between one pixel and one centroid.
#[inline]
pub fn sqdist(px: &[f32], centroid: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (a, b) in px.iter().zip(centroid) {
        let d = a - b;
        acc += d * d;
    }
    acc
}

/// Nearest centroid (lowest index wins ties) and its squared distance.
#[inline]
pub fn nearest(px: &[f32], centroids: &[f32], k: usize, channels: usize) -> (u32, f32) {
    let mut best = 0u32;
    let mut best_d = f32::INFINITY;
    for ki in 0..k {
        let d = sqdist(px, &centroids[ki * channels..(ki + 1) * channels]);
        // strict '<' keeps the first (lowest-index) minimum — matches
        // jnp.argmin.
        if d < best_d {
            best_d = d;
            best = ki as u32;
        }
    }
    (best, best_d)
}

/// Assign every pixel; writes `labels` and returns summed inertia.
///
/// Hot path (EXPERIMENTS.md §Perf): dispatches to the width-specialized
/// kernels in [`super::kernel`] — centroids in fixed stack arrays, no
/// slice bounds checks, four-pixel pipelining — bit-identical to the
/// reference loop (`nearest` per pixel, tested below). The mismatched-`k`
/// case fails loudly: the kernel layer asserts
/// `centroids.len() == k * channels` before touching the table.
pub fn assign_all(
    pixels: &[f32],
    centroids: &[f32],
    k: usize,
    channels: usize,
    labels: &mut Vec<u32>,
) -> f64 {
    super::kernel::assign_kernel(pixels, centroids, k, channels, labels)
}

/// One Lloyd accumulation pass over a pixel buffer (assign + sum).
/// Equivalent to `ref.step` with an all-ones mask.
///
/// Like [`assign_all`], executed by the width-dispatched kernel layer;
/// sums accumulate in f64 in pixel order exactly like the reference
/// loop — bit-identical results (tested).
pub fn step(pixels: &[f32], centroids: &[f32], k: usize, channels: usize) -> StepAccum {
    super::kernel::step_kernel(pixels, centroids, k, channels)
}

/// Centroid update with empty-cluster carry-over. Returns `true` if any
/// centroid moved more than `tol` (euclidean, per centroid).
pub fn update_centroids(acc: &StepAccum, centroids: &mut [f32], tol: f32) -> bool {
    assert_eq!(centroids.len(), acc.k * acc.channels);
    let mut moved = false;
    for ki in 0..acc.k {
        if acc.counts[ki] == 0 {
            continue; // keep previous centre
        }
        let inv = 1.0 / acc.counts[ki] as f64;
        let base = ki * acc.channels;
        let mut d2 = 0.0f32;
        for c in 0..acc.channels {
            let fresh = (acc.sums[base + c] * inv) as f32;
            let d = fresh - centroids[base + c];
            d2 += d * d;
            centroids[base + c] = fresh;
        }
        if d2.sqrt() > tol {
            moved = true;
        }
    }
    moved
}

#[cfg(test)]
mod tests {
    use super::*;

    const C: usize = 3;

    fn px4() -> Vec<f32> {
        // 4 pixels, clearly separated in two groups
        vec![
            0.0, 0.0, 0.0, //
            1.0, 0.0, 0.0, //
            10.0, 10.0, 10.0, //
            11.0, 10.0, 10.0,
        ]
    }

    #[test]
    fn nearest_breaks_ties_low_index() {
        let centroids = vec![1.0, 0.0, 0.0, /* c1 */ -1.0, 0.0, 0.0];
        let (l, d) = nearest(&[0.0, 0.0, 0.0], &centroids, 2, C);
        assert_eq!(l, 0);
        assert_eq!(d, 1.0);
    }

    #[test]
    fn step_accumulates_correctly() {
        let cen = vec![0.0, 0.0, 0.0, /* */ 10.0, 10.0, 10.0];
        let acc = step(&px4(), &cen, 2, C);
        assert_eq!(acc.counts, vec![2, 2]);
        assert_eq!(&acc.sums[..3], &[1.0, 0.0, 0.0]);
        assert_eq!(&acc.sums[3..], &[21.0, 20.0, 20.0]);
        // inertia: 0 + 1 + 0 + 1 = 2
        assert!((acc.inertia - 2.0).abs() < 1e-9);
    }

    #[test]
    fn merge_is_commutative_and_matches_whole() {
        let cen = vec![0.0, 0.0, 0.0, 10.0, 10.0, 10.0];
        let px = px4();
        let whole = step(&px, &cen, 2, C);
        let a = step(&px[..6], &cen, 2, C);
        let b = step(&px[6..], &cen, 2, C);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, whole);
        assert_eq!(ba, whole);
    }

    #[test]
    fn update_moves_to_means() {
        let cen_init = vec![0.0, 0.0, 0.0, 10.0, 10.0, 10.0];
        let acc = step(&px4(), &cen_init, 2, C);
        let mut cen = cen_init.clone();
        let moved = update_centroids(&acc, &mut cen, 1e-6);
        assert!(moved);
        assert_eq!(&cen[..3], &[0.5, 0.0, 0.0]);
        assert_eq!(&cen[3..], &[10.5, 10.0, 10.0]);
    }

    #[test]
    fn update_empty_cluster_keeps_centre() {
        let mut acc = StepAccum::zeros(2, C);
        acc.counts = vec![4, 0];
        acc.sums[..3].copy_from_slice(&[4.0, 8.0, 12.0]);
        let mut cen = vec![9.0, 9.0, 9.0, 7.0, 7.0, 7.0];
        update_centroids(&acc, &mut cen, 1e-6);
        assert_eq!(&cen[..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&cen[3..], &[7.0, 7.0, 7.0]); // untouched
    }

    #[test]
    fn update_below_tol_reports_converged() {
        let cen_init = vec![0.5, 0.0, 0.0, 10.5, 10.0, 10.0];
        let acc = step(&px4(), &cen_init, 2, C);
        let mut cen = cen_init.clone();
        let moved = update_centroids(&acc, &mut cen, 1e-3);
        assert!(!moved, "centroids already at the fixed point");
    }

    #[test]
    #[should_panic(expected = "centroid table length")]
    fn step_rejects_mismatched_k() {
        // 2 centroids supplied, k=4 claimed: must fail loudly, not read a
        // wrong-length centroid table.
        let cen = vec![0.0f32; 6];
        let _ = step(&px4(), &cen, 4, C);
    }

    #[test]
    #[should_panic(expected = "centroid table length")]
    fn assign_all_rejects_mismatched_k() {
        let cen = vec![0.0f32; 6];
        let mut labels = Vec::new();
        let _ = assign_all(&px4(), &cen, 4, C, &mut labels);
    }

    #[test]
    fn c3_specialization_is_bit_identical_to_generic() {
        // run the generic path by shaping the same data as C=3 via the
        // public API vs a hand-run of the generic loop
        use crate::util::prng::Rng;
        let mut rng = Rng::new(77);
        let n = 4097; // odd size
        let px: Vec<f32> = (0..n * 3).map(|_| rng.next_f32() * 255.0).collect();
        for k in [1usize, 2, 4, 8, 11] {
            let cen: Vec<f32> = (0..k * 3).map(|_| rng.next_f32() * 255.0).collect();
            // generic reference (inline copy of the generic loop)
            let mut want = StepAccum::zeros(k, 3);
            for p in px.chunks_exact(3) {
                let (l, d) = nearest(p, &cen, k, 3);
                let base = l as usize * 3;
                for (c, &v) in p.iter().enumerate() {
                    want.sums[base + c] += v as f64;
                }
                want.counts[l as usize] += 1;
                want.inertia += d as f64;
            }
            let got = step(&px, &cen, k, 3);
            assert_eq!(got, want, "k={k}");
            // assign path
            let mut want_labels = Vec::new();
            let mut want_inertia = 0.0f64;
            for p in px.chunks_exact(3) {
                let (l, d) = nearest(p, &cen, k, 3);
                want_labels.push(l);
                want_inertia += d as f64;
            }
            let mut got_labels = Vec::new();
            let got_inertia = assign_all(&px, &cen, k, 3, &mut got_labels);
            assert_eq!(got_labels, want_labels, "k={k}");
            assert_eq!(got_inertia, want_inertia, "k={k}");
        }
    }

    #[test]
    fn assign_all_matches_step_counts() {
        let cen = vec![0.0, 0.0, 0.0, 10.0, 10.0, 10.0];
        let mut labels = Vec::new();
        let inertia = assign_all(&px4(), &cen, 2, C, &mut labels);
        assert_eq!(labels, vec![0, 0, 1, 1]);
        let acc = step(&px4(), &cen, 2, C);
        assert!((inertia - acc.inertia).abs() < 1e-12);
    }
}
