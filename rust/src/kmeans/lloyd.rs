//! The sequential K-Means baseline (the paper's "Serial" column).

use super::init::InitMethod;
use super::math;

/// Shared K-Means configuration (used by baseline and coordinator).
#[derive(Clone, Debug)]
pub struct KMeansConfig {
    /// Cluster count (paper: 2 and 4).
    pub k: usize,
    /// Maximum Lloyd iterations.
    pub max_iters: usize,
    /// Convergence tolerance on centroid movement (euclidean per centre).
    pub tol: f32,
    /// Initialization strategy.
    pub init: InitMethod,
    /// Seed for the initialization draw.
    pub seed: u64,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        Self {
            k: 2,
            max_iters: 20,
            tol: 1e-3,
            init: InitMethod::RandomSample,
            seed: 0xC1_05_7E_12,
        }
    }
}

/// Result of a K-Means run.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    /// Final centroids, `k × channels` flat.
    pub centroids: Vec<f32>,
    /// Per-pixel labels.
    pub labels: Vec<u32>,
    /// Final inertia (sum of squared distances to owning centres).
    pub inertia: f64,
    /// Lloyd iterations actually executed.
    pub iterations: usize,
    /// Whether the tolerance was reached before `max_iters`.
    pub converged: bool,
}

/// Plain single-threaded Lloyd's algorithm over a flat pixel buffer.
#[derive(Clone, Debug, Default)]
pub struct SeqKMeans;

impl SeqKMeans {
    /// Run on `pixels[P, C]`.
    pub fn run(pixels: &[f32], channels: usize, cfg: &KMeansConfig) -> KMeansResult {
        assert!(cfg.k >= 1, "k must be >= 1");
        assert_eq!(pixels.len() % channels, 0);
        let mut centroids = cfg.init.centroids(pixels, cfg.k, channels, cfg.seed);
        let mut iterations = 0;
        let mut converged = false;
        for _ in 0..cfg.max_iters {
            iterations += 1;
            let acc = math::step(pixels, &centroids, cfg.k, channels);
            let moved = math::update_centroids(&acc, &mut centroids, cfg.tol);
            if !moved {
                converged = true;
                break;
            }
        }
        let mut labels = Vec::new();
        let inertia = math::assign_all(pixels, &centroids, cfg.k, channels, &mut labels);
        KMeansResult {
            centroids,
            labels,
            inertia,
            iterations,
            converged,
        }
    }

    /// Run a fixed number of iterations with NO convergence test — the
    /// exact-work-mirror used for serial-vs-parallel comparisons (both
    /// sides execute identical iteration counts; the paper times it this
    /// way by fixing cluster counts and letting MATLAB's default iters
    /// run).
    pub fn run_fixed_iters(
        pixels: &[f32],
        channels: usize,
        cfg: &KMeansConfig,
        iters: usize,
    ) -> KMeansResult {
        let mut centroids = cfg.init.centroids(pixels, cfg.k, channels, cfg.seed);
        for _ in 0..iters {
            let acc = math::step(pixels, &centroids, cfg.k, channels);
            math::update_centroids(&acc, &mut centroids, 0.0);
        }
        let mut labels = Vec::new();
        let inertia = math::assign_all(pixels, &centroids, cfg.k, channels, &mut labels);
        KMeansResult {
            centroids,
            labels,
            inertia,
            iterations: iters,
            converged: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::SyntheticOrtho;
    use crate::kmeans::math;

    fn two_groups() -> Vec<f32> {
        let mut v = Vec::new();
        for i in 0..40 {
            let j = (i % 4) as f32;
            v.extend_from_slice(&[j, j, j]);
            v.extend_from_slice(&[200.0 + j, 200.0 + j, 200.0 + j]);
        }
        v
    }

    #[test]
    fn separates_two_groups() {
        let px = two_groups();
        let cfg = KMeansConfig {
            k: 2,
            init: InitMethod::PlusPlus,
            ..Default::default()
        };
        let r = SeqKMeans::run(&px, 3, &cfg);
        assert!(r.converged, "did not converge in {} iters", r.iterations);
        // centroids near (1.5,) and (201.5,) in some order
        let mut c0 = r.centroids[0];
        let mut c1 = r.centroids[3];
        if c0 > c1 {
            std::mem::swap(&mut c0, &mut c1);
        }
        assert!((c0 - 1.5).abs() < 0.1, "c0={c0}");
        assert!((c1 - 201.5).abs() < 0.1, "c1={c1}");
        // labels split evenly
        let ones = r.labels.iter().filter(|&&l| l == 1).count();
        assert_eq!(ones, 40);
    }

    #[test]
    fn inertia_never_increases_between_iterations() {
        let img = SyntheticOrtho::default().with_seed(3).generate(40, 40);
        let px = img.as_pixels();
        let cfg = KMeansConfig {
            k: 4,
            ..Default::default()
        };
        let mut centroids = cfg.init.centroids(px, cfg.k, 3, cfg.seed);
        let mut prev = f64::INFINITY;
        for _ in 0..10 {
            let acc = math::step(px, &centroids, cfg.k, 3);
            assert!(
                acc.inertia <= prev * (1.0 + 1e-7) + 1e-6,
                "inertia rose: {} -> {}",
                prev,
                acc.inertia
            );
            prev = acc.inertia;
            math::update_centroids(&acc, &mut centroids, 0.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let img = SyntheticOrtho::default().with_seed(4).generate(30, 30);
        let cfg = KMeansConfig::default();
        let a = SeqKMeans::run(img.as_pixels(), 3, &cfg);
        let b = SeqKMeans::run(img.as_pixels(), 3, &cfg);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.inertia, b.inertia);
    }

    #[test]
    fn fixed_iters_executes_exact_count() {
        let px = two_groups();
        let cfg = KMeansConfig::default();
        let r = SeqKMeans::run_fixed_iters(&px, 3, &cfg, 5);
        assert_eq!(r.iterations, 5);
    }

    #[test]
    fn k1_assigns_everything_to_mean() {
        let px = two_groups();
        let cfg = KMeansConfig {
            k: 1,
            ..Default::default()
        };
        let r = SeqKMeans::run(&px, 3, &cfg);
        assert!(r.labels.iter().all(|&l| l == 0));
        assert!((r.centroids[0] - 101.5).abs() < 1e-3);
    }

    #[test]
    fn labels_are_within_k() {
        let img = SyntheticOrtho::default().with_seed(5).generate(20, 20);
        let cfg = KMeansConfig {
            k: 4,
            ..Default::default()
        };
        let r = SeqKMeans::run(img.as_pixels(), 3, &cfg);
        assert!(r.labels.iter().all(|&l| l < 4));
        assert_eq!(r.labels.len(), 400);
    }
}
