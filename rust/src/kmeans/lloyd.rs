//! The sequential K-Means baseline (the paper's "Serial" column).

use super::init::InitMethod;
use super::kernel::{self, CentroidDrift, KernelChoice, PrunedState};
use super::math;
use super::simd::SimdMode;
use super::tile::SoaTile;

/// Shared K-Means configuration (used by baseline and coordinator).
#[derive(Clone, Debug)]
pub struct KMeansConfig {
    /// Cluster count (paper: 2 and 4).
    pub k: usize,
    /// Maximum Lloyd iterations.
    pub max_iters: usize,
    /// Convergence tolerance on centroid movement (euclidean per centre).
    pub tol: f32,
    /// Initialization strategy.
    pub init: InitMethod,
    /// Seed for the initialization draw.
    pub seed: u64,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        Self {
            k: 2,
            max_iters: 20,
            tol: 1e-3,
            init: InitMethod::RandomSample,
            seed: 0xC1_05_7E_12,
        }
    }
}

/// Result of a K-Means run.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    /// Final centroids, `k × channels` flat.
    pub centroids: Vec<f32>,
    /// Per-pixel labels.
    pub labels: Vec<u32>,
    /// Final inertia (sum of squared distances to owning centres).
    pub inertia: f64,
    /// Lloyd iterations actually executed.
    pub iterations: usize,
    /// Whether the tolerance was reached before `max_iters`.
    pub converged: bool,
}

/// Plain single-threaded Lloyd's algorithm over a flat pixel buffer.
#[derive(Clone, Debug, Default)]
pub struct SeqKMeans;

impl SeqKMeans {
    /// Run on `pixels[P, C]` with the naive (reference) kernel.
    pub fn run(pixels: &[f32], channels: usize, cfg: &KMeansConfig) -> KMeansResult {
        Self::run_with(pixels, channels, cfg, KernelChoice::Naive)
    }

    /// Run with an explicit kernel choice. Pruned and fused kernels
    /// produce bit-identical labels, centroids, and iteration counts to
    /// the naive path (property-tested in `tests/kernel_equivalence.rs`)
    /// — only wall-clock changes, which keeps serial-vs-parallel
    /// comparisons exact work mirrors at any [`KernelChoice`].
    pub fn run_with(
        pixels: &[f32],
        channels: usize,
        cfg: &KMeansConfig,
        kernel: KernelChoice,
    ) -> KMeansResult {
        run_inner(pixels, channels, cfg, None, kernel, SimdMode::detected())
    }

    /// [`SeqKMeans::run_with`] with an explicit SIMD dispatch mode (only
    /// meaningful for [`KernelChoice::Simd`]; other kernels ignore it).
    pub fn run_with_simd(
        pixels: &[f32],
        channels: usize,
        cfg: &KMeansConfig,
        kernel: KernelChoice,
        simd: SimdMode,
    ) -> KMeansResult {
        run_inner(pixels, channels, cfg, None, kernel, simd)
    }

    /// Run a fixed number of iterations with NO convergence test — the
    /// exact-work-mirror used for serial-vs-parallel comparisons (both
    /// sides execute identical iteration counts; the paper times it this
    /// way by fixing cluster counts and letting MATLAB's default iters
    /// run).
    pub fn run_fixed_iters(
        pixels: &[f32],
        channels: usize,
        cfg: &KMeansConfig,
        iters: usize,
    ) -> KMeansResult {
        run_inner(
            pixels,
            channels,
            cfg,
            Some(iters),
            KernelChoice::Naive,
            SimdMode::default(),
        )
    }

    /// Fixed-iteration variant of [`SeqKMeans::run_with`].
    pub fn run_fixed_iters_with(
        pixels: &[f32],
        channels: usize,
        cfg: &KMeansConfig,
        iters: usize,
        kernel: KernelChoice,
    ) -> KMeansResult {
        run_inner(pixels, channels, cfg, Some(iters), kernel, SimdMode::detected())
    }

    /// Fixed-iteration variant of [`SeqKMeans::run_with_simd`].
    pub fn run_fixed_iters_with_simd(
        pixels: &[f32],
        channels: usize,
        cfg: &KMeansConfig,
        iters: usize,
        kernel: KernelChoice,
        simd: SimdMode,
    ) -> KMeansResult {
        run_inner(pixels, channels, cfg, Some(iters), kernel, simd)
    }
}

/// Shared Lloyd driver. `fixed = Some(n)` runs exactly `n` iterations
/// with no convergence test; `None` runs to `cfg.max_iters`/`cfg.tol`.
fn run_inner(
    pixels: &[f32],
    channels: usize,
    cfg: &KMeansConfig,
    fixed: Option<usize>,
    kernel: KernelChoice,
    simd: SimdMode,
) -> KMeansResult {
    assert!(cfg.k >= 1, "k must be >= 1");
    assert_eq!(pixels.len() % channels, 0);
    let mut centroids = cfg.init.centroids(pixels, cfg.k, channels, cfg.seed);
    let (max_iters, tol) = match fixed {
        Some(n) => (n, 0.0),
        None => (cfg.max_iters, cfg.tol),
    };
    let mut iterations = 0;
    let mut converged = false;
    let mut state = PrunedState::new();
    let mut drift: Option<CentroidDrift> = None;
    // The lanes/simd kernels run on the planar layout: deinterleave
    // once, reuse the tile for every round (the whole-image mirror of
    // the coordinator's per-block tile arena).
    let tile = matches!(kernel, KernelChoice::Lanes | KernelChoice::Simd)
        .then(|| SoaTile::from_interleaved(pixels, channels));
    for _ in 0..max_iters {
        iterations += 1;
        let acc = match kernel {
            KernelChoice::Naive => math::step(pixels, &centroids, cfg.k, channels),
            KernelChoice::Pruned | KernelChoice::Fused => {
                kernel::step_pruned(pixels, &centroids, cfg.k, channels, &mut state, drift.as_ref())
            }
            KernelChoice::Lanes => kernel::step_lanes(
                tile.as_ref().expect("tile built for lanes"),
                &centroids,
                cfg.k,
                &mut state,
                drift.as_ref(),
            ),
            KernelChoice::Simd => kernel::step_simd(
                tile.as_ref().expect("tile built for simd"),
                &centroids,
                cfg.k,
                &mut state,
                drift.as_ref(),
                simd,
            ),
        };
        let prev = (kernel != KernelChoice::Naive).then(|| centroids.clone());
        let moved = math::update_centroids(&acc, &mut centroids, tol);
        if let Some(prev) = prev {
            drift = Some(kernel::drift_between(&prev, &centroids, cfg.k, channels));
        }
        if fixed.is_none() && !moved {
            converged = true;
            break;
        }
    }
    let mut labels = Vec::new();
    let inertia = match kernel {
        KernelChoice::Fused => kernel::assign_pruned(
            pixels,
            &centroids,
            cfg.k,
            channels,
            &mut state,
            drift.as_ref(),
            &mut labels,
        ),
        KernelChoice::Lanes => kernel::assign_lanes(
            tile.as_ref().expect("tile built for lanes"),
            &centroids,
            cfg.k,
            &mut state,
            drift.as_ref(),
            &mut labels,
        ),
        KernelChoice::Simd => kernel::assign_simd(
            tile.as_ref().expect("tile built for simd"),
            &centroids,
            cfg.k,
            &mut state,
            drift.as_ref(),
            &mut labels,
            simd,
        ),
        _ => math::assign_all(pixels, &centroids, cfg.k, channels, &mut labels),
    };
    KMeansResult {
        centroids,
        labels,
        inertia,
        iterations,
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::SyntheticOrtho;
    use crate::kmeans::math;

    fn two_groups() -> Vec<f32> {
        let mut v = Vec::new();
        for i in 0..40 {
            let j = (i % 4) as f32;
            v.extend_from_slice(&[j, j, j]);
            v.extend_from_slice(&[200.0 + j, 200.0 + j, 200.0 + j]);
        }
        v
    }

    #[test]
    fn separates_two_groups() {
        let px = two_groups();
        let cfg = KMeansConfig {
            k: 2,
            init: InitMethod::PlusPlus,
            ..Default::default()
        };
        let r = SeqKMeans::run(&px, 3, &cfg);
        assert!(r.converged, "did not converge in {} iters", r.iterations);
        // centroids near (1.5,) and (201.5,) in some order
        let mut c0 = r.centroids[0];
        let mut c1 = r.centroids[3];
        if c0 > c1 {
            std::mem::swap(&mut c0, &mut c1);
        }
        assert!((c0 - 1.5).abs() < 0.1, "c0={c0}");
        assert!((c1 - 201.5).abs() < 0.1, "c1={c1}");
        // labels split evenly
        let ones = r.labels.iter().filter(|&&l| l == 1).count();
        assert_eq!(ones, 40);
    }

    #[test]
    fn inertia_never_increases_between_iterations() {
        let img = SyntheticOrtho::default().with_seed(3).generate(40, 40);
        let px = img.as_pixels();
        let cfg = KMeansConfig {
            k: 4,
            ..Default::default()
        };
        let mut centroids = cfg.init.centroids(px, cfg.k, 3, cfg.seed);
        let mut prev = f64::INFINITY;
        for _ in 0..10 {
            let acc = math::step(px, &centroids, cfg.k, 3);
            assert!(
                acc.inertia <= prev * (1.0 + 1e-7) + 1e-6,
                "inertia rose: {} -> {}",
                prev,
                acc.inertia
            );
            prev = acc.inertia;
            math::update_centroids(&acc, &mut centroids, 0.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let img = SyntheticOrtho::default().with_seed(4).generate(30, 30);
        let cfg = KMeansConfig::default();
        let a = SeqKMeans::run(img.as_pixels(), 3, &cfg);
        let b = SeqKMeans::run(img.as_pixels(), 3, &cfg);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.inertia, b.inertia);
    }

    #[test]
    fn pruned_and_fused_kernels_match_naive_exactly() {
        use crate::kmeans::kernel::KernelChoice;
        let img = SyntheticOrtho::default().with_seed(9).generate(40, 40);
        let px = img.as_pixels();
        for k in [1usize, 2, 4] {
            let cfg = KMeansConfig {
                k,
                ..Default::default()
            };
            let naive = SeqKMeans::run_with(px, 3, &cfg, KernelChoice::Naive);
            for kc in [
                KernelChoice::Pruned,
                KernelChoice::Fused,
                KernelChoice::Lanes,
                KernelChoice::Simd,
            ] {
                let other = SeqKMeans::run_with(px, 3, &cfg, kc);
                assert_eq!(other.labels, naive.labels, "k={k} {kc}");
                assert_eq!(other.centroids, naive.centroids, "k={k} {kc}");
                assert_eq!(other.iterations, naive.iterations, "k={k} {kc}");
                assert_eq!(other.converged, naive.converged, "k={k} {kc}");
                assert_eq!(other.inertia, naive.inertia, "k={k} {kc}");
            }
        }
    }

    #[test]
    fn fixed_iters_executes_exact_count() {
        let px = two_groups();
        let cfg = KMeansConfig::default();
        let r = SeqKMeans::run_fixed_iters(&px, 3, &cfg, 5);
        assert_eq!(r.iterations, 5);
    }

    #[test]
    fn k1_assigns_everything_to_mean() {
        let px = two_groups();
        let cfg = KMeansConfig {
            k: 1,
            ..Default::default()
        };
        let r = SeqKMeans::run(&px, 3, &cfg);
        assert!(r.labels.iter().all(|&l| l == 0));
        assert!((r.centroids[0] - 101.5).abs() < 1e-3);
    }

    #[test]
    fn labels_are_within_k() {
        let img = SyntheticOrtho::default().with_seed(5).generate(20, 20);
        let cfg = KMeansConfig {
            k: 4,
            ..Default::default()
        };
        let r = SeqKMeans::run(img.as_pixels(), 3, &cfg);
        assert!(r.labels.iter().all(|&l| l < 4));
        assert_eq!(r.labels.len(), 400);
    }
}
