//! K-Means: shared math, initialization, and the sequential baseline.
//!
//! [`SeqKMeans`] is the paper's "Serial" column — plain Lloyd iterations
//! over the whole image on one thread. It doubles as the correctness
//! oracle: the coordinator's global mode must reproduce its per-iteration
//! state *exactly* (same assignments, same centroids), because both are
//! built from the same associative accumulation in [`math`].
//!
//! Tie-breaking contract (shared with the Pallas kernels via
//! `python/compile/kernels/ref.py`): nearest centroid with the lowest
//! index wins; empty clusters keep their previous centre.

pub mod init;
pub mod kernel;
mod lloyd;
pub mod math;
pub mod simd;
pub mod tile;

pub use init::{InitMethod, StreamInit};
pub use kernel::{CentroidDrift, KernelChoice, PrunedState};
pub use lloyd::{KMeansConfig, KMeansResult, SeqKMeans};
pub use simd::{SimdLevel, SimdMode};
pub use tile::{ArenaStats, SoaTile, TileArena, TileLayout, LANES};
