//! The dispatching compute-kernel layer for the K-Means hot path.
//!
//! Every distance evaluation in the repo funnels through here (see
//! EXPERIMENTS.md §Kernel architecture). Three ideas, composable:
//!
//! 1. **Width specialization** — monomorphic kernels for `C ∈ {1, 3, 4}`
//!    (the 3-band case is every paper image) plus a chunked generic
//!    fallback. Centroids are copied once per call into fixed stack
//!    arrays ([`MAX_STACK_K`] entries; larger `k` spills to the heap),
//!    pixels are viewed as `&[f32; C]`, and the main loops run four
//!    pixels per step — no slice bounds checks on the hot path, four
//!    independent dependency chains for LLVM to keep in registers and
//!    auto-vectorize.
//! 2. **Hamerly-style pruning** — [`PrunedState`] carries per-pixel
//!    upper/lower distance bounds across Lloyd rounds. After each
//!    centroid update the leader measures how far every centre moved
//!    ([`drift_between`]); a pixel whose (drift-adjusted) upper bound to
//!    its own centre stays strictly below its lower bound to every other
//!    centre provably keeps its label, so the K-way scan collapses to a
//!    single distance evaluation. Labels, counts, sums, and inertia are
//!    **bit-identical** to the naive scan (see the invariant note below).
//! 3. **Fusion** — [`fused_step_assign`] produces the accumulator and
//!    the label map in one pass, and [`assign_pruned`] turns the final
//!    labeling round into a bounds-reuse pass over the last iteration's
//!    distances instead of a from-scratch K-way scan per pixel.
//! 4. **Lane vectorization** — [`step_lanes`]/[`assign_lanes`] run over
//!    planar [`SoaTile`]s, computing each centroid-channel term for
//!    [`LANES`] *pixels* at once (`[f32; LANES]` array SIMD, stable
//!    rustc, no intrinsics) instead of reducing across the C channels
//!    of one pixel; they compose with the same Hamerly bounds and the
//!    fused final pass. See the lane-kernel section below for why this
//!    stays bit-identical.
//!
//! ## The pruning invariant
//!
//! For a pixel `x` assigned to centre `a`, the state keeps `u ≥ d(x, a)`
//! and `l ≤ min_{j≠a} d(x, j)` (euclidean, f64). After centres move by
//! `δ_j`, the triangle inequality gives `u' = u + δ_a` and
//! `l' = l − max_j δ_j`. If `u' < l'` (with a guard band,
//! [`provably_closer`]) the old label is still the unique argmin, so the
//! kernel evaluates only `d(x, a)` — exactly the value the naive scan
//! would have accumulated — and skips the other `k − 1` centres. On a
//! failed test the pixel is rescanned in the same centroid order with
//! the same strict-`<` tie-breaking as [`super::math::nearest`], so the
//! result (label *and* f32 distance) is the one the naive kernel
//! produces, bit for bit. The guard band absorbs the gap between real
//! arithmetic (where the triangle inequality lives) and the f32 distance
//! evaluation (where labels are decided); it dominates the worst-case
//! f32 rounding of a squared distance up to [`PRUNE_MAX_CHANNELS`]
//! channels (~9× margin at the bound), and wider pixels are routed to
//! the naive scan so the invariant is enforced rather than assumed.

use super::math::StepAccum;
use super::simd::{self, SimdMode, GROUP_MAX};
use super::tile::{SoaTile, LANES};

/// Centroid tables up to this `k` live in a fixed stack array inside the
/// specialized kernels; larger tables spill to one heap allocation.
pub const MAX_STACK_K: usize = 16;

/// Relative guard band for the pruning test (see module docs).
const REL_SLACK: f64 = 1e-5;

/// Widest pixel the pruning paths accept. The guard band must dominate
/// the f32 rounding of a `C`-term squared distance (relative error
/// ≈ `(C + 2) · 2⁻²⁴`); at `C = 16` the band is still ~9× that
/// worst case. Wider pixels take the naive scan — enforced, not
/// assumed, so the bit-identity guarantee cannot silently erode.
pub const PRUNE_MAX_CHANNELS: usize = 16;

/// Which kernel path the K-Means driver uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KernelChoice {
    /// Full K-way scan every round (the reference path).
    #[default]
    Naive,
    /// Hamerly-pruned step rounds; final labeling is a full scan.
    Pruned,
    /// Pruned step rounds plus a bounds-reuse final labeling round.
    Fused,
    /// Lane-vectorized planar kernels over [`SoaTile`]s: full scans run
    /// [`LANES`] pixels wide within each channel plane, composed with
    /// the same Hamerly pruning and bounds-reuse final pass as `Fused`.
    Lanes,
    /// Native-SIMD planar kernels: the `Lanes` formulation executed with
    /// `std::arch` intrinsics at the run's dispatched
    /// [`simd::SimdLevel`] (AVX-512 / AVX2 / NEON, portable fallback).
    /// Non-FMA modes are bit-identical to `Lanes`; the opt-in FMA modes
    /// are tolerance-gated (see `kmeans/simd.rs`).
    Simd,
}

impl KernelChoice {
    pub const ALL: [KernelChoice; 5] = [
        KernelChoice::Naive,
        KernelChoice::Pruned,
        KernelChoice::Fused,
        KernelChoice::Lanes,
        KernelChoice::Simd,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            KernelChoice::Naive => "naive",
            KernelChoice::Pruned => "pruned",
            KernelChoice::Fused => "fused",
            KernelChoice::Lanes => "lanes",
            KernelChoice::Simd => "simd",
        }
    }

    /// The block layout this kernel wants when the caller leaves the
    /// layout unset: lane kernels consume planar tiles, everything else
    /// consumes interleaved buffers.
    pub fn default_layout(&self) -> super::tile::TileLayout {
        match self {
            KernelChoice::Lanes | KernelChoice::Simd => super::tile::TileLayout::Soa,
            _ => super::tile::TileLayout::Interleaved,
        }
    }
}

impl std::fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl std::str::FromStr for KernelChoice {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Ok(KernelChoice::Naive),
            "pruned" => Ok(KernelChoice::Pruned),
            "fused" => Ok(KernelChoice::Fused),
            "lanes" => Ok(KernelChoice::Lanes),
            "simd" => Ok(KernelChoice::Simd),
            other => Err(format!(
                "unknown kernel {other:?} (want naive|pruned|fused|lanes|simd)"
            )),
        }
    }
}

/// How far every centroid moved in one update, plus the maximum. The
/// leader computes this once per round and ships it to the workers; the
/// pruned kernels use it to advance per-pixel bounds. Distances are kept
/// in f64 and inflated by one part in 10¹² so f64 rounding can never
/// understate a movement.
#[derive(Clone, Debug, PartialEq)]
pub struct CentroidDrift {
    /// Euclidean movement per centroid, `k` entries.
    pub per_centroid: Vec<f64>,
    /// `max(per_centroid)` (0.0 when `k == 0`).
    pub max: f64,
}

/// Measure per-centroid movement between two centroid tables.
pub fn drift_between(old: &[f32], new: &[f32], k: usize, channels: usize) -> CentroidDrift {
    assert_eq!(old.len(), k * channels, "old centroid table length");
    assert_eq!(new.len(), k * channels, "new centroid table length");
    let mut per_centroid = Vec::with_capacity(k);
    let mut max = 0.0f64;
    for ki in 0..k {
        let base = ki * channels;
        let mut s = 0.0f64;
        for c in 0..channels {
            let d = new[base + c] as f64 - old[base + c] as f64;
            s += d * d;
        }
        let d = s.sqrt() * (1.0 + 1e-12);
        per_centroid.push(d);
        if d > max {
            max = d;
        }
    }
    CentroidDrift { per_centroid, max }
}

/// Per-pixel pruning state carried across Lloyd rounds (one per block in
/// the coordinator, one per image in the sequential driver).
#[derive(Clone, Debug, Default)]
pub struct PrunedState {
    labels: Vec<u32>,
    /// Upper bound on the distance to the assigned centre (f64 euclidean).
    upper: Vec<f64>,
    /// Lower bound on the distance to every *other* centre.
    lower: Vec<f64>,
    k: usize,
    ready: bool,
}

impl PrunedState {
    pub fn new() -> PrunedState {
        PrunedState::default()
    }

    /// Whether the state holds bounds at all (cleared states never prune).
    pub fn ready(&self) -> bool {
        self.ready
    }

    /// Whether the bounds apply to this pixel count and cluster count.
    pub fn is_valid_for(&self, n_pixels: usize, k: usize) -> bool {
        self.ready && self.k == k && self.labels.len() == n_pixels
    }

    /// Drop the bounds; the next pruned step does a full initializing scan.
    pub fn clear(&mut self) {
        self.ready = false;
    }

    /// Labels at the centroids of the last completed pass.
    pub fn labels(&self) -> &[u32] {
        &self.labels
    }

    fn reset(&mut self, n_pixels: usize, k: usize) {
        self.labels.clear();
        self.labels.resize(n_pixels, 0);
        self.upper.clear();
        self.upper.resize(n_pixels, 0.0);
        self.lower.clear();
        self.lower.resize(n_pixels, 0.0);
        self.k = k;
        self.ready = true;
    }
}

/// The pruning test: is the (bounded) own-centre distance `u` provably
/// below the (bounded) other-centre distance `l`, with enough slack that
/// f32 rounding of the underlying distances cannot flip the argmin?
#[inline]
fn provably_closer(u: f64, l: f64) -> bool {
    u * (1.0 + REL_SLACK) + 1e-12 < l
}

// ---------------------------------------------------------------------------
// Centroid tables: width-specialized and generic views.
// ---------------------------------------------------------------------------

/// What the algorithm cores need from a centroid table. Implemented by a
/// width-specialized view (`C` const, pixels as `&[f32; C]`, bounds
/// checks gone after monomorphization) and a generic slice view. All
/// implementations scan centroids in index order with strict-`<`
/// minima — the tie-breaking contract of [`super::math::nearest`].
trait CenTable {
    fn k(&self) -> usize;
    fn channels(&self) -> usize;
    /// Squared f32 distance to one centroid (same accumulation order as
    /// [`super::math::sqdist`], so values match bit for bit).
    fn dist2(&self, px: &[f32], ci: usize) -> f32;
    /// Nearest centroid (lowest index wins ties) and its squared distance.
    fn nearest(&self, px: &[f32]) -> (u32, f32);
    /// Nearest centroid plus the runner-up squared distance
    /// (`f32::INFINITY` when `k == 1`).
    fn nearest2(&self, px: &[f32]) -> (u32, f32, f32);
}

/// Width-specialized table over `[f32; C]` centroid rows.
struct SpecTable<'a, const C: usize> {
    cen: &'a [[f32; C]],
}

impl<const C: usize> CenTable for SpecTable<'_, C> {
    #[inline]
    fn k(&self) -> usize {
        self.cen.len()
    }

    #[inline]
    fn channels(&self) -> usize {
        C
    }

    #[inline]
    fn dist2(&self, px: &[f32], ci: usize) -> f32 {
        let px: &[f32; C] = px.try_into().expect("pixel width != C");
        let c = &self.cen[ci];
        let mut acc = 0.0f32;
        for ch in 0..C {
            let d = px[ch] - c[ch];
            acc += d * d;
        }
        acc
    }

    #[inline]
    fn nearest(&self, px: &[f32]) -> (u32, f32) {
        let px: &[f32; C] = px.try_into().expect("pixel width != C");
        let mut best = 0u32;
        let mut best_d = f32::INFINITY;
        for (i, c) in self.cen.iter().enumerate() {
            let mut d = 0.0f32;
            for ch in 0..C {
                let t = px[ch] - c[ch];
                d += t * t;
            }
            if d < best_d {
                best_d = d;
                best = i as u32;
            }
        }
        (best, best_d)
    }

    #[inline]
    fn nearest2(&self, px: &[f32]) -> (u32, f32, f32) {
        let px: &[f32; C] = px.try_into().expect("pixel width != C");
        let mut best = 0u32;
        let mut best_d = f32::INFINITY;
        let mut second_d = f32::INFINITY;
        for (i, c) in self.cen.iter().enumerate() {
            let mut d = 0.0f32;
            for ch in 0..C {
                let t = px[ch] - c[ch];
                d += t * t;
            }
            if d < best_d {
                second_d = best_d;
                best_d = d;
                best = i as u32;
            } else if d < second_d {
                second_d = d;
            }
        }
        (best, best_d, second_d)
    }
}

/// Owned, stack-first storage backing a [`SpecTable`].
struct SpecBuf<const C: usize> {
    stack: [[f32; C]; MAX_STACK_K],
    heap: Vec<[f32; C]>,
    k: usize,
}

impl<const C: usize> SpecBuf<C> {
    #[inline]
    fn new(centroids: &[f32], k: usize) -> SpecBuf<C> {
        debug_assert_eq!(
            centroids.len(),
            k * C,
            "centroid table length {} does not match k={k} x channels={C}",
            centroids.len()
        );
        let mut buf = SpecBuf {
            stack: [[0.0; C]; MAX_STACK_K],
            heap: Vec::new(),
            k,
        };
        if k <= MAX_STACK_K {
            for (dst, src) in buf.stack.iter_mut().zip(centroids.chunks_exact(C)) {
                dst.copy_from_slice(src);
            }
        } else {
            buf.heap = centroids
                .chunks_exact(C)
                .map(|src| {
                    let mut a = [0.0f32; C];
                    a.copy_from_slice(src);
                    a
                })
                .collect();
        }
        buf
    }

    #[inline]
    fn table(&self) -> SpecTable<'_, C> {
        SpecTable {
            cen: if self.k <= MAX_STACK_K {
                &self.stack[..self.k]
            } else {
                &self.heap
            },
        }
    }
}

/// Generic fallback over a flat centroid slice (any channel count).
struct DynTable<'a> {
    cen: &'a [f32],
    channels: usize,
}

impl CenTable for DynTable<'_> {
    #[inline]
    fn k(&self) -> usize {
        self.cen.len() / self.channels
    }

    #[inline]
    fn channels(&self) -> usize {
        self.channels
    }

    #[inline]
    fn dist2(&self, px: &[f32], ci: usize) -> f32 {
        let base = ci * self.channels;
        let c = &self.cen[base..base + self.channels];
        let mut acc = 0.0f32;
        for (a, b) in px.iter().zip(c) {
            let d = a - b;
            acc += d * d;
        }
        acc
    }

    #[inline]
    fn nearest(&self, px: &[f32]) -> (u32, f32) {
        let mut best = 0u32;
        let mut best_d = f32::INFINITY;
        for (i, c) in self.cen.chunks_exact(self.channels).enumerate() {
            let mut d = 0.0f32;
            for (a, b) in px.iter().zip(c) {
                let t = a - b;
                d += t * t;
            }
            if d < best_d {
                best_d = d;
                best = i as u32;
            }
        }
        (best, best_d)
    }

    #[inline]
    fn nearest2(&self, px: &[f32]) -> (u32, f32, f32) {
        let mut best = 0u32;
        let mut best_d = f32::INFINITY;
        let mut second_d = f32::INFINITY;
        for (i, c) in self.cen.chunks_exact(self.channels).enumerate() {
            let mut d = 0.0f32;
            for (a, b) in px.iter().zip(c) {
                let t = a - b;
                d += t * t;
            }
            if d < best_d {
                second_d = best_d;
                best_d = d;
                best = i as u32;
            } else if d < second_d {
                second_d = d;
            }
        }
        (best, best_d, second_d)
    }
}

/// Dispatch a kernel body over the width-specialized tables (C = 1/3/4)
/// or the generic fallback. The body is expanded once per arm, so every
/// specialized instantiation is fully monomorphic.
macro_rules! with_table {
    ($cen:expr, $k:expr, $ch:expr, |$t:ident| $body:expr) => {{
        match $ch {
            1 => {
                let buf = SpecBuf::<1>::new($cen, $k);
                let $t = buf.table();
                $body
            }
            3 => {
                let buf = SpecBuf::<3>::new($cen, $k);
                let $t = buf.table();
                $body
            }
            4 => {
                let buf = SpecBuf::<4>::new($cen, $k);
                let $t = buf.table();
                $body
            }
            ch => {
                let $t = DynTable {
                    cen: $cen,
                    channels: ch,
                };
                $body
            }
        }
    }};
}

// ---------------------------------------------------------------------------
// Algorithm cores (generic over the table; monomorphized per width).
// ---------------------------------------------------------------------------

/// Fold one labeled pixel into the accumulator — same value stream and
/// order as the reference loop in `math`, so sums/counts/inertia match
/// bit for bit.
#[inline]
fn accumulate_px(acc: &mut StepAccum, px: &[f32], label: u32, d2: f32) {
    let base = label as usize * px.len();
    for (c, &v) in px.iter().enumerate() {
        acc.sums[base + c] += v as f64;
    }
    acc.counts[label as usize] += 1;
    acc.inertia += d2 as f64;
}

fn step_core<T: CenTable>(pixels: &[f32], t: &T) -> StepAccum {
    let ch = t.channels();
    let mut acc = StepAccum::zeros(t.k(), ch);
    // Four-pixel software pipeline: labels/distances for four independent
    // pixels first (four dependency chains), accumulation after, in pixel
    // order — identical accumulation sequence to the one-at-a-time loop.
    let mut quads = pixels.chunks_exact(4 * ch);
    for quad in quads.by_ref() {
        let mut labs = [0u32; 4];
        let mut ds = [0f32; 4];
        for (j, px) in quad.chunks_exact(ch).enumerate() {
            let (l, d) = t.nearest(px);
            labs[j] = l;
            ds[j] = d;
        }
        for (j, px) in quad.chunks_exact(ch).enumerate() {
            accumulate_px(&mut acc, px, labs[j], ds[j]);
        }
    }
    for px in quads.remainder().chunks_exact(ch) {
        let (l, d) = t.nearest(px);
        accumulate_px(&mut acc, px, l, d);
    }
    acc
}

fn assign_core<T: CenTable>(pixels: &[f32], t: &T, labels: &mut Vec<u32>) -> f64 {
    let ch = t.channels();
    let mut inertia = 0.0f64;
    let mut quads = pixels.chunks_exact(4 * ch);
    for quad in quads.by_ref() {
        let mut labs = [0u32; 4];
        let mut ds = [0f32; 4];
        for (j, px) in quad.chunks_exact(ch).enumerate() {
            let (l, d) = t.nearest(px);
            labs[j] = l;
            ds[j] = d;
        }
        for j in 0..4 {
            labels.push(labs[j]);
            inertia += ds[j] as f64;
        }
    }
    for px in quads.remainder().chunks_exact(ch) {
        let (l, d) = t.nearest(px);
        labels.push(l);
        inertia += d as f64;
    }
    inertia
}

fn fused_core<T: CenTable>(pixels: &[f32], t: &T, labels: &mut Vec<u32>) -> StepAccum {
    let ch = t.channels();
    let mut acc = StepAccum::zeros(t.k(), ch);
    // Same 4-pixel pipeline as step_core/assign_core so the fused bench
    // row measures fusion, not a missing optimization.
    let mut quads = pixels.chunks_exact(4 * ch);
    for quad in quads.by_ref() {
        let mut labs = [0u32; 4];
        let mut ds = [0f32; 4];
        for (j, px) in quad.chunks_exact(ch).enumerate() {
            let (l, d) = t.nearest(px);
            labs[j] = l;
            ds[j] = d;
        }
        for (j, px) in quad.chunks_exact(ch).enumerate() {
            labels.push(labs[j]);
            accumulate_px(&mut acc, px, labs[j], ds[j]);
        }
    }
    for px in quads.remainder().chunks_exact(ch) {
        let (l, d) = t.nearest(px);
        labels.push(l);
        accumulate_px(&mut acc, px, l, d);
    }
    acc
}

/// Full scan that also seeds the pruning bounds (round 0 of a pruned run,
/// or any round where the state was invalidated).
fn init_core<T: CenTable>(pixels: &[f32], t: &T, st: &mut PrunedState) -> StepAccum {
    let ch = t.channels();
    let k = t.k();
    st.reset(pixels.len() / ch, k);
    let mut acc = StepAccum::zeros(k, ch);
    for (i, px) in pixels.chunks_exact(ch).enumerate() {
        let (lab, best_d2, second_d2) = t.nearest2(px);
        st.labels[i] = lab;
        st.upper[i] = (best_d2 as f64).sqrt();
        st.lower[i] = (second_d2 as f64).sqrt();
        accumulate_px(&mut acc, px, lab, best_d2);
    }
    acc
}

fn step_pruned_core<T: CenTable>(
    pixels: &[f32],
    t: &T,
    st: &mut PrunedState,
    drift: &CentroidDrift,
) -> StepAccum {
    let ch = t.channels();
    let k = t.k();
    debug_assert!(st.is_valid_for(pixels.len() / ch, k));
    debug_assert_eq!(drift.per_centroid.len(), k);
    let mut acc = StepAccum::zeros(k, ch);
    for (i, px) in pixels.chunks_exact(ch).enumerate() {
        let a = st.labels[i] as usize;
        let mut u = st.upper[i] + drift.per_centroid[a];
        let l = st.lower[i] - drift.max;
        // The own-centre distance is needed either way: it is this
        // pixel's exact inertia contribution when the label survives.
        let d2a = t.dist2(px, a);
        let skip = provably_closer(u, l) || {
            u = (d2a as f64).sqrt(); // tighten, retest
            provably_closer(u, l)
        };
        if skip {
            st.upper[i] = u;
            st.lower[i] = l;
            accumulate_px(&mut acc, px, a as u32, d2a);
        } else {
            let (lab, best_d2, second_d2) = t.nearest2(px);
            st.labels[i] = lab;
            st.upper[i] = (best_d2 as f64).sqrt();
            st.lower[i] = (second_d2 as f64).sqrt();
            accumulate_px(&mut acc, px, lab, best_d2);
        }
    }
    acc
}

fn assign_pruned_core<T: CenTable>(
    pixels: &[f32],
    t: &T,
    st: &mut PrunedState,
    drift: &CentroidDrift,
    labels: &mut Vec<u32>,
) -> f64 {
    let ch = t.channels();
    debug_assert!(st.is_valid_for(pixels.len() / ch, t.k()));
    let mut inertia = 0.0f64;
    for (i, px) in pixels.chunks_exact(ch).enumerate() {
        let a = st.labels[i] as usize;
        let mut u = st.upper[i] + drift.per_centroid[a];
        let l = st.lower[i] - drift.max;
        let d2a = t.dist2(px, a);
        let skip = provably_closer(u, l) || {
            u = (d2a as f64).sqrt();
            provably_closer(u, l)
        };
        if skip {
            st.upper[i] = u;
            st.lower[i] = l;
            labels.push(a as u32);
            inertia += d2a as f64;
        } else {
            let (lab, best_d2, second_d2) = t.nearest2(px);
            st.labels[i] = lab;
            st.upper[i] = (best_d2 as f64).sqrt();
            st.lower[i] = (second_d2 as f64).sqrt();
            labels.push(lab);
            inertia += best_d2 as f64;
        }
    }
    inertia
}

// ---------------------------------------------------------------------------
// Lane kernels over planar SoA tiles.
//
// The width-specialized kernels above vectorize *across channels* of one
// pixel — at C = 3 that is a 3-wide reduction, which LLVM mostly leaves
// scalar. The lane kernels flip the loop nest: with the block stored as
// channel planes (`SoaTile`), one centroid channel is broadcast against
// LANES consecutive *pixels* at a time — `[f32; LANES]` array arithmetic
// with unit-stride loads, exactly the shape the auto-vectorizer turns
// into packed subs/FMAs on stable rustc.
//
// Bit-identity argument (extends the module-level one): for each pixel
// lane `l`, `d[l]` accumulates `(plane[c][i] - cen[c])²` over channels in
// ascending `c` order — the identical f32 operation sequence the scalar
// `dist2` performs for that pixel, merely executed alongside 7
// neighbours; lanes never mix. The argmin scans centroids in index order
// with the same strict-`<` tie-breaking, the accumulator folds pixels in
// the same pixel order with the same f64 adds, and the padded tail lanes
// (zeros) are computed but never emitted. Pruning composes unchanged:
// the bounds math is per-pixel and uses these same distances, so the
// guard-band argument of `provably_closer` carries over verbatim, and
// channels above PRUNE_MAX_CHANNELS likewise never prune (they still
// lane-vectorize — full scans are exact at any width).
// ---------------------------------------------------------------------------

/// Scalar squared distance of tile pixel `i` to centroid `ci`, with the
/// exact accumulation order of [`CenTable::dist2`].
#[inline]
fn soa_dist2(tile: &SoaTile, i: usize, cen: &[f32], ci: usize) -> f32 {
    let ch = tile.channels();
    let base = ci * ch;
    let mut acc = 0.0f32;
    for c in 0..ch {
        let t = tile.plane(c)[i] - cen[base + c];
        acc += t * t;
    }
    acc
}

/// Scalar nearest-plus-runner-up for tile pixel `i` — the SoA mirror of
/// [`CenTable::nearest2`] (same scan order, same strict-`<` ties).
#[inline]
fn soa_nearest2(tile: &SoaTile, i: usize, cen: &[f32], k: usize) -> (u32, f32, f32) {
    let mut best = 0u32;
    let mut best_d = f32::INFINITY;
    let mut second_d = f32::INFINITY;
    for ci in 0..k {
        let d = soa_dist2(tile, i, cen, ci);
        if d < best_d {
            second_d = best_d;
            best_d = d;
            best = ci as u32;
        } else if d < second_d {
            second_d = d;
        }
    }
    (best, best_d, second_d)
}

/// Fold tile pixel `i` into the accumulator — the SoA mirror of
/// [`accumulate_px`] (channel-ascending f64 adds, identical sequence).
#[inline]
fn accumulate_soa(acc: &mut StepAccum, tile: &SoaTile, i: usize, label: u32, d2: f32) {
    let ch = tile.channels();
    let base = label as usize * ch;
    for c in 0..ch {
        acc.sums[base + c] += tile.plane(c)[i] as f64;
    }
    acc.counts[label as usize] += 1;
    acc.inertia += d2 as f64;
}

/// Nearest + runner-up for the LANES pixels starting at `start`, all
/// centroids. The hot loop of every lane kernel: per centroid, each
/// channel plane contributes to all LANES distance accumulators with
/// unit stride. Tail lanes past the pixel count compute on the zero
/// padding; callers mask them at emission.
#[inline]
pub(crate) fn lane_nearest2(
    tile: &SoaTile,
    start: usize,
    cen: &[f32],
    k: usize,
) -> ([u32; LANES], [f32; LANES], [f32; LANES]) {
    let ch = tile.channels();
    let mut best = [0u32; LANES];
    let mut best_d = [f32::INFINITY; LANES];
    let mut second_d = [f32::INFINITY; LANES];
    for ci in 0..k {
        let mut d = [0.0f32; LANES];
        for c in 0..ch {
            let cv = cen[ci * ch + c];
            let p = &tile.plane(c)[start..start + LANES];
            for l in 0..LANES {
                let t = p[l] - cv;
                d[l] += t * t;
            }
        }
        for l in 0..LANES {
            if d[l] < best_d[l] {
                second_d[l] = best_d[l];
                best_d[l] = d[l];
                best[l] = ci as u32;
            } else if d[l] < second_d[l] {
                second_d[l] = d[l];
            }
        }
    }
    (best, best_d, second_d)
}

/// Lane-vectorized full accumulation scan. With `st`, also seeds the
/// Hamerly bounds (round 0 of a lanes run); without, a plain exact pass
/// (the wide-channel never-prune path).
fn lanes_scan_step(
    tile: &SoaTile,
    cen: &[f32],
    k: usize,
    mut st: Option<&mut PrunedState>,
) -> StepAccum {
    let n = tile.pixels();
    if let Some(st) = st.as_deref_mut() {
        st.reset(n, k);
    }
    let mut acc = StepAccum::zeros(k, tile.channels());
    let mut start = 0;
    while start < n {
        let (labs, best_d, second_d) = lane_nearest2(tile, start, cen, k);
        let lim = LANES.min(n - start); // mask the padded tail lanes
        for l in 0..lim {
            let i = start + l;
            if let Some(st) = st.as_deref_mut() {
                st.labels[i] = labs[l];
                st.upper[i] = (best_d[l] as f64).sqrt();
                st.lower[i] = (second_d[l] as f64).sqrt();
            }
            accumulate_soa(&mut acc, tile, i, labs[l], best_d[l]);
        }
        start += LANES;
    }
    acc
}

/// Lane-vectorized full labeling scan (the final round when no bounds
/// are available).
fn lanes_scan_assign(tile: &SoaTile, cen: &[f32], k: usize, labels: &mut Vec<u32>) -> f64 {
    let n = tile.pixels();
    let mut inertia = 0.0f64;
    let mut start = 0;
    while start < n {
        let (labs, best_d, _) = lane_nearest2(tile, start, cen, k);
        let lim = LANES.min(n - start);
        for l in 0..lim {
            labels.push(labs[l]);
            inertia += best_d[l] as f64;
        }
        start += LANES;
    }
    inertia
}

/// Hamerly-pruned accumulation round over a tile — [`step_pruned_core`]
/// with every distance routed through the SoA helpers (bit-identical by
/// construction).
fn lanes_step_pruned_core(
    tile: &SoaTile,
    cen: &[f32],
    k: usize,
    st: &mut PrunedState,
    drift: &CentroidDrift,
) -> StepAccum {
    let n = tile.pixels();
    debug_assert!(st.is_valid_for(n, k));
    debug_assert_eq!(drift.per_centroid.len(), k);
    let mut acc = StepAccum::zeros(k, tile.channels());
    for i in 0..n {
        let a = st.labels[i] as usize;
        let mut u = st.upper[i] + drift.per_centroid[a];
        let l = st.lower[i] - drift.max;
        let d2a = soa_dist2(tile, i, cen, a);
        let skip = provably_closer(u, l) || {
            u = (d2a as f64).sqrt();
            provably_closer(u, l)
        };
        if skip {
            st.upper[i] = u;
            st.lower[i] = l;
            accumulate_soa(&mut acc, tile, i, a as u32, d2a);
        } else {
            let (lab, best_d2, second_d2) = soa_nearest2(tile, i, cen, k);
            st.labels[i] = lab;
            st.upper[i] = (best_d2 as f64).sqrt();
            st.lower[i] = (second_d2 as f64).sqrt();
            accumulate_soa(&mut acc, tile, i, lab, best_d2);
        }
    }
    acc
}

/// Bounds-reuse final labeling over a tile ([`assign_pruned_core`] on
/// SoA).
fn lanes_assign_pruned_core(
    tile: &SoaTile,
    cen: &[f32],
    k: usize,
    st: &mut PrunedState,
    drift: &CentroidDrift,
    labels: &mut Vec<u32>,
) -> f64 {
    let n = tile.pixels();
    debug_assert!(st.is_valid_for(n, k));
    let mut inertia = 0.0f64;
    for i in 0..n {
        let a = st.labels[i] as usize;
        let mut u = st.upper[i] + drift.per_centroid[a];
        let l = st.lower[i] - drift.max;
        let d2a = soa_dist2(tile, i, cen, a);
        let skip = provably_closer(u, l) || {
            u = (d2a as f64).sqrt();
            provably_closer(u, l)
        };
        if skip {
            st.upper[i] = u;
            st.lower[i] = l;
            labels.push(a as u32);
            inertia += d2a as f64;
        } else {
            let (lab, best_d2, second_d2) = soa_nearest2(tile, i, cen, k);
            st.labels[i] = lab;
            st.upper[i] = (best_d2 as f64).sqrt();
            st.lower[i] = (second_d2 as f64).sqrt();
            labels.push(lab);
            inertia += best_d2 as f64;
        }
    }
    inertia
}

fn check_tile_shapes(tile: &SoaTile, centroids: &[f32], k: usize) {
    assert!(tile.channels() >= 1, "channels must be >= 1");
    assert_eq!(
        centroids.len(),
        k * tile.channels(),
        "centroid table length {} does not match k={k} x channels={}",
        centroids.len(),
        tile.channels()
    );
}

/// One Lloyd accumulation pass of the lanes kernel: lane-vectorized
/// full scans, Hamerly-pruned when `state` carries usable bounds.
/// Returns exactly what [`step_kernel`] would for the interleaved view
/// of the same tile (property-tested).
pub fn step_lanes(
    tile: &SoaTile,
    centroids: &[f32],
    k: usize,
    state: &mut PrunedState,
    drift: Option<&CentroidDrift>,
) -> StepAccum {
    check_tile_shapes(tile, centroids, k);
    if tile.channels() > PRUNE_MAX_CHANNELS {
        // Outside the guard band: never prune, but still lane-vectorize
        // the (exact-at-any-width) full scan.
        state.clear();
        return lanes_scan_step(tile, centroids, k, None);
    }
    match drift {
        Some(d) if state.is_valid_for(tile.pixels(), k) => {
            lanes_step_pruned_core(tile, centroids, k, state, d)
        }
        _ => lanes_scan_step(tile, centroids, k, Some(state)),
    }
}

/// Final labeling of the lanes kernel: bounds-reuse when possible, a
/// lane-vectorized full scan otherwise. Labels and inertia identical to
/// [`assign_kernel`] at the same centroids.
pub fn assign_lanes(
    tile: &SoaTile,
    centroids: &[f32],
    k: usize,
    state: &mut PrunedState,
    drift: Option<&CentroidDrift>,
    labels: &mut Vec<u32>,
) -> f64 {
    check_tile_shapes(tile, centroids, k);
    labels.clear();
    labels.reserve(tile.pixels());
    if tile.channels() > PRUNE_MAX_CHANNELS {
        state.clear();
        return lanes_scan_assign(tile, centroids, k, labels);
    }
    match drift {
        Some(d) if state.is_valid_for(tile.pixels(), k) => {
            lanes_assign_pruned_core(tile, centroids, k, state, d, labels)
        }
        _ => lanes_scan_assign(tile, centroids, k, labels),
    }
}

// ---------------------------------------------------------------------------
// Native-SIMD kernels: the lanes formulation with the inner group loop
// dispatched through `simd::group_fn` (AVX-512 / AVX2 / NEON / portable,
// selected once per scan). Only the full scans change — pruned rounds
// are per-pixel scalar work dominated by the bounds test, so they share
// `lanes_step_pruned_core` / `lanes_assign_pruned_core` verbatim. The
// group width may be wider than LANES (AVX-512 runs 16 pixels); tile
// planes are padded to a GROUP_MAX multiple so group loads stay in
// bounds, and emission masks lanes past the pixel count in ascending
// pixel order — per-pixel op order, and therefore bit-identity, is
// independent of group width.
// ---------------------------------------------------------------------------

/// SIMD-dispatched full accumulation scan ([`lanes_scan_step`] with the
/// inner loop swapped for the mode's native group kernel).
fn simd_scan_step(
    tile: &SoaTile,
    cen: &[f32],
    k: usize,
    mut st: Option<&mut PrunedState>,
    mode: SimdMode,
) -> StepAccum {
    let n = tile.pixels();
    if let Some(st) = st.as_deref_mut() {
        st.reset(n, k);
    }
    let mut acc = StepAccum::zeros(k, tile.channels());
    let (group, width) = simd::group_fn(mode);
    let mut labs = [0u32; GROUP_MAX];
    let mut best_d = [0.0f32; GROUP_MAX];
    let mut second_d = [0.0f32; GROUP_MAX];
    let mut start = 0;
    while start < n {
        group(tile, start, cen, k, &mut labs, &mut best_d, &mut second_d);
        let lim = width.min(n - start); // mask the padded tail lanes
        for l in 0..lim {
            let i = start + l;
            if let Some(st) = st.as_deref_mut() {
                st.labels[i] = labs[l];
                st.upper[i] = (best_d[l] as f64).sqrt();
                st.lower[i] = (second_d[l] as f64).sqrt();
            }
            accumulate_soa(&mut acc, tile, i, labs[l], best_d[l]);
        }
        start += width;
    }
    acc
}

/// SIMD-dispatched full labeling scan ([`lanes_scan_assign`] on the
/// native group kernel).
fn simd_scan_assign(
    tile: &SoaTile,
    cen: &[f32],
    k: usize,
    labels: &mut Vec<u32>,
    mode: SimdMode,
) -> f64 {
    let n = tile.pixels();
    let mut inertia = 0.0f64;
    let (group, width) = simd::group_fn(mode);
    let mut labs = [0u32; GROUP_MAX];
    let mut best_d = [0.0f32; GROUP_MAX];
    let mut second_d = [0.0f32; GROUP_MAX];
    let mut start = 0;
    while start < n {
        group(tile, start, cen, k, &mut labs, &mut best_d, &mut second_d);
        let lim = width.min(n - start);
        for l in 0..lim {
            labels.push(labs[l]);
            inertia += best_d[l] as f64;
        }
        start += width;
    }
    inertia
}

/// One Lloyd accumulation pass of the native-SIMD kernel: full scans run
/// on the dispatched intrinsics, pruned rounds share the lanes cores.
/// Without FMA this returns exactly what [`step_lanes`] (and therefore
/// [`step_kernel`]) would — property-tested per level.
pub fn step_simd(
    tile: &SoaTile,
    centroids: &[f32],
    k: usize,
    state: &mut PrunedState,
    drift: Option<&CentroidDrift>,
    mode: SimdMode,
) -> StepAccum {
    check_tile_shapes(tile, centroids, k);
    if tile.channels() > PRUNE_MAX_CHANNELS {
        state.clear();
        return simd_scan_step(tile, centroids, k, None, mode);
    }
    match drift {
        Some(d) if state.is_valid_for(tile.pixels(), k) => {
            lanes_step_pruned_core(tile, centroids, k, state, d)
        }
        _ => simd_scan_step(tile, centroids, k, Some(state), mode),
    }
}

/// Final labeling of the native-SIMD kernel: bounds-reuse when possible,
/// a SIMD full scan otherwise. Identical to [`assign_lanes`] without
/// FMA.
pub fn assign_simd(
    tile: &SoaTile,
    centroids: &[f32],
    k: usize,
    state: &mut PrunedState,
    drift: Option<&CentroidDrift>,
    labels: &mut Vec<u32>,
    mode: SimdMode,
) -> f64 {
    check_tile_shapes(tile, centroids, k);
    labels.clear();
    labels.reserve(tile.pixels());
    if tile.channels() > PRUNE_MAX_CHANNELS {
        state.clear();
        return simd_scan_assign(tile, centroids, k, labels, mode);
    }
    match drift {
        Some(d) if state.is_valid_for(tile.pixels(), k) => {
            lanes_assign_pruned_core(tile, centroids, k, state, d, labels)
        }
        _ => simd_scan_assign(tile, centroids, k, labels, mode),
    }
}

// ---------------------------------------------------------------------------
// Public entry points.
// ---------------------------------------------------------------------------

fn check_shapes(pixels: &[f32], centroids: &[f32], k: usize, channels: usize) {
    assert!(channels >= 1, "channels must be >= 1");
    assert_eq!(
        pixels.len() % channels,
        0,
        "pixel buffer length {} is not a multiple of channels={channels}",
        pixels.len()
    );
    assert_eq!(
        centroids.len(),
        k * channels,
        "centroid table length {} does not match k={k} x channels={channels}",
        centroids.len()
    );
}

/// One Lloyd accumulation pass (width-dispatched naive kernel).
pub fn step_kernel(pixels: &[f32], centroids: &[f32], k: usize, channels: usize) -> StepAccum {
    check_shapes(pixels, centroids, k, channels);
    with_table!(centroids, k, channels, |t| step_core(pixels, &t))
}

/// Assign every pixel (width-dispatched naive kernel); writes `labels`,
/// returns summed inertia.
pub fn assign_kernel(
    pixels: &[f32],
    centroids: &[f32],
    k: usize,
    channels: usize,
    labels: &mut Vec<u32>,
) -> f64 {
    check_shapes(pixels, centroids, k, channels);
    labels.clear();
    labels.reserve(pixels.len() / channels);
    with_table!(centroids, k, channels, |t| assign_core(pixels, &t, labels))
}

/// One pass producing both the accumulator and the label map — the fused
/// step-and-assign kernel. Bit-identical to [`step_kernel`] +
/// [`assign_kernel`] run separately at the same centroids. This is the
/// standalone primitive for callers that need both outputs at one
/// centroid table (the pruned driver gets the same fusion implicitly:
/// its bound-seeding scan labels while it accumulates); the micro bench
/// tier tracks its cost against the separate passes.
pub fn fused_step_assign(
    pixels: &[f32],
    centroids: &[f32],
    k: usize,
    channels: usize,
    labels: &mut Vec<u32>,
) -> StepAccum {
    check_shapes(pixels, centroids, k, channels);
    labels.clear();
    labels.reserve(pixels.len() / channels);
    with_table!(centroids, k, channels, |t| fused_core(pixels, &t, labels))
}

/// One Lloyd accumulation pass with Hamerly pruning. When `drift` is
/// present and `state` carries bounds from the previous round, pixels
/// whose assignment provably cannot change are folded in with a single
/// distance evaluation; otherwise the pass runs a full scan that seeds
/// the bounds. The returned accumulator equals [`step_kernel`]'s exactly
/// (`StepAccum: PartialEq` — property-tested).
pub fn step_pruned(
    pixels: &[f32],
    centroids: &[f32],
    k: usize,
    channels: usize,
    state: &mut PrunedState,
    drift: Option<&CentroidDrift>,
) -> StepAccum {
    check_shapes(pixels, centroids, k, channels);
    if channels > PRUNE_MAX_CHANNELS {
        // Guard band no longer covers f32 distance rounding: never prune.
        state.clear();
        return step_kernel(pixels, centroids, k, channels);
    }
    let n = pixels.len() / channels;
    with_table!(centroids, k, channels, |t| match drift {
        Some(d) if state.is_valid_for(n, k) => step_pruned_core(pixels, &t, state, d),
        _ => init_core(pixels, &t, state),
    })
}

/// Final labeling that reuses the previous round's bounds instead of a
/// from-scratch K-way scan per pixel. Labels and inertia are identical
/// to [`assign_kernel`] at the same centroids; falls back to the full
/// scan when the state or drift is missing.
pub fn assign_pruned(
    pixels: &[f32],
    centroids: &[f32],
    k: usize,
    channels: usize,
    state: &mut PrunedState,
    drift: Option<&CentroidDrift>,
    labels: &mut Vec<u32>,
) -> f64 {
    check_shapes(pixels, centroids, k, channels);
    if channels > PRUNE_MAX_CHANNELS {
        state.clear();
        return assign_kernel(pixels, centroids, k, channels, labels);
    }
    let n = pixels.len() / channels;
    labels.clear();
    labels.reserve(n);
    with_table!(centroids, k, channels, |t| match drift {
        Some(d) if state.is_valid_for(n, k) => assign_pruned_core(pixels, &t, state, d, labels),
        _ => assign_core(pixels, &t, labels),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::math::{self, StepAccum};
    use crate::util::prng::Rng;

    fn random_pixels(n: usize, channels: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * channels).map(|_| rng.next_f32() * 255.0).collect()
    }

    /// Inline copy of the generic reference loop (the semantics every
    /// kernel must reproduce bit for bit).
    fn reference_step(px: &[f32], cen: &[f32], k: usize, channels: usize) -> StepAccum {
        let mut want = StepAccum::zeros(k, channels);
        for p in px.chunks_exact(channels) {
            let (l, d) = math::nearest(p, cen, k, channels);
            let base = l as usize * channels;
            for (c, &v) in p.iter().enumerate() {
                want.sums[base + c] += v as f64;
            }
            want.counts[l as usize] += 1;
            want.inertia += d as f64;
        }
        want
    }

    fn reference_assign(px: &[f32], cen: &[f32], k: usize, channels: usize) -> (Vec<u32>, f64) {
        let mut labels = Vec::new();
        let mut inertia = 0.0f64;
        for p in px.chunks_exact(channels) {
            let (l, d) = math::nearest(p, cen, k, channels);
            labels.push(l);
            inertia += d as f64;
        }
        (labels, inertia)
    }

    #[test]
    fn specialized_widths_match_reference_bitwise() {
        for channels in [1usize, 3, 4, 5] {
            for k in [1usize, 2, 4, 8, MAX_STACK_K + 4] {
                let px = random_pixels(1021, channels, 11 + channels as u64);
                let cen = random_pixels(k, channels, 99 + k as u64);
                let want = reference_step(&px, &cen, k, channels);
                let got = step_kernel(&px, &cen, k, channels);
                assert_eq!(got, want, "step C={channels} k={k}");

                let (want_labels, want_inertia) = reference_assign(&px, &cen, k, channels);
                let mut labels = Vec::new();
                let inertia = assign_kernel(&px, &cen, k, channels, &mut labels);
                assert_eq!(labels, want_labels, "assign C={channels} k={k}");
                assert_eq!(inertia, want_inertia, "assign inertia C={channels} k={k}");
            }
        }
    }

    #[test]
    fn fused_matches_step_plus_assign() {
        let px = random_pixels(513, 3, 5);
        let cen = random_pixels(4, 3, 6);
        let mut fused_labels = Vec::new();
        let fused_acc = fused_step_assign(&px, &cen, 4, 3, &mut fused_labels);
        assert_eq!(fused_acc, step_kernel(&px, &cen, 4, 3));
        let mut labels = Vec::new();
        let inertia = assign_kernel(&px, &cen, 4, 3, &mut labels);
        assert_eq!(fused_labels, labels);
        assert_eq!(fused_acc.inertia, inertia);
    }

    #[test]
    fn pruned_rounds_are_bit_identical_to_naive() {
        for channels in [1usize, 3, 4, 5] {
            for k in [1usize, 2, 4, 8] {
                let px = random_pixels(700, channels, 21 + channels as u64 * k as u64);
                let mut cen: Vec<f32> = px[..k * channels].to_vec();
                let mut state = PrunedState::new();
                let mut drift: Option<CentroidDrift> = None;
                for round in 0..6 {
                    let want = step_kernel(&px, &cen, k, channels);
                    let got = step_pruned(&px, &cen, k, channels, &mut state, drift.as_ref());
                    assert_eq!(got, want, "C={channels} k={k} round={round}");
                    let prev = cen.clone();
                    math::update_centroids(&want, &mut cen, 0.0);
                    drift = Some(drift_between(&prev, &cen, k, channels));
                }
                // Fused final labeling at the post-update centroids.
                let mut labels = Vec::new();
                let inertia =
                    assign_pruned(&px, &cen, k, channels, &mut state, drift.as_ref(), &mut labels);
                let mut want_labels = Vec::new();
                let want_inertia = assign_kernel(&px, &cen, k, channels, &mut want_labels);
                assert_eq!(labels, want_labels, "C={channels} k={k} final labels");
                assert_eq!(inertia, want_inertia, "C={channels} k={k} final inertia");
            }
        }
    }

    #[test]
    fn pruned_handles_duplicate_centroids_like_naive() {
        // Exact distance ties: duplicated centres and integer-grid pixels.
        let mut rng = Rng::new(3);
        let px: Vec<f32> = (0..600).map(|_| rng.range_usize(0, 4) as f32).collect();
        let cen = vec![1.0, 1.0, 1.0, /* dup */ 1.0, 1.0, 1.0, /* */ 3.0, 3.0, 3.0, 0.0, 1.0, 2.0];
        let mut state = PrunedState::new();
        let mut drift = None;
        let mut c = cen.clone();
        for _ in 0..4 {
            let want = step_kernel(&px, &c, 4, 3);
            let got = step_pruned(&px, &c, 4, 3, &mut state, drift.as_ref());
            assert_eq!(got, want);
            let prev = c.clone();
            math::update_centroids(&want, &mut c, 0.0);
            drift = Some(drift_between(&prev, &c, 4, 3));
        }
    }

    #[test]
    fn invalid_state_falls_back_to_full_scan() {
        let px = random_pixels(100, 3, 7);
        let cen = random_pixels(2, 3, 8);
        let mut state = PrunedState::new();
        // No drift, empty state: init scan.
        let acc = step_pruned(&px, &cen, 2, 3, &mut state, None);
        assert_eq!(acc, step_kernel(&px, &cen, 2, 3));
        assert!(state.ready());
        // Cleared state with a drift present: falls back and re-seeds.
        state.clear();
        let drift = drift_between(&cen, &cen, 2, 3);
        let acc2 = step_pruned(&px, &cen, 2, 3, &mut state, Some(&drift));
        assert_eq!(acc2, acc);
        // Assign with a cleared state: full scan.
        state.clear();
        let mut labels = Vec::new();
        let inertia = assign_pruned(&px, &cen, 2, 3, &mut state, Some(&drift), &mut labels);
        let mut want = Vec::new();
        assert_eq!(inertia, assign_kernel(&px, &cen, 2, 3, &mut want));
        assert_eq!(labels, want);
    }

    #[test]
    fn wide_pixels_take_the_naive_path_and_never_prune() {
        let channels = PRUNE_MAX_CHANNELS + 4;
        let px = random_pixels(60, channels, 41);
        let cen = random_pixels(2, channels, 42);
        let mut state = PrunedState::new();
        let acc = step_pruned(&px, &cen, 2, channels, &mut state, None);
        assert_eq!(acc, step_kernel(&px, &cen, 2, channels));
        assert!(!state.ready(), "wide pixels must not seed bounds");
        let drift = drift_between(&cen, &cen, 2, channels);
        let mut labels = Vec::new();
        let inertia = assign_pruned(&px, &cen, 2, channels, &mut state, Some(&drift), &mut labels);
        let mut want = Vec::new();
        assert_eq!(inertia, assign_kernel(&px, &cen, 2, channels, &mut want));
        assert_eq!(labels, want);
    }

    #[test]
    fn drift_between_measures_movement() {
        let old = vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let new = vec![3.0, 4.0, 0.0, 1.0, 1.0, 1.0];
        let d = drift_between(&old, &new, 2, 3);
        assert!((d.per_centroid[0] - 5.0).abs() < 1e-9);
        assert!(d.per_centroid[1] < 1e-12);
        assert!((d.max - 5.0).abs() < 1e-9);
    }

    #[test]
    fn provably_closer_requires_strict_margin() {
        assert!(provably_closer(1.0, 1.1));
        assert!(!provably_closer(1.0, 1.0)); // exact tie: never skip
        assert!(!provably_closer(1.0, 1.0 + 1e-9)); // inside the guard band
        assert!(provably_closer(0.0, 1e-3));
        assert!(provably_closer(5.0, f64::INFINITY));
    }

    #[test]
    fn lanes_rounds_are_bit_identical_to_naive() {
        use crate::kmeans::tile::SoaTile;
        for channels in [1usize, 3, 4, 5] {
            for k in [1usize, 2, 4, 8] {
                // 700 is not a LANES multiple: exercises tail masking
                let px = random_pixels(700, channels, 77 + channels as u64 * k as u64);
                let tile = SoaTile::from_interleaved(&px, channels);
                let mut cen: Vec<f32> = px[..k * channels].to_vec();
                let mut state = PrunedState::new();
                let mut drift: Option<CentroidDrift> = None;
                for round in 0..6 {
                    let want = step_kernel(&px, &cen, k, channels);
                    let got = step_lanes(&tile, &cen, k, &mut state, drift.as_ref());
                    assert_eq!(got, want, "C={channels} k={k} round={round}");
                    let prev = cen.clone();
                    math::update_centroids(&want, &mut cen, 0.0);
                    drift = Some(drift_between(&prev, &cen, k, channels));
                }
                let mut labels = Vec::new();
                let inertia =
                    assign_lanes(&tile, &cen, k, &mut state, drift.as_ref(), &mut labels);
                let mut want_labels = Vec::new();
                let want_inertia = assign_kernel(&px, &cen, k, channels, &mut want_labels);
                assert_eq!(labels, want_labels, "C={channels} k={k} final labels");
                assert_eq!(inertia, want_inertia, "C={channels} k={k} final inertia");
            }
        }
    }

    #[test]
    fn lanes_handles_distance_ties_like_naive() {
        use crate::kmeans::tile::SoaTile;
        let mut rng = Rng::new(13);
        let px: Vec<f32> = (0..601 * 3).map(|_| rng.range_usize(0, 4) as f32).collect();
        let cen = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 3.0, 3.0, 3.0, 0.0, 1.0, 2.0];
        let tile = SoaTile::from_interleaved(&px, 3);
        let mut state = PrunedState::new();
        let mut drift = None;
        let mut c = cen.clone();
        for _ in 0..4 {
            let want = step_kernel(&px, &c, 4, 3);
            let got = step_lanes(&tile, &c, 4, &mut state, drift.as_ref());
            assert_eq!(got, want);
            let prev = c.clone();
            math::update_centroids(&want, &mut c, 0.0);
            drift = Some(drift_between(&prev, &c, 4, 3));
        }
    }

    #[test]
    fn lanes_wide_pixels_never_prune_but_stay_exact() {
        use crate::kmeans::tile::SoaTile;
        let channels = PRUNE_MAX_CHANNELS + 4;
        let px = random_pixels(60, channels, 43);
        let tile = SoaTile::from_interleaved(&px, channels);
        let cen = random_pixels(2, channels, 44);
        let mut state = PrunedState::new();
        let acc = step_lanes(&tile, &cen, 2, &mut state, None);
        assert_eq!(acc, step_kernel(&px, &cen, 2, channels));
        assert!(!state.ready(), "wide pixels must not seed bounds");
        let drift = drift_between(&cen, &cen, 2, channels);
        let mut labels = Vec::new();
        let inertia = assign_lanes(&tile, &cen, 2, &mut state, Some(&drift), &mut labels);
        let mut want = Vec::new();
        assert_eq!(inertia, assign_kernel(&px, &cen, 2, channels, &mut want));
        assert_eq!(labels, want);
    }

    #[test]
    #[should_panic(expected = "centroid table length")]
    fn lanes_mismatched_k_fails_loudly() {
        use crate::kmeans::tile::SoaTile;
        let px = random_pixels(10, 3, 1);
        let tile = SoaTile::from_interleaved(&px, 3);
        let cen = random_pixels(2, 3, 2);
        let mut state = PrunedState::new();
        let _ = step_lanes(&tile, &cen, 3, &mut state, None);
    }

    /// The tentpole contract: at every *supported* SIMD level —
    /// including the portable fallback — non-FMA simd rounds are bit-
    /// identical to the naive kernel across multi-round runs with
    /// pruning engaged, exactly like the lanes test above.
    #[test]
    fn simd_rounds_are_bit_identical_to_naive_at_every_supported_level() {
        use crate::kmeans::simd::SimdLevel;
        use crate::kmeans::tile::SoaTile;
        for level in SimdLevel::ALL {
            if !SimdLevel::supported(level) {
                continue;
            }
            let mode = SimdMode { level, fma: false };
            for channels in [1usize, 3, 5] {
                for k in [1usize, 2, 4, 8] {
                    let px = random_pixels(700, channels, 177 + channels as u64 * k as u64);
                    let tile = SoaTile::from_interleaved(&px, channels);
                    let mut cen: Vec<f32> = px[..k * channels].to_vec();
                    let mut state = PrunedState::new();
                    let mut drift: Option<CentroidDrift> = None;
                    for round in 0..6 {
                        let want = step_kernel(&px, &cen, k, channels);
                        let got = step_simd(&tile, &cen, k, &mut state, drift.as_ref(), mode);
                        assert_eq!(got, want, "{level} C={channels} k={k} round={round}");
                        let prev = cen.clone();
                        math::update_centroids(&want, &mut cen, 0.0);
                        drift = Some(drift_between(&prev, &cen, k, channels));
                    }
                    let mut labels = Vec::new();
                    let inertia = assign_simd(
                        &tile,
                        &cen,
                        k,
                        &mut state,
                        drift.as_ref(),
                        &mut labels,
                        mode,
                    );
                    let mut want_labels = Vec::new();
                    let want_inertia = assign_kernel(&px, &cen, k, channels, &mut want_labels);
                    assert_eq!(labels, want_labels, "{level} C={channels} k={k} labels");
                    assert_eq!(inertia, want_inertia, "{level} C={channels} k={k} inertia");
                }
            }
        }
    }

    #[test]
    fn simd_handles_distance_ties_like_naive() {
        use crate::kmeans::tile::SoaTile;
        let mut rng = Rng::new(17);
        let px: Vec<f32> = (0..601 * 3).map(|_| rng.range_usize(0, 4) as f32).collect();
        let cen = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 3.0, 3.0, 3.0, 0.0, 1.0, 2.0];
        let tile = SoaTile::from_interleaved(&px, 3);
        let mode = SimdMode::detected();
        let mut state = PrunedState::new();
        let mut drift = None;
        let mut c = cen.clone();
        for _ in 0..4 {
            let want = step_kernel(&px, &c, 4, 3);
            let got = step_simd(&tile, &c, 4, &mut state, drift.as_ref(), mode);
            assert_eq!(got, want);
            let prev = c.clone();
            math::update_centroids(&want, &mut c, 0.0);
            drift = Some(drift_between(&prev, &c, 4, 3));
        }
    }

    #[test]
    fn simd_wide_pixels_never_prune_but_stay_exact() {
        use crate::kmeans::tile::SoaTile;
        let channels = PRUNE_MAX_CHANNELS + 4;
        let px = random_pixels(60, channels, 45);
        let tile = SoaTile::from_interleaved(&px, channels);
        let cen = random_pixels(2, channels, 46);
        let mode = SimdMode::detected();
        let mut state = PrunedState::new();
        let acc = step_simd(&tile, &cen, 2, &mut state, None, mode);
        assert_eq!(acc, step_kernel(&px, &cen, 2, channels));
        assert!(!state.ready(), "wide pixels must not seed bounds");
        let drift = drift_between(&cen, &cen, 2, channels);
        let mut labels = Vec::new();
        let inertia = assign_simd(&tile, &cen, 2, &mut state, Some(&drift), &mut labels, mode);
        let mut want = Vec::new();
        assert_eq!(inertia, assign_kernel(&px, &cen, 2, channels, &mut want));
        assert_eq!(labels, want);
    }

    #[test]
    #[should_panic(expected = "centroid table length")]
    fn simd_mismatched_k_fails_loudly() {
        use crate::kmeans::tile::SoaTile;
        let px = random_pixels(10, 3, 1);
        let tile = SoaTile::from_interleaved(&px, 3);
        let cen = random_pixels(2, 3, 2);
        let mut state = PrunedState::new();
        let _ = step_simd(&tile, &cen, 3, &mut state, None, SimdMode::detected());
    }

    #[test]
    fn kernel_choice_parses_and_prints() {
        for kc in KernelChoice::ALL {
            let s = kc.to_string();
            assert_eq!(s.parse::<KernelChoice>().unwrap(), kc);
        }
        assert!("turbo".parse::<KernelChoice>().is_err());
        assert_eq!(KernelChoice::default(), KernelChoice::Naive);
    }

    #[test]
    #[should_panic(expected = "centroid table length")]
    fn mismatched_k_fails_loudly() {
        let px = random_pixels(10, 3, 1);
        let cen = random_pixels(2, 3, 2);
        // claims k=3 but supplies 2 centroids
        let _ = step_kernel(&px, &cen, 3, 3);
    }
}
