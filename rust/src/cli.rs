//! The `blockms` binary's command-line surface, as a library.
//!
//! The option table, subcommand list, and the CLI-over-config option
//! resolver live here (not in `main.rs`) so the round-trip tests in
//! `tests/cli_parse.rs` can exercise exactly the spec the binary ships.
//!
//! Error discipline: anything that is a *usage* mistake — unknown
//! option, unknown subcommand, a value that fails to parse — surfaces
//! as a [`CliError`] and makes the binary exit with status **2**, with
//! a message naming the offending flag. Runtime failures (I/O, missing
//! artifacts) exit 1.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::cli::{Args, Cli, CliError};
use crate::util::config::Config;

/// Every subcommand the binary dispatches on.
pub const SUBCOMMANDS: &[&str] = &[
    "cluster",
    "plan",
    "paper-tables",
    "cases",
    "sweep",
    "kernels",
    "simd",
    "layout",
    "stream",
    "batch",
    "serve",
    "shard-worker",
    "distributed",
    "resilience",
    "hardening",
    "info",
];

/// The full option table (all subcommands share one namespace, like the
/// rest of the repo's benches).
pub fn blockms_cli() -> Cli {
    Cli::new("blockms", "parallel block processing for K-Means clustering")
        .opt("config", None, "INI config file (CLI overrides it)")
        .opt("k", Some("2"), "cluster count")
        .opt("workers", Some("4"), "worker count")
        .opt("approach", Some("column"), "block approach: row|column|square")
        .opt("block-rows", None, "explicit block rows (overrides approach)")
        .opt("block-cols", None, "explicit block cols (overrides approach)")
        .opt("width", Some("1280"), "synthetic image width")
        .opt("height", Some("800"), "synthetic image height")
        .opt("seed", Some("7"), "workload / init seed")
        .opt("input", None, "input PPM instead of synthetic scene")
        .opt("out", None, "output path (cluster: label map PPM; kernels/batch/plan/stream/sweep: JSON)")
        .opt("out-input", None, "also write the input scene PPM here")
        .opt("engine", Some("native"), "compute engine: native|pjrt")
        .opt("kernel", Some("naive"), "compute kernel: naive|pruned|fused|lanes|simd")
        .opt("layout", None, "block layout: interleaved|soa (default: kernel's native)")
        .opt("arena-mb", Some("256"), "per-worker SoA tile arena budget, MiB (0 disables)")
        .opt("strip-cache", None, "shared strip cache capacity, decoded strips (0 = off)")
        .opt(
            "mem-mb",
            None,
            "hard resident pixel-byte budget, MiB: stream pixels from disk to labels \
             under it (cluster/serve/plan; implies strip I/O; planner rejects over-budget plans)",
        )
        .opt("mode", Some("global"), "clustering mode: global|local")
        .opt("schedule", Some("dynamic"), "job schedule: static|dynamic")
        .opt("iters", None, "fixed Lloyd iterations (default: converge)")
        .opt("max-iters", Some("20"), "max Lloyd iterations")
        .opt("strip-rows", None, "enable strip I/O model with this strip height")
        .opt("table", Some("all"), "paper-tables: table number or 'all'")
        .opt("scale", Some("0.25"), "paper-tables/cases/batch: per-side size scale")
        .opt("bench-iters", Some("6"), "paper-tables/cases/batch: Lloyd iterations")
        .opt("jobs", Some("8"), "serve: number of jobs to drive through the pool")
        .opt("max-in-flight", Some("4"), "serve: admission cap (backpressure above it)")
        .opt("pools", Some("1,2,4,8"), "batch: comma-separated pool sizes")
        .opt("batches", Some("1,4,16"), "batch: comma-separated batch sizes")
        .opt("ks", Some("2..8"), "sweep: cluster-count grid, inclusive range (2..8) or list (2,4,8)")
        .opt("seeds", Some("1"), "sweep: seed replicates per (k, init) — seed, seed+1, …")
        .opt("inits", Some("random"), "sweep: comma list of init methods: random|plusplus")
        .opt(
            "retries",
            Some("0"),
            "per-block retry budget per round (0 = fail fast; retried blocks \
             recompute bit-identically from the round's centroids)",
        )
        .opt(
            "checkpoint-every",
            Some("0"),
            "cluster: write a round-boundary checkpoint every N rounds (0 = never; \
             needs --checkpoint)",
        )
        .opt(
            "checkpoint",
            None,
            "cluster: checkpoint file path (written atomically at the --checkpoint-every cadence)",
        )
        .opt(
            "resume",
            None,
            "cluster: resume from this checkpoint; the resumed run is bit-identical \
             to an uninterrupted one (config fingerprint must match)",
        )
        .opt(
            "fault",
            None,
            "inject a deterministic fault for drills: BLOCK[:KIND[:VISITS[:AFTER]]] \
             with KIND error|panic|reader-io|hang[MS] (e.g. 2:panic:1, 1:hang60000; \
             hang parks the worker silently — pair with --retries so the watchdog \
             can re-queue the block)",
        )
        .opt(
            "deadline-ms",
            Some("0"),
            "cluster/serve: per-job wall-clock deadline, ms (0 = none); a deadlined \
             run checkpoints its last round boundary when --checkpoint is set and \
             exits resumable",
        )
        .opt(
            "priority",
            Some("0"),
            "serve: QoS priority (higher drains first; under overload the admission \
             gate sheds lowest-priority jobs to make room)",
        )
        .opt(
            "shards",
            None,
            "cluster/serve/plan: distribute blocks over N shard processes, \
             N[:addr,...] — bare N spawns in-process loopback shards; with \
             addrs the leader connects to `blockms shard-worker` listeners \
             (host:port or a UDS path); results stay bit-identical to solo",
        )
        .opt(
            "heartbeat-ms",
            Some("1500"),
            "liveness probe timeout, ms (workers and shards); 0 is a usage error",
        )
        .opt(
            "listen",
            None,
            "shard-worker: address to listen on (host:port or a UDS path)",
        )
        .opt(
            "drain-timeout",
            Some("5000"),
            "serve: graceful-drain budget at end of run, ms — in-flight jobs get this \
             long to finish before being checkpointed or cancelled",
        )
        .flag(
            "fma",
            "simd kernel: fused multiply-add distances — faster but no longer \
             bit-identical to lanes (tolerance-gated; see EXPERIMENTS.md)",
        )
        .flag("serial", "cluster: also run the sequential baseline and compare")
        .flag(
            "speculate",
            "cluster: near end of round, re-run straggler blocks on idle workers \
             (first result wins; bit-identical either way)",
        )
        .flag("prefetch", "overlap next-block reads with compute (double buffering)")
        .flag(
            "file-backed",
            "pin the strip store to a real file (otherwise the planner decides under --mem-mb)",
        )
        .flag(
            "once",
            "shard-worker: serve exactly one leader connection, then exit",
        )
        .flag(
            "quick",
            "layout/plan/stream/sweep/distributed: CI-sized matrix (pins image size, ks, iters)",
        )
        .flag(
            "auto",
            "cluster/serve/plan: planner picks every knob not explicitly pinned \
             (typed flags constrain the search; results stay bit-identical)",
        )
        .flag(
            "dry-run",
            "cluster: resolve and print the execution plan, read no pixels, exit 0",
        )
        .flag("verbose", "more logging (plan: full candidate table)")
}

/// Merge `--config file` under the CLI args for a single typed lookup.
/// CLI beats config (`section.key` in the file, `--key` on the CLI).
/// Lookup failures are [`CliError`]s so the binary can exit 2 naming
/// the flag.
pub struct Opts<'a> {
    args: &'a Args,
    config: Config,
}

impl<'a> Opts<'a> {
    pub fn load(args: &'a Args) -> Result<Opts<'a>> {
        let config = match args.get("config") {
            Some(path) => {
                Config::load(Path::new(path)).with_context(|| format!("load config {path}"))?
            }
            None => Config::default(),
        };
        Ok(Opts { args, config })
    }

    pub fn get(&self, cli_key: &str, cfg_key: &str) -> Option<String> {
        self.args
            .get(cli_key)
            .map(str::to_string)
            .or_else(|| self.config.get(cfg_key).map(str::to_string))
    }

    pub fn parse<T: std::str::FromStr>(&self, cli_key: &str, cfg_key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(cli_key, cfg_key) {
            None => Ok(None),
            Some(raw) => match raw.parse::<T>() {
                Ok(v) => Ok(Some(v)),
                Err(e) => Err(anyhow::Error::new(CliError::BadValue(
                    cli_key.to_string(),
                    raw,
                    e.to_string(),
                ))),
            },
        }
    }

    pub fn require<T: std::str::FromStr>(&self, cli_key: &str, cfg_key: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.parse(cli_key, cfg_key)?.ok_or_else(|| {
            anyhow::Error::new(CliError::MissingRequired(cli_key.to_string()))
        })
    }

    /// A knob's *pin*: `Some` only when the user typed the flag or the
    /// config file sets the key — a spec default is not a pin. A typed
    /// flag beats the config; a config key beats nothing (the spec
    /// default never shadows it here, unlike [`Opts::get`]). Under
    /// `--auto` the planner chooses every `None`; without `--auto`,
    /// callers fall back to [`Opts::require`]'s defaulted value.
    pub fn pinned<T: std::str::FromStr>(&self, cli_key: &str, cfg_key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        if self.args.provided(cli_key) {
            return self.parse(cli_key, cfg_key);
        }
        match self.config.get(cfg_key) {
            None => Ok(None),
            Some(raw) => match raw.parse::<T>() {
                Ok(v) => Ok(Some(v)),
                Err(e) => Err(anyhow::Error::new(CliError::BadValue(
                    cli_key.to_string(),
                    raw.to_string(),
                    e.to_string(),
                ))),
            },
        }
    }
}

/// Parse a comma-separated list of positive integers (`"1,2,4,8"`).
/// The offending flag name lands in the error.
pub fn parse_usize_list(raw: &str, flag: &str) -> Result<Vec<usize>> {
    let bad = |why: &str| {
        anyhow::Error::new(CliError::BadValue(
            flag.to_string(),
            raw.to_string(),
            why.to_string(),
        ))
    };
    let mut out = Vec::new();
    for part in raw.split(',') {
        let part = part.trim();
        if part.is_empty() {
            return Err(bad("empty element"));
        }
        let v: usize = part.parse().map_err(|_| bad("not an integer"))?;
        if v == 0 {
            return Err(bad("elements must be positive"));
        }
        out.push(v);
    }
    if out.is_empty() {
        return Err(bad("empty list"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_knows_every_subcommand_flag() {
        let cli = blockms_cli();
        let a = cli
            .parse(vec![
                "batch", "--pools", "1,2", "--batches", "4", "--scale", "0.1",
            ])
            .unwrap();
        assert_eq!(a.subcommand(), Some("batch"));
        assert_eq!(a.get("pools"), Some("1,2"));
    }

    #[test]
    fn usize_list_parses_and_rejects() {
        assert_eq!(parse_usize_list("1,2,4,8", "pools").unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(parse_usize_list(" 4 ", "pools").unwrap(), vec![4]);
        for bad in ["", "1,,2", "a", "0", "1,0"] {
            let err = parse_usize_list(bad, "pools").unwrap_err();
            let cli = err.downcast_ref::<CliError>().expect("CliError");
            assert!(matches!(cli, CliError::BadValue(flag, ..) if flag == "pools"), "{bad:?}");
        }
    }

    #[test]
    fn pinned_distinguishes_typed_from_default() {
        let cli = blockms_cli();
        let args = cli.parse(vec!["cluster", "--kernel", "lanes"]).unwrap();
        let opts = Opts::load(&args).unwrap();
        assert_eq!(
            opts.pinned::<String>("kernel", "run.kernel").unwrap().as_deref(),
            Some("lanes")
        );
        // the spec default --k 2 is a value but not a pin
        assert_eq!(opts.pinned::<usize>("k", "cluster.k").unwrap(), None);
        assert_eq!(opts.require::<usize>("k", "cluster.k").unwrap(), 2);
    }

    #[test]
    fn pinned_config_key_wins_over_spec_default() {
        // A config-file key is a pin with the CONFIG's value — the CLI
        // spec default must not shadow it (a typed flag still would).
        let cli = blockms_cli();
        let args = cli.parse(vec!["cluster"]).unwrap();
        let config = Config::parse("[run]\nkernel = lanes\nworkers = 7").unwrap();
        let opts = Opts { args: &args, config };
        assert_eq!(
            opts.pinned::<String>("kernel", "run.kernel").unwrap().as_deref(),
            Some("lanes")
        );
        assert_eq!(opts.pinned::<usize>("workers", "run.workers").unwrap(), Some(7));
        let typed = cli.parse(vec!["cluster", "--kernel", "pruned"]).unwrap();
        let opts = Opts {
            args: &typed,
            config: Config::parse("[run]\nkernel = lanes").unwrap(),
        };
        assert_eq!(
            opts.pinned::<String>("kernel", "run.kernel").unwrap().as_deref(),
            Some("pruned"),
            "typed flag beats config"
        );
    }

    #[test]
    fn require_produces_cli_errors() {
        let cli = blockms_cli();
        let args = cli.parse(vec!["cluster", "--k", "nope"]).unwrap();
        let opts = Opts::load(&args).unwrap();
        let err = opts.require::<usize>("k", "cluster.k").unwrap_err();
        assert!(matches!(
            err.downcast_ref::<CliError>(),
            Some(CliError::BadValue(flag, ..)) if flag == "k"
        ));
        let err = opts.require::<usize>("iters", "cluster.iters").unwrap_err();
        assert!(matches!(
            err.downcast_ref::<CliError>(),
            Some(CliError::MissingRequired(flag)) if flag == "iters"
        ));
    }
}
