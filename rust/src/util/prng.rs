//! Deterministic pseudo-random number generation.
//!
//! `rand` is not vendored, so we implement the two small generators the
//! framework needs: **SplitMix64** (seeding / stream splitting) and
//! **Xoshiro256++** (the workhorse). Both are the reference algorithms
//! from Blackman & Vigna; determinism across platforms is part of the
//! contract — every synthetic image, initialization and property-test
//! case is reproducible from a `u64` seed.

/// SplitMix64 — used to expand a single `u64` seed into generator state
/// and to derive independent child seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 as the authors recommend.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent child generator (e.g. one per worker or per
    /// image band) from this one.
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0x5851_F42D_4C95_7F2D)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift
    /// (unbiased rejection variant).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)` (half-open).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "range_usize: empty range {lo}..{hi}");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn next_gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from `[0, len)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, len: usize, n: usize) -> Vec<usize> {
        assert!(n <= len, "cannot sample {n} distinct from {len}");
        let mut idx: Vec<usize> = (0..len).collect();
        for i in 0..n {
            let j = self.range_usize(i, len);
            idx.swap(i, j);
        }
        idx.truncate(n);
        idx
    }

    /// [`Rng::sample_indices`] in `O(n)` memory instead of `O(len)`:
    /// the identity permutation is virtual and only displaced entries
    /// are stored. Draw-for-draw identical to the dense version (same
    /// generator calls, same output) — the streaming centroid init uses
    /// this so a billion-pixel image never allocates a billion-entry
    /// index table. A tested equivalence.
    pub fn sample_indices_sparse(&mut self, len: usize, n: usize) -> Vec<usize> {
        assert!(n <= len, "cannot sample {n} distinct from {len}");
        let mut displaced: std::collections::HashMap<usize, usize> = std::collections::HashMap::new();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let j = self.range_usize(i, len);
            let vi = displaced.get(&i).copied().unwrap_or(i);
            let vj = displaced.get(&j).copied().unwrap_or(j);
            // swap positions i and j of the virtual permutation
            displaced.insert(i, vj);
            displaced.insert(j, vi);
            out.push(vj);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Rng::new(7);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        let n = 20_000;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn next_below_is_in_range_and_hits_all() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gauss();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "gauss mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "gauss var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle left identity");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        let s = r.sample_indices(50, 10);
        assert_eq!(s.len(), 10);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 10);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    #[should_panic(expected = "empty range")]
    fn range_usize_rejects_empty() {
        Rng::new(1).range_usize(5, 5);
    }

    #[test]
    fn sparse_sampler_is_bit_identical_to_dense() {
        for seed in 0..25u64 {
            for (len, n) in [(1usize, 1usize), (10, 3), (50, 10), (1000, 7), (64, 64)] {
                let dense = Rng::new(seed).sample_indices(len, n);
                let sparse = Rng::new(seed).sample_indices_sparse(len, n);
                assert_eq!(dense, sparse, "seed={seed} len={len} n={n}");
            }
        }
    }
}
