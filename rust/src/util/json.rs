//! A strict, minimal JSON parser — just enough for the artifact manifest.
//!
//! `serde`/`serde_json` are not vendored, so the manifest emitted by
//! `python/compile/aot.py` is parsed with this ~200-line recursive-descent
//! parser. It supports the full JSON value grammar (objects, arrays,
//! strings with escapes, numbers, booleans, null) but is deliberately
//! strict: no trailing commas, no comments, UTF-8 input only.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so output and
/// comparisons are deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, thiserror::Error, PartialEq)]
#[error("json parse error at byte {at}: {msg}")]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data after value"));
        }
        Ok(v)
    }

    // -- typed accessors (all return Option; callers decide severity) ----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write!(f, "{:?}", s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{:?}:{}", k, v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            at: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs: JSON encodes astral chars
                            // as \uD8xx\uDCxx.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let c =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = &self.b[self.i..];
                    let txt = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf8"))?;
                    let ch = txt.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("invalid utf8 in \\u"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").and_then(Json::as_str), Some("x"));
        let arr = j.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_usize(), Some(1));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"c\" A 😀");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"", "{\"a\" 1}", "[1 2]", "tru", "01x", ""] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-2").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format": 1,
          "chunk": 16384,
          "artifacts": [
            {"name": "step_k2", "file": "step_k2.hlo.txt",
             "inputs": [{"shape": [16384, 3], "dtype": "float32"}]}
          ]
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("chunk").and_then(Json::as_usize), Some(16384));
        let arts = j.get("artifacts").and_then(Json::as_arr).unwrap();
        assert_eq!(
            arts[0].get("name").and_then(Json::as_str),
            Some("step_k2")
        );
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(16384));
    }

    #[test]
    fn display_round_trips() {
        let src = r#"{"a":[1,true,null],"b":"x"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }
}
