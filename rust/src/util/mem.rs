//! Resident-byte accounting for the out-of-core pipeline.
//!
//! The `--mem-mb` contract is *asserted, not assumed*: every component
//! that holds pixel-derived bytes (ingestion strip buffers, reader
//! strip/block buffers, the decoded-strip cache, memory-backed stores,
//! spill row buffers) records its allocations against one shared
//! [`ResidentGauge`], and the high-water mark is surfaced through
//! [`crate::stripstore::AccessSnapshot::peak_resident_bytes`] so tests
//! can check `peak ≤ budget` instead of trusting the cost model.
//!
//! The gauge is advisory accounting, not an allocator: exceeding it
//! never aborts a run — the planner's feasibility check is what keeps
//! runs under budget, and the gauge is how that promise is audited.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared current/peak byte counters. All operations are relaxed — the
/// peak is a reporting number, and the transient interleavings a relaxed
/// `fetch_max` can miss are bounded by per-thread buffer sizes.
#[derive(Debug, Default)]
pub struct ResidentGauge {
    current: AtomicU64,
    peak: AtomicU64,
}

impl ResidentGauge {
    pub fn new_shared() -> Arc<ResidentGauge> {
        Arc::new(ResidentGauge::default())
    }

    /// Record `bytes` becoming resident.
    pub fn add(&self, bytes: u64) {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Record `bytes` released (saturating: a mismatched release clamps
    /// at zero rather than wrapping).
    pub fn sub(&self, bytes: u64) {
        let mut cur = self.current.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(bytes);
            match self.current.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Adjust a tracked buffer from `old` to `new` bytes.
    pub fn resize(&self, old: u64, new: u64) {
        if new > old {
            self.add(new - old);
        } else {
            self.sub(old - new);
        }
    }

    pub fn current(&self) -> u64 {
        self.current.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.current.store(0, Ordering::Relaxed);
        self.peak.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water() {
        let g = ResidentGauge::default();
        g.add(100);
        g.add(50);
        g.sub(120);
        g.add(10);
        assert_eq!(g.current(), 40);
        assert_eq!(g.peak(), 150);
    }

    #[test]
    fn sub_saturates_at_zero() {
        let g = ResidentGauge::default();
        g.add(10);
        g.sub(25);
        assert_eq!(g.current(), 0);
        assert_eq!(g.peak(), 10);
    }

    #[test]
    fn resize_moves_both_ways() {
        let g = ResidentGauge::default();
        g.resize(0, 64);
        assert_eq!(g.current(), 64);
        g.resize(64, 16);
        assert_eq!(g.current(), 16);
        assert_eq!(g.peak(), 64);
    }

    #[test]
    fn concurrent_adds_are_exact() {
        let g = ResidentGauge::new_shared();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let g = std::sync::Arc::clone(&g);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    g.add(3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(g.current(), 12_000);
        assert!(g.peak() >= 3 && g.peak() <= 12_000);
        g.reset();
        assert_eq!(g.peak(), 0);
    }
}
