//! Descriptive statistics for timing data (the bench harness's math).
//!
//! Provides both a streaming accumulator ([`Welford`], numerically stable
//! single-pass mean/variance) and batch helpers over slices (median,
//! percentiles, min/max). Used by the bench harness to summarize repeated
//! timing samples the way criterion would have.

/// Streaming mean/variance via Welford's algorithm.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel reduction of stats).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Batch summary of a sample set.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a slice of samples. Panics on an empty slice.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let mut w = Welford::new();
        for &x in samples {
            w.push(x);
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        Summary {
            count: samples.len(),
            mean: w.mean(),
            stddev: w.stddev(),
            min: sorted[0],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct), "percentile out of range");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of an unsorted slice (convenience; copies).
pub fn median(samples: &[f64]) -> f64 {
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
    percentile_sorted(&v, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // naive sample variance = 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
        assert_eq!(a.count(), whole.count());
    }

    #[test]
    fn empty_welford_mean_is_nan() {
        assert!(Welford::new().mean().is_nan());
    }

    #[test]
    fn percentile_endpoints() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 4.0);
        assert!((percentile_sorted(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((median(&[4.0, 1.0, 3.0, 2.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.median - 50.5).abs() < 1e-9);
        assert!(s.p95 > 94.0 && s.p95 < 97.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn summary_rejects_empty() {
        Summary::of(&[]);
    }
}
