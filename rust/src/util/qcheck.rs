//! Miniature property-testing harness (proptest is not vendored).
//!
//! The subset the test suite needs:
//!
//! - [`Gen`] — a value generator over the crate's deterministic [`Rng`];
//! - [`forall`] — run a property over N generated cases; on failure,
//!   greedily **shrink** the failing case toward a minimal counterexample
//!   before reporting;
//! - combinators: [`usize_in`], [`f32_in`], [`vec_of`], [`pair`],
//!   [`choice_of`].
//!
//! A failing property panics with the (shrunk) case's debug rendering and
//! the seed, so reproduction is one `Rng::new(seed)` away.

use super::prng::Rng;

/// A generator: produces a value and can enumerate shrink candidates.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values, most aggressive first. Default: none.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` over `cases` generated values; shrink and panic on failure.
pub fn forall<G: Gen>(seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Rng::new(seed);
    for case_no in 0..cases {
        let v = gen.generate(&mut rng);
        if prop(&v) {
            continue;
        }
        // Shrink: repeatedly take the first failing shrink candidate.
        let mut cur = v;
        let mut budget = 1000;
        'outer: while budget > 0 {
            for cand in gen.shrink(&cur) {
                budget -= 1;
                if !prop(&cand) {
                    cur = cand;
                    continue 'outer;
                }
                if budget == 0 {
                    break;
                }
            }
            break;
        }
        panic!(
            "property failed (seed={seed}, case {case_no}/{cases}):\n  shrunk counterexample: {cur:?}"
        );
    }
}

/// Uniform usize in `[lo, hi]` (inclusive); shrinks toward `lo`.
pub struct UsizeIn {
    pub lo: usize,
    pub hi: usize,
}

pub fn usize_in(lo: usize, hi: usize) -> UsizeIn {
    assert!(lo <= hi);
    UsizeIn { lo, hi }
}

impl Gen for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range_usize(self.lo, self.hi + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            let mid = self.lo + (*v - self.lo) / 2;
            if mid != self.lo && mid != *v {
                out.push(mid);
            }
            if *v - 1 != self.lo {
                out.push(*v - 1);
            }
        }
        out
    }
}

/// Uniform f32 in `[lo, hi)`; shrinks toward 0 / lo.
pub struct F32In {
    pub lo: f32,
    pub hi: f32,
}

pub fn f32_in(lo: f32, hi: f32) -> F32In {
    assert!(lo < hi);
    F32In { lo, hi }
}

impl Gen for F32In {
    type Value = f32;
    fn generate(&self, rng: &mut Rng) -> f32 {
        rng.range_f64(self.lo as f64, self.hi as f64) as f32
    }
    fn shrink(&self, v: &f32) -> Vec<f32> {
        let mut out = Vec::new();
        let zero = if self.lo <= 0.0 && self.hi > 0.0 { 0.0 } else { self.lo };
        if *v != zero {
            out.push(zero);
            out.push(zero + (*v - zero) / 2.0);
        }
        out
    }
}

/// Vector of `inner` values with length in `[min_len, max_len]`; shrinks
/// by halving length, dropping elements, and shrinking elements.
pub struct VecOf<G> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

pub fn vec_of<G: Gen>(inner: G, min_len: usize, max_len: usize) -> VecOf<G> {
    assert!(min_len <= max_len);
    VecOf {
        inner,
        min_len,
        max_len,
    }
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let len = rng.range_usize(self.min_len, self.max_len + 1);
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // halve
            let half = v[..self.min_len.max(v.len() / 2)].to_vec();
            if half.len() < v.len() {
                out.push(half);
            }
            // drop one element (first and last)
            let mut d = v.clone();
            d.remove(0);
            if d.len() >= self.min_len {
                out.push(d);
            }
            let mut d = v.clone();
            d.pop();
            if d.len() >= self.min_len {
                out.push(d);
            }
        }
        // shrink a single element (first shrinkable)
        for (i, x) in v.iter().enumerate() {
            let cands = self.inner.shrink(x);
            if let Some(c) = cands.into_iter().next() {
                let mut w = v.clone();
                w[i] = c;
                out.push(w);
                break;
            }
        }
        out
    }
}

/// Pair of two generators; shrinks each side.
pub struct Pair<A, B>(pub A, pub B);

pub fn pair<A: Gen, B: Gen>(a: A, b: B) -> Pair<A, B> {
    Pair(a, b)
}

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.0.shrink(&v.0) {
            out.push((a, v.1.clone()));
        }
        for b in self.1.shrink(&v.1) {
            out.push((v.0.clone(), b));
        }
        out
    }
}

/// Uniform choice from a fixed list; shrinks toward the first entry.
pub struct ChoiceOf<T> {
    items: Vec<T>,
}

pub fn choice_of<T: Clone + std::fmt::Debug>(items: &[T]) -> ChoiceOf<T> {
    assert!(!items.is_empty());
    ChoiceOf {
        items: items.to_vec(),
    }
}

impl<T: Clone + std::fmt::Debug> Gen for ChoiceOf<T> {
    type Value = T;
    fn generate(&self, rng: &mut Rng) -> T {
        self.items[rng.range_usize(0, self.items.len())].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(1, 200, &usize_in(0, 100), |&v| v <= 100);
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        // property: v < 50. minimal counterexample is 50.
        let result = std::panic::catch_unwind(|| {
            forall(2, 500, &usize_in(0, 1000), |&v| v < 50);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("counterexample: 50"), "got: {msg}");
    }

    #[test]
    fn vec_shrinks_toward_short() {
        // property: no vector contains an element > 90.
        let result = std::panic::catch_unwind(|| {
            forall(3, 500, &vec_of(usize_in(0, 100), 0, 20), |v| {
                v.iter().all(|&x| x <= 90)
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // shrunk case should be a short vector (len 1 ideally)
        assert!(msg.contains('['), "got: {msg}");
    }

    #[test]
    fn pair_generates_both() {
        forall(4, 100, &pair(usize_in(1, 5), f32_in(0.0, 1.0)), |(n, x)| {
            (1..=5).contains(n) && (0.0..1.0).contains(x)
        });
    }

    #[test]
    fn choice_respects_items() {
        forall(5, 100, &choice_of(&[2usize, 4, 8]), |&k| {
            k == 2 || k == 4 || k == 8
        });
    }

    #[test]
    fn forall_deterministic_per_seed() {
        let mut seen = Vec::new();
        for _ in 0..2 {
            let mut got = Vec::new();
            let mut rng = Rng::new(99);
            let g = usize_in(0, 1_000_000);
            for _ in 0..10 {
                got.push(g.generate(&mut rng));
            }
            seen.push(got);
        }
        assert_eq!(seen[0], seen[1]);
    }
}
