//! Foundation substrates built from scratch for the offline environment.
//!
//! The build environment vendors only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (rand, serde, clap, proptest,
//! criterion) are unavailable. Everything the framework needs from them
//! is implemented here, small and purpose-built:
//!
//! - [`prng`]    — SplitMix64 / Xoshiro256++ PRNG with normal variates
//! - [`stats`]   — streaming + batch descriptive statistics
//! - [`json`]    — a strict, minimal JSON parser (artifact manifest)
//! - [`csv`]     — RFC-4180 CSV writer (sweep exports)
//! - [`cli`]     — declarative command-line argument parser
//! - [`config`]  — INI-style run-configuration files
//! - [`qcheck`]  — miniature property-testing harness with shrinking
//! - [`fmt`]     — fixed-width table rendering for paper-style output
//! - [`mem`]     — resident-byte gauge auditing the `--mem-mb` budget

pub mod cli;
pub mod config;
pub mod csv;
pub mod fmt;
pub mod json;
pub mod mem;
pub mod prng;
pub mod qcheck;
pub mod stats;
