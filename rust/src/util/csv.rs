//! Minimal CSV writer (RFC-4180 quoting) for sweep exports.
//!
//! `blockms sweep --csv out.csv` dumps every paper-table cell as one row
//! so downstream plotting (the paper's Figures 8–20) can be done in any
//! tool without re-running the sweep.

use std::io::Write;

/// A CSV document under construction.
#[derive(Clone, Debug, Default)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<I, S>(&mut self, cells: I)
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "csv row width {} != header {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with RFC-4180 quoting (quote fields containing `",\n`).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let write_row = |out: &mut String, row: &[String]| {
            for (i, cell) in row.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if cell.contains(['"', ',', '\n', '\r']) {
                    out.push('"');
                    out.push_str(&cell.replace('"', "\"\""));
                    out.push('"');
                } else {
                    out.push_str(cell);
                }
            }
            out.push('\n');
        };
        write_row(&mut out, &self.header);
        for row in &self.rows {
            write_row(&mut out, row);
        }
        out
    }

    pub fn write_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.render().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(["1", "2"]);
        c.row(["x", "y"]);
        assert_eq!(c.render(), "a,b\n1,2\nx,y\n");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn quotes_special_cells() {
        let mut c = Csv::new(&["a"]);
        c.row(["has,comma"]);
        c.row(["has\"quote"]);
        c.row(["has\nnewline"]);
        let r = c.render();
        assert!(r.contains("\"has,comma\""));
        assert!(r.contains("\"has\"\"quote\""));
        assert!(r.contains("\"has\nnewline\""));
    }

    #[test]
    #[should_panic(expected = "csv row width")]
    fn width_mismatch_panics() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(["only-one"]);
    }

    #[test]
    fn writes_to_disk() {
        let mut c = Csv::new(&["x"]);
        c.row(["1"]);
        let path = std::env::temp_dir().join("blockms_csv_test.csv");
        c.write_to(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "x\n1\n");
    }
}
