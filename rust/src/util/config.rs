//! INI-style run-configuration files.
//!
//! The launcher accepts `--config run.ini` describing a whole experiment
//! (workload, block shape, workers, clusters, engine). Format:
//!
//! ```ini
//! ; comment
//! [workload]
//! width = 4656
//! height = 5793
//! seed = 7
//!
//! [cluster]
//! k = 4
//! max_iters = 20
//! ```
//!
//! Keys are `section.key` flattened; values are strings with typed
//! accessors. Later duplicate keys override earlier ones (so a CLI layer
//! can merge on top).

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    map: BTreeMap<String, String>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ConfigError {
    #[error("config line {0}: {1}")]
    Parse(usize, String),
    #[error("missing key {0:?}")]
    Missing(String),
    #[error("invalid value for {0:?}: {1:?} ({2})")]
    BadValue(String, String, String),
}

impl Config {
    pub fn parse(src: &str) -> Result<Config, ConfigError> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with(';') || line.starts_with('#') {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .ok_or_else(|| ConfigError::Parse(lineno + 1, "unclosed section".into()))?
                    .trim();
                if name.is_empty() {
                    return Err(ConfigError::Parse(lineno + 1, "empty section name".into()));
                }
                section = name.to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::Parse(lineno + 1, "expected key = value".into()))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            if key.ends_with('.') || key.starts_with('.') || k.trim().is_empty() {
                return Err(ConfigError::Parse(lineno + 1, "empty key".into()));
            }
            map.insert(key, v.trim().to_string());
        }
        Ok(Config { map })
    }

    pub fn load(path: &std::path::Path) -> Result<Config, ConfigError> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::Parse(0, format!("read {}: {e}", path.display())))?;
        Config::parse(&src)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    pub fn require(&self, key: &str) -> Result<&str, ConfigError> {
        self.get(key).ok_or_else(|| ConfigError::Missing(key.into()))
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, ConfigError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(raw) => raw.parse::<T>().map(Some).map_err(|e| {
                ConfigError::BadValue(key.to_string(), raw.to_string(), e.to_string())
            }),
        }
    }

    /// Typed get with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, ConfigError>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(key)?.unwrap_or(default))
    }

    /// Merge `other` on top of `self` (other wins).
    pub fn merged_with(mut self, other: &Config) -> Config {
        for (k, v) in &other.map {
            self.map.insert(k.clone(), v.clone());
        }
        self
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "
; a comment
top = 1
[workload]
width = 4656
height = 5793
# another comment
seed = 7

[cluster]
k = 4
tol = 1e-4
name = row shaped
";

    #[test]
    fn parses_sections_and_keys() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("top"), Some("1"));
        assert_eq!(c.get("workload.width"), Some("4656"));
        assert_eq!(c.get("cluster.k"), Some("4"));
        assert_eq!(c.get("cluster.name"), Some("row shaped"));
    }

    #[test]
    fn typed_accessors() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_parse::<usize>("workload.width").unwrap(), Some(4656));
        assert_eq!(c.get_or::<f64>("cluster.tol", 0.0).unwrap(), 1e-4);
        assert_eq!(c.get_or::<usize>("cluster.missing", 9).unwrap(), 9);
        assert!(matches!(
            c.get_parse::<usize>("cluster.name"),
            Err(ConfigError::BadValue(..))
        ));
    }

    #[test]
    fn require_missing_errors() {
        let c = Config::parse("a = 1").unwrap();
        assert_eq!(c.require("b"), Err(ConfigError::Missing("b".into())));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[open").is_err());
        assert!(Config::parse("noequals").is_err());
        assert!(Config::parse("= bare").is_err());
        assert!(Config::parse("[]").is_err());
    }

    #[test]
    fn merge_overrides() {
        let base = Config::parse("a=1\nb=2").unwrap();
        let over = Config::parse("b=3\nc=4").unwrap();
        let m = base.merged_with(&over);
        assert_eq!(m.get("a"), Some("1"));
        assert_eq!(m.get("b"), Some("3"));
        assert_eq!(m.get("c"), Some("4"));
    }

    #[test]
    fn duplicate_keys_last_wins() {
        let c = Config::parse("a=1\na=2").unwrap();
        assert_eq!(c.get("a"), Some("2"));
    }
}
