//! Fixed-width table rendering for paper-style console output.
//!
//! The bench harness prints tables shaped exactly like the paper's
//! (Data Size / Serial / Parallel / Speedup / Efficiency). This module
//! renders aligned ASCII tables and formats floats with stable width.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            header: Vec::new(),
            rows: Vec::new(),
        }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&self.title);
            out.push('\n');
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                line.push(' ');
                line.push_str(cell);
                line.push_str(&" ".repeat(widths[i] - cell.len() + 1));
                if i + 1 < ncol {
                    line.push('|');
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format seconds with 6 decimal places (the paper's precision).
pub fn secs(v: f64) -> String {
    format!("{v:.6}")
}

/// Format a ratio (speedup/efficiency) with 4 decimal places.
pub fn ratio(v: f64) -> String {
    format!("{v:.4}")
}

/// Format a pixel dimension as the paper writes it: `4656x5793`.
pub fn dims(h: usize, w: usize) -> String {
    format!("{h}x{w}")
}

/// Human-readable byte count.
pub fn bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable duration from seconds.
pub fn duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo").header(&["Data Size", "Serial", "Speedup"]);
        t.row(vec!["1024x768".into(), secs(0.050589), ratio(1.3911)]);
        t.row(vec!["9052x4965".into(), secs(2.442462), ratio(1.2246)]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[0], "Demo");
        assert!(lines[1].contains("Data Size"));
        // all data lines equal length
        assert_eq!(lines[3].len(), lines[4].len());
        assert!(r.contains("0.050589"));
        assert!(r.contains("1.3911"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(dims(4656, 5793), "4656x5793");
        assert_eq!(secs(1.5), "1.500000");
        assert_eq!(ratio(0.5), "0.5000");
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.00 KiB");
        assert!(bytes(77_300_000).contains("MiB"));
        assert_eq!(duration(0.0025), "2.50 ms");
        assert!(duration(2.5).contains("s"));
        assert!(duration(2.5e-7).contains("ns"));
    }
}
