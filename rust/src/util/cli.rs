//! Declarative command-line parsing (clap is not vendored).
//!
//! Supports the subset the `blockms` binary and examples need:
//! `--flag`, `--opt value`, `--opt=value`, positional arguments,
//! subcommands (first positional), `-h/--help` text generation, and typed
//! accessors with defaults. Unknown options are hard errors — silent typos
//! in a bench sweep would corrupt results.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `true` if the option takes a value (`--k 4`), `false` for a flag.
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A declarative CLI: options + positionals, then `parse`.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

/// Parse result: resolved option values + positional arguments.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    /// Options/flags the user actually typed (vs spec defaults) — what
    /// distinguishes a *pinned* knob from a planner-free one under
    /// `--auto`.
    explicit: BTreeSet<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CliError {
    #[error("unknown option --{0}")]
    Unknown(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1:?} ({2})")]
    BadValue(String, String, String),
    #[error("invalid environment {0}={1:?} ({2})")]
    BadEnv(String, String, String),
    #[error("unknown subcommand {0:?} (see --help)")]
    UnknownSubcommand(String),
    #[error("missing required option --{0}")]
    MissingRequired(String),
    #[error("help requested")]
    HelpRequested,
}

impl Cli {
    pub fn new(bin: &'static str, about: &'static str) -> Self {
        Self {
            bin,
            about,
            opts: Vec::new(),
        }
    }

    /// Add a value-taking option with an optional default.
    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default,
        });
        self
    }

    /// Add a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.bin, self.about);
        let _ = writeln!(s, "USAGE: {} [subcommand] [options]\n\nOPTIONS:", self.bin);
        for o in &self.opts {
            let mut left = format!("  --{}", o.name);
            if o.takes_value {
                left.push_str(" <value>");
            }
            let pad = if left.len() < 26 { 26 - left.len() } else { 1 };
            let _ = write!(s, "{}{}{}", left, " ".repeat(pad), o.help);
            if let Some(d) = o.default {
                let _ = write!(s, " [default: {d}]");
            }
            s.push('\n');
        }
        let _ = writeln!(s, "  --help                  print this help");
        s
    }

    fn spec(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    /// Parse an argument vector (excluding argv[0]).
    pub fn parse<I, S>(&self, argv: I) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
            if !o.takes_value {
                args.flags.insert(o.name.to_string(), false);
            }
        }
        let mut it = argv.into_iter().map(Into::into).peekable();
        while let Some(a) = it.next() {
            if a == "-h" || a == "--help" {
                return Err(CliError::HelpRequested);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self.spec(&name).ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    args.explicit.insert(name.clone());
                    args.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        return Err(CliError::BadValue(
                            name.clone(),
                            inline.unwrap(),
                            "flag takes no value".into(),
                        ));
                    }
                    args.explicit.insert(name.clone());
                    args.flags.insert(name, true);
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Did the user type this option/flag (vs it resolving from the
    /// spec default)? The `--auto` planner treats typed options as
    /// pinned and spec defaults as free.
    pub fn provided(&self, name: &str) -> bool {
        self.explicit.contains(name)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .values
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        raw.parse::<T>().map_err(|e| {
            CliError::BadValue(name.to_string(), raw.clone(), e.to_string())
        })
    }

    /// First positional (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("k", Some("2"), "clusters")
            .opt("shape", None, "block shape")
            .flag("verbose", "talk more")
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.get("k"), Some("2"));
        assert_eq!(a.get("shape"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn explicit_options_are_distinguishable_from_defaults() {
        let a = cli().parse(vec!["--k", "2", "--verbose"]).unwrap();
        assert!(a.provided("k"), "typed --k 2 must count as pinned");
        assert!(a.provided("verbose"));
        assert!(!a.provided("shape"));
        let d = cli().parse(Vec::<String>::new()).unwrap();
        assert_eq!(d.get("k"), Some("2"));
        assert!(!d.provided("k"), "spec default must not count as pinned");
    }

    #[test]
    fn parses_values_and_flags() {
        let a = cli()
            .parse(vec!["run", "--k", "8", "--shape=row", "--verbose", "x"])
            .unwrap();
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.get_parse::<usize>("k").unwrap(), 8);
        assert_eq!(a.get("shape"), Some("row"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run", "x"]);
    }

    #[test]
    fn unknown_option_is_error() {
        assert_eq!(
            cli().parse(vec!["--nope"]),
            Err(CliError::Unknown("nope".into()))
        );
    }

    #[test]
    fn missing_value_is_error() {
        assert_eq!(
            cli().parse(vec!["--shape"]),
            Err(CliError::MissingValue("shape".into()))
        );
    }

    #[test]
    fn bad_typed_value_is_error() {
        let a = cli().parse(vec!["--k", "abc"]).unwrap();
        assert!(matches!(a.get_parse::<usize>("k"), Err(CliError::BadValue(..))));
    }

    #[test]
    fn help_is_requested() {
        assert_eq!(cli().parse(vec!["--help"]), Err(CliError::HelpRequested));
        assert!(cli().help_text().contains("--shape"));
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(matches!(
            cli().parse(vec!["--verbose=yes"]),
            Err(CliError::BadValue(..))
        ));
    }
}
