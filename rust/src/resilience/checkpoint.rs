//! Versioned, checksummed, atomically-renamed run checkpoints.
//!
//! A checkpoint captures the round-boundary state of a global-mode
//! run: the current centroids, how many Lloyd rounds have been
//! absorbed, which phase comes next (another step round or the final
//! assign pass), the convergence trace, the per-block completion
//! bitmap, and the spooled-label cursor. Everything downstream of the
//! centroids (labels, counts, inertia) is recomputed on resume, which
//! is why resumed runs are bit-identical: per-block work is a pure
//! function of the shipped centroids.
//!
//! ## File format (version 1, all little-endian)
//!
//! ```text
//! offset  size  field
//! 0       8     magic "BMSCKPT\0"
//! 8       4     version (u32) = 1
//! 12      8     fingerprint (u64) — FNV-1a of the run configuration
//! 20      8     iterations (u64) — step rounds absorbed so far
//! 28      1     phase (u8): 0 = next round is a step, 1 = final assign
//! 29      1     converged (u8 bool)
//! 30      8     centroid f32 count (u64), then that many f32s
//! ..      8     inertia-trace f64 count (u64), then that many f64s
//! ..      8     block count (u64), then ceil(n/8) bitmap bytes
//! ..      8     spooled-label cursor (u64, pixels already assembled)
//! ..      8     checksum (u64) — FNV-1a of every preceding byte
//! ```
//!
//! Writes go to a `.tmp` sibling and are published with `fs::rename`,
//! so a crash mid-write can never corrupt the previous checkpoint.
//! Loads reject bad magic, unknown versions, truncation, checksum
//! mismatches, and (at resume time, via the caller's fingerprint
//! comparison) checkpoints from a different run configuration — each
//! with a clean, specific error.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Leading magic bytes of every checkpoint file.
pub const CKPT_MAGIC: [u8; 8] = *b"BMSCKPT\0";
/// Current format version.
pub const CKPT_VERSION: u32 = 1;

/// Which kind of round the resumed machine runs next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckpointPhase {
    /// More Lloyd step rounds to go.
    Step,
    /// Centroids are final; only the label-assign pass remains.
    Assign,
}

/// A round-boundary snapshot of a global-mode run.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// FNV-1a digest of the run configuration (geometry, k, seed,
    /// tolerance, round caps, plan shape, kernel, mode). Resume
    /// refuses a checkpoint whose fingerprint disagrees with the
    /// current run's — silently mixing configurations could not stay
    /// bit-identical.
    pub fingerprint: u64,
    /// Step rounds absorbed (the machine's `iterations`).
    pub iterations: u64,
    /// What the next round is.
    pub phase: CheckpointPhase,
    /// Whether the centroid update declared convergence.
    pub converged: bool,
    /// Current centroids, row-major `k * channels`, exact f32 bits.
    pub centroids: Vec<f32>,
    /// Per-round inertia trace so far, exact f64 bits.
    pub inertia_trace: Vec<f64>,
    /// Per-block completion bitmap for the in-progress round. At a
    /// round boundary every block is complete; kept in the format so
    /// a future mid-round checkpoint is a version bump, not a rewrite.
    pub blocks_done: Vec<bool>,
    /// Pixels already assembled into the (possibly spooled) label
    /// sink. Zero at every pre-assign boundary.
    pub label_cursor: u64,
}

/// FNV-1a 64-bit digest (the checksum and fingerprint hash).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!(
                "truncated checkpoint: wanted {} bytes at offset {}, file has {}",
                n,
                self.pos,
                self.bytes.len()
            );
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

impl Checkpoint {
    /// Serialize to the version-1 byte layout (checksum included).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64 + self.centroids.len() * 4);
        buf.extend_from_slice(&CKPT_MAGIC);
        buf.extend_from_slice(&CKPT_VERSION.to_le_bytes());
        push_u64(&mut buf, self.fingerprint);
        push_u64(&mut buf, self.iterations);
        buf.push(match self.phase {
            CheckpointPhase::Step => 0,
            CheckpointPhase::Assign => 1,
        });
        buf.push(self.converged as u8);
        push_u64(&mut buf, self.centroids.len() as u64);
        for &c in &self.centroids {
            buf.extend_from_slice(&c.to_le_bytes());
        }
        push_u64(&mut buf, self.inertia_trace.len() as u64);
        for &v in &self.inertia_trace {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        push_u64(&mut buf, self.blocks_done.len() as u64);
        let mut bitmap = vec![0u8; self.blocks_done.len().div_ceil(8)];
        for (i, &done) in self.blocks_done.iter().enumerate() {
            if done {
                bitmap[i / 8] |= 1 << (i % 8);
            }
        }
        buf.extend_from_slice(&bitmap);
        push_u64(&mut buf, self.label_cursor);
        let sum = fnv1a(&buf);
        push_u64(&mut buf, sum);
        buf
    }

    /// Parse and verify a checkpoint from bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let mut c = Cursor { bytes, pos: 0 };
        let magic = c.take(8).context("not a blockms checkpoint (too short)")?;
        if magic != CKPT_MAGIC {
            bail!("not a blockms checkpoint (bad magic)");
        }
        let version = c.u32()?;
        if version != CKPT_VERSION {
            bail!("unsupported checkpoint version {version} (this build reads version {CKPT_VERSION})");
        }
        // Checksum covers everything up to the final 8 bytes; verify
        // before trusting any length field.
        if bytes.len() < 8 {
            bail!("truncated checkpoint: no checksum");
        }
        let body = &bytes[..bytes.len() - 8];
        let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        if fnv1a(body) != stored {
            bail!("corrupted checkpoint: checksum mismatch");
        }
        let fingerprint = c.u64()?;
        let iterations = c.u64()?;
        let phase = match c.u8()? {
            0 => CheckpointPhase::Step,
            1 => CheckpointPhase::Assign,
            other => bail!("corrupted checkpoint: unknown phase tag {other}"),
        };
        let converged = c.u8()? != 0;
        let n_centroids = c.u64()? as usize;
        let mut centroids = Vec::with_capacity(n_centroids);
        for _ in 0..n_centroids {
            centroids.push(f32::from_le_bytes(c.take(4)?.try_into().unwrap()));
        }
        let n_trace = c.u64()? as usize;
        let mut inertia_trace = Vec::with_capacity(n_trace);
        for _ in 0..n_trace {
            inertia_trace.push(f64::from_le_bytes(c.take(8)?.try_into().unwrap()));
        }
        let n_blocks = c.u64()? as usize;
        let bitmap = c.take(n_blocks.div_ceil(8))?;
        let blocks_done = (0..n_blocks)
            .map(|i| bitmap[i / 8] >> (i % 8) & 1 == 1)
            .collect();
        let label_cursor = c.u64()?;
        Ok(Checkpoint {
            fingerprint,
            iterations,
            phase,
            converged,
            centroids,
            inertia_trace,
            blocks_done,
            label_cursor,
        })
    }

    /// Write atomically: serialize to `<path>.tmp`, then rename over
    /// `path`. A crash mid-write leaves the previous checkpoint (or
    /// nothing) — never a half-written file under the published name.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, self.to_bytes())
            .with_context(|| format!("write checkpoint to {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publish checkpoint at {}", path.display()))?;
        Ok(())
    }

    /// Load and verify a checkpoint file.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read checkpoint {}", path.display()))?;
        Checkpoint::from_bytes(&bytes)
            .with_context(|| format!("load checkpoint {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            fingerprint: 0xdead_beef_cafe_f00d,
            iterations: 7,
            phase: CheckpointPhase::Step,
            converged: false,
            centroids: vec![0.25, -1.5, 3.75e-3, f32::MIN_POSITIVE, 255.0, 0.0],
            inertia_trace: vec![1234.5678, 987.654_321, 42.0],
            blocks_done: vec![true, true, false, true, false, false, true, true, true],
            label_cursor: 65_536,
        }
    }

    #[test]
    fn byte_round_trip_is_exact() {
        let ck = sample();
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back, ck);
        for (a, b) in ck.centroids.iter().zip(&back.centroids) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ck.inertia_trace.iter().zip(&back.inertia_trace) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn file_round_trip_and_atomic_overwrite() {
        let dir = std::env::temp_dir().join(format!("blockms_ckpt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.ckpt");
        let ck = sample();
        ck.save(&path).unwrap();
        let mut later = ck.clone();
        later.iterations = 9;
        later.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap().iterations, 9);
        assert!(
            !path.with_extension("ckpt.tmp").exists()
                && !dir.join("run.ckpt.tmp").exists(),
            "temp file must not outlive the rename"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_bad_magic_version_truncation_and_corruption() {
        let good = sample().to_bytes();

        let mut bad = good.clone();
        bad[0] = b'X';
        let err = Checkpoint::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        let mut bad = good.clone();
        bad[8] = 99; // version field
        let err = format!("{:#}", Checkpoint::from_bytes(&bad).unwrap_err());
        assert!(err.contains("version"), "{err}");

        let err = format!(
            "{:#}",
            Checkpoint::from_bytes(&good[..good.len() - 11]).unwrap_err()
        );
        assert!(
            err.contains("truncated") || err.contains("checksum"),
            "{err}"
        );

        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40; // flip one payload bit
        let err = format!("{:#}", Checkpoint::from_bytes(&bad).unwrap_err());
        assert!(err.contains("checksum"), "{err}");

        let err = format!("{:#}", Checkpoint::from_bytes(b"short").unwrap_err());
        assert!(err.contains("not a blockms checkpoint"), "{err}");
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }
}
