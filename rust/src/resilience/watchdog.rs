//! Per-worker heartbeat watchdog: liveness detection for hung blocks.
//!
//! PR 6's retry machinery only protects against faults that *announce*
//! themselves — an `Err` or a panic reaches the leader as a `JobError`
//! and the block is re-queued. A worker that silently stops making
//! progress (a hung syscall, a livelocked reader, an injected
//! [`crate::resilience::FaultKind::Hang`]) produces nothing at all, and
//! an unbounded `recv()` round barrier waits forever.
//!
//! The watchdog closes that gap with shared epoch counters:
//!
//! - **workers stamp**: every worker owns a [`WorkerSlot`] of atomics
//!   and calls [`Watchdog::begin`] when it picks a block up and
//!   [`Watchdog::end`] when the result is sent — two `SeqCst` stores
//!   per block, no locks on the hot path;
//! - **the leader scans**: [`Watchdog::scan`] compares each busy
//!   worker's epoch against the last observed value; a worker whose
//!   epoch has not advanced for longer than the staleness timeout is
//!   reported as a [`Stall`] naming the worker, job, block, round, and
//!   silence duration. Each stuck epoch is escalated exactly once, so
//!   a caller polling every few milliseconds re-queues one spare copy,
//!   not hundreds.
//!
//! Escalation reuses the retry path: the leader clones the parked
//! block's job onto another worker and takes the first completed
//! result. That is bit-identical by construction — per-block work is a
//! pure function of the round's shipped centroids and the reduction
//! stays block-ordered — so a hung block is indistinguishable from a
//! panicked one: recovery costs time, never values.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Sentinel for "this worker is not on a block right now".
const IDLE: u64 = u64::MAX;

/// Default staleness threshold before a silent busy worker is treated
/// as hung. Generous against real block times (milliseconds at the
/// paper geometries) while keeping hang recovery snappy.
pub const DEFAULT_HEARTBEAT_TIMEOUT_MS: u64 = 1500;

/// One worker's heartbeat state: an epoch counter bumped on every
/// pickup/completion, plus the identity of the block in hand.
#[derive(Debug)]
struct WorkerSlot {
    /// Monotone epoch: odd while busy, even while idle — every
    /// transition bumps it, so a stuck value means a stuck worker.
    seq: AtomicU64,
    /// Block in hand, or [`IDLE`].
    block: AtomicU64,
    /// Job the block belongs to (valid while busy).
    job: AtomicU64,
    /// Round of the block in hand (valid while busy).
    round: AtomicU64,
}

/// Leader-side per-worker scan memory.
#[derive(Clone, Copy, Debug)]
struct ScanState {
    last_seq: u64,
    since: Instant,
    /// The busy epoch already escalated (escalate once per stall).
    escalated_seq: u64,
}

/// A busy worker whose heartbeat went stale: the block it is parked on
/// should be speculatively re-queued elsewhere.
#[derive(Clone, Copy, Debug)]
pub struct Stall {
    pub worker: usize,
    pub job: u64,
    pub block: usize,
    pub round: u64,
    /// How long the worker has been silent.
    pub silent: Duration,
}

/// The shared heartbeat table: workers stamp, the leader scans.
#[derive(Debug)]
pub struct Watchdog {
    slots: Vec<WorkerSlot>,
    /// Staleness threshold in milliseconds; 0 disables the watchdog.
    timeout_ms: AtomicU64,
    scan: Mutex<Vec<ScanState>>,
}

impl Watchdog {
    /// A watchdog for `workers` workers with the given staleness
    /// timeout (`0` = disabled: [`Watchdog::scan`] never reports).
    pub fn new(workers: usize, timeout_ms: u64) -> Watchdog {
        let now = Instant::now();
        Watchdog {
            slots: (0..workers)
                .map(|_| WorkerSlot {
                    seq: AtomicU64::new(0),
                    block: AtomicU64::new(IDLE),
                    job: AtomicU64::new(0),
                    round: AtomicU64::new(0),
                })
                .collect(),
            timeout_ms: AtomicU64::new(timeout_ms),
            scan: Mutex::new(
                (0..workers)
                    .map(|_| ScanState {
                        last_seq: 0,
                        since: now,
                        escalated_seq: u64::MAX,
                    })
                    .collect(),
            ),
        }
    }

    /// Current staleness threshold.
    pub fn timeout(&self) -> Duration {
        Duration::from_millis(self.timeout_ms.load(Ordering::Relaxed))
    }

    /// Retune the staleness threshold (0 disables). Takes effect on the
    /// next scan; safe while workers are running.
    pub fn set_timeout_ms(&self, ms: u64) {
        self.timeout_ms.store(ms, Ordering::Relaxed);
    }

    /// Worker-side: `worker` picked up `block` of `job` at `round`.
    pub fn begin(&self, worker: usize, job: u64, block: usize, round: u64) {
        let s = &self.slots[worker];
        s.job.store(job, Ordering::Relaxed);
        s.round.store(round, Ordering::Relaxed);
        s.block.store(block as u64, Ordering::Relaxed);
        s.seq.fetch_add(1, Ordering::SeqCst);
    }

    /// Worker-side: `worker` finished (or abandoned) its block.
    pub fn end(&self, worker: usize) {
        let s = &self.slots[worker];
        s.block.store(IDLE, Ordering::Relaxed);
        s.seq.fetch_add(1, Ordering::SeqCst);
    }

    /// Leader-side: report every busy worker whose epoch has been
    /// stuck for longer than the timeout. Each stuck epoch is reported
    /// exactly once — the stall re-arms only after the worker makes
    /// progress (its epoch advances).
    pub fn scan(&self) -> Vec<Stall> {
        let timeout_ms = self.timeout_ms.load(Ordering::Relaxed);
        let mut states = self.scan.lock().expect("watchdog scan lock");
        let now = Instant::now();
        let mut stalls = Vec::new();
        for (w, slot) in self.slots.iter().enumerate() {
            let seq = slot.seq.load(Ordering::SeqCst);
            let st = &mut states[w];
            if seq != st.last_seq {
                st.last_seq = seq;
                st.since = now;
                continue;
            }
            let block = slot.block.load(Ordering::Relaxed);
            if block == IDLE || timeout_ms == 0 {
                continue;
            }
            let silent = now.duration_since(st.since);
            if silent >= Duration::from_millis(timeout_ms) && st.escalated_seq != seq {
                st.escalated_seq = seq;
                stalls.push(Stall {
                    worker: w,
                    job: slot.job.load(Ordering::Relaxed),
                    block: block as usize,
                    round: slot.round.load(Ordering::Relaxed),
                    silent,
                });
            }
        }
        stalls
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_workers_never_stall() {
        let wd = Watchdog::new(2, 1);
        std::thread::sleep(Duration::from_millis(5));
        assert!(wd.scan().is_empty(), "idle workers must not be reported");
    }

    #[test]
    fn silent_busy_worker_is_reported_once_per_epoch() {
        let wd = Watchdog::new(2, 5);
        wd.begin(1, 7, 3, 2);
        wd.scan(); // observe the fresh epoch
        std::thread::sleep(Duration::from_millis(10));
        let stalls = wd.scan();
        assert_eq!(stalls.len(), 1);
        let s = stalls[0];
        assert_eq!((s.worker, s.job, s.block, s.round), (1, 7, 3, 2));
        assert!(s.silent >= Duration::from_millis(5));
        assert!(wd.scan().is_empty(), "the same stuck epoch escalates once");
        // Progress re-arms the stall detector.
        wd.end(1);
        wd.begin(1, 7, 4, 2);
        wd.scan();
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(wd.scan().len(), 1, "a new stuck epoch escalates again");
    }

    #[test]
    fn completing_clears_the_stall() {
        let wd = Watchdog::new(1, 5);
        wd.begin(0, 0, 0, 0);
        wd.scan();
        wd.end(0);
        std::thread::sleep(Duration::from_millis(10));
        assert!(wd.scan().is_empty(), "finished worker is idle, not hung");
    }

    #[test]
    fn zero_timeout_disables_the_watchdog() {
        let wd = Watchdog::new(1, 0);
        wd.begin(0, 0, 0, 0);
        wd.scan();
        std::thread::sleep(Duration::from_millis(5));
        assert!(wd.scan().is_empty());
        wd.set_timeout_ms(1);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(wd.scan().len(), 1, "re-enabling arms the existing stall");
    }
}
