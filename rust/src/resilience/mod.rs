//! Fault-tolerant execution: deterministic fault injection, versioned
//! checkpoints, and the retry/resume policy knobs.
//!
//! The paper's target workload is >1000×1000 orthoimagery clustered
//! block-by-block on legacy hardware — the regime where a multi-hour
//! streamed run dying on one bad block, one crashed worker, or one
//! power cut is unacceptable. This module provides the two primitives
//! the coordinator, pool, and service layers build recovery on:
//!
//! - [`FaultPlan`] — a deterministic injector that makes a chosen block
//!   fail in a chosen way ([`FaultKind::Error`], [`FaultKind::Panic`],
//!   [`FaultKind::ReaderIo`]) on a chosen window of visits. It
//!   generalizes the old `fail_block` test hook: instead of "block N
//!   always errors", a plan says "block N's visits `skip..skip+visits`
//!   fail, the rest succeed", which is exactly what retry tests need
//!   (fail once, succeed on the re-queue) and what kill/resume tests
//!   need (succeed for R rounds, then die every time).
//!
//! - [`Checkpoint`] — a versioned, checksummed, atomically-renamed
//!   snapshot of the global round state (centroids, round index,
//!   per-block completion bitmap, spooled-label cursor, convergence
//!   trace). A run resumed from a checkpoint produces labels,
//!   centroids, counts, and inertia **bit-identical** to an
//!   uninterrupted run, because per-block assign/step is a pure
//!   function of the shipped centroids and Hamerly pruning is exact:
//!   resuming with no drift history only disables pruning for one
//!   round, it never changes a value.
//!
//! Retry bit-identity rests on the same argument: a re-queued block
//! recomputes from the same shipped centroids, and the failing
//! worker's possibly half-mutated Hamerly bounds and arena tile for
//! that `(job, block)` are evicted before the retry, so the re-run
//! re-seeds from scratch exactly like a first visit after migration.
//!
//! - [`Watchdog`] — per-worker heartbeat epochs for faults that
//!   *don't* announce themselves: a hung or straggling block
//!   ([`FaultKind::Hang`]) produces no error and no panic, so the
//!   leader's bounded round barrier scans the heartbeat table and
//!   escalates a silent worker to the same re-queue path. First
//!   completed result wins; the duplicate is discarded before
//!   reduction, so speculation is bit-identical too.

mod checkpoint;
mod fault;
mod watchdog;

pub use checkpoint::{fnv1a, Checkpoint, CheckpointPhase, CKPT_MAGIC, CKPT_VERSION};
pub use fault::{FaultKind, FaultPlan, DEFAULT_HANG_MS};
pub use watchdog::{Stall, Watchdog, DEFAULT_HEARTBEAT_TIMEOUT_MS};
