//! Deterministic fault injection for the worker pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// What happens when the fault fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The block computation returns an `Err` (a recoverable job
    /// failure, like a bad decode or a poisoned tile).
    Error,
    /// The worker thread panics mid-block. The pool's supervisor
    /// converts the panic into a `JobError` and restarts the worker
    /// loop, so capacity does not decay.
    Panic,
    /// The block read fails with an I/O error before any compute runs
    /// (a flaky disk / NFS hiccup on the strip store).
    ReaderIo,
}

impl FaultKind {
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Error => "error",
            FaultKind::Panic => "panic",
            FaultKind::ReaderIo => "reader-io",
        }
    }
}

impl std::str::FromStr for FaultKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Ok(FaultKind::Error),
            "panic" => Ok(FaultKind::Panic),
            "reader-io" | "readerio" | "io" => Ok(FaultKind::ReaderIo),
            other => Err(format!(
                "unknown fault kind {other:?} (want error|panic|reader-io)"
            )),
        }
    }
}

/// A deterministic fault schedule for one block.
///
/// The plan counts *visits* to its block (across all workers and
/// retries — clones share the counter) and fires on the visit window
/// `skip .. skip + visits`:
///
/// - `FaultPlan::new(b, kind, 1)` — the classic retry scenario: the
///   first visit to block `b` fails, every re-queue succeeds.
/// - `.always()` — every visit fails; with zero retries the run must
///   fail loudly (the old `fail_block` hook's behaviour).
/// - `.after(r)` — succeed for the first `r` visits, then fail; with
///   one visit per round this kills a run *after* round `r`, which is
///   how the kill/resume tests die mid-run with checkpoints on disk.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    block: usize,
    kind: FaultKind,
    /// Successful visits before the fault window opens.
    skip: usize,
    /// Width of the fault window (`usize::MAX` = never heals).
    visits: usize,
    /// Visits observed so far, shared across clones: the contexts a
    /// plan is threaded through (coordinator config, worker contexts,
    /// job specs) must agree on the count.
    counter: Arc<AtomicUsize>,
}

impl FaultPlan {
    /// Fail the first `visits` visits to `block` with `kind`, succeed
    /// afterwards.
    pub fn new(block: usize, kind: FaultKind, visits: usize) -> FaultPlan {
        FaultPlan {
            block,
            kind,
            skip: 0,
            visits,
            counter: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Fail *every* visit to `block` (never heals).
    pub fn always(block: usize, kind: FaultKind) -> FaultPlan {
        FaultPlan::new(block, kind, usize::MAX)
    }

    /// Let the first `skip` visits succeed before the window opens.
    pub fn after(mut self, skip: usize) -> FaultPlan {
        self.skip = skip;
        self
    }

    /// The targeted block index.
    pub fn block(&self) -> usize {
        self.block
    }

    /// What the fault does when it fires.
    pub fn kind(&self) -> FaultKind {
        self.kind
    }

    /// Record a visit to `block`; true iff the fault fires this visit.
    ///
    /// Visits to other blocks are not counted and never fire.
    pub fn fires(&self, block: usize) -> bool {
        if block != self.block {
            return false;
        }
        let n = self.counter.fetch_add(1, Ordering::SeqCst);
        n >= self.skip && n - self.skip < self.visits
    }

    /// Visits recorded so far (tests assert the fault actually fired).
    pub fn trips(&self) -> usize {
        self.counter.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_only_inside_the_visit_window() {
        let f = FaultPlan::new(3, FaultKind::Error, 2).after(1);
        assert!(!f.fires(0), "other blocks never fire");
        assert!(!f.fires(3), "visit 0 is skipped");
        assert!(f.fires(3), "visit 1 opens the window");
        assert!(f.fires(3), "visit 2 still inside");
        assert!(!f.fires(3), "window closed, block healed");
        assert_eq!(f.trips(), 4);
    }

    #[test]
    fn clones_share_the_visit_counter() {
        let f = FaultPlan::new(0, FaultKind::Panic, 1);
        let g = f.clone();
        assert!(g.fires(0), "first visit (via the clone) fires");
        assert!(!f.fires(0), "the original sees the clone's visit");
        assert_eq!(f.trips(), 2);
    }

    #[test]
    fn always_never_heals() {
        let f = FaultPlan::always(1, FaultKind::ReaderIo);
        for _ in 0..100 {
            assert!(f.fires(1));
        }
    }

    #[test]
    fn kind_round_trips_from_str() {
        for kind in [FaultKind::Error, FaultKind::Panic, FaultKind::ReaderIo] {
            assert_eq!(kind.label().parse::<FaultKind>().unwrap(), kind);
        }
        assert!("bogus".parse::<FaultKind>().is_err());
    }
}
