//! Deterministic fault injection for the worker pool.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Park duration a bare `hang` spec defaults to: long enough to trip
/// the default heartbeat timeout, short enough that a sleeping worker
/// never stalls pool shutdown for more than a few seconds.
pub const DEFAULT_HANG_MS: u64 = 4000;

/// What happens when the fault fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The block computation returns an `Err` (a recoverable job
    /// failure, like a bad decode or a poisoned tile).
    Error,
    /// The worker thread panics mid-block. The pool's supervisor
    /// converts the panic into a `JobError` and restarts the worker
    /// loop, so capacity does not decay.
    Panic,
    /// The block read fails with an I/O error before any compute runs
    /// (a flaky disk / NFS hiccup on the strip store).
    ReaderIo,
    /// The worker parks on the block for `ms` milliseconds (or until
    /// the plan's release latch opens) and then computes normally — a
    /// silent stall that produces no error and no panic, which only
    /// the heartbeat watchdog can see. The duration is finite by
    /// design: a sleeping worker must still join at shutdown.
    Hang { ms: u64 },
}

impl FaultKind {
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Error => "error",
            FaultKind::Panic => "panic",
            FaultKind::ReaderIo => "reader-io",
            FaultKind::Hang { .. } => "hang",
        }
    }
}

impl std::str::FromStr for FaultKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        let lower = s.to_ascii_lowercase();
        if let Some(rest) = lower.strip_prefix("hang") {
            if rest.is_empty() {
                return Ok(FaultKind::Hang { ms: DEFAULT_HANG_MS });
            }
            return match rest.parse::<u64>() {
                Ok(ms) if ms > 0 => Ok(FaultKind::Hang { ms }),
                _ => Err(format!(
                    "bad hang duration {rest:?} (want hang or hangMS, e.g. hang500)"
                )),
            };
        }
        match lower.as_str() {
            "error" => Ok(FaultKind::Error),
            "panic" => Ok(FaultKind::Panic),
            "reader-io" | "readerio" | "io" => Ok(FaultKind::ReaderIo),
            other => Err(format!(
                "unknown fault kind {other:?} (want error|panic|reader-io|hang[MS])"
            )),
        }
    }
}

/// A deterministic fault schedule for one block.
///
/// The plan counts *visits* to its block (across all workers and
/// retries — clones share the counter) and fires on the visit window
/// `skip .. skip + visits`:
///
/// - `FaultPlan::new(b, kind, 1)` — the classic retry scenario: the
///   first visit to block `b` fails, every re-queue succeeds.
/// - `.always()` — every visit fails; with zero retries the run must
///   fail loudly (the old `fail_block` hook's behaviour).
/// - `.after(r)` — succeed for the first `r` visits, then fail; with
///   one visit per round this kills a run *after* round `r`, which is
///   how the kill/resume tests die mid-run with checkpoints on disk.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Targeted blocks, ascending (usually one; the hardening bench
    /// parks several victims at once). Shared so clones stay cheap.
    blocks: Arc<Vec<usize>>,
    kind: FaultKind,
    /// Successful visits before the fault window opens.
    skip: usize,
    /// Width of the fault window (`usize::MAX` = never heals).
    visits: usize,
    /// Visits observed so far, shared across clones: the contexts a
    /// plan is threaded through (coordinator config, worker contexts,
    /// job specs) must agree on the count.
    counter: Arc<AtomicUsize>,
    /// Release latch for [`FaultKind::Hang`]: opening it wakes every
    /// parked worker early (tests and drains use it; shared across
    /// clones like the counter).
    release: Arc<AtomicBool>,
}

impl FaultPlan {
    /// Fail the first `visits` visits to `block` with `kind`, succeed
    /// afterwards.
    pub fn new(block: usize, kind: FaultKind, visits: usize) -> FaultPlan {
        FaultPlan::on_blocks(vec![block], kind, visits)
    }

    /// Fault a set of victim blocks: the window counts visits to *any*
    /// member, so `visits == blocks.len()` fails each victim's first
    /// visit (the multi-straggler scenario).
    pub fn on_blocks(mut blocks: Vec<usize>, kind: FaultKind, visits: usize) -> FaultPlan {
        assert!(!blocks.is_empty(), "a fault plan needs at least one block");
        blocks.sort_unstable();
        blocks.dedup();
        FaultPlan {
            blocks: Arc::new(blocks),
            kind,
            skip: 0,
            visits,
            counter: Arc::new(AtomicUsize::new(0)),
            release: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Fail *every* visit to `block` (never heals).
    pub fn always(block: usize, kind: FaultKind) -> FaultPlan {
        FaultPlan::new(block, kind, usize::MAX)
    }

    /// Let the first `skip` visits succeed before the window opens.
    pub fn after(mut self, skip: usize) -> FaultPlan {
        self.skip = skip;
        self
    }

    /// The (first) targeted block index.
    pub fn block(&self) -> usize {
        self.blocks[0]
    }

    /// Every targeted block, ascending.
    pub fn victim_blocks(&self) -> &[usize] {
        &self.blocks
    }

    /// What the fault does when it fires.
    pub fn kind(&self) -> FaultKind {
        self.kind
    }

    /// Record a visit to `block`; true iff the fault fires this visit.
    ///
    /// Visits to other blocks are not counted and never fire.
    pub fn fires(&self, block: usize) -> bool {
        if self.blocks.binary_search(&block).is_err() {
            return false;
        }
        let n = self.counter.fetch_add(1, Ordering::SeqCst);
        n >= self.skip && n - self.skip < self.visits
    }

    /// Visits recorded so far (tests assert the fault actually fired).
    pub fn trips(&self) -> usize {
        self.counter.load(Ordering::SeqCst)
    }

    /// Open the hang release latch: every currently-parked worker
    /// wakes within one poll tick, and future hang firings return
    /// immediately. Irreversible (like a tripped breaker).
    pub fn release(&self) {
        self.release.store(true, Ordering::SeqCst);
    }

    /// Whether the hang release latch is open.
    pub fn released(&self) -> bool {
        self.release.load(Ordering::SeqCst)
    }

    /// Park the calling worker for `ms` milliseconds or until the
    /// release latch opens, polling every few milliseconds so shutdown
    /// and tests can cut the park short.
    pub fn park(&self, ms: u64) {
        let until = Instant::now() + Duration::from_millis(ms);
        while Instant::now() < until && !self.released() {
            let left = until.saturating_duration_since(Instant::now());
            std::thread::sleep(left.min(Duration::from_millis(5)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_only_inside_the_visit_window() {
        let f = FaultPlan::new(3, FaultKind::Error, 2).after(1);
        assert!(!f.fires(0), "other blocks never fire");
        assert!(!f.fires(3), "visit 0 is skipped");
        assert!(f.fires(3), "visit 1 opens the window");
        assert!(f.fires(3), "visit 2 still inside");
        assert!(!f.fires(3), "window closed, block healed");
        assert_eq!(f.trips(), 4);
    }

    #[test]
    fn clones_share_the_visit_counter() {
        let f = FaultPlan::new(0, FaultKind::Panic, 1);
        let g = f.clone();
        assert!(g.fires(0), "first visit (via the clone) fires");
        assert!(!f.fires(0), "the original sees the clone's visit");
        assert_eq!(f.trips(), 2);
    }

    #[test]
    fn always_never_heals() {
        let f = FaultPlan::always(1, FaultKind::ReaderIo);
        for _ in 0..100 {
            assert!(f.fires(1));
        }
    }

    #[test]
    fn kind_round_trips_from_str() {
        for kind in [FaultKind::Error, FaultKind::Panic, FaultKind::ReaderIo] {
            assert_eq!(kind.label().parse::<FaultKind>().unwrap(), kind);
        }
        assert!("bogus".parse::<FaultKind>().is_err());
    }

    #[test]
    fn hang_parses_with_and_without_a_duration() {
        assert_eq!(
            "hang".parse::<FaultKind>().unwrap(),
            FaultKind::Hang { ms: DEFAULT_HANG_MS }
        );
        assert_eq!(
            "hang250".parse::<FaultKind>().unwrap(),
            FaultKind::Hang { ms: 250 }
        );
        assert!("hangx".parse::<FaultKind>().is_err());
        assert!("hang0".parse::<FaultKind>().is_err());
        assert_eq!(FaultKind::Hang { ms: 7 }.label(), "hang");
    }

    #[test]
    fn multi_block_plan_fires_each_victims_first_visit() {
        let f = FaultPlan::on_blocks(vec![5, 1, 3], FaultKind::Error, 3);
        assert_eq!(f.victim_blocks(), &[1, 3, 5]);
        assert_eq!(f.block(), 1);
        assert!(!f.fires(0), "non-victims never fire");
        assert!(f.fires(3));
        assert!(f.fires(1));
        assert!(f.fires(5), "each victim's first visit is in the window");
        assert!(!f.fires(3), "window exhausted after blocks.len() firings");
    }

    #[test]
    fn park_honors_the_release_latch() {
        let f = FaultPlan::new(0, FaultKind::Hang { ms: 60_000 }, 1);
        let g = f.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || g.park(60_000));
        std::thread::sleep(Duration::from_millis(20));
        f.release();
        h.join().unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "release must cut the park short"
        );
        let t0 = Instant::now();
        f.park(60_000); // latch already open: returns immediately
        assert!(t0.elapsed() < Duration::from_secs(1));
    }
}
