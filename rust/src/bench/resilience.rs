//! Resilience-layer benchmark: what fault tolerance costs when nothing
//! fails, and what recovery costs when something does — with the
//! machine-readable `BENCH_resilience.json` trail (EXPERIMENTS.md
//! §Resilience documents the schema).
//!
//! For every case geometry the bench runs the same clustering four ways:
//!
//! 1. **baseline** — fault-free, zero retries, no checkpoints: the seed
//!    behaviour, and the reference every other scenario must match
//!    bitwise;
//! 2. **retry** — a deterministic single-block fault
//!    ([`FaultPlan::new`]) under a retry budget: the failed block is
//!    re-queued and recomputed from the round's shipped centroids, so
//!    the run completes bit-identically;
//! 3. **checkpoint** — fault-free with round-boundary checkpoints
//!    written at a fixed cadence: measures the pure checkpoint-write
//!    overhead;
//! 4. **resume** — the run is killed mid-flight (an unhealing fault
//!    with zero retries) after checkpoints exist, then resumed from the
//!    last checkpoint: `recovery_secs` is the resumed leg's wall, and
//!    the stitched result must still match the baseline bitwise.
//!
//! Every non-baseline row re-verifies `matches_baseline`
//! (labels/centroids/inertia/iterations bitwise equal) — the bench is a
//! measurement and an acceptance test in one.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::{
    ClusterConfig, ClusterOutput, Coordinator, CoordinatorConfig, Schedule,
};
use crate::image::SyntheticOrtho;
use crate::plan::{ExecPlan, Planner, PlanRequest};
use crate::resilience::{FaultKind, FaultPlan};
use crate::util::fmt::Table;
use crate::util::json::Json;

/// Benchmark shape. Defaults measure a paper-sized 1024² and a 512²
/// control, k=4, 6 fixed Lloyd rounds, a 1-retry budget, and a
/// 2-round checkpoint cadence (3 checkpoint writes over 6 rounds).
#[derive(Clone, Debug)]
pub struct ResilienceBenchOpts {
    /// Case geometries `(height, width)`.
    pub cases: Vec<(usize, usize)>,
    pub k: usize,
    /// Fixed Lloyd rounds — must exceed `2 * checkpoint_every` so the
    /// kill in the resume scenario lands after a checkpoint exists.
    pub iters: usize,
    /// Timed repetitions per scenario (best reported; one warmup first).
    pub samples: usize,
    pub seed: u64,
    pub workers: usize,
    /// Retry budget for the retry scenario (the injected fault fails
    /// one visit, so any budget ≥ 1 completes).
    pub retries: usize,
    /// Checkpoint cadence in rounds for the checkpoint/resume scenarios.
    pub checkpoint_every: usize,
}

impl Default for ResilienceBenchOpts {
    fn default() -> Self {
        ResilienceBenchOpts {
            cases: vec![(1024, 1024), (512, 512)],
            k: 4,
            iters: 6,
            samples: 2,
            seed: 0x4E_51_7E,
            workers: 4,
            retries: 1,
            checkpoint_every: 2,
        }
    }
}

impl ResilienceBenchOpts {
    /// CI smoke size: small geometries, short runs, one sample — the
    /// same four scenarios and the same bitwise acceptance checks.
    pub fn quick() -> ResilienceBenchOpts {
        ResilienceBenchOpts {
            cases: vec![(128, 96), (96, 160)],
            k: 2,
            iters: 4,
            samples: 1,
            checkpoint_every: 1,
            ..Default::default()
        }
    }
}

/// One benchmark cell (one scenario of one geometry).
#[derive(Clone, Debug)]
pub struct ResilienceBenchRow {
    /// `"baseline"`, `"retry"`, `"checkpoint"`, or `"resume"`.
    pub scenario: &'static str,
    pub height: usize,
    pub width: usize,
    /// Best-sample wall seconds to a finished result. The resume row
    /// counts the killed leg *plus* the resumed leg — the honest cost
    /// of a mid-run death.
    pub wall_secs: f64,
    pub ns_per_pixel_round: f64,
    /// Wall overhead vs the baseline row, percent (0 for baseline).
    pub overhead_pct: f64,
    /// Resume only: wall seconds of the resumed leg (checkpoint load →
    /// finished labels). 0 elsewhere.
    pub recovery_secs: f64,
    /// Fault-plan firings observed (0 in fault-free scenarios).
    pub faults_injected: usize,
    /// Block re-queues consumed from the retry budget.
    pub retries_used: usize,
    /// Labels, centroids, inertia, and iteration count bitwise equal to
    /// the baseline run (true by definition on the baseline row).
    pub matches_baseline: bool,
}

fn identical(a: &ClusterOutput, b: &ClusterOutput) -> bool {
    a.labels == b.labels
        && a.centroids == b.centroids
        && a.inertia.to_bits() == b.inertia.to_bits()
        && a.iterations == b.iterations
}

/// A coordinator for one scenario leg. Every leg shares the plan,
/// schedule, and engine; only the resilience config differs.
fn coord(
    exec: ExecPlan,
    fault: Option<FaultPlan>,
    checkpoint: Option<PathBuf>,
    resume: Option<PathBuf>,
) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        exec,
        schedule: Schedule::Static,
        fault,
        checkpoint,
        resume,
        ..Default::default()
    })
}

/// Run the four-scenario matrix.
pub fn run_resilience_bench(opts: &ResilienceBenchOpts) -> Result<Vec<ResilienceBenchRow>> {
    ensure!(!opts.cases.is_empty(), "need at least one case geometry");
    ensure!(opts.retries >= 1, "the retry scenario needs a budget of at least 1");
    ensure!(
        opts.checkpoint_every >= 1 && opts.iters > 2 * opts.checkpoint_every,
        "need iters > 2*checkpoint_every so the resume kill lands after a checkpoint"
    );
    let samples = opts.samples.max(1);
    let mut rows = Vec::new();
    for &(height, width) in &opts.cases {
        let gen = SyntheticOrtho::default().with_seed(opts.seed ^ ((height as u64) << 1));
        let img = Arc::new(gen.generate(height, width));
        let ccfg = ClusterConfig {
            k: opts.k,
            fixed_iters: Some(opts.iters),
            seed: opts.seed,
            ..Default::default()
        };
        let pixels = (height * width) as f64;
        let passes = (opts.iters + 1) as f64;
        let per_round = |wall: f64| wall * 1e9 / (pixels * passes);

        let mut req = PlanRequest::new(height, width, 3, opts.k).with_rounds(opts.iters);
        req.workers = Some(opts.workers);
        let (exec, explain) = Planner::default().resolve(&req);
        let blocks = explain.chosen().blocks;
        // Fault a middle block: not the one carrying the init, not the
        // boundary remainder block.
        let victim = blocks / 2;

        // --- baseline ----------------------------------------------------
        let mut base_best = f64::INFINITY;
        let mut base_out = None;
        for sample in 0..samples + 1 {
            let c = coord(exec, None, None, None);
            let t0 = Instant::now();
            let out = c.cluster(&img, &ccfg)?;
            let dt = t0.elapsed().as_secs_f64();
            if sample > 0 {
                base_best = base_best.min(dt);
            }
            base_out = Some(out);
        }
        let base_out = base_out.expect("at least one baseline sample ran");
        rows.push(ResilienceBenchRow {
            scenario: "baseline",
            height,
            width,
            wall_secs: base_best,
            ns_per_pixel_round: per_round(base_best),
            overhead_pct: 0.0,
            recovery_secs: 0.0,
            faults_injected: 0,
            retries_used: 0,
            matches_baseline: true,
        });
        let overhead = |wall: f64| (wall / base_best - 1.0) * 100.0;

        // --- retry: one injected failure, re-queued, bit-identical -------
        let mut retry_best = f64::INFINITY;
        let mut retry_out = None;
        let mut faults = 0;
        for sample in 0..samples + 1 {
            let fault = FaultPlan::new(victim, FaultKind::Error, 1);
            let c = coord(exec.with_retries(opts.retries), Some(fault.clone()), None, None);
            let t0 = Instant::now();
            let out = c.cluster(&img, &ccfg)?;
            let dt = t0.elapsed().as_secs_f64();
            if sample > 0 {
                retry_best = retry_best.min(dt);
            }
            // trips counts every visit; the window is exactly one wide.
            faults = fault.trips().min(1);
            retry_out = Some(out);
        }
        let retry_out = retry_out.expect("at least one retry sample ran");
        rows.push(ResilienceBenchRow {
            scenario: "retry",
            height,
            width,
            wall_secs: retry_best,
            ns_per_pixel_round: per_round(retry_best),
            overhead_pct: overhead(retry_best),
            recovery_secs: 0.0,
            faults_injected: faults,
            retries_used: faults,
            matches_baseline: identical(&retry_out, &base_out),
        });

        // --- checkpoint: fault-free, cadence writes ----------------------
        let ckpt = std::env::temp_dir().join(format!(
            "blockms_resbench_p{}_{}x{}.ckpt",
            std::process::id(),
            width,
            height
        ));
        let mut ck_best = f64::INFINITY;
        let mut ck_out = None;
        for sample in 0..samples + 1 {
            let c = coord(
                exec.with_checkpoint_every(opts.checkpoint_every),
                None,
                Some(ckpt.clone()),
                None,
            );
            let t0 = Instant::now();
            let out = c.cluster(&img, &ccfg)?;
            let dt = t0.elapsed().as_secs_f64();
            if sample > 0 {
                ck_best = ck_best.min(dt);
            }
            ck_out = Some(out);
        }
        let ck_out = ck_out.expect("at least one checkpoint sample ran");
        rows.push(ResilienceBenchRow {
            scenario: "checkpoint",
            height,
            width,
            wall_secs: ck_best,
            ns_per_pixel_round: per_round(ck_best),
            overhead_pct: overhead(ck_best),
            recovery_secs: 0.0,
            faults_injected: 0,
            retries_used: 0,
            matches_baseline: identical(&ck_out, &base_out),
        });

        // --- resume: kill after a checkpoint exists, restart from it -----
        // One shot (the kill/resume pair is stateful through the
        // checkpoint file); `.after(n)` lets n visits to the victim
        // succeed first — one visit per round, so the run dies in round
        // n+1, after n/cadence checkpoints landed.
        let kill_after = (opts.iters - 1) / opts.checkpoint_every * opts.checkpoint_every;
        let kill = FaultPlan::always(victim, FaultKind::Error).after(kill_after);
        let c = coord(
            exec.with_checkpoint_every(opts.checkpoint_every),
            Some(kill.clone()),
            Some(ckpt.clone()),
            None,
        );
        let t0 = Instant::now();
        let died = c.cluster(&img, &ccfg);
        let killed_secs = t0.elapsed().as_secs_f64();
        if died.is_ok() {
            bail!("{height}x{width}: the kill fault did not kill the run");
        }
        let c = coord(exec, None, None, Some(ckpt.clone()));
        let t0 = Instant::now();
        let resumed = c.cluster(&img, &ccfg)?;
        let recovery_secs = t0.elapsed().as_secs_f64();
        let _ = std::fs::remove_file(&ckpt);
        let wall = killed_secs + recovery_secs;
        rows.push(ResilienceBenchRow {
            scenario: "resume",
            height,
            width,
            wall_secs: wall,
            ns_per_pixel_round: per_round(wall),
            overhead_pct: overhead(wall),
            recovery_secs,
            faults_injected: 1,
            retries_used: 0,
            matches_baseline: identical(&resumed, &base_out),
        });
    }
    Ok(rows)
}

/// Serialize the matrix as the `BENCH_resilience.json` document.
pub fn resilience_bench_json(opts: &ResilienceBenchOpts, rows: &[ResilienceBenchRow]) -> String {
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert("source".to_string(), Json::Str("rust".to_string()));
    doc.insert("channels".to_string(), num(3.0));
    doc.insert("k".to_string(), num(opts.k as f64));
    doc.insert("iters".to_string(), num(opts.iters as f64));
    doc.insert("samples".to_string(), num(opts.samples as f64));
    doc.insert("seed".to_string(), num(opts.seed as f64));
    doc.insert("workers".to_string(), num(opts.workers as f64));
    doc.insert("retries".to_string(), num(opts.retries as f64));
    doc.insert(
        "checkpoint_every".to_string(),
        num(opts.checkpoint_every as f64),
    );
    let cases = rows
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert("scenario".to_string(), Json::Str(r.scenario.to_string()));
            c.insert("height".to_string(), num(r.height as f64));
            c.insert("width".to_string(), num(r.width as f64));
            c.insert("wall_secs".to_string(), num(r.wall_secs));
            c.insert(
                "ns_per_pixel_round".to_string(),
                num(r.ns_per_pixel_round),
            );
            c.insert("overhead_pct".to_string(), num(r.overhead_pct));
            c.insert("recovery_secs".to_string(), num(r.recovery_secs));
            c.insert("faults_injected".to_string(), num(r.faults_injected as f64));
            c.insert("retries_used".to_string(), num(r.retries_used as f64));
            c.insert(
                "matches_baseline".to_string(),
                Json::Bool(r.matches_baseline),
            );
            Json::Obj(c)
        })
        .collect();
    doc.insert("cases".to_string(), Json::Arr(cases));
    Json::Obj(doc).to_string()
}

/// Run the matrix and write `BENCH_resilience.json` to `path`.
pub fn write_resilience_bench(
    path: &Path,
    opts: &ResilienceBenchOpts,
) -> Result<Vec<ResilienceBenchRow>> {
    let rows = run_resilience_bench(opts)?;
    std::fs::write(path, resilience_bench_json(opts, &rows))
        .with_context(|| format!("write resilience bench to {}", path.display()))?;
    Ok(rows)
}

/// Human-readable rendering of the matrix.
pub fn render_resilience_bench(
    opts: &ResilienceBenchOpts,
    rows: &[ResilienceBenchRow],
) -> String {
    let mut t = Table::new(format!(
        "Fault tolerance: overhead and recovery, k={}, {} rounds, {} retries, ckpt/{}r",
        opts.k, opts.iters, opts.retries, opts.checkpoint_every
    ))
    .header(&[
        "Image", "Scenario", "ns/px/round", "Overhead", "Recovery", "Faults", "Identical",
    ]);
    for r in rows {
        t.row(vec![
            format!("{}x{}", r.width, r.height),
            r.scenario.to_string(),
            format!("{:.2}", r.ns_per_pixel_round),
            if r.scenario == "baseline" {
                "-".to_string()
            } else {
                format!("{:+.1}%", r.overhead_pct)
            },
            if r.recovery_secs > 0.0 {
                format!("{:.3}s", r.recovery_secs)
            } else {
                "-".to_string()
            },
            r.faults_injected.to_string(),
            if r.matches_baseline { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_matrix_recovers_and_matches_bitwise() {
        let opts = ResilienceBenchOpts {
            cases: vec![(64, 48)],
            iters: 3,
            workers: 2,
            ..ResilienceBenchOpts::quick()
        };
        let rows = run_resilience_bench(&opts).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(
                r.matches_baseline,
                "{} {}x{} diverged from the baseline",
                r.scenario, r.width, r.height
            );
        }
        let retry = rows.iter().find(|r| r.scenario == "retry").unwrap();
        assert_eq!(retry.faults_injected, 1, "the retry fault must actually fire");
        let resume = rows.iter().find(|r| r.scenario == "resume").unwrap();
        assert!(resume.recovery_secs > 0.0, "resume must time its recovery leg");
        let json = resilience_bench_json(&opts, &rows);
        let doc = Json::parse(&json).unwrap();
        assert_eq!(doc.get("cases").and_then(Json::as_arr).unwrap().len(), 4);
        let text = render_resilience_bench(&opts, &rows);
        assert!(text.contains("resume") && text.contains("yes"), "{text}");
    }
}
