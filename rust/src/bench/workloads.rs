//! Workload generation: the paper's nine image sizes.

use crate::image::{Raster, SyntheticOrtho};

/// The nine data sizes of Tables 1–11, as the paper writes them
/// (`width x height` per its "4656 pixels wide" prose for 4656x5793).
pub const PAPER_SIZES: [PaperSize; 9] = [
    PaperSize::new(1024, 768),
    PaperSize::new(1226, 878),
    PaperSize::new(3729, 2875),
    PaperSize::new(1355, 1255),
    PaperSize::new(5528, 5350),
    PaperSize::new(2640, 2640),
    PaperSize::new(4656, 5793),
    PaperSize::new(5490, 5442),
    PaperSize::new(9052, 4965),
];

/// The size the comparison tables (12–19, Cases 1–3) single out.
pub const HERO_SIZE: PaperSize = PaperSize::new(4656, 5793);

/// One paper data size (stored as the paper prints it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PaperSize {
    pub width: usize,
    pub height: usize,
}

impl PaperSize {
    pub const fn new(width: usize, height: usize) -> PaperSize {
        PaperSize { width, height }
    }

    /// The paper's label, e.g. `4656x5793`.
    pub fn label(&self) -> String {
        format!("{}x{}", self.width, self.height)
    }

    pub fn pixels(&self) -> usize {
        self.width * self.height
    }

    /// Scale both sides by `scale` (≥ 1 px each).
    pub fn scaled(&self, scale: f64) -> (usize, usize) {
        assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0,1]");
        (
            ((self.height as f64 * scale).round() as usize).max(8),
            ((self.width as f64 * scale).round() as usize).max(8),
        )
    }
}

/// A concrete workload: a synthetic scene standing in for one paper image.
#[derive(Clone, Debug)]
pub struct Workload {
    /// The paper size this scene represents (label used in tables).
    pub nominal: PaperSize,
    /// Actual generated dims (scaled for bench-time budgets).
    pub height: usize,
    pub width: usize,
    pub scale: f64,
    pub seed: u64,
}

impl Workload {
    pub fn new(nominal: PaperSize, scale: f64, seed: u64) -> Workload {
        let (height, width) = nominal.scaled(scale);
        Workload {
            nominal,
            height,
            width,
            scale,
            seed,
        }
    }

    /// Generate the scene (deterministic in `seed`).
    pub fn generate(&self) -> Raster {
        SyntheticOrtho::default()
            .with_seed(self.seed ^ (self.nominal.pixels() as u64))
            .generate(self.height, self.width)
    }
}

/// All nine paper workloads at a common scale.
pub fn paper_sizes(scale: f64, seed: u64) -> Vec<Workload> {
    PAPER_SIZES
        .iter()
        .map(|&s| Workload::new(s, scale, seed))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_sizes_match_paper_labels() {
        let labels: Vec<String> = PAPER_SIZES.iter().map(|s| s.label()).collect();
        assert_eq!(labels[0], "1024x768");
        assert_eq!(labels[6], "4656x5793");
        assert_eq!(labels[8], "9052x4965");
        assert_eq!(labels.len(), 9);
    }

    #[test]
    fn hero_is_in_the_list() {
        assert!(PAPER_SIZES.contains(&HERO_SIZE));
    }

    #[test]
    fn scaling_shrinks_both_sides() {
        let w = Workload::new(HERO_SIZE, 0.25, 1);
        assert_eq!(w.height, (5793.0f64 * 0.25).round() as usize);
        assert_eq!(w.width, 1164);
        let img = w.generate();
        assert_eq!(img.height(), w.height);
        assert_eq!(img.width(), w.width);
    }

    #[test]
    fn generation_deterministic_per_size_and_seed() {
        let a = Workload::new(PAPER_SIZES[0], 0.1, 7).generate();
        let b = Workload::new(PAPER_SIZES[0], 0.1, 7).generate();
        assert_eq!(a, b);
        let c = Workload::new(PAPER_SIZES[1], 0.1, 7).generate();
        assert_ne!(a.data().len(), c.data().len());
    }

    #[test]
    #[should_panic(expected = "scale must be")]
    fn bad_scale_rejected() {
        PaperSize::new(100, 100).scaled(0.0);
    }
}
