//! Planner benchmark: predicted vs measured cost, and planner regret.
//!
//! For every paper shape × k, the planner's candidate grid (kernel ×
//! layout, with cache/prefetch pinned off so the I/O counters stay
//! closed-form) is run **for real** through the coordinator over a
//! strip store. Two honesty numbers per cell land in
//! `BENCH_plan.json`:
//!
//! - **prediction error** — |predicted − measured| / measured for the
//!   planner's pick; must stay inside the model's stated
//!   [`CostModel::error_bound`];
//! - **regret** — measured(pick) / measured(best-of-grid) − 1: how much
//!   wall time auto-selection leaves on the table vs exhaustively
//!   trying everything. The acceptance bar is regret ≤ the stated
//!   error bound (in practice it is far smaller: ranking is much
//!   easier than absolute prediction).
//!
//! The measured pick also flows back through [`CostModel::refine`], so
//! the JSON records the feedback path working (`refined_ns` moves
//! toward the measurement).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::layout::shape_key;
use crate::blocks::{ApproachKind, BlockShape};
use crate::coordinator::{
    ClusterConfig, Coordinator, CoordinatorConfig, IoMode, Schedule,
};
use crate::image::SyntheticOrtho;
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::tile::TileLayout;
use crate::plan::{CostModel, ExecPlan, Planner, PlanRequest};
use crate::util::fmt::Table;
use crate::util::json::Json;

/// Benchmark shape. Defaults are the acceptance configuration: the
/// paper's three shapes at 1024², k ∈ {2, 4, 8}.
#[derive(Clone, Debug)]
pub struct PlanBenchOpts {
    pub height: usize,
    pub width: usize,
    pub ks: Vec<usize>,
    /// Fixed Lloyd iterations per run (plus one labeling pass).
    pub iters: usize,
    /// Timed repetitions per cell (best reported; one warmup first).
    pub samples: usize,
    pub seed: u64,
    pub workers: usize,
    pub strip_rows: usize,
}

impl Default for PlanBenchOpts {
    fn default() -> Self {
        PlanBenchOpts {
            height: 1024,
            width: 1024,
            ks: vec![2, 4, 8],
            iters: 4,
            samples: 2,
            seed: 0x9_1A_4E,
            workers: 4,
            strip_rows: 64,
        }
    }
}

impl PlanBenchOpts {
    /// CI smoke configuration — same schema, workflow-step sized.
    /// Three samples per cell: quick timings are milliseconds, and the
    /// schema checker's regret gate only applies to full-size runs, but
    /// wildly noisy numbers would still make the smoke output useless.
    pub fn quick() -> PlanBenchOpts {
        PlanBenchOpts {
            height: 128,
            width: 128,
            ks: vec![2],
            iters: 3,
            samples: 3,
            strip_rows: 16,
            ..Default::default()
        }
    }
}

/// One (shape, k) cell of the regret matrix.
#[derive(Clone, Debug)]
pub struct PlanBenchRow {
    pub approach: ApproachKind,
    pub k: usize,
    /// The planner's pick over the measured grid.
    pub picked: ExecPlan,
    /// Model prediction for the pick (ns/px/pass).
    pub predicted_ns: f64,
    /// Measured wall for the pick (ns/px/pass, best sample).
    pub measured_ns: f64,
    /// Best measured cell of the whole grid.
    pub best_kernel: KernelChoice,
    pub best_layout: TileLayout,
    pub best_ns: f64,
    /// measured(pick) / measured(best) − 1 (0 = the planner found the
    /// true optimum).
    pub regret: f64,
    /// |predicted − measured| / measured for the pick.
    pub prediction_error: f64,
    /// The pick's prediction after one [`CostModel::refine`] feedback
    /// step with the measurement.
    pub refined_ns: f64,
}

/// Run the full matrix. See module docs.
pub fn run_plan_bench(opts: &PlanBenchOpts) -> Result<(CostModel, Vec<PlanBenchRow>)> {
    let img = Arc::new(
        SyntheticOrtho::default()
            .with_seed(opts.seed)
            .generate(opts.height, opts.width),
    );
    let planner = Planner::default();
    let n_px = (opts.height * opts.width) as f64;
    let passes = (opts.iters + 1) as f64;
    let mut model = planner.model().clone();
    let mut rows = Vec::new();
    for approach in ApproachKind::ALL {
        let shape = BlockShape::paper_default(approach, opts.height, opts.width);
        for &k in &opts.ks {
            // The candidate grid: kernel × layout free, everything else
            // pinned (cache/prefetch off keeps the measurement
            // closed-form and the grid 8 cells).
            let mut req = PlanRequest::new(opts.height, opts.width, 3, k)
                .with_rounds(opts.iters)
                .with_strip_rows(Some(opts.strip_rows));
            req.shape = Some(shape);
            req.workers = Some(opts.workers);
            req.strip_cache = Some(0);
            req.prefetch = Some(false);
            let (picked, explain) = planner.resolve(&req);

            let ccfg = ClusterConfig {
                k,
                fixed_iters: Some(opts.iters),
                seed: opts.seed ^ 0xC0FFEE,
                ..Default::default()
            };
            let mut measured: Vec<(ExecPlan, f64)> = Vec::new();
            for cand in &explain.candidates {
                let coord = Coordinator::new(CoordinatorConfig {
                    exec: cand.plan,
                    schedule: Schedule::Static,
                    io: IoMode::Strips {
                        strip_rows: opts.strip_rows,
                        file_backed: false,
                    },
                    ..Default::default()
                });
                let mut best = f64::INFINITY;
                for sample in 0..opts.samples.max(1) + 1 {
                    let t0 = Instant::now();
                    let _ = coord.cluster(&img, &ccfg)?;
                    let dt = t0.elapsed().as_secs_f64();
                    if sample > 0 {
                        best = best.min(dt); // sample 0 is warmup
                    }
                }
                measured.push((cand.plan, best * 1e9 / (n_px * passes)));
            }
            let (_, measured_ns) = *measured
                .iter()
                .find(|(p, _)| *p == picked)
                .expect("the pick is one of the candidates");
            let &(best_plan, best_ns) = measured
                .iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).expect("finite walls"))
                .expect("non-empty grid");
            let predicted_ns = explain.chosen().cost.ns_per_pixel_pass;

            // Feedback: fold the measurement into the returned model
            // (cumulative across cells), but record `refined_ns` as ONE
            // step from the pristine priors — the per-cell quantity the
            // python mirror emits, independent of cell order.
            model.refine(picked.kernel, picked.layout, k, measured_ns);
            let mut fresh = planner.model().clone();
            fresh.refine(picked.kernel, picked.layout, k, measured_ns);
            let refined_ns = fresh.compute_ns_px_pass(picked.kernel, picked.layout, k);

            rows.push(PlanBenchRow {
                approach,
                k,
                picked,
                predicted_ns,
                measured_ns,
                best_kernel: best_plan.kernel,
                best_layout: best_plan.layout,
                best_ns,
                regret: measured_ns / best_ns - 1.0,
                prediction_error: (predicted_ns - measured_ns).abs() / measured_ns,
                refined_ns,
            });
        }
    }
    Ok((model, rows))
}

/// Serialize the matrix as the `BENCH_plan.json` document.
pub fn plan_bench_json(
    opts: &PlanBenchOpts,
    model: &CostModel,
    rows: &[PlanBenchRow],
) -> String {
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert(
        "image".to_string(),
        Json::Arr(vec![num(opts.height as f64), num(opts.width as f64)]),
    );
    doc.insert("channels".to_string(), num(3.0));
    doc.insert("iters".to_string(), num(opts.iters as f64));
    doc.insert("samples".to_string(), num(opts.samples as f64));
    doc.insert("seed".to_string(), num(opts.seed as f64));
    doc.insert("workers".to_string(), num(opts.workers as f64));
    doc.insert("strip_rows".to_string(), num(opts.strip_rows as f64));
    doc.insert("error_bound".to_string(), num(model.error_bound));
    doc.insert(
        "decode_ns_per_byte".to_string(),
        num(model.decode_ns_per_byte),
    );
    doc.insert("source".to_string(), Json::Str("rust".to_string()));
    let max_regret = rows.iter().map(|r| r.regret).fold(0.0, f64::max);
    doc.insert("max_regret".to_string(), num(max_regret));
    let cases = rows
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert(
                "shape".to_string(),
                Json::Str(shape_key(r.approach).to_string()),
            );
            c.insert("k".to_string(), num(r.k as f64));
            c.insert(
                "picked_kernel".to_string(),
                Json::Str(r.picked.kernel.label().to_string()),
            );
            c.insert(
                "picked_layout".to_string(),
                Json::Str(r.picked.layout.label().to_string()),
            );
            c.insert("predicted_ns_px_pass".to_string(), num(r.predicted_ns));
            c.insert("measured_ns_px_pass".to_string(), num(r.measured_ns));
            c.insert(
                "best_kernel".to_string(),
                Json::Str(r.best_kernel.label().to_string()),
            );
            c.insert(
                "best_layout".to_string(),
                Json::Str(r.best_layout.label().to_string()),
            );
            c.insert("best_ns_px_pass".to_string(), num(r.best_ns));
            c.insert("regret".to_string(), num(r.regret));
            c.insert("prediction_error".to_string(), num(r.prediction_error));
            c.insert("refined_ns_px_pass".to_string(), num(r.refined_ns));
            c.insert(
                "within_bound".to_string(),
                Json::Bool(r.regret <= model.error_bound),
            );
            Json::Obj(c)
        })
        .collect();
    doc.insert("cases".to_string(), Json::Arr(cases));
    Json::Obj(doc).to_string()
}

/// Run the matrix and write `BENCH_plan.json` to `path`.
pub fn write_plan_bench(
    path: &Path,
    opts: &PlanBenchOpts,
) -> Result<(CostModel, Vec<PlanBenchRow>)> {
    let (model, rows) = run_plan_bench(opts)?;
    std::fs::write(path, plan_bench_json(opts, &model, &rows))
        .with_context(|| format!("write plan bench to {}", path.display()))?;
    Ok((model, rows))
}

/// Human-readable rendering of the matrix.
pub fn render_plan_bench(
    opts: &PlanBenchOpts,
    model: &CostModel,
    rows: &[PlanBenchRow],
) -> String {
    let mut t = Table::new(format!(
        "Planner regret: {}x{}, {} iters, {} workers, strips of {} rows (model ±{:.0}%)",
        opts.width,
        opts.height,
        opts.iters,
        opts.workers,
        opts.strip_rows,
        100.0 * model.error_bound
    ))
    .header(&[
        "Shape", "K", "Pick", "Pred ns", "Meas ns", "Best", "Best ns", "Regret", "Pred err",
    ]);
    for r in rows {
        t.row(vec![
            shape_key(r.approach).to_string(),
            r.k.to_string(),
            format!("{}/{}", r.picked.kernel, r.picked.layout),
            format!("{:.2}", r.predicted_ns),
            format!("{:.2}", r.measured_ns),
            format!("{}/{}", r.best_kernel, r.best_layout),
            format!("{:.2}", r.best_ns),
            format!("{:+.1}%", 100.0 * r.regret),
            format!("{:.1}%", 100.0 * r.prediction_error),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> PlanBenchOpts {
        PlanBenchOpts {
            height: 40,
            width: 36,
            ks: vec![2],
            iters: 2,
            samples: 1,
            workers: 2,
            strip_rows: 8,
            ..Default::default()
        }
    }

    #[test]
    fn matrix_covers_shapes_and_reports_consistent_regret() {
        let (model, rows) = run_plan_bench(&tiny()).unwrap();
        assert_eq!(rows.len(), 3); // 3 shapes x 1 k
        for r in &rows {
            assert!(r.measured_ns > 0.0 && r.best_ns > 0.0);
            assert!(r.regret >= 0.0, "regret is vs the grid minimum");
            assert!(
                r.measured_ns >= r.best_ns,
                "pick cannot beat the grid best it belongs to"
            );
            assert!(r.refined_ns > 0.0);
        }
        assert!(model.error_bound > 0.0);
    }

    #[test]
    fn json_has_schema() {
        let opts = tiny();
        let (model, rows) = run_plan_bench(&opts).unwrap();
        let text = plan_bench_json(&opts, &model, &rows);
        let doc = Json::parse(&text).expect("valid json");
        assert!(doc.get("error_bound").and_then(Json::as_f64).is_some());
        assert!(doc.get("max_regret").and_then(Json::as_f64).is_some());
        let cases = doc.get("cases").and_then(Json::as_arr).expect("cases");
        assert_eq!(cases.len(), rows.len());
        for c in cases {
            for key in ["shape", "picked_kernel", "picked_layout", "best_kernel", "best_layout"] {
                assert!(c.get(key).and_then(Json::as_str).is_some(), "{key}");
            }
            for key in [
                "k",
                "predicted_ns_px_pass",
                "measured_ns_px_pass",
                "best_ns_px_pass",
                "regret",
                "prediction_error",
                "refined_ns_px_pass",
            ] {
                assert!(c.get(key).and_then(Json::as_f64).is_some(), "{key}");
            }
            assert!(c.get("within_bound").and_then(Json::as_bool).is_some());
        }
    }

    #[test]
    fn write_creates_the_file() {
        let path = std::env::temp_dir().join("blockms_test_BENCH_plan.json");
        let (_, rows) = write_plan_bench(&path, &tiny()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        assert_eq!(rows.len(), 3);
        let _ = std::fs::remove_file(&path);
    }
}
