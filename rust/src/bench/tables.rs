//! Paper table reproduction: Tables 1–19 and the Figure 8–20 series.
//!
//! Each paper table is a sweep cell-set over (approach, K, workers,
//! data sizes). `run_table(id, opts)` regenerates one table as formatted
//! text (identical columns to the paper: Data Size / Serial / Parallel /
//! Speedup / Efficiency) plus the figure series (speedup per size) that
//! the corresponding graph plots.

use anyhow::{bail, Result};

use super::runner::{EngineChoice, ExperimentConfig, ExperimentRow, Runner};
use super::workloads::{PaperSize, Workload, HERO_SIZE, PAPER_SIZES};
use crate::blocks::shape::ApproachKind;
use crate::blocks::BlockShape;
use crate::util::fmt::{ratio, secs, Table};

/// Sweep options shared by all tables.
#[derive(Clone, Debug)]
pub struct SweepOpts {
    /// Per-side scale factor on the paper dimensions (1.0 = full size).
    pub scale: f64,
    pub seed: u64,
    pub engine: EngineChoice,
    /// Fixed Lloyd iterations per run.
    pub iters: usize,
    pub strip_rows: usize,
}

impl Default for SweepOpts {
    fn default() -> Self {
        SweepOpts {
            scale: 0.25,
            seed: 0xB_10C,
            engine: EngineChoice::Native,
            iters: 6,
            strip_rows: 64,
        }
    }
}

/// Parameters of one paper table.
#[derive(Clone, Copy, Debug)]
pub enum TableSpec {
    /// Tables 1–11: one (approach, k, workers) over all nine sizes.
    Sweep {
        approach: ApproachKind,
        k: usize,
        workers: usize,
        figure: usize,
    },
    /// Tables 12–14 / 16–18: hero size, one approach, workers 2/4/8.
    Hero { approach: ApproachKind, k: usize },
    /// Tables 15 / 19: approach comparison at the paper block sizes.
    Comparison { k: usize, figure: usize },
}

/// The paper's table index.
pub fn spec(table: usize) -> Result<TableSpec> {
    use ApproachKind::*;
    Ok(match table {
        1 => TableSpec::Sweep { approach: Rows, k: 2, workers: 2, figure: 8 },
        2 => TableSpec::Sweep { approach: Rows, k: 2, workers: 4, figure: 9 },
        3 => TableSpec::Sweep { approach: Cols, k: 2, workers: 2, figure: 10 },
        4 => TableSpec::Sweep { approach: Cols, k: 2, workers: 4, figure: 11 },
        5 => TableSpec::Sweep { approach: Square, k: 2, workers: 2, figure: 12 },
        6 => TableSpec::Sweep { approach: Square, k: 2, workers: 4, figure: 13 },
        7 => TableSpec::Sweep { approach: Rows, k: 4, workers: 2, figure: 14 },
        8 => TableSpec::Sweep { approach: Rows, k: 4, workers: 4, figure: 15 },
        9 => TableSpec::Sweep { approach: Cols, k: 4, workers: 4, figure: 16 },
        10 => TableSpec::Sweep { approach: Square, k: 4, workers: 4, figure: 17 },
        11 => TableSpec::Sweep { approach: Square, k: 4, workers: 8, figure: 18 },
        12 => TableSpec::Hero { approach: Rows, k: 2 },
        13 => TableSpec::Hero { approach: Cols, k: 2 },
        14 => TableSpec::Hero { approach: Square, k: 2 },
        15 => TableSpec::Comparison { k: 2, figure: 19 },
        16 => TableSpec::Hero { approach: Rows, k: 4 },
        17 => TableSpec::Hero { approach: Cols, k: 4 },
        18 => TableSpec::Hero { approach: Square, k: 4 },
        19 => TableSpec::Comparison { k: 4, figure: 20 },
        other => bail!("no such paper table: {other} (1..=19)"),
    })
}

pub fn all_table_ids() -> Vec<usize> {
    (1..=19).collect()
}

/// The paper's per-approach block geometry for the sweep tables,
/// parameterized to keep the three approaches' block counts comparable
/// (see `BlockShape::paper_default`).
fn sweep_shape(kind: ApproachKind, height: usize, width: usize) -> BlockShape {
    BlockShape::paper_default(kind, height, width)
}

/// The paper's *exact* hero block sizes — `[1200 4656]`, `[5793 1000]`,
/// `[1200 1200]` — scaled with the workload.
pub fn hero_shape(kind: ApproachKind, scale: f64) -> BlockShape {
    let s = |v: usize| ((v as f64 * scale).round() as usize).max(1);
    match kind {
        ApproachKind::Rows => BlockShape::Custom {
            rows: s(1200),
            cols: s(4656),
        },
        ApproachKind::Cols => BlockShape::Custom {
            rows: s(5793),
            cols: s(1000),
        },
        ApproachKind::Square => BlockShape::Custom {
            rows: s(1200),
            cols: s(1200),
        },
    }
}

fn cell(
    runner: &mut Runner,
    opts: &SweepOpts,
    size: PaperSize,
    shape: BlockShape,
    k: usize,
    workers: usize,
) -> Result<ExperimentRow> {
    let workload = Workload::new(size, opts.scale, opts.seed);
    let mut cfg = ExperimentConfig::new(workload, shape, k, workers);
    cfg.engine = opts.engine;
    cfg.iters = opts.iters;
    cfg.strip_rows = ((opts.strip_rows as f64) * opts.scale).round().max(4.0) as usize;
    runner.measure(&cfg)
}

fn paper_columns(t: Table) -> Table {
    t.header(&["Data Size", "Serial", "Parallel", "Speedup", "Efficiency"])
}

fn push_row(t: &mut Table, r: &ExperimentRow) {
    t.row(vec![
        r.data_size.clone(),
        secs(r.serial_secs),
        secs(r.parallel_secs),
        ratio(r.speedup),
        ratio(r.efficiency),
    ]);
}

/// Render the figure series (what the bar chart plots): speedup per size.
fn figure_series(figure: usize, rows: &[ExperimentRow]) -> String {
    let mut s = format!("Fig {figure} series (Speedup):");
    for r in rows {
        s.push_str(&format!(" {}={}", r.data_size, ratio(r.speedup)));
    }
    s.push('\n');
    s
}

/// Regenerate one paper table; returns the formatted text block.
pub fn run_table(table: usize, opts: &SweepOpts) -> Result<String> {
    let mut runner = Runner::new();
    let text = match spec(table)? {
        TableSpec::Sweep {
            approach,
            k,
            workers,
            figure,
        } => {
            let title = format!(
                "Table {table}. Efficiency calculation for {}, Cluster {k}, {workers} Cores (scale {:.2})",
                approach.label(),
                opts.scale,
            );
            let mut t = paper_columns(Table::new(title));
            let mut rows = Vec::new();
            for &size in &PAPER_SIZES {
                let (h, w) = size.scaled(opts.scale);
                let shape = sweep_shape(approach, h, w);
                let row = cell(&mut runner, opts, size, shape, k, workers)?;
                push_row(&mut t, &row);
                rows.push(row);
            }
            format!("{}\n{}", t.render(), figure_series(figure, &rows))
        }
        TableSpec::Hero { approach, k } => {
            let title = format!(
                "Table {table}. Comparison results of {} (Cluster {k}, 4656x5793, scale {:.2})",
                approach.label(),
                opts.scale,
            );
            let mut t = Table::new(title).header(&[
                "Data Size",
                "Serial",
                "Cores",
                approach.label(),
                "Speed Up",
                "Efficiency",
            ]);
            for workers in [2usize, 4, 8] {
                let shape = hero_shape(approach, opts.scale);
                let r = cell(&mut runner, opts, HERO_SIZE, shape, k, workers)?;
                t.row(vec![
                    r.data_size.clone(),
                    secs(r.serial_secs),
                    workers.to_string(),
                    secs(r.parallel_secs),
                    ratio(r.speedup),
                    ratio(r.efficiency),
                ]);
            }
            t.render()
        }
        TableSpec::Comparison { k, figure } => {
            let title = format!(
                "Table {table}. Comparison of Different Approaches of Block processing for cluster {k} (4656x5793, 4 cores, scale {:.2})",
                opts.scale,
            );
            let mut t = Table::new(title).header(&[
                "Metric",
                "Non Block",
                "Row-Shaped [1200 4656]",
                "Column-Shaped [5793 1000]",
                "Square Block [1200 1200]",
            ]);
            let workers = 4;
            let mut rows = Vec::new();
            for kind in ApproachKind::ALL {
                let shape = hero_shape(kind, opts.scale);
                rows.push(cell(&mut runner, opts, HERO_SIZE, shape, k, workers)?);
            }
            let serial = rows[0].serial_secs;
            t.row(vec![
                "Processing Time".into(),
                secs(serial),
                secs(rows[0].parallel_secs),
                secs(rows[1].parallel_secs),
                secs(rows[2].parallel_secs),
            ]);
            t.row(vec![
                "Speed Up".into(),
                ratio(1.0),
                ratio(rows[0].speedup),
                ratio(rows[1].speedup),
                ratio(rows[2].speedup),
            ]);
            t.row(vec![
                "Efficiency".into(),
                String::from("-"),
                ratio(rows[0].efficiency),
                ratio(rows[1].efficiency),
                ratio(rows[2].efficiency),
            ]);
            let mut s = t.render();
            s.push_str(&format!(
                "Fig {figure} series (Speedup): Row={} Column={} Square={}\n",
                ratio(rows[0].speedup),
                ratio(rows[1].speedup),
                ratio(rows[2].speedup)
            ));
            s
        }
    };
    Ok(text)
}

/// Every cell of every paper table as a flat row set, for CSV export
/// (`blockms sweep`). Cells are `(table_id, row)`.
pub fn sweep_all(opts: &SweepOpts) -> Result<Vec<(usize, ExperimentRow)>> {
    let mut runner = Runner::new();
    let mut out = Vec::new();
    for table in all_table_ids() {
        match spec(table)? {
            TableSpec::Sweep {
                approach,
                k,
                workers,
                ..
            } => {
                for &size in &PAPER_SIZES {
                    let (h, w) = size.scaled(opts.scale);
                    let shape = sweep_shape(approach, h, w);
                    out.push((table, cell(&mut runner, opts, size, shape, k, workers)?));
                }
            }
            TableSpec::Hero { approach, k } => {
                for workers in [2usize, 4, 8] {
                    let shape = hero_shape(approach, opts.scale);
                    out.push((table, cell(&mut runner, opts, HERO_SIZE, shape, k, workers)?));
                }
            }
            TableSpec::Comparison { k, .. } => {
                for kind in ApproachKind::ALL {
                    let shape = hero_shape(kind, opts.scale);
                    out.push((table, cell(&mut runner, opts, HERO_SIZE, shape, k, 4)?));
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_opts() -> SweepOpts {
        SweepOpts {
            scale: 0.04,
            iters: 2,
            ..Default::default()
        }
    }

    #[test]
    fn every_table_id_has_a_spec() {
        for id in all_table_ids() {
            assert!(spec(id).is_ok(), "table {id}");
        }
        assert!(spec(0).is_err());
        assert!(spec(20).is_err());
    }

    #[test]
    fn hero_shapes_scale_with_workload() {
        let s = hero_shape(ApproachKind::Cols, 0.25);
        assert_eq!(
            s,
            BlockShape::Custom {
                rows: 1448,
                cols: 250
            }
        );
    }

    #[test]
    fn sweep_table_renders_nine_rows() {
        let text = run_table(1, &fast_opts()).unwrap();
        assert!(text.contains("Table 1."), "{text}");
        assert!(text.contains("Row-Shaped"));
        for size in &PAPER_SIZES {
            assert!(text.contains(&size.label()), "missing {}", size.label());
        }
        assert!(text.contains("Fig 8 series"));
    }

    #[test]
    fn hero_table_has_three_worker_rows() {
        let text = run_table(13, &fast_opts()).unwrap();
        assert!(text.contains("Column-Shaped"));
        // three core counts
        for w in ["2", "4", "8"] {
            assert!(text.lines().any(|l| l.contains(&format!(" {w} "))), "workers {w}");
        }
    }

    #[test]
    fn comparison_table_covers_all_approaches() {
        let text = run_table(15, &fast_opts()).unwrap();
        assert!(text.contains("Row-Shaped"));
        assert!(text.contains("Column-Shaped"));
        assert!(text.contains("Square Block"));
        assert!(text.contains("Fig 19 series"));
    }
}
