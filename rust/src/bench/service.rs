//! Service-layer benchmark: multi-job throughput through one shared
//! pool, with the machine-readable `BENCH_service.json` trail
//! (EXPERIMENTS.md §Service documents the schema).
//!
//! For every (pool size, batch size) cell the bench drives `batch`
//! distinct synthetic images through one [`ClusterServer`] twice:
//!
//! 1. **batched** — all jobs submitted at once, blocks interleaving on
//!    the shared workers;
//! 2. **serialized** — the same jobs one at a time (submit, wait,
//!    next), i.e. the solo-coordinator usage pattern on a warm pool.
//!
//! `speedup_vs_serialized > 1` is the service's reason to exist: with a
//! per-iteration barrier, a lone job strands workers at every round
//! edge; interleaved jobs fill those bubbles. Every cell also
//! re-verifies the determinism contract (`matches_solo`): job 0's
//! labels/centroids/inertia must be bit-identical to a solo
//! [`Coordinator`] run of the same spec.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::blocks::BlockShape;
use crate::coordinator::{
    ClusterConfig, ClusterOutput, Coordinator, CoordinatorConfig, Schedule,
};
use crate::image::{Raster, SyntheticOrtho};
use crate::kmeans::kernel::KernelChoice;
use crate::plan::ExecPlan;
use crate::service::{ClusterServer, JobSpec, ServerConfig};
use crate::util::fmt::Table;
use crate::util::json::Json;

/// Benchmark shape. Defaults are the acceptance configuration: 256×256
/// 3-band scenes, k=4, 6 fixed Lloyd rounds, pool sizes {1,2,4,8},
/// batch sizes {1,4,16}.
#[derive(Clone, Debug)]
pub struct ServiceBenchOpts {
    pub height: usize,
    pub width: usize,
    pub k: usize,
    /// Fixed Lloyd iterations per job (fixed so every cell does
    /// identical work).
    pub iters: usize,
    pub seed: u64,
    pub pool_sizes: Vec<usize>,
    pub batch_sizes: Vec<usize>,
    pub kernel: KernelChoice,
    pub schedule: Schedule,
    /// Cluster this PPM (every job slot shares it) instead of distinct
    /// synthetic scenes — `blockms batch --input scene.ppm`.
    pub input: Option<std::path::PathBuf>,
}

impl Default for ServiceBenchOpts {
    fn default() -> Self {
        ServiceBenchOpts {
            height: 256,
            width: 256,
            k: 4,
            iters: 6,
            seed: 0x5E_81C3,
            pool_sizes: vec![1, 2, 4, 8],
            batch_sizes: vec![1, 4, 16],
            kernel: KernelChoice::Fused,
            schedule: Schedule::Dynamic,
            input: None,
        }
    }
}

/// One (pool, batch) cell.
#[derive(Clone, Debug)]
pub struct ServiceBenchRow {
    pub pool: usize,
    pub batch: usize,
    /// Wall seconds with all jobs submitted at once.
    pub batch_wall_secs: f64,
    /// Wall seconds with the same jobs one at a time on the same pool.
    pub serialized_wall_secs: f64,
    /// `batch / batch_wall_secs`.
    pub jobs_per_sec: f64,
    /// Batched wall normalized per pixel per pass
    /// (`iters` step rounds + 1 assign round).
    pub ns_per_pixel_pass: f64,
    /// `serialized_wall_secs / batch_wall_secs` (higher is better;
    /// the acceptance bar is strictly above 1.0 at pool 4, batch 16).
    pub speedup_vs_serialized: f64,
    /// Mean per-job latency (activation → done) in the batched run.
    pub mean_latency_secs: f64,
    /// Worst per-job latency in the batched run.
    pub max_latency_secs: f64,
    /// Job 0's batched output is bit-identical to a solo
    /// `Coordinator::cluster` of the same spec.
    pub matches_solo: bool,
}

/// One resolved plan shared by every job of the bench (and the solo
/// reference run, which must be bit-identical).
fn bench_exec(opts: &ServiceBenchOpts) -> ExecPlan {
    let side = (opts.height.min(opts.width) / 4).max(8);
    ExecPlan::pinned(BlockShape::Square { side }).with_kernel(opts.kernel)
}

fn job_spec(opts: &ServiceBenchOpts, images: &[Arc<Raster>], j: usize) -> JobSpec {
    JobSpec::new(
        Arc::clone(&images[j]),
        bench_exec(opts),
        ClusterConfig {
            k: opts.k,
            seed: opts.seed.wrapping_add(j as u64),
            fixed_iters: Some(opts.iters),
            ..Default::default()
        },
    )
}

fn solo_reference(opts: &ServiceBenchOpts, images: &[Arc<Raster>]) -> Result<ClusterOutput> {
    let spec = job_spec(opts, images, 0);
    let coord = Coordinator::new(CoordinatorConfig {
        exec: spec.exec.with_workers(1),
        schedule: opts.schedule,
        ..Default::default()
    });
    coord.cluster(spec.raster().expect("bench jobs carry rasters"), &spec.cluster)
}

/// Run the full (pool × batch) matrix.
pub fn run_service_bench(opts: &ServiceBenchOpts) -> Result<Vec<ServiceBenchRow>> {
    ensure!(
        !opts.pool_sizes.is_empty() && !opts.batch_sizes.is_empty(),
        "need at least one pool size and one batch size"
    );
    ensure!(
        opts.pool_sizes.iter().all(|&p| p > 0) && opts.batch_sizes.iter().all(|&b| b > 0),
        "pool and batch sizes must be positive"
    );
    let max_batch = opts.batch_sizes.iter().copied().max().unwrap_or(1);
    // Distinct image per job slot — this is *cross-image* interleaving.
    // With --input, every slot clusters the same on-disk scene instead.
    let images: Vec<Arc<Raster>> = match &opts.input {
        Some(path) => {
            let img = Arc::new(
                crate::image::read_ppm(path)
                    .with_context(|| format!("load {}", path.display()))?,
            );
            (0..max_batch).map(|_| Arc::clone(&img)).collect()
        }
        None => (0..max_batch)
            .map(|j| {
                Arc::new(
                    SyntheticOrtho::default()
                        .with_seed(opts.seed.wrapping_add(j as u64))
                        .generate(opts.height, opts.width),
                )
            })
            .collect(),
    };
    let reference = solo_reference(opts, &images)?;
    let pixels = (opts.height * opts.width) as f64;
    let passes = (opts.iters + 1) as f64;

    let mut rows = Vec::new();
    for &pool in &opts.pool_sizes {
        for &batch in &opts.batch_sizes {
            let server = ClusterServer::start(ServerConfig {
                workers: pool,
                schedule: opts.schedule,
                max_in_flight: batch,
                ..Default::default()
            });
            // Batched: submit everything, then wait.
            let t0 = Instant::now();
            let handles: Vec<_> = (0..batch)
                .map(|j| server.submit(job_spec(opts, &images, j)))
                .collect::<Result<_>>()?;
            let outputs: Vec<ClusterOutput> = handles
                .iter()
                .map(|h| h.wait_output())
                .collect::<Result<_>>()?;
            let batch_wall_secs = t0.elapsed().as_secs_f64();

            // Serialized: same jobs, one at a time, same warm pool.
            let t0 = Instant::now();
            for j in 0..batch {
                server.submit(job_spec(opts, &images, j))?.wait_output()?;
            }
            let serialized_wall_secs = t0.elapsed().as_secs_f64();
            server.shutdown();

            let matches_solo = outputs[0].labels == reference.labels
                && outputs[0].centroids == reference.centroids
                && outputs[0].inertia.to_bits() == reference.inertia.to_bits();
            let latencies: Vec<f64> = outputs.iter().map(|o| o.total_secs).collect();
            rows.push(ServiceBenchRow {
                pool,
                batch,
                batch_wall_secs,
                serialized_wall_secs,
                jobs_per_sec: batch as f64 / batch_wall_secs,
                ns_per_pixel_pass: batch_wall_secs * 1e9 / (batch as f64 * pixels * passes),
                speedup_vs_serialized: serialized_wall_secs / batch_wall_secs,
                mean_latency_secs: latencies.iter().sum::<f64>() / latencies.len() as f64,
                max_latency_secs: latencies.iter().cloned().fold(0.0, f64::max),
                matches_solo,
            });
        }
    }
    Ok(rows)
}

/// Serialize the matrix as the `BENCH_service.json` document.
pub fn service_bench_json(opts: &ServiceBenchOpts, rows: &[ServiceBenchRow]) -> String {
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert(
        "image".to_string(),
        Json::Arr(vec![num(opts.height as f64), num(opts.width as f64)]),
    );
    doc.insert("channels".to_string(), num(3.0));
    doc.insert("k".to_string(), num(opts.k as f64));
    doc.insert("iters".to_string(), num(opts.iters as f64));
    doc.insert("seed".to_string(), num(opts.seed as f64));
    doc.insert(
        "kernel".to_string(),
        Json::Str(opts.kernel.label().to_string()),
    );
    let cases = rows
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert("pool".to_string(), num(r.pool as f64));
            c.insert("batch".to_string(), num(r.batch as f64));
            c.insert("batch_wall_secs".to_string(), num(r.batch_wall_secs));
            c.insert(
                "serialized_wall_secs".to_string(),
                num(r.serialized_wall_secs),
            );
            c.insert("jobs_per_sec".to_string(), num(r.jobs_per_sec));
            c.insert("ns_per_pixel_pass".to_string(), num(r.ns_per_pixel_pass));
            c.insert(
                "speedup_vs_serialized".to_string(),
                num(r.speedup_vs_serialized),
            );
            c.insert("mean_latency_secs".to_string(), num(r.mean_latency_secs));
            c.insert("max_latency_secs".to_string(), num(r.max_latency_secs));
            c.insert("matches_solo".to_string(), Json::Bool(r.matches_solo));
            Json::Obj(c)
        })
        .collect();
    doc.insert("cases".to_string(), Json::Arr(cases));
    Json::Obj(doc).to_string()
}

/// Run the matrix and write `BENCH_service.json` to `path`.
pub fn write_service_bench(path: &Path, opts: &ServiceBenchOpts) -> Result<Vec<ServiceBenchRow>> {
    let rows = run_service_bench(opts)?;
    std::fs::write(path, service_bench_json(opts, &rows))
        .with_context(|| format!("write service bench to {}", path.display()))?;
    Ok(rows)
}

/// Human-readable rendering of the matrix.
pub fn render_service_bench(opts: &ServiceBenchOpts, rows: &[ServiceBenchRow]) -> String {
    let mut t = Table::new(format!(
        "Service throughput: {}x{} scenes, k={}, {} iters, {} kernel",
        opts.width, opts.height, opts.k, opts.iters, opts.kernel
    ))
    .header(&[
        "Pool",
        "Batch",
        "jobs/s",
        "ns/px/pass",
        "vs serialized",
        "mean lat",
        "max lat",
        "Identical",
    ]);
    for r in rows {
        t.row(vec![
            r.pool.to_string(),
            r.batch.to_string(),
            format!("{:.2}", r.jobs_per_sec),
            format!("{:.3}", r.ns_per_pixel_pass),
            format!("{:.2}x", r.speedup_vs_serialized),
            format!("{:.1} ms", r.mean_latency_secs * 1e3),
            format!("{:.1} ms", r.max_latency_secs * 1e3),
            if r.matches_solo { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ServiceBenchOpts {
        ServiceBenchOpts {
            height: 40,
            width: 36,
            k: 2,
            iters: 2,
            pool_sizes: vec![1, 2],
            batch_sizes: vec![1, 3],
            ..Default::default()
        }
    }

    #[test]
    fn matrix_covers_all_cells_and_matches_solo() {
        let opts = tiny();
        let rows = run_service_bench(&opts).unwrap();
        assert_eq!(rows.len(), 4); // 2 pools x 2 batches
        for r in &rows {
            assert!(r.matches_solo, "pool {} batch {} diverged from solo", r.pool, r.batch);
            assert!(r.jobs_per_sec > 0.0);
            assert!(r.ns_per_pixel_pass > 0.0);
            assert!(r.batch_wall_secs > 0.0 && r.serialized_wall_secs > 0.0);
            assert!(r.max_latency_secs >= r.mean_latency_secs);
        }
    }

    #[test]
    fn json_round_trips_and_has_schema() {
        let opts = tiny();
        let rows = run_service_bench(&opts).unwrap();
        let text = service_bench_json(&opts, &rows);
        let doc = Json::parse(&text).expect("valid json");
        assert_eq!(doc.get("k").and_then(Json::as_usize), Some(2));
        assert_eq!(doc.get("iters").and_then(Json::as_usize), Some(2));
        let cases = doc.get("cases").and_then(Json::as_arr).expect("cases");
        assert_eq!(cases.len(), rows.len());
        for c in cases {
            assert!(c.get("pool").and_then(Json::as_usize).is_some());
            assert!(c.get("jobs_per_sec").and_then(Json::as_f64).is_some());
            assert!(c.get("speedup_vs_serialized").and_then(Json::as_f64).is_some());
            assert_eq!(c.get("matches_solo").and_then(Json::as_bool), Some(true));
        }
    }

    #[test]
    fn write_creates_the_file() {
        let path = std::env::temp_dir().join("blockms_test_BENCH_service.json");
        let mut opts = tiny();
        opts.pool_sizes = vec![1];
        opts.batch_sizes = vec![2];
        let rows = write_service_bench(&path, &opts).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        assert_eq!(rows.len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn render_mentions_every_cell() {
        let mut opts = tiny();
        opts.pool_sizes = vec![2];
        opts.batch_sizes = vec![3];
        let rows = run_service_bench(&opts).unwrap();
        let text = render_service_bench(&opts, &rows);
        assert!(text.contains("jobs/s") && text.contains("yes"), "{text}");
    }
}
