//! Streaming-layer benchmark: the out-of-core pipeline vs the
//! in-memory pipeline, with the machine-readable `BENCH_stream.json`
//! trail (EXPERIMENTS.md §Streaming documents the schema).
//!
//! For every case geometry the bench runs the same clustering twice:
//!
//! 1. **in-memory** — the seed path: the scene is materialized as a
//!    raster, copied into a memory-backed strip store, clustered;
//! 2. **streamed** — [`Coordinator::cluster_source`] under a hard
//!    `mem_mb` budget: strips decode on demand into a planner-chosen
//!    (usually file-backed) store, the init rides the ingest pass, and
//!    labels leave through the spillable sink.
//!
//! Every streamed row re-verifies the two acceptance invariants:
//! `matches_in_memory` (labels/centroids/inertia bitwise equal to the
//! in-memory run) and `peak_resident_bytes ≤ mem_mb` (the audited
//! gauge, not the model). The tall 4096×1024 case is the
//! height-independence witness: 4× the pixels of 1024², same streamed
//! footprint.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::coordinator::{
    ClusterConfig, Coordinator, CoordinatorConfig, IoMode, Schedule, StreamRun,
};
use crate::image::{SyntheticOrtho, SyntheticSource};
use crate::plan::{Planner, PlanRequest};
use crate::util::fmt::Table;
use crate::util::json::Json;

/// Benchmark shape. Defaults are the acceptance configuration: 1024²
/// plus the 4096×1024 tall case, k=4, 6 fixed Lloyd rounds, an 8 MiB
/// budget (the 1024² image alone is 12 MiB — the budget forces real
/// streaming).
#[derive(Clone, Debug)]
pub struct StreamBenchOpts {
    /// Case geometries `(height, width)`.
    pub cases: Vec<(usize, usize)>,
    pub k: usize,
    pub iters: usize,
    /// Timed repetitions per mode (best reported; one warmup first).
    pub samples: usize,
    pub seed: u64,
    pub workers: usize,
    pub strip_rows: usize,
    /// Resident budget for the streamed runs, MiB.
    pub mem_mb: usize,
}

impl Default for StreamBenchOpts {
    fn default() -> Self {
        StreamBenchOpts {
            cases: vec![(1024, 1024), (4096, 1024)],
            k: 4,
            iters: 6,
            samples: 2,
            seed: 0x57_8EA4,
            workers: 4,
            strip_rows: 64,
            mem_mb: 8,
        }
    }
}

impl StreamBenchOpts {
    /// CI smoke size: small geometries whose images still exceed the
    /// budget (384×256×3×4 = 1.125 MiB > 1 MiB), so the smoke run
    /// exercises the same degrade-to-file machinery as the full bench.
    pub fn quick() -> StreamBenchOpts {
        StreamBenchOpts {
            cases: vec![(384, 256), (1024, 96)],
            k: 2,
            iters: 3,
            samples: 1,
            strip_rows: 16,
            mem_mb: 1,
            ..Default::default()
        }
    }
}

/// One benchmark cell (one mode of one geometry).
#[derive(Clone, Debug)]
pub struct StreamBenchRow {
    /// `"in-memory"` or `"streamed"`.
    pub mode: &'static str,
    pub height: usize,
    pub width: usize,
    pub k: usize,
    /// Best-sample wall seconds for the whole drive — the streamed
    /// wall *includes* source decode/ingest (that is the pipeline).
    pub wall_secs: f64,
    pub ns_per_pixel_pass: f64,
    /// Audited high-water mark of tracked resident pixel bytes.
    pub peak_resident_bytes: u64,
    /// Budget the row ran under (0 = unbounded, the in-memory rows).
    pub mem_mb: usize,
    /// Streamed rows: the planner degraded to file backing.
    pub file_backed: bool,
    /// Labels, centroids, and inertia bitwise equal to the in-memory
    /// run (true by definition on in-memory rows).
    pub matches_in_memory: bool,
}

/// Run the streamed-vs-in-memory matrix.
pub fn run_stream_bench(opts: &StreamBenchOpts) -> Result<Vec<StreamBenchRow>> {
    ensure!(!opts.cases.is_empty(), "need at least one case geometry");
    let mut rows = Vec::new();
    for &(height, width) in &opts.cases {
        let gen = SyntheticOrtho::default().with_seed(opts.seed ^ ((height as u64) << 1));
        let ccfg = ClusterConfig {
            k: opts.k,
            fixed_iters: Some(opts.iters),
            seed: opts.seed,
            ..Default::default()
        };
        let pixels = (height * width) as f64;
        let passes = (opts.iters + 1) as f64;

        // Streamed plan: budget + strips, workers pinned, rest free.
        let mut req = PlanRequest::new(height, width, 3, opts.k)
            .with_rounds(opts.iters)
            .with_strip_rows(Some(opts.strip_rows))
            .with_mem_mb(Some(opts.mem_mb));
        req.workers = Some(opts.workers);
        let (exec, explain) = Planner::default().resolve(&req);
        ensure!(
            !explain.budget_exceeded(),
            "{height}x{width}: no feasible plan under {} MiB",
            opts.mem_mb
        );

        // In-memory reference: identical strategy, no budget, memory
        // backing, dense labels — the seed pipeline.
        let mem_exec = exec.with_mem_mb(0).with_file_backing(false);
        let img = Arc::new(gen.generate(height, width));
        let coord_mem = Coordinator::new(CoordinatorConfig {
            exec: mem_exec,
            io: IoMode::Strips {
                strip_rows: opts.strip_rows,
                file_backed: false,
            },
            schedule: Schedule::Static,
            ..Default::default()
        });
        let mut mem_best = f64::INFINITY;
        let mut mem_out = None;
        for sample in 0..opts.samples.max(1) + 1 {
            let t0 = Instant::now();
            let out = coord_mem.cluster(&img, &ccfg)?;
            let dt = t0.elapsed().as_secs_f64();
            if sample > 0 {
                mem_best = mem_best.min(dt);
            }
            mem_out = Some(out);
        }
        let mem_out = mem_out.expect("at least one sample ran");
        rows.push(StreamBenchRow {
            mode: "in-memory",
            height,
            width,
            k: opts.k,
            wall_secs: mem_best,
            ns_per_pixel_pass: mem_best * 1e9 / (pixels * passes),
            peak_resident_bytes: mem_out
                .io_stats
                .map(|s| s.peak_resident_bytes)
                .unwrap_or(0),
            mem_mb: 0,
            file_backed: false,
            matches_in_memory: true,
        });

        // Streamed: same clustering, pixels never fully resident.
        let coord_stream = Coordinator::new(CoordinatorConfig {
            exec,
            io: IoMode::Strips {
                strip_rows: opts.strip_rows,
                file_backed: exec.file_backed,
            },
            schedule: Schedule::Static,
            ..Default::default()
        });
        let mut stream_best = f64::INFINITY;
        let mut stream_run: Option<StreamRun> = None;
        for sample in 0..opts.samples.max(1) + 1 {
            let mut src = SyntheticSource::new(&gen, height, width);
            let t0 = Instant::now();
            let run = coord_stream.cluster_source(&mut src, &ccfg)?;
            let dt = t0.elapsed().as_secs_f64();
            if sample > 0 {
                stream_best = stream_best.min(dt);
            }
            stream_run = Some(run);
        }
        let run = stream_run.expect("at least one sample ran");
        let peak = run.peak_resident_bytes;
        let matches = {
            let streamed_labels = run.labels.into_dense()?;
            streamed_labels == mem_out.labels
                && run.centroids == mem_out.centroids
                && run.inertia.to_bits() == mem_out.inertia.to_bits()
                && run.iterations == mem_out.iterations
        };
        rows.push(StreamBenchRow {
            mode: "streamed",
            height,
            width,
            k: opts.k,
            wall_secs: stream_best,
            ns_per_pixel_pass: stream_best * 1e9 / (pixels * passes),
            peak_resident_bytes: peak,
            mem_mb: opts.mem_mb,
            file_backed: exec.file_backed,
            matches_in_memory: matches,
        });
    }
    Ok(rows)
}

/// Serialize the matrix as the `BENCH_stream.json` document.
pub fn stream_bench_json(opts: &StreamBenchOpts, rows: &[StreamBenchRow]) -> String {
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert("source".to_string(), Json::Str("rust".to_string()));
    doc.insert("channels".to_string(), num(3.0));
    doc.insert("k".to_string(), num(opts.k as f64));
    doc.insert("iters".to_string(), num(opts.iters as f64));
    doc.insert("samples".to_string(), num(opts.samples as f64));
    doc.insert("seed".to_string(), num(opts.seed as f64));
    doc.insert("workers".to_string(), num(opts.workers as f64));
    doc.insert("strip_rows".to_string(), num(opts.strip_rows as f64));
    doc.insert("mem_mb".to_string(), num(opts.mem_mb as f64));
    let cases = rows
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert("mode".to_string(), Json::Str(r.mode.to_string()));
            c.insert("height".to_string(), num(r.height as f64));
            c.insert("width".to_string(), num(r.width as f64));
            c.insert("k".to_string(), num(r.k as f64));
            c.insert("wall_secs".to_string(), num(r.wall_secs));
            c.insert("ns_per_pixel_pass".to_string(), num(r.ns_per_pixel_pass));
            c.insert(
                "peak_resident_bytes".to_string(),
                num(r.peak_resident_bytes as f64),
            );
            c.insert("mem_mb".to_string(), num(r.mem_mb as f64));
            c.insert("file_backed".to_string(), Json::Bool(r.file_backed));
            c.insert(
                "matches_in_memory".to_string(),
                Json::Bool(r.matches_in_memory),
            );
            Json::Obj(c)
        })
        .collect();
    doc.insert("cases".to_string(), Json::Arr(cases));
    Json::Obj(doc).to_string()
}

/// Run the matrix and write `BENCH_stream.json` to `path`.
pub fn write_stream_bench(path: &Path, opts: &StreamBenchOpts) -> Result<Vec<StreamBenchRow>> {
    let rows = run_stream_bench(opts)?;
    std::fs::write(path, stream_bench_json(opts, &rows))
        .with_context(|| format!("write stream bench to {}", path.display()))?;
    Ok(rows)
}

/// Human-readable rendering of the matrix.
pub fn render_stream_bench(opts: &StreamBenchOpts, rows: &[StreamBenchRow]) -> String {
    let mut t = Table::new(format!(
        "Out-of-core pipeline: streamed (budget {} MiB) vs in-memory, k={}, {} iters",
        opts.mem_mb, opts.k, opts.iters
    ))
    .header(&[
        "Image", "Mode", "ns/px/pass", "Peak resident", "Budget", "Store", "Identical",
    ]);
    for r in rows {
        t.row(vec![
            format!("{}x{}", r.width, r.height),
            r.mode.to_string(),
            format!("{:.2}", r.ns_per_pixel_pass),
            format!("{:.2} MiB", r.peak_resident_bytes as f64 / (1 << 20) as f64),
            if r.mem_mb > 0 {
                format!("{} MiB", r.mem_mb)
            } else {
                "-".to_string()
            },
            if r.file_backed { "file" } else { "mem" }.to_string(),
            if r.matches_in_memory { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_matrix_streams_under_budget_and_matches() {
        let opts = StreamBenchOpts {
            cases: vec![(96, 40), (220, 24)],
            iters: 2,
            samples: 1,
            workers: 2,
            strip_rows: 8,
            // Tiny test geometries fit a 1 MiB budget even
            // memory-backed — the invariants (bit-identity, peak under
            // budget) hold either way; the CI quick profile and the
            // committed bench exercise the over-budget degrade.
            mem_mb: 1,
            ..StreamBenchOpts::quick()
        };
        let rows = run_stream_bench(&opts).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.matches_in_memory, "{} {}x{} diverged", r.mode, r.width, r.height);
            if r.mode == "streamed" && r.mem_mb > 0 {
                assert!(
                    r.peak_resident_bytes <= (r.mem_mb as u64) << 20,
                    "{}x{}: {} over budget",
                    r.width,
                    r.height,
                    r.peak_resident_bytes
                );
            }
        }
        let json = stream_bench_json(&opts, &rows);
        let doc = Json::parse(&json).unwrap();
        assert_eq!(doc.get("cases").and_then(Json::as_arr).unwrap().len(), 4);
        let text = render_stream_bench(&opts, &rows);
        assert!(text.contains("streamed") && text.contains("yes"), "{text}");
    }
}
