//! §4 Cases 1–3: the block-size / file-access analysis.
//!
//! The paper demonstrates block-shape influence on `blockproc` I/O with
//! three block sizes on the 4656×5793 image (Cluster 2):
//!
//! - Case 1 (typical): square `[1200 1200]` — 4 blocks wide, every strip
//!   read ≈4×, elapsed 0.256/0.147/0.143 s at workers 2/4/8;
//! - Case 2 (worst for I/O): row `[1200 4656]` — each strip read once;
//! - Case 3 (best overall): column `[5793 1000]` — file read ≈5×.
//!
//! `run_cases` reproduces the analysis: closed-form + measured strip
//! reads, amplification, and replayed elapsed time per worker count.

use std::sync::Arc;

use anyhow::Result;

use super::kernels::NaiveBaseline;
use super::runner::{ExperimentConfig, Runner};
use super::tables::{hero_shape, SweepOpts};
use super::workloads::{Workload, HERO_SIZE};
use crate::blocks::{ApproachKind, BlockPlan};
use crate::coordinator::{ClusterConfig, Coordinator, CoordinatorConfig, Schedule};
use crate::kmeans::kernel::KernelChoice;
use crate::metrics::time_it;
use crate::plan::ExecPlan;
use crate::stripstore::read_amplification;
use crate::util::fmt::{ratio, secs, Table};

/// One case's numbers.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub case_no: usize,
    pub label: &'static str,
    pub approach: ApproachKind,
    pub block_dims: (usize, usize),
    pub blocks: usize,
    pub strip_reads_per_pass: usize,
    pub amplification: f64,
    /// Elapsed (replayed) seconds at workers 2, 4, 8.
    pub elapsed: [f64; 3],
}

/// The paper's case ordering and naming.
const CASES: [(usize, &str, ApproachKind); 3] = [
    (1, "Typical case — Square-Block", ApproachKind::Square),
    (2, "Worst case — Row-Shaped Block", ApproachKind::Rows),
    (3, "Best case — Column-Shaped Block", ApproachKind::Cols),
];

/// Run the three cases at the given sweep options.
pub fn run_cases(opts: &SweepOpts) -> Result<Vec<CaseResult>> {
    let workload = Workload::new(HERO_SIZE, opts.scale, opts.seed);
    let strip_rows = ((opts.strip_rows as f64) * opts.scale).round().max(4.0) as usize;
    let mut out = Vec::new();
    let mut runner = Runner::new();
    for (case_no, label, approach) in CASES {
        let shape = hero_shape(approach, opts.scale);
        let plan = BlockPlan::new(workload.height, workload.width, shape);
        let (reads, _strips, amp) = read_amplification(&plan, strip_rows);
        let mut elapsed = [0.0f64; 3];
        for (i, workers) in [2usize, 4, 8].into_iter().enumerate() {
            let mut cfg = ExperimentConfig::new(workload.clone(), shape, 2, workers);
            cfg.engine = opts.engine;
            cfg.iters = opts.iters;
            cfg.strip_rows = strip_rows;
            let row = runner.measure(&cfg)?;
            elapsed[i] = row.parallel_secs;
        }
        out.push(CaseResult {
            case_no,
            label,
            approach,
            block_dims: shape.block_dims(workload.height, workload.width),
            blocks: plan.len(),
            strip_reads_per_pass: reads,
            amplification: amp,
            elapsed,
        });
    }
    Ok(out)
}

/// One kernel-comparison cell: a paper block shape run end-to-end
/// through the coordinator under one [`KernelChoice`].
#[derive(Clone, Debug)]
pub struct KernelCaseResult {
    pub approach: ApproachKind,
    pub kernel: KernelChoice,
    pub block_dims: (usize, usize),
    pub blocks: usize,
    /// Wall seconds of the full coordinated run (fixed iterations).
    pub wall_secs: f64,
    /// Naive wall over this kernel's wall for the same shape.
    pub speedup_vs_naive: f64,
    /// Labels and centroids bit-identical to the naive run.
    pub matches_naive: bool,
}

/// Every [`KernelChoice`] (naive, pruned, fused, lanes) over the
/// paper's three block shapes (Cases 1–3 geometry), real coordinator,
/// fixed iterations, static schedule so per-block pruning state and
/// SoA tiles stay worker-local.
pub fn run_kernel_cases(opts: &SweepOpts, k: usize, workers: usize) -> Result<Vec<KernelCaseResult>> {
    let workload = Workload::new(HERO_SIZE, opts.scale, opts.seed);
    let img = Arc::new(workload.generate());
    let mut out = Vec::new();
    for (_case_no, _label, approach) in CASES {
        let shape = hero_shape(approach, opts.scale);
        let plan = BlockPlan::new(workload.height, workload.width, shape);
        let ccfg = ClusterConfig {
            k,
            fixed_iters: Some(opts.iters),
            ..Default::default()
        };
        let mut baseline: Option<NaiveBaseline> = None;
        for kernel in KernelChoice::ALL {
            let coord = Coordinator::new(CoordinatorConfig {
                exec: ExecPlan::pinned(shape)
                    .with_workers(workers)
                    .with_kernel(kernel),
                schedule: Schedule::Static,
                ..Default::default()
            });
            // Warmup run to absorb allocator/cache effects, then timed.
            let _ = coord.cluster(&img, &ccfg)?;
            let (result, wall) = {
                let (r, secs) = time_it(|| coord.cluster(&img, &ccfg));
                (r?, secs)
            };
            let (speedup, matches_naive) = match &baseline {
                None => (1.0, true),
                Some(b) => b.score(wall, &result.labels, &result.centroids),
            };
            if kernel == KernelChoice::Naive {
                baseline = Some(NaiveBaseline::new(wall, result.labels, result.centroids));
            }
            out.push(KernelCaseResult {
                approach,
                kernel,
                block_dims: shape.block_dims(workload.height, workload.width),
                blocks: plan.len(),
                wall_secs: wall,
                speedup_vs_naive: speedup,
                matches_naive,
            });
        }
    }
    Ok(out)
}

/// Render the kernel comparison as a table.
pub fn render_kernel_cases(results: &[KernelCaseResult], k: usize) -> String {
    let mut t = Table::new(format!(
        "Kernel comparison over the paper block shapes (k={k})"
    ))
    .header(&["Approach", "Block", "Blocks", "Kernel", "Wall", "Speedup", "Identical"]);
    for r in results {
        t.row(vec![
            r.approach.label().to_string(),
            format!("[{} {}]", r.block_dims.0, r.block_dims.1),
            r.blocks.to_string(),
            r.kernel.to_string(),
            secs(r.wall_secs),
            format!("{:.2}x", r.speedup_vs_naive),
            if r.matches_naive { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

/// Render the case analysis as a paper-style table.
pub fn render_cases(results: &[CaseResult]) -> String {
    let mut t = Table::new(format!(
        "Influence of block size on blockproc performance (4656x5793, Cluster 2)"
    ))
    .header(&[
        "Case",
        "Block",
        "Blocks",
        "Strip reads/pass",
        "Amplification",
        "T(2w)",
        "T(4w)",
        "T(8w)",
    ]);
    for r in results {
        t.row(vec![
            format!("Case {}: {}", r.case_no, r.label),
            format!("[{} {}]", r.block_dims.0, r.block_dims.1),
            r.blocks.to_string(),
            r.strip_reads_per_pass.to_string(),
            ratio(r.amplification),
            secs(r.elapsed[0]),
            secs(r.elapsed[1]),
            secs(r.elapsed[2]),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_reproduce_paper_amplifications() {
        // At scale 1 geometry (we can compute plans without running):
        let opts = SweepOpts {
            scale: 1.0,
            ..Default::default()
        };
        let strip_rows = opts.strip_rows; // 64 at scale 1
        for (case_no, _, approach) in CASES {
            let shape = hero_shape(approach, 1.0);
            let plan = BlockPlan::new(5793, 4656, shape);
            let (_, _, amp) = read_amplification(&plan, strip_rows);
            match case_no {
                // 4656/1200 = 3.88 -> 4 blocks wide; strip-misalignment at
                // block row boundaries adds a few % on top of the paper's
                // "reads every strip 4 times".
                1 => assert!((amp - 4.0).abs() < 0.2, "square amp {amp}"),
                2 => assert!(amp < 1.1, "row amp {amp}"),
                3 => assert!((amp - 5.0).abs() < 0.01, "col amp {amp}"),
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn kernel_cases_agree_bitwise_at_small_scale() {
        let opts = SweepOpts {
            scale: 0.02,
            iters: 3,
            ..Default::default()
        };
        let results = run_kernel_cases(&opts, 4, 2).unwrap();
        assert_eq!(results.len(), 3 * KernelChoice::ALL.len()); // 3 shapes x kernels
        for r in &results {
            assert!(r.matches_naive, "{:?} {} diverged", r.approach, r.kernel);
            assert!(r.wall_secs > 0.0);
        }
        let text = render_kernel_cases(&results, 4);
        for name in ["naive", "pruned", "fused", "lanes"] {
            assert!(text.contains(name), "{text}");
        }
    }

    #[test]
    fn run_cases_small_scale() {
        let opts = SweepOpts {
            scale: 0.05,
            iters: 2,
            ..Default::default()
        };
        let results = run_cases(&opts).unwrap();
        assert_eq!(results.len(), 3);
        for r in &results {
            assert!(r.amplification >= 1.0);
            assert!(r.elapsed.iter().all(|&t| t > 0.0));
            // more workers never slower in replay
            assert!(r.elapsed[1] <= r.elapsed[0] * 1.05);
            assert!(r.elapsed[2] <= r.elapsed[1] * 1.10);
        }
        // rendering mentions all three cases
        let text = render_cases(&results);
        for c in ["Case 1", "Case 2", "Case 3"] {
            assert!(text.contains(c), "{text}");
        }
    }
}
