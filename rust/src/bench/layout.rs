//! Layout-layer benchmark: interleaved-vs-SoA × kernel × block shape,
//! through the real coordinator over a strip store, with the
//! machine-readable `BENCH_layout.json` trail (EXPERIMENTS.md §Layout).
//!
//! Two axes the tile-arena PR added, crossed with the paper's three
//! block shapes:
//!
//! - **layout** — `interleaved` re-reads each block's strip span every
//!   round (seed behaviour); `soa` fills a planar tile once per job and
//!   reuses it, so `bytes_read` collapses to one pass;
//! - **kernel** — `naive` / `pruned` / `lanes` (lanes = the
//!   lane-vectorized planar kernels, SoA's native compute shape).
//!
//! Every non-baseline cell is checked bit-identical against the
//! interleaved-naive run of the same shape and k: a fast row with
//! `matches_naive: false` is a broken kernel, not a result.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::kernels::NaiveBaseline;
use crate::blocks::{ApproachKind, BlockPlan, BlockShape};
use crate::coordinator::{
    ClusterConfig, Coordinator, CoordinatorConfig, IoMode, Schedule,
};
use crate::image::SyntheticOrtho;
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::tile::TileLayout;
use crate::plan::ExecPlan;
use crate::util::fmt::Table;
use crate::util::json::Json;

/// The (layout, kernel) cells of the matrix.
pub const LAYOUT_CELLS: [(TileLayout, KernelChoice); 6] = [
    (TileLayout::Interleaved, KernelChoice::Naive),
    (TileLayout::Interleaved, KernelChoice::Pruned),
    (TileLayout::Interleaved, KernelChoice::Lanes),
    (TileLayout::Soa, KernelChoice::Naive),
    (TileLayout::Soa, KernelChoice::Pruned),
    (TileLayout::Soa, KernelChoice::Lanes),
];

/// Benchmark shape. Defaults are the acceptance configuration:
/// 1024×1024 3-band scene, k ∈ {2, 4, 8}, the paper's three shapes.
#[derive(Clone, Debug)]
pub struct LayoutBenchOpts {
    pub height: usize,
    pub width: usize,
    pub ks: Vec<usize>,
    /// Fixed Lloyd iterations per run (plus one labeling pass).
    pub iters: usize,
    /// Timed repetitions per cell (best reported; one warmup first).
    pub samples: usize,
    pub seed: u64,
    pub workers: usize,
    /// Strip height of the store every cell reads through.
    pub strip_rows: usize,
    /// Shared strip-cache capacity in strips (0 = uncached).
    pub cache_strips: usize,
}

impl Default for LayoutBenchOpts {
    fn default() -> Self {
        LayoutBenchOpts {
            height: 1024,
            width: 1024,
            ks: vec![2, 4, 8],
            iters: 4,
            samples: 2,
            seed: 0x50A_71E,
            workers: 4,
            strip_rows: 64,
            cache_strips: 0,
        }
    }
}

impl LayoutBenchOpts {
    /// CI smoke configuration: small image, one k, one sample — fast
    /// enough for a workflow step, same schema as the full matrix.
    pub fn quick() -> LayoutBenchOpts {
        LayoutBenchOpts {
            height: 128,
            width: 128,
            ks: vec![2],
            iters: 3,
            samples: 1,
            strip_rows: 16,
            ..Default::default()
        }
    }
}

/// One benchmark cell.
#[derive(Clone, Debug)]
pub struct LayoutBenchRow {
    pub layout: TileLayout,
    pub kernel: KernelChoice,
    pub approach: ApproachKind,
    pub k: usize,
    pub blocks: usize,
    /// Best-sample wall seconds of the whole coordinated run.
    pub wall_secs: f64,
    /// Nanoseconds per pixel per pass (`iters` steps + 1 labeling).
    pub ns_per_pixel_round: f64,
    /// Strip-store bytes transferred in one run (the layout axis's
    /// headline number: SoA cells read one pass, interleaved cells
    /// read `iters + 1`).
    pub bytes_read: u64,
    pub strip_reads: u64,
    pub strip_cache_hits: u64,
    pub strip_cache_misses: u64,
    /// Interleaved-naive wall over this cell's wall (same shape, k).
    pub speedup_vs_naive: f64,
    /// Labels and centroids bit-identical to interleaved-naive.
    pub matches_naive: bool,
}

/// Run the full matrix.
pub fn run_layout_bench(opts: &LayoutBenchOpts) -> Result<Vec<LayoutBenchRow>> {
    let img = Arc::new(
        SyntheticOrtho::default()
            .with_seed(opts.seed)
            .generate(opts.height, opts.width),
    );
    let n_pixels = (opts.height * opts.width) as f64;
    let passes = (opts.iters + 1) as f64;
    let mut rows = Vec::new();
    for approach in ApproachKind::ALL {
        let shape = BlockShape::paper_default(approach, opts.height, opts.width);
        let plan = BlockPlan::new(opts.height, opts.width, shape);
        for &k in &opts.ks {
            let ccfg = ClusterConfig {
                k,
                fixed_iters: Some(opts.iters),
                seed: opts.seed ^ 0xC0FFEE,
                ..Default::default()
            };
            let mut baseline: Option<NaiveBaseline> = None;
            for (layout, kernel) in LAYOUT_CELLS {
                let coord = Coordinator::new(CoordinatorConfig {
                    exec: ExecPlan::pinned(shape)
                        .with_workers(opts.workers)
                        .with_kernel(kernel)
                        .with_layout(layout)
                        .with_strip_cache(opts.cache_strips),
                    // Static: per-worker tiles and pruned bounds stay
                    // warm, and I/O counters are closed-form.
                    schedule: Schedule::Static,
                    io: IoMode::Strips {
                        strip_rows: opts.strip_rows,
                        file_backed: false,
                    },
                    ..Default::default()
                });
                let mut best = f64::INFINITY;
                let mut result = None;
                for sample in 0..opts.samples.max(1) + 1 {
                    let t0 = Instant::now();
                    let out = coord.cluster(&img, &ccfg)?;
                    let dt = t0.elapsed().as_secs_f64();
                    if sample > 0 {
                        best = best.min(dt); // sample 0 is warmup
                    }
                    result = Some(out);
                }
                let out = result.expect("at least one sample ran");
                let io = out.io_stats.expect("strip mode reports stats");
                let (speedup_vs_naive, matches_naive) = match &baseline {
                    None => (1.0, true),
                    Some(b) => b.score(best, &out.labels, &out.centroids),
                };
                if (layout, kernel) == (TileLayout::Interleaved, KernelChoice::Naive) {
                    baseline = Some(NaiveBaseline::new(best, out.labels, out.centroids));
                }
                rows.push(LayoutBenchRow {
                    layout,
                    kernel,
                    approach,
                    k,
                    blocks: plan.len(),
                    wall_secs: best,
                    ns_per_pixel_round: best * 1e9 / (n_pixels * passes),
                    bytes_read: io.bytes_read,
                    strip_reads: io.strip_reads,
                    strip_cache_hits: io.strip_cache_hits,
                    strip_cache_misses: io.strip_cache_misses,
                    speedup_vs_naive,
                    matches_naive,
                });
            }
        }
    }
    Ok(rows)
}

/// Serialize the matrix as the `BENCH_layout.json` document.
pub fn layout_bench_json(opts: &LayoutBenchOpts, rows: &[LayoutBenchRow]) -> String {
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert(
        "image".to_string(),
        Json::Arr(vec![num(opts.height as f64), num(opts.width as f64)]),
    );
    doc.insert("channels".to_string(), num(3.0));
    doc.insert("iters".to_string(), num(opts.iters as f64));
    doc.insert("samples".to_string(), num(opts.samples as f64));
    doc.insert("seed".to_string(), num(opts.seed as f64));
    doc.insert("workers".to_string(), num(opts.workers as f64));
    doc.insert("strip_rows".to_string(), num(opts.strip_rows as f64));
    doc.insert("cache_strips".to_string(), num(opts.cache_strips as f64));
    doc.insert("source".to_string(), Json::Str("rust".to_string()));
    let cases = rows
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert("layout".to_string(), Json::Str(r.layout.label().to_string()));
            c.insert("kernel".to_string(), Json::Str(r.kernel.label().to_string()));
            c.insert(
                "shape".to_string(),
                Json::Str(shape_key(r.approach).to_string()),
            );
            c.insert("k".to_string(), num(r.k as f64));
            c.insert("blocks".to_string(), num(r.blocks as f64));
            c.insert("wall_secs".to_string(), num(r.wall_secs));
            c.insert("ns_per_pixel_round".to_string(), num(r.ns_per_pixel_round));
            c.insert("bytes_read".to_string(), num(r.bytes_read as f64));
            c.insert("strip_reads".to_string(), num(r.strip_reads as f64));
            c.insert(
                "strip_cache_hits".to_string(),
                num(r.strip_cache_hits as f64),
            );
            c.insert(
                "strip_cache_misses".to_string(),
                num(r.strip_cache_misses as f64),
            );
            c.insert("speedup_vs_naive".to_string(), num(r.speedup_vs_naive));
            c.insert("matches_naive".to_string(), Json::Bool(r.matches_naive));
            Json::Obj(c)
        })
        .collect();
    doc.insert("cases".to_string(), Json::Arr(cases));
    Json::Obj(doc).to_string()
}

/// The JSON `shape` key for an approach (row | column | square).
pub fn shape_key(approach: ApproachKind) -> &'static str {
    match approach {
        ApproachKind::Rows => "row",
        ApproachKind::Cols => "column",
        ApproachKind::Square => "square",
    }
}

/// Run the matrix and write `BENCH_layout.json` to `path`.
pub fn write_layout_bench(path: &Path, opts: &LayoutBenchOpts) -> Result<Vec<LayoutBenchRow>> {
    let rows = run_layout_bench(opts)?;
    std::fs::write(path, layout_bench_json(opts, &rows))
        .with_context(|| format!("write layout bench to {}", path.display()))?;
    Ok(rows)
}

/// Human-readable rendering of the matrix.
pub fn render_layout_bench(opts: &LayoutBenchOpts, rows: &[LayoutBenchRow]) -> String {
    let mut t = Table::new(format!(
        "Layout matrix: {}x{}, {} iters, {} workers, strips of {} rows",
        opts.width, opts.height, opts.iters, opts.workers, opts.strip_rows
    ))
    .header(&[
        "Shape", "K", "Layout", "Kernel", "ns/px/round", "MiB read", "Speedup", "Identical",
    ]);
    for r in rows {
        t.row(vec![
            shape_key(r.approach).to_string(),
            r.k.to_string(),
            r.layout.to_string(),
            r.kernel.to_string(),
            format!("{:.3}", r.ns_per_pixel_round),
            format!("{:.1}", r.bytes_read as f64 / (1 << 20) as f64),
            format!("{:.2}x", r.speedup_vs_naive),
            if r.matches_naive { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> LayoutBenchOpts {
        LayoutBenchOpts {
            height: 40,
            width: 36,
            ks: vec![2],
            iters: 2,
            samples: 1,
            workers: 2,
            strip_rows: 8,
            ..Default::default()
        }
    }

    #[test]
    fn matrix_covers_cells_and_matches() {
        let rows = run_layout_bench(&tiny()).unwrap();
        assert_eq!(rows.len(), 3 * 6); // 3 shapes x 6 (layout, kernel) cells
        for r in &rows {
            assert!(
                r.matches_naive,
                "{} {} {} k={} diverged",
                shape_key(r.approach),
                r.layout,
                r.kernel,
                r.k
            );
            assert!(r.ns_per_pixel_round > 0.0);
            assert!(r.bytes_read > 0);
        }
    }

    #[test]
    fn soa_cells_read_one_pass_interleaved_read_all() {
        let opts = tiny();
        let rows = run_layout_bench(&opts).unwrap();
        for w in rows.chunks(6) {
            // within one (shape, k) group: cells 0..3 interleaved, 3..6 soa
            let interleaved = &w[0];
            let soa = &w[3];
            assert_eq!(
                interleaved.bytes_read,
                soa.bytes_read * (opts.iters as u64 + 1),
                "soa must read once per job, interleaved once per pass"
            );
        }
    }

    #[test]
    fn json_has_schema() {
        let opts = tiny();
        let rows = run_layout_bench(&opts).unwrap();
        let text = layout_bench_json(&opts, &rows);
        let doc = Json::parse(&text).expect("valid json");
        assert_eq!(doc.get("iters").and_then(Json::as_usize), Some(2));
        assert!(doc.get("source").and_then(Json::as_str).is_some());
        let cases = doc.get("cases").and_then(Json::as_arr).expect("cases");
        assert_eq!(cases.len(), rows.len());
        for c in cases {
            for key in ["layout", "kernel", "shape"] {
                assert!(c.get(key).and_then(Json::as_str).is_some(), "{key}");
            }
            for key in [
                "k",
                "ns_per_pixel_round",
                "bytes_read",
                "strip_reads",
                "strip_cache_hits",
                "strip_cache_misses",
                "speedup_vs_naive",
            ] {
                assert!(c.get(key).and_then(Json::as_f64).is_some(), "{key}");
            }
            assert_eq!(c.get("matches_naive").and_then(Json::as_bool), Some(true));
        }
    }

    #[test]
    fn write_creates_the_file() {
        let path = std::env::temp_dir().join("blockms_test_BENCH_layout.json");
        let rows = write_layout_bench(&path, &tiny()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        assert_eq!(rows.len(), 18);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn render_mentions_layouts_and_kernels() {
        let opts = tiny();
        let rows = run_layout_bench(&opts).unwrap();
        let text = render_layout_bench(&opts, &rows);
        for name in ["interleaved", "soa", "naive", "pruned", "lanes"] {
            assert!(text.contains(name), "{name} missing:\n{text}");
        }
    }
}
