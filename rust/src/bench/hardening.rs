//! Liveness-hardening benchmark: what the heartbeat watchdog and
//! speculative re-execution cost when nothing fails, how fast a run
//! recovers when workers silently hang, and how QoS admission behaves
//! under overload — with the machine-readable `BENCH_hardening.json`
//! trail (EXPERIMENTS.md §Hardening documents the schema).
//!
//! For every case geometry the bench runs the same clustering two ways,
//! then (on the first geometry) drills the failure paths:
//!
//! 1. **baseline** — hardening at rest: the watchdog is armed (it always
//!    is) but nothing fails and speculation is off — the reference every
//!    other scenario must match bitwise;
//! 2. **hardened** — speculation on, nothing fails: `overhead_pct` is
//!    the full hardening tax on a healthy run (CI gates it at ≤3%);
//! 3. **hang_1 / hang_2 / hang_4** — N victim blocks park their worker
//!    silently ([`FaultKind::Hang`]) with a retry budget armed: the
//!    watchdog escalates the silent workers, the blocks re-queue, and
//!    the run completes bit-identically; `recovery_secs` is the wall
//!    cost over baseline (bounded by the heartbeat timeout or the hang
//!    release, never the worst-case stall);
//! 4. **overload** — 2× the admission cap offered through `try_submit`
//!    with mixed priorities: every high-priority job is served (bitwise
//!    equal to baseline), every low-priority squatter is shed — the
//!    `served`/`shed` mix is the QoS contract.
//!
//! Every non-baseline row re-verifies `matches_baseline` — the bench is
//! a measurement and an acceptance test in one.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::coordinator::{
    ClusterConfig, ClusterOutput, Coordinator, CoordinatorConfig, Schedule,
};
use crate::image::SyntheticOrtho;
use crate::plan::{ExecPlan, Planner, PlanRequest};
use crate::resilience::{FaultKind, FaultPlan, DEFAULT_HANG_MS, DEFAULT_HEARTBEAT_TIMEOUT_MS};
use crate::service::{ClusterServer, JobSpec, JobStatus, ServerConfig};
use crate::util::fmt::Table;
use crate::util::json::Json;

/// Benchmark shape. Defaults measure a paper-sized 1024² and a 512²
/// control, k=4, 6 fixed Lloyd rounds, a 1-retry budget, and hang
/// drills at 1, 2, and 4 victim blocks.
#[derive(Clone, Debug)]
pub struct HardeningBenchOpts {
    /// Case geometries `(height, width)`. The hang and overload drills
    /// run on the first geometry only (they cost wall-clock by design —
    /// a hang is only over once the watchdog timeout or the hang
    /// release has passed).
    pub cases: Vec<(usize, usize)>,
    pub k: usize,
    /// Fixed Lloyd rounds, so every scenario does exactly the same work.
    pub iters: usize,
    /// Timed repetitions for the fault-free scenarios (best reported;
    /// one warmup first). The drills run once — they are latency
    /// measurements, not throughput ones.
    pub samples: usize,
    pub seed: u64,
    pub workers: usize,
    /// Retry budget for the hang drills (each victim block needs one
    /// re-queue once the watchdog escalates its worker).
    pub retries: usize,
    /// How long a hung worker stays parked. Must exceed the watchdog's
    /// heartbeat timeout for the escalation path (rather than the hang
    /// release) to be what recovers the run.
    pub hang_ms: u64,
    /// Victim-block counts for the hang drills (one row per entry).
    pub hang_victims: Vec<usize>,
    /// Admission cap for the overload drill; 2× this many jobs are
    /// offered.
    pub overload_cap: usize,
}

impl Default for HardeningBenchOpts {
    fn default() -> Self {
        HardeningBenchOpts {
            cases: vec![(1024, 1024), (512, 512)],
            k: 4,
            iters: 6,
            samples: 2,
            seed: 0x4A_4E_47,
            workers: 4,
            retries: 1,
            hang_ms: DEFAULT_HANG_MS,
            hang_victims: vec![1, 2, 4],
            overload_cap: 2,
        }
    }
}

impl HardeningBenchOpts {
    /// CI smoke size: one small geometry, short runs, one sample, and a
    /// hang just past the heartbeat timeout — the same scenarios and
    /// the same bitwise acceptance checks.
    pub fn quick() -> HardeningBenchOpts {
        HardeningBenchOpts {
            cases: vec![(128, 96)],
            k: 2,
            iters: 4,
            samples: 1,
            hang_ms: DEFAULT_HEARTBEAT_TIMEOUT_MS + 1000,
            ..Default::default()
        }
    }
}

/// One benchmark cell (one scenario of one geometry).
#[derive(Clone, Debug)]
pub struct HardeningBenchRow {
    /// `"baseline"`, `"hardened"`, `"hang_N"`, or `"overload"`.
    pub scenario: String,
    pub height: usize,
    pub width: usize,
    /// Wall seconds to finished results (best sample for the fault-free
    /// scenarios; the single drill run otherwise).
    pub wall_secs: f64,
    /// Per-pixel-pass cost (0 for the overload row — it measures an
    /// admission mix, not a kernel).
    pub ns_per_pixel_round: f64,
    /// Wall overhead vs the baseline row, percent (0 for baseline).
    pub overhead_pct: f64,
    /// Hang drills: wall cost over baseline — the stall-plus-recovery
    /// latency the watchdog bounds. 0 elsewhere.
    pub recovery_secs: f64,
    /// Hang drills: how many distinct blocks parked their worker.
    pub hang_victims: usize,
    /// Overload drill: jobs that finished with full results.
    pub served: usize,
    /// Overload drill: admission-gate shed events (each one preempted a
    /// lower-priority open job to make room).
    pub shed: usize,
    /// Labels, centroids, inertia, and iteration count bitwise equal to
    /// the baseline run (true by definition on the baseline row).
    pub matches_baseline: bool,
}

fn identical(a: &ClusterOutput, b: &ClusterOutput) -> bool {
    a.labels == b.labels
        && a.centroids == b.centroids
        && a.inertia.to_bits() == b.inertia.to_bits()
        && a.iterations == b.iterations
}

/// A coordinator for one scenario leg. Every leg shares the plan,
/// schedule, and engine; only the hardening config differs.
fn coord(exec: ExecPlan, fault: Option<FaultPlan>) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        exec,
        schedule: Schedule::Static,
        fault,
        ..Default::default()
    })
}

/// Run the hardening matrix.
pub fn run_hardening_bench(opts: &HardeningBenchOpts) -> Result<Vec<HardeningBenchRow>> {
    ensure!(!opts.cases.is_empty(), "need at least one case geometry");
    ensure!(opts.retries >= 1, "the hang drills need a retry budget of at least 1");
    ensure!(!opts.hang_victims.is_empty(), "need at least one hang victim count");
    ensure!(opts.overload_cap >= 1, "the overload drill needs an admission cap of at least 1");
    let samples = opts.samples.max(1);
    let mut rows = Vec::new();
    for (case_idx, &(height, width)) in opts.cases.iter().enumerate() {
        let gen = SyntheticOrtho::default().with_seed(opts.seed ^ ((height as u64) << 1));
        let img = Arc::new(gen.generate(height, width));
        let ccfg = ClusterConfig {
            k: opts.k,
            fixed_iters: Some(opts.iters),
            seed: opts.seed,
            ..Default::default()
        };
        let pixels = (height * width) as f64;
        let passes = (opts.iters + 1) as f64;
        let per_round = |wall: f64| wall * 1e9 / (pixels * passes);

        let mut req = PlanRequest::new(height, width, 3, opts.k).with_rounds(opts.iters);
        req.workers = Some(opts.workers);
        let (exec, explain) = Planner::default().resolve(&req);
        let blocks = explain.chosen().blocks;

        // --- baseline: watchdog armed, nothing fails, no speculation -----
        let mut base_best = f64::INFINITY;
        let mut base_out = None;
        for sample in 0..samples + 1 {
            let c = coord(exec, None);
            let t0 = Instant::now();
            let out = c.cluster(&img, &ccfg)?;
            let dt = t0.elapsed().as_secs_f64();
            if sample > 0 {
                base_best = base_best.min(dt);
            }
            base_out = Some(out);
        }
        let base_out = base_out.expect("at least one baseline sample ran");
        rows.push(HardeningBenchRow {
            scenario: "baseline".to_string(),
            height,
            width,
            wall_secs: base_best,
            ns_per_pixel_round: per_round(base_best),
            overhead_pct: 0.0,
            recovery_secs: 0.0,
            hang_victims: 0,
            served: 0,
            shed: 0,
            matches_baseline: true,
        });
        let overhead = |wall: f64| (wall / base_best - 1.0) * 100.0;

        // --- hardened: speculation on, nothing fails ---------------------
        // Measures the full hardening tax on a healthy run: heartbeat
        // stamping, watchdog scans, and straggler sizing — with no
        // stragglers, no clone should ever launch.
        let mut hard_best = f64::INFINITY;
        let mut hard_out = None;
        for sample in 0..samples + 1 {
            let c = coord(exec.with_speculate(true), None);
            let t0 = Instant::now();
            let out = c.cluster(&img, &ccfg)?;
            let dt = t0.elapsed().as_secs_f64();
            if sample > 0 {
                hard_best = hard_best.min(dt);
            }
            hard_out = Some(out);
        }
        let hard_out = hard_out.expect("at least one hardened sample ran");
        rows.push(HardeningBenchRow {
            scenario: "hardened".to_string(),
            height,
            width,
            wall_secs: hard_best,
            ns_per_pixel_round: per_round(hard_best),
            overhead_pct: overhead(hard_best),
            recovery_secs: 0.0,
            hang_victims: 0,
            served: 0,
            shed: 0,
            matches_baseline: identical(&hard_out, &base_out),
        });

        // The drills pay real stall latency; one geometry is enough.
        if case_idx != 0 {
            continue;
        }

        // --- hang drills: N silent workers, watchdog recovery ------------
        for &n in &opts.hang_victims {
            // Victims skip block 0 (it carries the init broadcast) and
            // clamp to the grid — the row records the real count.
            let victims: Vec<usize> = (1..blocks).take(n).collect();
            ensure!(
                !victims.is_empty(),
                "{height}x{width} resolves to {blocks} blocks — too few to stage a hang"
            );
            let fault = FaultPlan::on_blocks(
                victims.clone(),
                FaultKind::Hang { ms: opts.hang_ms },
                1,
            );
            let c = coord(exec.with_retries(opts.retries).with_speculate(true), Some(fault));
            let t0 = Instant::now();
            let out = c.cluster(&img, &ccfg)?;
            let wall = t0.elapsed().as_secs_f64();
            rows.push(HardeningBenchRow {
                scenario: format!("hang_{n}"),
                height,
                width,
                wall_secs: wall,
                ns_per_pixel_round: per_round(wall),
                overhead_pct: overhead(wall),
                recovery_secs: (wall - base_best).max(0.0),
                hang_victims: victims.len(),
                served: 0,
                shed: 0,
                matches_baseline: identical(&out, &base_out),
            });
        }

        // --- overload drill: 2× the cap, QoS sheds the squatters ---------
        let cap = opts.overload_cap;
        let server = ClusterServer::start(ServerConfig {
            workers: opts.workers,
            schedule: Schedule::Static,
            max_in_flight: cap,
            ..Default::default()
        });
        // Low-priority squatters that cannot finish on their own fill
        // the gate; each high-priority offer must preempt one.
        let squat = ClusterConfig {
            k: opts.k,
            fixed_iters: Some(1_000_000),
            seed: opts.seed,
            ..Default::default()
        };
        let t0 = Instant::now();
        let mut lows = Vec::with_capacity(cap);
        for _ in 0..cap {
            let h = server
                .try_submit(JobSpec::new(Arc::clone(&img), exec, squat.clone()))?
                .expect("an empty admission gate admits");
            lows.push(h);
        }
        let mut highs = Vec::with_capacity(cap);
        for _ in 0..cap {
            if let Some(h) = server
                .try_submit(JobSpec::new(Arc::clone(&img), exec, ccfg.clone()).with_priority(1))?
            {
                highs.push(h);
            }
        }
        let mut served = 0;
        let mut matches = true;
        for h in &highs {
            match h.wait() {
                JobStatus::Done(out) => {
                    served += 1;
                    matches &= identical(&out, &base_out);
                }
                _ => matches = false,
            }
        }
        for h in &lows {
            // Every squatter must end shed, not served.
            matches &= matches!(h.wait(), JobStatus::Cancelled);
        }
        let wall = t0.elapsed().as_secs_f64();
        let shed = server.stats().shed as usize;
        let report = server.drain(Duration::from_millis(5_000));
        // Nothing was open by now; a non-empty report means a leak.
        matches &= report.dispositions.is_empty();
        rows.push(HardeningBenchRow {
            scenario: "overload".to_string(),
            height,
            width,
            wall_secs: wall,
            ns_per_pixel_round: 0.0,
            overhead_pct: 0.0,
            recovery_secs: 0.0,
            hang_victims: 0,
            served,
            shed,
            matches_baseline: matches,
        });
    }
    Ok(rows)
}

/// Serialize the matrix as the `BENCH_hardening.json` document.
pub fn hardening_bench_json(opts: &HardeningBenchOpts, rows: &[HardeningBenchRow]) -> String {
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert("source".to_string(), Json::Str("rust".to_string()));
    doc.insert("channels".to_string(), num(3.0));
    doc.insert("k".to_string(), num(opts.k as f64));
    doc.insert("iters".to_string(), num(opts.iters as f64));
    doc.insert("samples".to_string(), num(opts.samples as f64));
    doc.insert("seed".to_string(), num(opts.seed as f64));
    doc.insert("workers".to_string(), num(opts.workers as f64));
    doc.insert("retries".to_string(), num(opts.retries as f64));
    doc.insert("hang_ms".to_string(), num(opts.hang_ms as f64));
    doc.insert(
        "heartbeat_timeout_ms".to_string(),
        num(DEFAULT_HEARTBEAT_TIMEOUT_MS as f64),
    );
    doc.insert("overload_cap".to_string(), num(opts.overload_cap as f64));
    let cases = rows
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert("scenario".to_string(), Json::Str(r.scenario.clone()));
            c.insert("height".to_string(), num(r.height as f64));
            c.insert("width".to_string(), num(r.width as f64));
            c.insert("wall_secs".to_string(), num(r.wall_secs));
            c.insert("ns_per_pixel_round".to_string(), num(r.ns_per_pixel_round));
            c.insert("overhead_pct".to_string(), num(r.overhead_pct));
            c.insert("recovery_secs".to_string(), num(r.recovery_secs));
            c.insert("hang_victims".to_string(), num(r.hang_victims as f64));
            c.insert("served".to_string(), num(r.served as f64));
            c.insert("shed".to_string(), num(r.shed as f64));
            c.insert(
                "matches_baseline".to_string(),
                Json::Bool(r.matches_baseline),
            );
            Json::Obj(c)
        })
        .collect();
    doc.insert("cases".to_string(), Json::Arr(cases));
    Json::Obj(doc).to_string()
}

/// Run the matrix and write `BENCH_hardening.json` to `path`.
pub fn write_hardening_bench(
    path: &Path,
    opts: &HardeningBenchOpts,
) -> Result<Vec<HardeningBenchRow>> {
    let rows = run_hardening_bench(opts)?;
    std::fs::write(path, hardening_bench_json(opts, &rows))
        .with_context(|| format!("write hardening bench to {}", path.display()))?;
    Ok(rows)
}

/// Human-readable rendering of the matrix.
pub fn render_hardening_bench(opts: &HardeningBenchOpts, rows: &[HardeningBenchRow]) -> String {
    let mut t = Table::new(format!(
        "Liveness hardening: overhead, recovery, QoS — k={}, {} rounds, hang {}ms, cap {}",
        opts.k, opts.iters, opts.hang_ms, opts.overload_cap
    ))
    .header(&[
        "Image", "Scenario", "ns/px/round", "Overhead", "Recovery", "Victims", "Served/Shed",
        "Identical",
    ]);
    for r in rows {
        t.row(vec![
            format!("{}x{}", r.width, r.height),
            r.scenario.clone(),
            if r.ns_per_pixel_round > 0.0 {
                format!("{:.2}", r.ns_per_pixel_round)
            } else {
                "-".to_string()
            },
            if r.scenario == "baseline" || r.scenario == "overload" {
                "-".to_string()
            } else {
                format!("{:+.1}%", r.overhead_pct)
            },
            if r.recovery_secs > 0.0 {
                format!("{:.3}s", r.recovery_secs)
            } else {
                "-".to_string()
            },
            if r.hang_victims > 0 {
                r.hang_victims.to_string()
            } else {
                "-".to_string()
            },
            if r.scenario == "overload" {
                format!("{}/{}", r.served, r.shed)
            } else {
                "-".to_string()
            },
            if r.matches_baseline { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_matrix_recovers_sheds_and_matches_bitwise() {
        // A sub-heartbeat hang keeps this fast: the parked worker wakes
        // and computes before the watchdog fires, which still exercises
        // the drill plumbing and the bitwise acceptance checks.
        let opts = HardeningBenchOpts {
            cases: vec![(64, 48)],
            iters: 3,
            workers: 2,
            hang_ms: 60,
            hang_victims: vec![1],
            overload_cap: 1,
            ..HardeningBenchOpts::quick()
        };
        let rows = run_hardening_bench(&opts).unwrap();
        // baseline + hardened + hang_1 + overload
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(
                r.matches_baseline,
                "{} {}x{} diverged from the baseline",
                r.scenario, r.width, r.height
            );
        }
        let hang = rows.iter().find(|r| r.scenario == "hang_1").unwrap();
        assert_eq!(hang.hang_victims, 1);
        assert!(hang.recovery_secs > 0.0, "a hang must cost measurable recovery time");
        let over = rows.iter().find(|r| r.scenario == "overload").unwrap();
        assert_eq!(over.served, 1, "the high-priority job must be served");
        assert_eq!(over.shed, 1, "the squatter must be shed exactly once");
        let json = hardening_bench_json(&opts, &rows);
        let doc = Json::parse(&json).unwrap();
        assert_eq!(doc.get("cases").and_then(Json::as_arr).unwrap().len(), 4);
        let text = render_hardening_bench(&opts, &rows);
        assert!(text.contains("overload") && text.contains("yes"), "{text}");
    }
}
