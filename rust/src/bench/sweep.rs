//! Sweep-amortization benchmark: one image, a `(k, seed, init)` grid,
//! run both as a share group and serialized — the machine-readable
//! `BENCH_sweep.json` trail (EXPERIMENTS.md §Sweep documents the
//! schema).
//!
//! The bench runs the same variant grid twice through a
//! [`ClusterServer`]:
//!
//! 1. **amortized** — every variant in one share group, all in flight:
//!    one strip store, one decoded pass (`bytes_read` ≈ the image,
//!    once);
//! 2. **serialized** — the same specs unshared, one at a time: each
//!    variant ingests and decodes privately (`bytes_read` ≈ N× the
//!    image).
//!
//! `bytes_read_ratio` ≈ 1/N is the tentpole number ("N variants ≠ N×
//! bytes read"); `matches_solo` re-verifies the bit-identity contract
//! per variant (amortized vs serialized vs a solo single-worker
//! [`Coordinator`]). The grid's quality report (Davies-Bouldin best-k
//! and the inertia knee) rides along so the JSON doubles as an elbow
//! study.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::blocks::BlockShape;
use crate::coordinator::{ClusterConfig, ClusterOutput, Coordinator, CoordinatorConfig, IoMode};
use crate::image::{Raster, SyntheticOrtho};
use crate::kmeans::InitMethod;
use crate::plan::{CostModel, ExecPlan, Workload as CostWorkload};
use crate::service::{ClusterServer, JobSpec, ServerConfig};
use crate::sweep::{init_name, submit_sweep, SweepGrid, SweepReport};
use crate::util::fmt::Table;
use crate::util::json::Json;

/// Benchmark shape. Defaults are the acceptance configuration: a
/// 256×256 3-band scene, k ∈ 2..=8, one seed, random init, 6 fixed
/// Lloyd rounds, 4 workers, row blocks aligned to 32-row strips with a
/// full strip cache (the config whose amortized `bytes_read` has an
/// exact closed form: one decode per strip per sweep).
#[derive(Clone, Debug)]
pub struct SweepBenchOpts {
    pub height: usize,
    pub width: usize,
    pub ks: Vec<usize>,
    pub base_seed: u64,
    pub n_seeds: usize,
    pub inits: Vec<InitMethod>,
    /// Fixed Lloyd iterations per variant (fixed so amortized and
    /// serialized do identical work).
    pub iters: usize,
    pub workers: usize,
    pub strip_rows: usize,
    /// Sweep this PPM instead of the synthetic scene.
    pub input: Option<std::path::PathBuf>,
}

impl Default for SweepBenchOpts {
    fn default() -> Self {
        SweepBenchOpts {
            height: 256,
            width: 256,
            ks: (2..=8).collect(),
            base_seed: 0x51_EEE7,
            n_seeds: 1,
            inits: vec![InitMethod::RandomSample],
            iters: 6,
            workers: 4,
            strip_rows: 32,
            input: None,
        }
    }
}

impl SweepBenchOpts {
    /// CI-sized variant: small scene, 3 ks, 3 rounds.
    pub fn quick() -> Self {
        SweepBenchOpts {
            height: 96,
            width: 80,
            ks: vec![2, 3, 4],
            iters: 3,
            workers: 2,
            strip_rows: 16,
            ..Default::default()
        }
    }

    pub fn grid(&self) -> Result<SweepGrid> {
        ensure!(self.n_seeds >= 1, "sweep bench needs at least one seed");
        SweepGrid::new(
            self.ks.clone(),
            (0..self.n_seeds as u64).map(|i| self.base_seed + i).collect(),
            self.inits.clone(),
        )
    }
}

/// One variant's row in the bench document.
#[derive(Clone, Debug)]
pub struct SweepBenchRow {
    pub label: String,
    pub k: usize,
    pub seed: u64,
    pub init: String,
    pub iterations: usize,
    pub inertia: f64,
    pub db_index: f64,
    /// Amortized output is bit-identical to the serialized run of the
    /// same spec (labels, centroids, inertia bits).
    pub matches_solo: bool,
}

/// The whole bench outcome: per-variant rows plus the amortization
/// headline numbers.
#[derive(Clone, Debug)]
pub struct SweepBenchResult {
    pub rows: Vec<SweepBenchRow>,
    pub amortized_wall_secs: f64,
    pub serialized_wall_secs: f64,
    /// Group strip-store bytes decoded for the whole shared sweep.
    pub amortized_bytes_read: u64,
    /// Sum of every unshared variant's private decode bytes.
    pub serialized_bytes_read: u64,
    /// The cost model's predicted amortized/serialized byte ratio for
    /// this grid (committed alongside the measured one so drift shows).
    pub predicted_bytes_ratio: f64,
    /// Davies-Bouldin winner over the amortized outputs.
    pub best_k: Option<usize>,
    /// Inertia-elbow knee over the amortized outputs.
    pub knee_k: Option<usize>,
}

impl SweepBenchResult {
    pub fn variants(&self) -> usize {
        self.rows.len()
    }

    pub fn amortized_jobs_per_sec(&self) -> f64 {
        self.variants() as f64 / self.amortized_wall_secs.max(1e-12)
    }

    pub fn serialized_jobs_per_sec(&self) -> f64 {
        self.variants() as f64 / self.serialized_wall_secs.max(1e-12)
    }

    /// Measured `amortized / serialized` decode bytes (≈ 1/N).
    pub fn bytes_read_ratio(&self) -> f64 {
        if self.serialized_bytes_read == 0 {
            return 1.0;
        }
        self.amortized_bytes_read as f64 / self.serialized_bytes_read as f64
    }

    pub fn all_match_solo(&self) -> bool {
        self.rows.iter().all(|r| r.matches_solo)
    }
}

/// The bench's pinned plan: row blocks aligned to the strip height
/// (every strip belongs to exactly one block) and a cache sized to the
/// whole store, so each strip decodes exactly once per store lifetime
/// and the amortized byte count is closed-form.
fn bench_exec(opts: &SweepBenchOpts) -> ExecPlan {
    let strips = opts.height.div_ceil(opts.strip_rows.max(1));
    ExecPlan::pinned(BlockShape::Rows {
        band_rows: opts.strip_rows,
    })
    .with_workers(opts.workers)
    .with_strip_cache(strips)
}

fn load_image(opts: &SweepBenchOpts) -> Result<Arc<Raster>> {
    Ok(match &opts.input {
        Some(path) => Arc::new(
            crate::image::read_ppm(path).with_context(|| format!("load {}", path.display()))?,
        ),
        None => Arc::new(
            SyntheticOrtho::default()
                .with_seed(opts.base_seed)
                .generate(opts.height, opts.width),
        ),
    })
}

/// Run the grid both ways and assemble the result.
pub fn run_sweep_bench(opts: &SweepBenchOpts) -> Result<SweepBenchResult> {
    let grid = opts.grid()?;
    let variants = grid.expand();
    let img = load_image(opts)?;
    let exec = bench_exec(opts);
    let base = ClusterConfig {
        fixed_iters: Some(opts.iters),
        ..Default::default()
    };

    // Amortized: one share group, everything in flight at once.
    let server = ClusterServer::start(ServerConfig {
        workers: opts.workers,
        max_in_flight: grid.len(),
        ..Default::default()
    });
    let t0 = Instant::now();
    let handles = submit_sweep(&server, &img, exec, &base, &grid, opts.strip_rows, Some(1))?;
    let amortized: Vec<ClusterOutput> = handles
        .iter()
        .map(|h| h.wait_output())
        .collect::<Result<_>>()?;
    let amortized_wall_secs = t0.elapsed().as_secs_f64();
    let amortized_bytes_read = amortized
        .iter()
        .filter_map(|o| o.io_stats)
        .map(|s| s.bytes_read)
        .max()
        .unwrap_or(0);

    // Serialized: same specs, unshared, strictly one at a time on the
    // warm pool (submit, wait, next — the no-sweep usage pattern).
    let t0 = Instant::now();
    let mut serialized = Vec::with_capacity(grid.len());
    for v in &variants {
        let mut cfg = base.clone();
        cfg.k = v.k;
        cfg.seed = v.seed;
        cfg.init = v.init.clone();
        let spec = JobSpec::new(Arc::clone(&img), exec, cfg).with_io(IoMode::Strips {
            strip_rows: opts.strip_rows,
            file_backed: exec.file_backed,
        });
        serialized.push(server.submit(spec)?.wait_output()?);
    }
    let serialized_wall_secs = t0.elapsed().as_secs_f64();
    server.shutdown();
    let serialized_bytes_read = serialized
        .iter()
        .filter_map(|o| o.io_stats)
        .map(|s| s.bytes_read)
        .sum();

    // Solo single-worker reference for variant 0 — the same anchor the
    // service bench uses, closing the loop back to `Coordinator`.
    let mut solo_cfg = base.clone();
    solo_cfg.k = variants[0].k;
    solo_cfg.seed = variants[0].seed;
    solo_cfg.init = variants[0].init.clone();
    let coord = Coordinator::new(CoordinatorConfig {
        exec: exec.with_workers(1),
        ..Default::default()
    });
    let reference = coord.cluster(&img, &solo_cfg)?;

    let identical = |a: &ClusterOutput, b: &ClusterOutput| {
        a.labels == b.labels
            && a.centroids == b.centroids
            && a.inertia.to_bits() == b.inertia.to_bits()
    };
    let report = SweepReport::build(&variants, &amortized, img.as_pixels(), img.channels())?;
    let rows = variants
        .iter()
        .zip(&amortized)
        .zip(&serialized)
        .enumerate()
        .map(|(i, ((v, a), s))| SweepBenchRow {
            label: v.label(),
            k: v.k,
            seed: v.seed,
            init: init_name(&v.init).to_string(),
            iterations: a.iterations,
            inertia: a.inertia,
            db_index: report.rows[i].db_index,
            matches_solo: identical(a, s) && (i != 0 || identical(a, &reference)),
        })
        .collect();

    let ks: Vec<usize> = variants.iter().map(|v| v.k).collect();
    let w = CostWorkload {
        height: img.height(),
        width: img.width(),
        channels: img.channels(),
        k: ks[0],
        rounds: opts.iters,
        strip_rows: Some(opts.strip_rows),
    };
    let predicted = CostModel::baked().predict_sweep(
        &w,
        &ks,
        &exec.block_plan(img.height(), img.width()),
        exec.kernel,
        exec.layout,
        exec.workers,
        exec.strip_cache,
        exec.prefetch,
    );

    Ok(SweepBenchResult {
        rows,
        amortized_wall_secs,
        serialized_wall_secs,
        amortized_bytes_read,
        serialized_bytes_read,
        predicted_bytes_ratio: predicted.bytes_ratio(),
        best_k: report.best().map(|r| r.variant.k),
        knee_k: report.knee_k(),
    })
}

/// Serialize the result as the `BENCH_sweep.json` document.
pub fn sweep_bench_json(opts: &SweepBenchOpts, res: &SweepBenchResult) -> String {
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert("source".to_string(), Json::Str("rust".to_string()));
    doc.insert(
        "image".to_string(),
        Json::Arr(vec![num(opts.height as f64), num(opts.width as f64)]),
    );
    doc.insert("channels".to_string(), num(3.0));
    doc.insert("iters".to_string(), num(opts.iters as f64));
    doc.insert("base_seed".to_string(), num(opts.base_seed as f64));
    doc.insert("seeds".to_string(), num(opts.n_seeds as f64));
    doc.insert("workers".to_string(), num(opts.workers as f64));
    doc.insert("strip_rows".to_string(), num(opts.strip_rows as f64));
    doc.insert(
        "ks".to_string(),
        Json::Arr(opts.ks.iter().map(|&k| num(k as f64)).collect()),
    );
    doc.insert(
        "inits".to_string(),
        Json::Arr(
            opts.inits
                .iter()
                .map(|i| Json::Str(init_name(i).to_string()))
                .collect(),
        ),
    );
    doc.insert("variants".to_string(), num(res.variants() as f64));
    doc.insert(
        "amortized_wall_secs".to_string(),
        num(res.amortized_wall_secs),
    );
    doc.insert(
        "serialized_wall_secs".to_string(),
        num(res.serialized_wall_secs),
    );
    doc.insert(
        "amortized_jobs_per_sec".to_string(),
        num(res.amortized_jobs_per_sec()),
    );
    doc.insert(
        "serialized_jobs_per_sec".to_string(),
        num(res.serialized_jobs_per_sec()),
    );
    doc.insert(
        "amortized_bytes_read".to_string(),
        num(res.amortized_bytes_read as f64),
    );
    doc.insert(
        "serialized_bytes_read".to_string(),
        num(res.serialized_bytes_read as f64),
    );
    doc.insert("bytes_read_ratio".to_string(), num(res.bytes_read_ratio()));
    doc.insert(
        "predicted_bytes_ratio".to_string(),
        num(res.predicted_bytes_ratio),
    );
    doc.insert(
        "matches_solo".to_string(),
        Json::Bool(res.all_match_solo()),
    );
    doc.insert(
        "best_k".to_string(),
        res.best_k.map_or(Json::Null, |k| num(k as f64)),
    );
    doc.insert(
        "knee_k".to_string(),
        res.knee_k.map_or(Json::Null, |k| num(k as f64)),
    );
    let cases = res
        .rows
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert("label".to_string(), Json::Str(r.label.clone()));
            c.insert("k".to_string(), num(r.k as f64));
            c.insert("seed".to_string(), num(r.seed as f64));
            c.insert("init".to_string(), Json::Str(r.init.clone()));
            c.insert("iterations".to_string(), num(r.iterations as f64));
            c.insert("inertia".to_string(), num(r.inertia));
            c.insert("db_index".to_string(), num(r.db_index));
            c.insert("matches_solo".to_string(), Json::Bool(r.matches_solo));
            Json::Obj(c)
        })
        .collect();
    doc.insert("cases".to_string(), Json::Arr(cases));
    Json::Obj(doc).to_string()
}

/// Run the grid and write `BENCH_sweep.json` to `path`.
pub fn write_sweep_bench(path: &Path, opts: &SweepBenchOpts) -> Result<SweepBenchResult> {
    let res = run_sweep_bench(opts)?;
    std::fs::write(path, sweep_bench_json(opts, &res))
        .with_context(|| format!("write sweep bench to {}", path.display()))?;
    Ok(res)
}

/// Human-readable rendering: the variant table plus the amortization
/// headline.
pub fn render_sweep_bench(opts: &SweepBenchOpts, res: &SweepBenchResult) -> String {
    let mut t = Table::new(format!(
        "Sweep: {}x{} scene, {} variants, {} iters, {} workers",
        opts.width,
        opts.height,
        res.variants(),
        opts.iters,
        opts.workers
    ))
    .header(&["Variant", "Iters", "Inertia", "DB index", "Identical"]);
    for r in &res.rows {
        t.row(vec![
            r.label.clone(),
            r.iterations.to_string(),
            format!("{:.4e}", r.inertia),
            format!("{:.4}", r.db_index),
            if r.matches_solo { "yes" } else { "NO" }.to_string(),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "\namortized {:.2} jobs/s vs serialized {:.2} jobs/s; bytes ratio {:.3} (model {:.3})\n",
        res.amortized_jobs_per_sec(),
        res.serialized_jobs_per_sec(),
        res.bytes_read_ratio(),
        res.predicted_bytes_ratio,
    ));
    if let (Some(best), Some(knee)) = (res.best_k, res.knee_k) {
        out.push_str(&format!("model selection: DB best k={best}, inertia knee k={knee}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SweepBenchOpts {
        SweepBenchOpts {
            height: 32,
            width: 24,
            ks: vec![2, 3],
            iters: 2,
            workers: 2,
            strip_rows: 8,
            ..Default::default()
        }
    }

    #[test]
    fn bench_amortizes_bytes_and_stays_identical() {
        let opts = tiny();
        let res = run_sweep_bench(&opts).unwrap();
        assert_eq!(res.variants(), 2);
        assert!(res.all_match_solo(), "{:?}", res.rows);
        // Row blocks aligned to strips + full cache: the shared sweep
        // decodes the image exactly once; serialized decodes it per
        // variant.
        let image_bytes = (32 * 24 * 3 * 4) as u64;
        assert_eq!(res.amortized_bytes_read, image_bytes);
        assert_eq!(res.serialized_bytes_read, 2 * image_bytes);
        assert!((res.bytes_read_ratio() - 0.5).abs() < 1e-12);
        assert!(res.predicted_bytes_ratio <= 0.5 + 1e-12);
    }

    #[test]
    fn json_has_the_gated_schema() {
        let opts = tiny();
        let res = run_sweep_bench(&opts).unwrap();
        let text = sweep_bench_json(&opts, &res);
        let doc = Json::parse(&text).expect("valid json");
        assert_eq!(doc.get("variants").and_then(Json::as_usize), Some(2));
        assert_eq!(doc.get("matches_solo").and_then(Json::as_bool), Some(true));
        assert!(doc.get("bytes_read_ratio").and_then(Json::as_f64).is_some());
        assert!(doc.get("amortized_jobs_per_sec").and_then(Json::as_f64).is_some());
        let cases = doc.get("cases").and_then(Json::as_arr).expect("cases");
        assert_eq!(cases.len(), 2);
        for c in cases {
            assert!(c.get("label").and_then(Json::as_str).is_some());
            assert!(c.get("inertia").and_then(Json::as_f64).is_some());
            assert_eq!(c.get("matches_solo").and_then(Json::as_bool), Some(true));
        }
    }

    #[test]
    fn write_creates_the_file_and_render_mentions_variants() {
        let path = std::env::temp_dir().join("blockms_test_BENCH_sweep.json");
        let opts = tiny();
        let res = write_sweep_bench(&path, &opts).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(&path);
        let rendered = render_sweep_bench(&opts, &res);
        assert!(rendered.contains("k2-") && rendered.contains("bytes ratio"), "{rendered}");
    }
}
