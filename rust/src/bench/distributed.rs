//! Distributed-scaling benchmark: the same workload solo and over
//! loopback shard processes at increasing shard counts, with the
//! machine-readable `BENCH_distributed.json` trail that
//! `check_distributed_schema.py` gates in CI (EXPERIMENTS.md
//! §Distributed).
//!
//! Every sharded cell is a full leader/shard run over the real wire
//! protocol (framing, registration, fingerprint checks, byte
//! accounting) — only the sockets are replaced by in-process loopback
//! channels, so the rows measure protocol cost without network noise.
//! Three properties are checked per row and recorded in the document:
//!
//! - `matches_solo` — labels, centroid bits, inertia bits, and
//!   iteration count identical to the solo twin (the tentpole
//!   bit-identity claim, also proven across the kernel × layout ×
//!   backing matrix in `tests/shard_equivalence.rs`);
//! - `wire_bytes` — measured bytes on the wire, which must equal the
//!   closed form [`sharded_wire_bytes`] the planner prices;
//! - `model_wall_secs` — the cost model's predicted wall, so the
//!   schema gate can hold the measured scaling curve against the
//!   modeled sweet spot.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::blocks::{BlockPlan, BlockShape};
use crate::coordinator::{ClusterConfig, ClusterOutput, Coordinator, CoordinatorConfig, Schedule};
use crate::image::SyntheticOrtho;
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::tile::TileLayout;
use crate::plan::{sharded_wire_bytes, CostModel, ExecPlan, Workload};
use crate::shard::{wire_stats, ShardEndpoints};
use crate::util::fmt::Table;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Benchmark shape. Defaults are the acceptance configuration:
/// 1024×1024 3-band scene, k ∈ {2, 4, 8}, shard counts 1/2/4 against
/// the solo anchor, two connections per shard.
#[derive(Clone, Debug)]
pub struct DistributedBenchOpts {
    pub height: usize,
    pub width: usize,
    pub ks: Vec<usize>,
    /// Shard counts to sweep (the solo anchor row is always run).
    pub shard_counts: Vec<usize>,
    /// Leader connections per shard (= blocks pipelined per shard).
    pub conns_per_shard: usize,
    /// Fixed Lloyd iterations per run (plus one labeling pass).
    pub iters: usize,
    /// Timed repetitions per cell (best reported; one warmup first).
    pub samples: usize,
    pub seed: u64,
}

impl Default for DistributedBenchOpts {
    fn default() -> Self {
        DistributedBenchOpts {
            height: 1024,
            width: 1024,
            ks: vec![2, 4, 8],
            shard_counts: vec![1, 2, 4],
            conns_per_shard: 2,
            iters: 4,
            samples: 2,
            seed: 0xD1_57_81,
        }
    }
}

impl DistributedBenchOpts {
    /// CI smoke configuration: small image, one k, one sample — fast
    /// enough for a workflow step, same schema as the full matrix.
    pub fn quick() -> DistributedBenchOpts {
        DistributedBenchOpts {
            height: 96,
            width: 96,
            ks: vec![2],
            shard_counts: vec![1, 2],
            iters: 3,
            samples: 1,
            ..Default::default()
        }
    }

    /// The block grid every cell runs: a 4×4 square grid, so even the
    /// widest shard sweep has blocks to balance (the paper's ~5-block
    /// default would starve 4 shards × 2 connections).
    pub fn shape(&self) -> BlockShape {
        BlockShape::Square {
            side: self.height.div_ceil(4).max(1),
        }
    }

    fn workload(&self, k: usize) -> Workload {
        Workload {
            height: self.height,
            width: self.width,
            channels: 3,
            k,
            rounds: self.iters,
            strip_rows: None,
        }
    }
}

/// One benchmark cell: this workload at `shards` shard processes
/// (`0` = the solo in-process anchor).
#[derive(Clone, Debug)]
pub struct DistributedBenchRow {
    pub shards: usize,
    pub k: usize,
    /// Best-sample wall seconds of the whole coordinated run.
    pub wall_secs: f64,
    /// Nanoseconds per pixel per pass (`iters` steps + 1 labeling).
    pub ns_per_pixel_round: f64,
    /// Solo wall over this cell's wall; 1.0 on the solo row.
    pub speedup_vs_solo: f64,
    /// Labels, centroid bits, inertia bits, and iterations identical
    /// to the solo twin.
    pub matches_solo: bool,
    /// Measured bytes moved on the wire (one run; both directions).
    pub wire_bytes: u64,
    /// The planner's closed-form byte count for the same run.
    pub model_wire_bytes: u64,
    /// The cost model's predicted wall for this cell.
    pub model_wall_secs: f64,
}

/// Bit-exact comparison of two runs: labels, centroid **bits**,
/// inertia **bits**, and the iteration count. Centroids compare as
/// `f32` bit patterns — an "equal within epsilon" match would hide a
/// broken merge order.
fn identical(a: &ClusterOutput, b: &ClusterOutput) -> bool {
    a.labels == b.labels
        && a.iterations == b.iterations
        && a.centroids.len() == b.centroids.len()
        && a.centroids
            .iter()
            .zip(&b.centroids)
            .all(|(x, y)| x.to_bits() == y.to_bits())
        && a.inertia.to_bits() == b.inertia.to_bits()
}

fn coordinator(opts: &DistributedBenchOpts, shards: usize) -> Coordinator {
    let exec = ExecPlan::pinned(opts.shape())
        .with_workers(opts.conns_per_shard)
        .with_kernel(KernelChoice::Lanes)
        .with_layout(TileLayout::Soa);
    let coord = Coordinator::new(CoordinatorConfig {
        exec,
        schedule: Schedule::Dynamic,
        ..Default::default()
    });
    if shards > 0 {
        coord.with_shards(ShardEndpoints::Loopback { shards })
    } else {
        coord
    }
}

/// Run the full matrix: for each k, the solo anchor then every shard
/// count, bit-compared against the anchor and byte-checked against the
/// closed form.
pub fn run_distributed_bench(opts: &DistributedBenchOpts) -> Result<Vec<DistributedBenchRow>> {
    let img = Arc::new(
        SyntheticOrtho::default()
            .with_seed(opts.seed)
            .generate(opts.height, opts.width),
    );
    let n_pixels = (opts.height * opts.width) as f64;
    let passes = (opts.iters + 1) as f64;
    let model = CostModel::baked();
    let plan = BlockPlan::new(opts.height, opts.width, opts.shape());
    let mut rows = Vec::new();
    for &k in &opts.ks {
        let ccfg = ClusterConfig {
            k,
            fixed_iters: Some(opts.iters),
            seed: opts.seed ^ 0xC0FFEE,
            ..Default::default()
        };
        let w = opts.workload(k);
        let mut solo_out: Option<ClusterOutput> = None;
        let mut solo_wall = f64::NAN;
        for shards in std::iter::once(0).chain(opts.shard_counts.iter().copied()) {
            let coord = coordinator(opts, shards);
            let mut best = f64::INFINITY;
            let mut result = None;
            let mut wire = 0u64;
            for sample in 0..opts.samples.max(1) + 1 {
                let (sent0, _) = wire_stats();
                let t0 = Instant::now();
                let out = coord.cluster(&img, &ccfg)?;
                let dt = t0.elapsed().as_secs_f64();
                let (sent1, _) = wire_stats();
                if sample > 0 {
                    best = best.min(dt); // sample 0 is warmup
                }
                // Every byte is sent exactly once (down by the leader,
                // up by the shards), so the sent delta is the run's
                // total traffic.
                wire = sent1 - sent0;
                result = Some(out);
            }
            let out = result.expect("at least one sample ran");
            let matches_solo = match &solo_out {
                None => true, // the anchor row is its own reference
                Some(anchor) => identical(anchor, &out),
            };
            let lanes = shards * opts.conns_per_shard;
            let (down, up) = if shards > 0 {
                sharded_wire_bytes(&w, plan.len(), lanes)
            } else {
                (0, 0)
            };
            let cost = model.predict_sharded(
                &w,
                &plan,
                KernelChoice::Lanes,
                TileLayout::Soa,
                opts.conns_per_shard,
                0,
                false,
                shards,
            );
            if shards == 0 {
                solo_wall = best;
                solo_out = Some(out);
            }
            rows.push(DistributedBenchRow {
                shards,
                k,
                wall_secs: best,
                ns_per_pixel_round: best * 1e9 / (n_pixels * passes),
                speedup_vs_solo: solo_wall / best,
                matches_solo,
                wire_bytes: wire,
                model_wire_bytes: down + up,
                model_wall_secs: cost.wall_secs,
            });
        }
    }
    Ok(rows)
}

/// Serialize the matrix as the `BENCH_distributed.json` document.
pub fn distributed_bench_json(opts: &DistributedBenchOpts, rows: &[DistributedBenchRow]) -> String {
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert(
        "image".to_string(),
        Json::Arr(vec![num(opts.height as f64), num(opts.width as f64)]),
    );
    doc.insert("channels".to_string(), num(3.0));
    doc.insert("iters".to_string(), num(opts.iters as f64));
    doc.insert("samples".to_string(), num(opts.samples as f64));
    doc.insert("seed".to_string(), num(opts.seed as f64));
    doc.insert("conns_per_shard".to_string(), num(opts.conns_per_shard as f64));
    doc.insert("blocks".to_string(), {
        let plan = BlockPlan::new(opts.height, opts.width, opts.shape());
        num(plan.len() as f64)
    });
    doc.insert(
        "wire_ns_per_byte".to_string(),
        num(CostModel::baked().wire_ns_per_byte),
    );
    doc.insert("source".to_string(), Json::Str("rust".to_string()));
    let cases = rows
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert("shards".to_string(), num(r.shards as f64));
            c.insert("k".to_string(), num(r.k as f64));
            c.insert("wall_secs".to_string(), num(r.wall_secs));
            c.insert("ns_per_pixel_round".to_string(), num(r.ns_per_pixel_round));
            c.insert("speedup_vs_solo".to_string(), num(r.speedup_vs_solo));
            c.insert("matches_solo".to_string(), Json::Bool(r.matches_solo));
            c.insert("wire_bytes".to_string(), num(r.wire_bytes as f64));
            c.insert("model_wire_bytes".to_string(), num(r.model_wire_bytes as f64));
            c.insert("model_wall_secs".to_string(), num(r.model_wall_secs));
            Json::Obj(c)
        })
        .collect();
    doc.insert("cases".to_string(), Json::Arr(cases));
    Json::Obj(doc).to_string()
}

/// Run the matrix and write `BENCH_distributed.json` to `path`.
pub fn write_distributed_bench(
    path: &Path,
    opts: &DistributedBenchOpts,
) -> Result<Vec<DistributedBenchRow>> {
    let rows = run_distributed_bench(opts)?;
    std::fs::write(path, distributed_bench_json(opts, &rows))
        .with_context(|| format!("write distributed bench to {}", path.display()))?;
    Ok(rows)
}

/// Human-readable rendering of the matrix.
pub fn render_distributed_bench(
    opts: &DistributedBenchOpts,
    rows: &[DistributedBenchRow],
) -> String {
    let mut t = Table::new(format!(
        "Distributed scaling: solo vs loopback shards at {}x{}, {} iters, {} conns/shard",
        opts.width, opts.height, opts.iters, opts.conns_per_shard
    ))
    .header(&[
        "Shards",
        "K",
        "Wall (s)",
        "Speedup vs solo",
        "Wire bytes",
        "Model wall (s)",
        "Identical",
    ]);
    for r in rows {
        t.row(vec![
            match r.shards {
                0 => "solo".to_string(),
                s => s.to_string(),
            },
            r.k.to_string(),
            format!("{:.4}", r.wall_secs),
            format!("{:.2}x", r.speedup_vs_solo),
            r.wire_bytes.to_string(),
            format!("{:.4}", r.model_wall_secs),
            if r.matches_solo { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> DistributedBenchOpts {
        DistributedBenchOpts {
            height: 48,
            width: 48,
            ks: vec![2],
            shard_counts: vec![1, 2],
            conns_per_shard: 1,
            iters: 3,
            samples: 1,
            ..Default::default()
        }
    }

    #[test]
    fn matrix_is_bit_identical_and_bytes_match_the_closed_form() {
        let opts = tiny();
        let rows = run_distributed_bench(&opts).unwrap();
        assert_eq!(rows.len(), 3); // solo + 2 shard counts
        assert_eq!(rows[0].shards, 0);
        assert_eq!(rows[0].wire_bytes, 0);
        for r in &rows {
            assert!(r.matches_solo, "{} shards diverged from solo", r.shards);
            assert!(r.wall_secs > 0.0 && r.model_wall_secs > 0.0);
            // wire_stats is process-global (other tests may run
            // concurrently), so measured is a floor, not an equality,
            // here; the single-threaded `blockms distributed` binary
            // asserts equality through check_distributed_schema.py.
            assert!(
                r.wire_bytes >= r.model_wire_bytes,
                "{} shards moved {} bytes; closed form says {}",
                r.shards,
                r.wire_bytes,
                r.model_wire_bytes
            );
        }
    }

    #[test]
    fn json_round_trips_and_has_schema() {
        let opts = tiny();
        let rows = run_distributed_bench(&opts).unwrap();
        let text = distributed_bench_json(&opts, &rows);
        let doc = Json::parse(&text).expect("valid json");
        assert_eq!(doc.get("iters").and_then(Json::as_usize), Some(3));
        assert!(doc.get("wire_ns_per_byte").and_then(Json::as_f64).is_some());
        let cases = doc.get("cases").and_then(Json::as_arr).expect("cases");
        assert_eq!(cases.len(), rows.len());
        for c in cases {
            assert!(c.get("shards").and_then(Json::as_usize).is_some());
            assert!(c.get("wall_secs").and_then(Json::as_f64).is_some());
            assert!(c.get("model_wire_bytes").and_then(Json::as_f64).is_some());
            assert_eq!(c.get("matches_solo").and_then(Json::as_bool), Some(true));
        }
    }
}
