//! The paper-table bench harness: workloads, sweeps, table printers.
//!
//! Regenerates every table and figure of the paper's evaluation — see
//! DESIGN.md §3 for the experiment index and `blockms paper-tables` /
//! `cargo bench` for the entry points.

pub mod cases;
pub mod distributed;
pub mod hardening;
pub mod kernels;
pub mod layout;
pub mod plan;
pub mod resilience;
pub mod runner;
pub mod service;
pub mod simd;
pub mod stream;
pub mod sweep;
pub mod tables;
pub mod workloads;

pub use distributed::{DistributedBenchOpts, DistributedBenchRow};
pub use hardening::{HardeningBenchOpts, HardeningBenchRow};
pub use kernels::{KernelBenchOpts, KernelBenchRow};
pub use layout::{LayoutBenchOpts, LayoutBenchRow};
pub use plan::{PlanBenchOpts, PlanBenchRow};
pub use resilience::{ResilienceBenchOpts, ResilienceBenchRow};
pub use runner::{ExperimentConfig, ExperimentRow, Runner};
pub use service::{ServiceBenchOpts, ServiceBenchRow};
pub use simd::{SimdBenchOpts, SimdBenchRow};
pub use stream::{StreamBenchOpts, StreamBenchRow};
pub use sweep::{SweepBenchOpts, SweepBenchResult, SweepBenchRow};
pub use workloads::{paper_sizes, PaperSize, Workload};
