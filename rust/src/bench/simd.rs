//! SIMD-layer benchmark: naive vs lanes vs simd-at-every-supported-level
//! step-round throughput over the paper's three block shapes, with the
//! machine-readable `BENCH_simd.json` trail that `check_simd_schema.py`
//! gates in CI (EXPERIMENTS.md §SIMD).
//!
//! Every cell is a full coordinated run (strip store, static schedule —
//! the same drive the layout bench uses), so the numbers include the
//! dispatch overhead the planner actually pays. The headline column is
//! `speedup_vs_lanes`: the Simd kernel only earns its keep where native
//! vectors beat the portable `[f32; LANES]` formulation, and the
//! committed document must show ≥ 1.0 at the host's detected level.
//! Every non-FMA row is also checked bit-identical against a solo
//! sequential naive run (`matches_solo`) — a fast row that diverged is
//! a broken kernel, not a fast one.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::bench::kernels::NaiveBaseline;
use crate::blocks::{ApproachKind, BlockShape};
use crate::coordinator::{
    ClusterConfig, Coordinator, CoordinatorConfig, IoMode, Schedule,
};
use crate::image::SyntheticOrtho;
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::simd::{SimdLevel, SimdMode};
use crate::kmeans::tile::TileLayout;
use crate::plan::ExecPlan;
use crate::util::fmt::Table;
use crate::util::json::Json;

/// Benchmark shape. Defaults are the acceptance configuration:
/// 1024×1024 3-band scene, k ∈ {2, 4, 8}, the paper's three shapes.
#[derive(Clone, Debug)]
pub struct SimdBenchOpts {
    pub height: usize,
    pub width: usize,
    pub ks: Vec<usize>,
    /// Fixed Lloyd iterations per run (plus one labeling pass).
    pub iters: usize,
    /// Timed repetitions per cell (best reported; one warmup first).
    pub samples: usize,
    pub seed: u64,
    pub workers: usize,
    /// Strip height of the store every cell reads through.
    pub strip_rows: usize,
}

impl Default for SimdBenchOpts {
    fn default() -> Self {
        SimdBenchOpts {
            height: 1024,
            width: 1024,
            ks: vec![2, 4, 8],
            iters: 4,
            samples: 2,
            seed: 0x51_AD_BE,
            workers: 4,
            strip_rows: 64,
        }
    }
}

impl SimdBenchOpts {
    /// CI smoke configuration: small image, one k, one sample — fast
    /// enough for a workflow step, same schema as the full matrix.
    pub fn quick() -> SimdBenchOpts {
        SimdBenchOpts {
            height: 128,
            width: 128,
            ks: vec![2],
            iters: 3,
            samples: 1,
            strip_rows: 16,
            ..Default::default()
        }
    }
}

/// One benchmark cell.
#[derive(Clone, Debug)]
pub struct SimdBenchRow {
    pub kernel: KernelChoice,
    /// Dispatched capability level — `Some` only on simd rows.
    pub level: Option<SimdLevel>,
    pub approach: ApproachKind,
    pub k: usize,
    /// Best-sample wall seconds of the whole coordinated run.
    pub wall_secs: f64,
    /// Nanoseconds per pixel per pass (`iters` steps + 1 labeling).
    pub ns_per_pixel_round: f64,
    /// Lanes wall over this cell's wall (same shape, k); 1.0 on the
    /// lanes row itself, < 1.0 typically on naive.
    pub speedup_vs_lanes: f64,
    /// Labels and centroids bit-identical to the solo sequential naive
    /// run of the same workload.
    pub matches_solo: bool,
}

/// The per-(shape, k) cell list: the naive and lanes anchors, then the
/// simd kernel at every level this host can execute — the `Portable`
/// fallback row is always present, so the document is comparable across
/// machines.
fn cells() -> Vec<(KernelChoice, TileLayout, Option<SimdLevel>)> {
    let mut cells = vec![
        (KernelChoice::Naive, TileLayout::Interleaved, None),
        (KernelChoice::Lanes, TileLayout::Soa, None),
    ];
    for level in SimdLevel::ALL {
        if SimdLevel::supported(level) {
            cells.push((KernelChoice::Simd, TileLayout::Soa, Some(level)));
        }
    }
    cells
}

/// Run the full matrix.
pub fn run_simd_bench(opts: &SimdBenchOpts) -> Result<Vec<SimdBenchRow>> {
    let img = Arc::new(
        SyntheticOrtho::default()
            .with_seed(opts.seed)
            .generate(opts.height, opts.width),
    );
    let n_pixels = (opts.height * opts.width) as f64;
    let passes = (opts.iters + 1) as f64;
    // Solo sequential naive reference per k — shape-independent, the
    // identity anchor every parallel cell must reproduce bitwise.
    let mut solo: BTreeMap<usize, NaiveBaseline> = BTreeMap::new();
    let mut rows = Vec::new();
    for approach in ApproachKind::ALL {
        let shape = BlockShape::paper_default(approach, opts.height, opts.width);
        for &k in &opts.ks {
            let ccfg = ClusterConfig {
                k,
                fixed_iters: Some(opts.iters),
                seed: opts.seed ^ 0xC0FFEE,
                ..Default::default()
            };
            let mut lanes_wall: Option<f64> = None;
            let group_start = rows.len();
            for (kernel, layout, level) in cells() {
                let coord = Coordinator::new(CoordinatorConfig {
                    exec: ExecPlan::pinned(shape)
                        .with_workers(opts.workers)
                        .with_kernel(kernel)
                        .with_layout(layout)
                        .with_simd(SimdMode {
                            level: level.unwrap_or_default(),
                            fma: false,
                        }),
                    // Static: per-worker tiles stay warm across rounds.
                    schedule: Schedule::Static,
                    io: IoMode::Strips {
                        strip_rows: opts.strip_rows,
                        file_backed: false,
                    },
                    ..Default::default()
                });
                if !solo.contains_key(&k) {
                    let s = coord.serial(&img, &ccfg)?;
                    solo.insert(k, NaiveBaseline::new(s.total_secs, s.labels, s.centroids));
                }
                let mut best = f64::INFINITY;
                let mut result = None;
                for sample in 0..opts.samples.max(1) + 1 {
                    let t0 = Instant::now();
                    let out = coord.cluster(&img, &ccfg)?;
                    let dt = t0.elapsed().as_secs_f64();
                    if sample > 0 {
                        best = best.min(dt); // sample 0 is warmup
                    }
                    result = Some(out);
                }
                let out = result.expect("at least one sample ran");
                let (_, matches_solo) = solo[&k].score(best, &out.labels, &out.centroids);
                if kernel == KernelChoice::Lanes {
                    lanes_wall = Some(best);
                }
                rows.push(SimdBenchRow {
                    kernel,
                    level,
                    approach,
                    k,
                    wall_secs: best,
                    ns_per_pixel_round: best * 1e9 / (n_pixels * passes),
                    speedup_vs_lanes: lanes_wall.map_or(f64::NAN, |l| l / best),
                    matches_solo,
                });
            }
            // The naive anchor ran before lanes; backfill its column so
            // every row carries a finite ratio.
            let lanes = lanes_wall.expect("cell list always contains lanes");
            for r in &mut rows[group_start..] {
                if r.speedup_vs_lanes.is_nan() {
                    r.speedup_vs_lanes = lanes / r.wall_secs;
                }
            }
        }
    }
    Ok(rows)
}

/// The JSON `level` key for a row (`"-"` on the naive/lanes anchors).
fn level_key(level: Option<SimdLevel>) -> String {
    level.map_or_else(|| "-".to_string(), |l| l.label().to_string())
}

/// Serialize the matrix as the `BENCH_simd.json` document.
pub fn simd_bench_json(opts: &SimdBenchOpts, rows: &[SimdBenchRow]) -> String {
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert(
        "image".to_string(),
        Json::Arr(vec![num(opts.height as f64), num(opts.width as f64)]),
    );
    doc.insert("channels".to_string(), num(3.0));
    doc.insert("iters".to_string(), num(opts.iters as f64));
    doc.insert("samples".to_string(), num(opts.samples as f64));
    doc.insert("seed".to_string(), num(opts.seed as f64));
    doc.insert("workers".to_string(), num(opts.workers as f64));
    doc.insert("strip_rows".to_string(), num(opts.strip_rows as f64));
    doc.insert("source".to_string(), Json::Str("rust".to_string()));
    doc.insert(
        "detected_level".to_string(),
        Json::Str(SimdLevel::detect().label().to_string()),
    );
    let cases = rows
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert("kernel".to_string(), Json::Str(r.kernel.label().to_string()));
            c.insert("level".to_string(), Json::Str(level_key(r.level)));
            c.insert("fma".to_string(), Json::Bool(false));
            c.insert(
                "shape".to_string(),
                Json::Str(crate::bench::layout::shape_key(r.approach).to_string()),
            );
            c.insert("k".to_string(), num(r.k as f64));
            c.insert("wall_secs".to_string(), num(r.wall_secs));
            c.insert("ns_per_pixel_round".to_string(), num(r.ns_per_pixel_round));
            c.insert("speedup_vs_lanes".to_string(), num(r.speedup_vs_lanes));
            c.insert("matches_solo".to_string(), Json::Bool(r.matches_solo));
            Json::Obj(c)
        })
        .collect();
    doc.insert("cases".to_string(), Json::Arr(cases));
    Json::Obj(doc).to_string()
}

/// Run the matrix and write `BENCH_simd.json` to `path`.
pub fn write_simd_bench(path: &Path, opts: &SimdBenchOpts) -> Result<Vec<SimdBenchRow>> {
    let rows = run_simd_bench(opts)?;
    std::fs::write(path, simd_bench_json(opts, &rows))
        .with_context(|| format!("write simd bench to {}", path.display()))?;
    Ok(rows)
}

/// Human-readable rendering of the matrix.
pub fn render_simd_bench(opts: &SimdBenchOpts, rows: &[SimdBenchRow]) -> String {
    let mut t = Table::new(format!(
        "SIMD matrix: step-round throughput at {}x{}, {} iters (detected: {})",
        opts.width,
        opts.height,
        opts.iters,
        SimdLevel::detect()
    ))
    .header(&["Kernel", "Level", "Shape", "K", "ns/px/round", "Speedup vs lanes", "Identical"]);
    for r in rows {
        t.row(vec![
            r.kernel.to_string(),
            level_key(r.level),
            crate::bench::layout::shape_key(r.approach).to_string(),
            r.k.to_string(),
            format!("{:.3}", r.ns_per_pixel_round),
            format!("{:.2}x", r.speedup_vs_lanes),
            if r.matches_solo { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SimdBenchOpts {
        SimdBenchOpts {
            height: 48,
            width: 40,
            ks: vec![2],
            iters: 3,
            samples: 1,
            ..Default::default()
        }
    }

    #[test]
    fn matrix_covers_every_supported_level_and_matches_solo() {
        let rows = run_simd_bench(&tiny()).unwrap();
        let levels = SimdLevel::ALL
            .iter()
            .filter(|&&l| SimdLevel::supported(l))
            .count();
        assert_eq!(rows.len(), 3 * (2 + levels)); // 3 shapes x (anchors + levels)
        for r in &rows {
            assert!(r.matches_solo, "{} {:?} diverged from solo", r.kernel, r.level);
            assert!(r.ns_per_pixel_round > 0.0);
            assert!(r.speedup_vs_lanes.is_finite() && r.speedup_vs_lanes > 0.0);
        }
        // The portable fallback row is present on every machine.
        assert!(rows
            .iter()
            .any(|r| r.level == Some(SimdLevel::Portable)));
        // The lanes anchor carries exactly 1.0 by construction.
        for r in rows.iter().filter(|r| r.kernel == KernelChoice::Lanes) {
            assert!((r.speedup_vs_lanes - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn json_round_trips_and_has_schema() {
        let opts = tiny();
        let rows = run_simd_bench(&opts).unwrap();
        let text = simd_bench_json(&opts, &rows);
        let doc = Json::parse(&text).expect("valid json");
        assert_eq!(doc.get("iters").and_then(Json::as_usize), Some(3));
        assert!(doc.get("detected_level").and_then(Json::as_str).is_some());
        let cases = doc.get("cases").and_then(Json::as_arr).expect("cases");
        assert_eq!(cases.len(), rows.len());
        for c in cases {
            assert!(c.get("kernel").and_then(Json::as_str).is_some());
            assert!(c.get("level").and_then(Json::as_str).is_some());
            assert!(c.get("speedup_vs_lanes").and_then(Json::as_f64).is_some());
            assert_eq!(c.get("matches_solo").and_then(Json::as_bool), Some(true));
            assert_eq!(c.get("fma").and_then(Json::as_bool), Some(false));
        }
    }

    #[test]
    fn write_creates_the_file() {
        let path = std::env::temp_dir().join("blockms_test_BENCH_simd.json");
        let rows = write_simd_bench(&path, &tiny()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        assert!(!rows.is_empty());
        let _ = std::fs::remove_file(&path);
    }
}
