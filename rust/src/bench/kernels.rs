//! Kernel-layer benchmark: naive vs pruned vs fused step-round
//! throughput, with the machine-readable `BENCH_kernels.json` trail that
//! later PRs regress against (EXPERIMENTS.md §Kernel architecture).
//!
//! Each case runs a full fixed-iteration Lloyd drive (`iters` step
//! rounds plus the final labeling pass) through [`SeqKMeans`] under one
//! [`KernelChoice`], then reports nanoseconds per pixel per round from
//! the best of `samples` timed repetitions. Every non-naive case is also
//! checked for bit-identical labels and centroids against the naive run
//! — a throughput row with `matches_naive: false` means the kernel layer
//! is broken, not fast.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::image::SyntheticOrtho;
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::{KMeansConfig, SeqKMeans};
use crate::util::fmt::Table;
use crate::util::json::Json;

/// Benchmark shape. The defaults are the acceptance configuration:
/// 1024×1024 3-band synthetic ortho scene, `k ∈ {2, 4}`, 8 Lloyd rounds.
#[derive(Clone, Debug)]
pub struct KernelBenchOpts {
    pub height: usize,
    pub width: usize,
    /// Cluster counts to sweep (paper: 2 and 4).
    pub ks: Vec<usize>,
    /// Fixed Lloyd iterations per run.
    pub iters: usize,
    /// Timed repetitions per case (best is reported; one extra warmup
    /// repetition is always run first).
    pub samples: usize,
    pub seed: u64,
}

impl Default for KernelBenchOpts {
    fn default() -> Self {
        KernelBenchOpts {
            height: 1024,
            width: 1024,
            ks: vec![2, 4],
            iters: 8,
            samples: 3,
            seed: 0xBE_11C4,
        }
    }
}

/// Reference result of a sweep's naive run; scores later kernels for
/// speedup and bit-identity. This is the single implementation of the
/// comparison contract, shared by the kernel matrix here and the
/// block-shape kernel cases in `bench::cases`.
#[derive(Clone, Debug)]
pub struct NaiveBaseline {
    wall_secs: f64,
    labels: Vec<u32>,
    centroids: Vec<f32>,
}

impl NaiveBaseline {
    pub fn new(wall_secs: f64, labels: Vec<u32>, centroids: Vec<f32>) -> NaiveBaseline {
        NaiveBaseline {
            wall_secs,
            labels,
            centroids,
        }
    }

    /// `(speedup_vs_naive, matches_naive)` for another kernel's run of
    /// the same work. Identity is bitwise on labels *and* centroids.
    pub fn score(&self, wall_secs: f64, labels: &[u32], centroids: &[f32]) -> (f64, bool) {
        (
            self.wall_secs / wall_secs,
            labels == &self.labels[..] && centroids == &self.centroids[..],
        )
    }
}

/// One benchmark cell.
#[derive(Clone, Debug)]
pub struct KernelBenchRow {
    pub kernel: KernelChoice,
    pub k: usize,
    /// Best-sample wall time of the whole drive, seconds.
    pub wall_secs: f64,
    /// Nanoseconds per pixel per pass (`iters` step rounds + 1 labeling
    /// pass).
    pub ns_per_pixel_round: f64,
    /// Naive ns/pixel/round divided by this row's (higher is better;
    /// 1.0 for the naive row itself).
    pub speedup_vs_naive: f64,
    /// Labels and centroids bit-identical to the naive run.
    pub matches_naive: bool,
}

/// Run the naive/pruned/fused matrix.
pub fn run_kernel_bench(opts: &KernelBenchOpts) -> Vec<KernelBenchRow> {
    let img = SyntheticOrtho::default()
        .with_seed(opts.seed)
        .generate(opts.height, opts.width);
    let px = img.as_pixels();
    let n_pixels = (px.len() / 3) as f64;
    let passes = (opts.iters + 1) as f64;
    let mut rows = Vec::new();
    for &k in &opts.ks {
        let cfg = KMeansConfig {
            k,
            ..Default::default()
        };
        let mut baseline: Option<NaiveBaseline> = None;
        for kernel in KernelChoice::ALL {
            let mut best = f64::INFINITY;
            let mut result = None;
            for sample in 0..opts.samples.max(1) + 1 {
                let t0 = Instant::now();
                let r = SeqKMeans::run_fixed_iters_with(px, 3, &cfg, opts.iters, kernel);
                let dt = t0.elapsed().as_secs_f64();
                if sample > 0 {
                    best = best.min(dt); // sample 0 is warmup
                }
                result = Some(r);
            }
            let r = result.expect("at least one sample ran");
            let (speedup_vs_naive, matches_naive) = match &baseline {
                None => (1.0, true),
                Some(b) => b.score(best, &r.labels, &r.centroids),
            };
            if kernel == KernelChoice::Naive {
                baseline = Some(NaiveBaseline::new(best, r.labels, r.centroids));
            }
            rows.push(KernelBenchRow {
                kernel,
                k,
                wall_secs: best,
                ns_per_pixel_round: best * 1e9 / (n_pixels * passes),
                speedup_vs_naive,
                matches_naive,
            });
        }
    }
    rows
}

/// Serialize the matrix as the `BENCH_kernels.json` document.
pub fn kernel_bench_json(opts: &KernelBenchOpts, rows: &[KernelBenchRow]) -> String {
    let num = Json::Num;
    let mut doc = BTreeMap::new();
    doc.insert(
        "image".to_string(),
        Json::Arr(vec![num(opts.height as f64), num(opts.width as f64)]),
    );
    doc.insert("channels".to_string(), num(3.0));
    doc.insert("iters".to_string(), num(opts.iters as f64));
    doc.insert("samples".to_string(), num(opts.samples as f64));
    doc.insert("seed".to_string(), num(opts.seed as f64));
    let cases = rows
        .iter()
        .map(|r| {
            let mut c = BTreeMap::new();
            c.insert("kernel".to_string(), Json::Str(r.kernel.label().to_string()));
            c.insert("k".to_string(), num(r.k as f64));
            c.insert("wall_secs".to_string(), num(r.wall_secs));
            c.insert("ns_per_pixel_round".to_string(), num(r.ns_per_pixel_round));
            c.insert("speedup_vs_naive".to_string(), num(r.speedup_vs_naive));
            c.insert("matches_naive".to_string(), Json::Bool(r.matches_naive));
            Json::Obj(c)
        })
        .collect();
    doc.insert("cases".to_string(), Json::Arr(cases));
    Json::Obj(doc).to_string()
}

/// Run the matrix and write `BENCH_kernels.json` to `path`.
pub fn write_kernel_bench(path: &Path, opts: &KernelBenchOpts) -> Result<Vec<KernelBenchRow>> {
    let rows = run_kernel_bench(opts);
    std::fs::write(path, kernel_bench_json(opts, &rows))
        .with_context(|| format!("write kernel bench to {}", path.display()))?;
    Ok(rows)
}

/// Human-readable rendering of the matrix.
pub fn render_kernel_bench(opts: &KernelBenchOpts, rows: &[KernelBenchRow]) -> String {
    let mut t = Table::new(format!(
        "Kernel matrix: step-round throughput at {}x{}, {} iters",
        opts.width, opts.height, opts.iters
    ))
    .header(&["Kernel", "K", "ns/px/round", "Speedup vs naive", "Identical"]);
    for r in rows {
        t.row(vec![
            r.kernel.to_string(),
            r.k.to_string(),
            format!("{:.3}", r.ns_per_pixel_round),
            format!("{:.2}x", r.speedup_vs_naive),
            if r.matches_naive { "yes" } else { "NO" }.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> KernelBenchOpts {
        KernelBenchOpts {
            height: 48,
            width: 40,
            ks: vec![2, 4],
            iters: 3,
            samples: 1,
            ..Default::default()
        }
    }

    #[test]
    fn matrix_covers_all_kernels_and_matches() {
        let rows = run_kernel_bench(&tiny());
        assert_eq!(rows.len(), KernelChoice::ALL.len() * 2); // kernels x 2 ks
        for r in &rows {
            assert!(r.matches_naive, "{} k={} diverged from naive", r.kernel, r.k);
            assert!(r.ns_per_pixel_round > 0.0);
            assert!(r.speedup_vs_naive > 0.0);
        }
        assert_eq!(rows[0].kernel, KernelChoice::Naive);
        assert!((rows[0].speedup_vs_naive - 1.0).abs() < 1e-12);
    }

    #[test]
    fn json_round_trips_and_has_schema() {
        let opts = tiny();
        let rows = run_kernel_bench(&opts);
        let text = kernel_bench_json(&opts, &rows);
        let doc = Json::parse(&text).expect("valid json");
        assert_eq!(doc.get("iters").and_then(Json::as_usize), Some(3));
        let cases = doc.get("cases").and_then(Json::as_arr).expect("cases");
        assert_eq!(cases.len(), rows.len());
        for c in cases {
            assert!(c.get("kernel").and_then(Json::as_str).is_some());
            assert!(c.get("ns_per_pixel_round").and_then(Json::as_f64).is_some());
            assert_eq!(c.get("matches_naive").and_then(Json::as_bool), Some(true));
        }
    }

    #[test]
    fn write_creates_the_file() {
        let path = std::env::temp_dir().join("blockms_test_BENCH_kernels.json");
        let rows = write_kernel_bench(&path, &tiny()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        assert_eq!(rows.len(), KernelChoice::ALL.len() * 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn render_mentions_every_kernel() {
        let opts = tiny();
        let rows = run_kernel_bench(&opts);
        let text = render_kernel_bench(&opts, &rows);
        for name in ["naive", "pruned", "fused", "lanes"] {
            assert!(text.contains(name), "{text}");
        }
    }
}
