//! Experiment runner: measure → calibrate → replay at N workers.
//!
//! Method (DESIGN.md §5): the pipeline really runs (strip reads, block
//! crops, kernel execution) under a single worker to collect undisturbed
//! per-block costs; the [`WorkerSim`] then replays those costs at the
//! requested worker count. `Serial` is the same replay at one worker plus
//! the leader's fixed costs — so serial and parallel columns are derived
//! from identical measured work, exactly like the paper's serial/parallel
//! pairs (same image, same algorithm, different worker counts).

use std::sync::Arc;

use anyhow::Result;

use super::workloads::Workload;
use crate::blocks::BlockShape;
use crate::coordinator::{
    ClusterConfig, ClusterMode, Coordinator, CoordinatorConfig, Engine, IoMode, RoundRecord,
    Schedule,
};
use crate::image::Raster;
use crate::kmeans::kernel::KernelChoice;
use crate::metrics::Speedup;
use crate::plan::ExecPlan;
use crate::simtime::{SimParams, WorkerSim};

/// Full description of one experiment cell (one table row at one worker
/// count).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub workload: Workload,
    pub shape: BlockShape,
    pub k: usize,
    pub workers: usize,
    pub engine: EngineChoice,
    /// Lloyd iterations (fixed, so serial == parallel work).
    pub iters: usize,
    /// Strip height for the I/O model.
    pub strip_rows: usize,
    pub schedule: Schedule,
    pub mode: ClusterMode,
    /// Compute kernel for the measured run (naive/pruned/fused —
    /// identical results, different per-block costs).
    pub kernel: KernelChoice,
    /// Disk model for the replay.
    pub disk_serialized: bool,
}

impl ExperimentConfig {
    pub fn new(workload: Workload, shape: BlockShape, k: usize, workers: usize) -> Self {
        ExperimentConfig {
            workload,
            shape,
            k,
            workers,
            engine: EngineChoice::Native,
            iters: 6,
            strip_rows: 64,
            schedule: Schedule::Dynamic,
            mode: ClusterMode::Global,
            kernel: KernelChoice::Naive,
            disk_serialized: true,
        }
    }
}

/// Engine selector (mirrors [`Engine`] but `Copy` for sweep tables).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineChoice {
    Native,
    Pjrt,
}

impl EngineChoice {
    fn to_engine(self) -> Engine {
        match self {
            EngineChoice::Native => Engine::Native,
            EngineChoice::Pjrt => Engine::Pjrt {
                artifacts_dir: None,
            },
        }
    }
}

impl std::str::FromStr for EngineChoice {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(EngineChoice::Native),
            "pjrt" => Ok(EngineChoice::Pjrt),
            other => Err(format!("unknown engine {other:?} (want native|pjrt)")),
        }
    }
}

/// One output row, shaped like the paper's tables.
#[derive(Clone, Debug)]
pub struct ExperimentRow {
    /// The paper-size label (e.g. `4656x5793`).
    pub data_size: String,
    pub serial_secs: f64,
    pub parallel_secs: f64,
    pub speedup: f64,
    pub efficiency: f64,
    pub workers: usize,
    pub k: usize,
    pub approach: &'static str,
    /// Real wall-clock of the calibration run (reported in EXPERIMENTS.md
    /// §Method; not a table column in the paper).
    pub wall_secs: f64,
    pub blocks: usize,
    /// Strip reads per full pass over the plan.
    pub strip_reads: u64,
    /// Final clustering inertia (sanity: parallel == serial work).
    pub inertia: f64,
}

/// One calibration run's reusable measurements.
#[derive(Clone, Debug)]
struct Calibration {
    rounds: Vec<RoundRecord>,
    leader_fixed: f64,
    leader_per_round: f64,
    wall_secs: f64,
    blocks: usize,
    strip_reads_per_pass: u64,
    inertia: f64,
}

/// Cache key: everything that affects measured per-block costs
/// (deliberately excludes `workers`/`disk_serialized`, which only affect
/// the replay — a whole worker sweep shares one calibration, so speedup
/// curves are free of run-to-run timing noise).
type CalKey = (
    u64,
    usize,
    usize,
    String,
    usize,
    usize,
    usize,
    EngineChoice,
    ClusterMode,
    KernelChoice,
);

fn cal_key(cfg: &ExperimentConfig) -> CalKey {
    (
        cfg.workload.seed,
        cfg.workload.height,
        cfg.workload.width,
        format!("{}", cfg.shape),
        cfg.k,
        cfg.iters,
        cfg.strip_rows,
        cfg.engine,
        cfg.mode,
        cfg.kernel,
    )
}

/// The measurement/replay engine.
#[derive(Default)]
pub struct Runner {
    /// Reuse the generated image across cells of a sweep (same workload).
    image_cache: Option<(u64, usize, usize, Arc<Raster>)>,
    /// Reuse measured per-block costs across worker counts.
    cal_cache: Vec<(CalKey, Calibration)>,
}

impl Runner {
    pub fn new() -> Runner {
        Runner::default()
    }

    fn image(&mut self, w: &Workload) -> Arc<Raster> {
        let key = (w.seed, w.height, w.width);
        if let Some((s, h, ww, img)) = &self.image_cache {
            if (*s, *h, *ww) == key {
                return Arc::clone(img);
            }
        }
        let img = Arc::new(w.generate());
        self.image_cache = Some((key.0, key.1, key.2, Arc::clone(&img)));
        img
    }

    /// Calibration run: 1 worker, real strip I/O + kernel execution.
    fn calibrate(&mut self, cfg: &ExperimentConfig) -> Result<Calibration> {
        let key = cal_key(cfg);
        if let Some((_, c)) = self.cal_cache.iter().find(|(k, _)| *k == key) {
            return Ok(c.clone());
        }
        let img = self.image(&cfg.workload);
        let coord = Coordinator::new(CoordinatorConfig {
            // Calibration measures per-block costs undisturbed: one
            // worker, the cell's pinned shape and kernel.
            exec: ExecPlan::pinned(cfg.shape)
                .with_workers(1)
                .with_kernel(cfg.kernel),
            engine: cfg.engine.to_engine(),
            mode: cfg.mode,
            io: IoMode::Strips {
                strip_rows: cfg.strip_rows,
                file_backed: false,
            },
            schedule: cfg.schedule,
            ..Default::default()
        });
        let ccfg = ClusterConfig {
            k: cfg.k,
            fixed_iters: Some(cfg.iters),
            ..Default::default()
        };
        let out = coord.cluster(&img, &ccfg)?;
        // Exclude worker startup (spawn_secs): the paper times processing
        // with the parpool already up.
        let (leader_fixed, leader_per_round) =
            leader_costs(&out.rounds, out.total_secs - out.spawn_secs);
        let cal = Calibration {
            leader_fixed,
            leader_per_round,
            wall_secs: out.total_secs,
            blocks: out.blocks,
            strip_reads_per_pass: out
                .io_stats
                .map(|s| s.strip_reads / out.rounds.len().max(1) as u64)
                .unwrap_or(0),
            inertia: out.inertia,
            rounds: out.rounds,
        };
        self.cal_cache.push((key, cal.clone()));
        Ok(cal)
    }

    /// Run one experiment cell (calibrate once, replay at `cfg.workers`).
    pub fn measure(&mut self, cfg: &ExperimentConfig) -> Result<ExperimentRow> {
        let cal = self.calibrate(cfg)?;
        let sim = |workers: usize| {
            WorkerSim::new(SimParams {
                workers,
                schedule: cfg.schedule,
                disk_serialized: cfg.disk_serialized,
                leader_secs_per_round: cal.leader_per_round,
                leader_secs_fixed: cal.leader_fixed,
            })
            .replay(&cal.rounds)
        };
        let serial_secs = sim(1);
        let parallel_secs = sim(cfg.workers);
        let speedup = Speedup::compute(serial_secs, parallel_secs);
        Ok(ExperimentRow {
            data_size: cfg.workload.nominal.label(),
            serial_secs,
            parallel_secs,
            speedup: speedup.0,
            efficiency: speedup.efficiency(cfg.workers),
            workers: cfg.workers,
            k: cfg.k,
            approach: cfg.shape.label(),
            wall_secs: cal.wall_secs,
            blocks: cal.blocks,
            strip_reads: cal.strip_reads_per_pass,
            inertia: cal.inertia,
        })
    }
}

/// Estimate leader overheads from the measured run: per-round dispatch
/// overhead = wall − Σ block busy (clamped ≥ 0, single worker so busy is
/// sequential); fixed = total − Σ round walls (init + assembly).
fn leader_costs(rounds: &[RoundRecord], total_secs: f64) -> (f64, f64) {
    if rounds.is_empty() {
        return (total_secs.max(0.0), 0.0);
    }
    let mut per_round_overheads = Vec::with_capacity(rounds.len());
    let mut wall_sum = 0.0;
    for r in rounds {
        let busy: f64 = r.costs.iter().map(|c| c.total_secs()).sum();
        per_round_overheads.push((r.wall_secs - busy).max(0.0));
        wall_sum += r.wall_secs;
    }
    let per_round =
        per_round_overheads.iter().sum::<f64>() / per_round_overheads.len() as f64;
    let fixed = (total_secs - wall_sum).max(0.0);
    (fixed, per_round)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::PaperSize;

    fn tiny_cfg(workers: usize, shape: BlockShape) -> ExperimentConfig {
        let wl = Workload::new(PaperSize::new(256, 192), 0.5, 3);
        let mut cfg = ExperimentConfig::new(wl, shape, 2, workers);
        cfg.iters = 2;
        cfg.strip_rows = 16;
        cfg
    }

    #[test]
    fn measure_produces_consistent_row() {
        let mut runner = Runner::new();
        let row = runner
            .measure(&tiny_cfg(4, BlockShape::Square { side: 32 }))
            .unwrap();
        assert_eq!(row.data_size, "256x192");
        assert!(row.serial_secs > 0.0);
        assert!(row.parallel_secs > 0.0);
        assert!(row.speedup >= 1.0, "speedup {}", row.speedup);
        assert!(row.speedup <= 4.0 + 1e-9, "super-linear speedup {}", row.speedup);
        assert!((row.efficiency - row.speedup / 4.0).abs() < 1e-12);
        assert!(row.blocks > 1);
        assert!(row.strip_reads > 0);
    }

    #[test]
    fn worker_sweep_shares_one_calibration() {
        let mut runner = Runner::new();
        let r2 = runner
            .measure(&tiny_cfg(2, BlockShape::Square { side: 24 }))
            .unwrap();
        let r4 = runner
            .measure(&tiny_cfg(4, BlockShape::Square { side: 24 }))
            .unwrap();
        // identical measured work: serial columns agree exactly and the
        // replay is monotone in worker count (dynamic scheduling)
        assert_eq!(r2.serial_secs, r4.serial_secs);
        assert!(r4.parallel_secs <= r2.parallel_secs * (1.0 + 1e-9));
        assert_eq!(runner.cal_cache.len(), 1, "calibration must be cached");
    }

    #[test]
    fn image_cache_reused_across_cells() {
        let mut runner = Runner::new();
        let _ = runner
            .measure(&tiny_cfg(2, BlockShape::Rows { band_rows: 32 }))
            .unwrap();
        let cached = runner.image_cache.as_ref().map(|(_, h, w, _)| (*h, *w));
        let _ = runner
            .measure(&tiny_cfg(4, BlockShape::Cols { band_cols: 32 }))
            .unwrap();
        assert_eq!(
            cached,
            runner.image_cache.as_ref().map(|(_, h, w, _)| (*h, *w)),
            "same workload must reuse the cached image"
        );
        assert_eq!(runner.cal_cache.len(), 2, "different shapes calibrate separately");
    }

    #[test]
    fn leader_costs_clamped_nonnegative() {
        let (fixed, per_round) = leader_costs(&[], 1.5);
        assert_eq!((fixed, per_round), (1.5, 0.0));
    }
}
