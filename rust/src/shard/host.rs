//! Shard-side host: serves leader connections over any transport.
//!
//! One [`ShardHost`] per shard **process**; one connection handler per
//! leader connection (the leader opens `--workers` connections per
//! shard so blocks pipeline). Handlers share the host's job table —
//! the first `Register` for a job materializes the [`ShardSpec`] into
//! a [`WorkerContext`] (rebuilding the raster and strip store from the
//! shipped bytes), later connections reuse the same `Arc`.
//!
//! Every handler owns a single-worker [`WorkerPool`] and drives each
//! incoming `Block` frame through `run_round` — exactly the code path
//! solo execution uses, which is the heart of the bit-identity
//! argument: a shard computes the same pure function of the round's
//! shipped centroids that a local worker would.
//!
//! Protocol violations are loud: a `Register` whose header fingerprint
//! does not match the fingerprint recomputed from the shipped spec, or
//! a `Block` for a different fingerprint than the job registered,
//! aborts the connection with [`WireError::Fingerprint`] — the
//! listener entry point turns that into exit code 2 so a shard never
//! silently computes on stale geometry.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::coordinator::{
    Job, JobId, JobOutcome, JobPayload, JobResult, Schedule, WorkerContext, WorkerPool,
};
use crate::kmeans::kernel::CentroidDrift;

use super::spec::ShardSpec;
use super::transport::{loopback_pair, ShardTransport, StreamTransport};
use super::wire::{BlockPhase, ShardMsg, WireError};

/// Shared fault hook for the kill tests: `(blocks_served, limit)`. The
/// counter spans all of a shard's connections; once it passes the
/// limit every handler "dies" (returns without replying) the next time
/// it receives a block — modelling a whole shard process vanishing
/// mid-round.
pub type KillSwitch = (Arc<AtomicUsize>, usize);

struct RegisteredJob {
    fingerprint: u64,
    ctx: Arc<WorkerContext>,
}

/// Per-process shard state: materialized job contexts keyed by job id,
/// shared across connection handlers.
pub struct ShardHost {
    jobs: Mutex<HashMap<JobId, RegisteredJob>>,
}

impl ShardHost {
    pub fn new() -> Arc<ShardHost> {
        Arc::new(ShardHost { jobs: Mutex::new(HashMap::new()) })
    }

    /// Serve one leader connection until it closes, shuts down, or
    /// violates the protocol. Blocking; runs on the connection thread.
    pub fn serve_connection(
        self: &Arc<ShardHost>,
        transport: &mut dyn ShardTransport,
        kill_after: Option<KillSwitch>,
    ) -> Result<(), WireError> {
        let pool = WorkerPool::spawn(1, Schedule::Dynamic);
        let result = self.serve_loop(&pool, transport, kill_after);
        pool.shutdown();
        result
    }

    fn serve_loop(
        &self,
        pool: &WorkerPool,
        transport: &mut dyn ShardTransport,
        kill_after: Option<KillSwitch>,
    ) -> Result<(), WireError> {
        // Jobs registered into *this* connection's pool, with the
        // fingerprint every later frame for the job must carry.
        let mut known: HashMap<JobId, u64> = HashMap::new();
        loop {
            let frame = match transport.recv() {
                Ok(frame) => frame,
                Err(WireError::Closed) => return Ok(()),
                Err(e) => return Err(e),
            };
            match ShardMsg::decode(&frame)? {
                ShardMsg::Register { job, spec } => {
                    let want = spec.fingerprint();
                    if frame.fingerprint != want {
                        return Err(WireError::Fingerprint { got: frame.fingerprint, want });
                    }
                    let ctx = {
                        let mut jobs = self.jobs.lock().unwrap();
                        match jobs.get(&job) {
                            Some(reg) if reg.fingerprint == want => Arc::clone(&reg.ctx),
                            _ => {
                                let ctx = Arc::new(spec.materialize(job).map_err(|e| {
                                    WireError::Mismatch(format!(
                                        "materialize shard job {job}: {e:#}"
                                    ))
                                })?);
                                jobs.insert(
                                    job,
                                    RegisteredJob { fingerprint: want, ctx: Arc::clone(&ctx) },
                                );
                                ctx
                            }
                        }
                    };
                    pool.register_job(job, ctx);
                    known.insert(job, want);
                    transport.send(&ShardMsg::RegisterAck.to_frame(want))?;
                }
                ShardMsg::Block { job, block, round, phase, centroids, drift, .. } => {
                    let want = match known.get(&job) {
                        Some(&fp) => fp,
                        None => {
                            return Err(WireError::Mismatch(format!(
                                "block frame for unregistered job {job}"
                            )))
                        }
                    };
                    if frame.fingerprint != want {
                        return Err(WireError::Fingerprint { got: frame.fingerprint, want });
                    }
                    if let Some((served, limit)) = &kill_after {
                        if served.fetch_add(1, Ordering::SeqCst) >= *limit {
                            // Simulated shard death: vanish without a
                            // reply; the leader's watchdog + retry
                            // budget re-queue the block elsewhere.
                            return Ok(());
                        }
                    }
                    let centroids = Arc::new(centroids);
                    let drift = drift.map(|d| {
                        Arc::new(CentroidDrift { per_centroid: d.per_centroid, max: d.max })
                    });
                    let payload = match phase {
                        BlockPhase::Step => JobPayload::Step { centroids, drift },
                        BlockPhase::Assign => JobPayload::Assign { centroids, drift },
                        BlockPhase::Local => JobPayload::Local { init: centroids },
                    };
                    let work = Job { job, block: block as usize, round, payload };
                    let reply = match pool.run_round(vec![work]) {
                        Ok(mut outs) => match outs.pop() {
                            Some(out) => outcome_to_msg(out),
                            None => ShardMsg::ErrorResult {
                                job,
                                block,
                                round,
                                message: "round returned no outcome".into(),
                            },
                        },
                        Err(e) => {
                            ShardMsg::ErrorResult { job, block, round, message: format!("{e:#}") }
                        }
                    };
                    transport.send(&reply.to_frame(want))?;
                }
                ShardMsg::Ping { job } => {
                    transport.send(&ShardMsg::Pong { job }.to_frame(frame.fingerprint))?;
                }
                ShardMsg::Retire { job, purge_content: _ } => {
                    // No reply — mirrors the in-process Retire payload.
                    pool.retire_job(job);
                    known.remove(&job);
                    self.jobs.lock().unwrap().remove(&job);
                }
                ShardMsg::Shutdown => return Ok(()),
                other => {
                    return Err(WireError::Mismatch(format!(
                        "unexpected {:?} frame on shard",
                        other.kind()
                    )))
                }
            }
        }
    }
}

/// Convert a pool outcome into its wire reply.
fn outcome_to_msg(out: JobOutcome) -> ShardMsg {
    let (job, block, round) = (out.job, out.block as u64, out.round);
    let t = out.timing;
    match out.result {
        JobResult::Step { accum } => ShardMsg::StepResult {
            job,
            block,
            round,
            k: accum.k as u32,
            channels: accum.channels as u32,
            counts: accum.counts,
            sums: accum.sums,
            inertia: accum.inertia,
            io_secs: t.io_secs,
            compute_secs: t.compute_secs,
            pixels: t.pixels as u64,
        },
        JobResult::Assign { labels, inertia } => ShardMsg::AssignResult {
            job,
            block,
            round,
            inertia,
            io_secs: t.io_secs,
            compute_secs: t.compute_secs,
            pixels: t.pixels as u64,
            labels,
        },
        JobResult::Local { labels, centroids, inertia, counts } => {
            let k = counts.len();
            let channels = if k > 0 { centroids.len() / k } else { 0 };
            ShardMsg::LocalResult {
                job,
                block,
                round,
                k: k as u32,
                channels: channels as u32,
                labels,
                centroids,
                counts,
                inertia,
                io_secs: t.io_secs,
                compute_secs: t.compute_secs,
                pixels: t.pixels as u64,
            }
        }
        JobResult::Pong => ShardMsg::Pong { job },
    }
}

/// An in-process shard: connection handler threads serving the shard
/// end of loopback transports. Drop joins the handlers, so drop this
/// **after** shutting down the leader pool that holds the other ends —
/// handlers exit when their transport closes.
pub struct LoopbackShard {
    handles: Vec<JoinHandle<()>>,
}

impl Drop for LoopbackShard {
    fn drop(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Spawn one in-process shard with `conns` connections; returns the
/// leader-side transport ends. `kill_after_blocks` arms the shared
/// [`KillSwitch`] for shard-death tests.
pub fn spawn_loopback_shard(
    conns: usize,
    kill_after_blocks: Option<usize>,
) -> (Vec<Box<dyn ShardTransport + Send>>, LoopbackShard) {
    assert!(conns > 0, "a shard needs at least one connection");
    let host = ShardHost::new();
    let kill = kill_after_blocks.map(|limit| (Arc::new(AtomicUsize::new(0)), limit));
    let mut leader_ends: Vec<Box<dyn ShardTransport + Send>> = Vec::with_capacity(conns);
    let mut handles = Vec::with_capacity(conns);
    for c in 0..conns {
        let (leader_end, mut shard_end) = loopback_pair();
        let host = Arc::clone(&host);
        let kill = kill.clone();
        let handle = std::thread::Builder::new()
            .name(format!("blockms-shard-conn-{c}"))
            .spawn(move || {
                if let Err(e) = host.serve_connection(&mut shard_end, kill) {
                    eprintln!("loopback shard connection {c}: {e}");
                }
            })
            .expect("spawn shard connection thread");
        leader_ends.push(Box::new(leader_end));
        handles.push(handle);
    }
    (leader_ends, LoopbackShard { handles })
}

/// Host a shard worker on `addr` (a path with `/` means a Unix-domain
/// socket, otherwise `host:port` TCP). With `once`, serve exactly one
/// connection sequentially and return — what the CI drill uses so the
/// process exits deterministically. A protocol-version or fingerprint
/// violation exits the process with code 2, both values named.
pub fn run_listener(addr: &str, once: bool) -> Result<()> {
    if addr.contains('/') {
        #[cfg(unix)]
        {
            // Remove a stale socket from a previous run, else bind fails.
            let _ = std::fs::remove_file(addr);
            let listener = std::os::unix::net::UnixListener::bind(addr)
                .with_context(|| format!("bind shard socket {addr}"))?;
            eprintln!("blockms shard-worker: listening on unix socket {addr}");
            return serve_streams(listener.incoming(), once);
        }
        #[cfg(not(unix))]
        anyhow::bail!("unix-domain shard sockets are not supported on this platform: {addr}");
    }
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("bind shard address {addr}"))?;
    eprintln!("blockms shard-worker: listening on tcp {addr}");
    serve_streams(listener.incoming(), once)
}

fn serve_streams<S, I>(incoming: I, once: bool) -> Result<()>
where
    S: Read + Write + Send + 'static,
    I: Iterator<Item = std::io::Result<S>>,
{
    let host = ShardHost::new();
    for (cid, stream) in incoming.enumerate() {
        let stream = stream.context("accept shard connection")?;
        if once {
            let mut transport = StreamTransport::new(stream);
            serve_or_exit(&host, &mut transport, cid);
            return Ok(());
        }
        let host = Arc::clone(&host);
        std::thread::Builder::new()
            .name(format!("blockms-shard-conn-{cid}"))
            .spawn(move || {
                let mut transport = StreamTransport::new(stream);
                serve_or_exit(&host, &mut transport, cid);
            })
            .context("spawn shard connection thread")?;
    }
    Ok(())
}

fn serve_or_exit(host: &Arc<ShardHost>, transport: &mut dyn ShardTransport, cid: usize) {
    match host.serve_connection(transport, None) {
        Ok(()) => {}
        Err(e @ (WireError::Version { .. } | WireError::Fingerprint { .. })) => {
            // Satellite: never silently compute on stale geometry.
            eprintln!("shard-worker connection {cid}: {e}");
            std::process::exit(2);
        }
        Err(e) => eprintln!("shard-worker connection {cid}: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::coordinator::ClusterMode;
    use crate::image::SyntheticOrtho;
    use crate::kmeans::InitMethod;
    use crate::kmeans::kernel::KernelChoice;
    use crate::kmeans::math;
    use crate::kmeans::simd::SimdMode;
    use crate::kmeans::tile::TileLayout;

    const H: usize = 16;
    const W: usize = 12;
    const C: usize = 3;
    const K: usize = 2;

    fn tiny_spec() -> ShardSpec {
        let img = SyntheticOrtho::default().with_seed(7).generate(H, W);
        ShardSpec {
            height: H,
            width: W,
            channels: C,
            k: K,
            seed: 7,
            tol_bits: 0.0f32.to_bits(),
            max_iters: 4,
            fixed_iters: Some(4),
            init: InitMethod::Fixed(vec![0.1, 0.2, 0.3, 0.8, 0.7, 0.6]),
            mode: ClusterMode::Global,
            shape: BlockShape::Square { side: 8 },
            kernel: KernelChoice::Naive,
            layout: TileLayout::Interleaved,
            arena_mb: 0,
            prefetch: false,
            strip_cache: 0,
            simd: SimdMode::default(),
            strip_rows: 0,
            file_backed: false,
            pixels: Arc::new(img.as_pixels().to_vec()),
        }
    }

    fn register(leader: &mut dyn ShardTransport, job: u64, spec: &ShardSpec) -> u64 {
        let fp = spec.fingerprint();
        leader.send(&ShardMsg::Register { job, spec: spec.clone() }.to_frame(fp)).unwrap();
        let ack = ShardMsg::decode(&leader.recv().unwrap()).unwrap();
        assert!(matches!(ack, ShardMsg::RegisterAck), "expected ack, got {:?}", ack.kind());
        fp
    }

    #[test]
    fn shard_step_partials_merge_to_the_whole_image_sums() {
        let spec = tiny_spec();
        let img = SyntheticOrtho::default().with_seed(7).generate(H, W);
        let cen = vec![0.2f32, 0.3, 0.4, 0.7, 0.6, 0.5];
        let (mut ends, shard) = spawn_loopback_shard(1, None);
        let leader = &mut *ends[0];
        let fp = register(leader, 5, &spec);
        // 16x12 in side-8 squares -> 2x2 grid of 4 blocks.
        let mut merged = math::StepAccum::zeros(K, C);
        for block in 0..4u64 {
            let msg = ShardMsg::Block {
                job: 5,
                block,
                round: 1,
                phase: BlockPhase::Step,
                k: K as u32,
                channels: C as u32,
                centroids: cen.clone(),
                drift: None,
            };
            leader.send(&msg.to_frame(fp)).unwrap();
            match ShardMsg::decode(&leader.recv().unwrap()).unwrap() {
                ShardMsg::StepResult { block: b, round, counts, sums, inertia, .. } => {
                    assert_eq!(b, block);
                    assert_eq!(round, 1);
                    merged.merge(&math::StepAccum {
                        k: K,
                        channels: C,
                        sums,
                        counts,
                        inertia,
                    });
                }
                other => panic!("expected step result, got {:?}", other.kind()),
            }
        }
        leader.send(&ShardMsg::Shutdown.to_frame(fp)).unwrap();
        drop(ends);
        drop(shard);
        let want = math::step(img.as_pixels(), &cen, K, C);
        assert_eq!(merged.counts, want.counts);
        for (got, expect) in merged.sums.iter().zip(want.sums.iter()) {
            assert_eq!(got.to_bits(), expect.to_bits(), "sums must merge bit-exactly");
        }
        assert_eq!(merged.inertia.to_bits(), want.inertia.to_bits());
    }

    #[test]
    fn register_with_stale_fingerprint_is_refused() {
        let host = ShardHost::new();
        let (mut leader, mut shard_end) = loopback_pair();
        let handle = std::thread::spawn(move || host.serve_connection(&mut shard_end, None));
        let spec = tiny_spec();
        let want = spec.fingerprint();
        leader.send(&ShardMsg::Register { job: 9, spec }.to_frame(0xDEAD)).unwrap();
        drop(leader);
        let err = handle.join().unwrap().unwrap_err();
        match err {
            WireError::Fingerprint { got, want: w } => {
                assert_eq!(got, 0xDEAD);
                assert_eq!(w, want);
            }
            other => panic!("expected fingerprint refusal, got {other}"),
        }
    }

    #[test]
    fn kill_switch_drops_the_connection_without_a_reply() {
        let spec = tiny_spec();
        let cen = vec![0.2f32, 0.3, 0.4, 0.7, 0.6, 0.5];
        let (mut ends, shard) = spawn_loopback_shard(1, Some(1));
        let leader = &mut *ends[0];
        let fp = register(leader, 1, &spec);
        let block = |b: u64| ShardMsg::Block {
            job: 1,
            block: b,
            round: 1,
            phase: BlockPhase::Step,
            k: K as u32,
            channels: C as u32,
            centroids: cen.clone(),
            drift: None,
        };
        leader.send(&block(0).to_frame(fp)).unwrap();
        let first = ShardMsg::decode(&leader.recv().unwrap()).unwrap();
        assert!(matches!(first, ShardMsg::StepResult { .. }));
        // Second block trips the kill switch: the shard vanishes.
        leader.send(&block(1).to_frame(fp)).unwrap();
        assert!(matches!(leader.recv(), Err(WireError::Closed)));
        drop(ends);
        drop(shard);
    }
}
