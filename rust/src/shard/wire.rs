//! Fixed little-endian wire protocol for leader ↔ shard-worker traffic.
//!
//! Every frame is a 20-byte header followed by a kind-specific payload:
//!
//! ```text
//! offset  size  field
//!      0     4  magic        b"BMSH"
//!      4     1  version      WIRE_VERSION (1)
//!      5     1  kind         FrameKind discriminant
//!      6     2  reserved     0
//!      8     8  fingerprint  run_fingerprint() of the job's config (LE)
//!     16     4  payload_len  bytes following the header (LE)
//! ```
//!
//! All multi-byte integers and floats are little-endian, independent of
//! the host: a frame written on any machine parses identically on any
//! other. The header's `fingerprint` binds every frame to the exact run
//! configuration (geometry + clustering config + mode, see
//! [`crate::coordinator::run_fingerprint`]); a shard that receives a
//! frame whose version or fingerprint does not match what it registered
//! fails loudly ([`WireError::Version`] / [`WireError::Fingerprint`],
//! both values named) instead of silently computing on stale geometry.
//!
//! Payload layouts are documented per-variant on [`ShardMsg`] and in
//! EXPERIMENTS.md §Distributed; `python/check_distributed_schema.py`
//! recomputes the closed-form byte counts from the same tables.

use std::fmt;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};

use super::spec::ShardSpec;

/// First four bytes of every frame.
pub const WIRE_MAGIC: [u8; 4] = *b"BMSH";
/// Protocol version carried in byte 4 of every frame header.
pub const WIRE_VERSION: u8 = 1;
/// Fixed frame header size in bytes.
pub const HEADER_LEN: usize = 20;

// Process-wide transport byte counters. Every transport implementation
// bumps these so the distributed bench can report exact wire traffic
// without threading counter handles through the pool.
static WIRE_SENT: AtomicU64 = AtomicU64::new(0);
static WIRE_RECEIVED: AtomicU64 = AtomicU64::new(0);

/// Total (sent, received) wire bytes moved by every transport in this
/// process since start. Loopback traffic counts each frame once on each
/// side, so for an in-process leader+shard pair sent == received.
pub fn wire_stats() -> (u64, u64) {
    (WIRE_SENT.load(Ordering::Relaxed), WIRE_RECEIVED.load(Ordering::Relaxed))
}

pub(crate) fn note_sent(n: u64) {
    WIRE_SENT.fetch_add(n, Ordering::Relaxed);
}

pub(crate) fn note_received(n: u64) {
    WIRE_RECEIVED.fetch_add(n, Ordering::Relaxed);
}

/// Frame kind discriminants (header byte 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    Register = 1,
    RegisterAck = 2,
    Block = 3,
    StepResult = 4,
    AssignResult = 5,
    LocalResult = 6,
    ErrorResult = 7,
    Ping = 8,
    Pong = 9,
    Retire = 10,
    Shutdown = 11,
}

impl FrameKind {
    fn from_u8(v: u8) -> Option<FrameKind> {
        Some(match v {
            1 => FrameKind::Register,
            2 => FrameKind::RegisterAck,
            3 => FrameKind::Block,
            4 => FrameKind::StepResult,
            5 => FrameKind::AssignResult,
            6 => FrameKind::LocalResult,
            7 => FrameKind::ErrorResult,
            8 => FrameKind::Ping,
            9 => FrameKind::Pong,
            10 => FrameKind::Retire,
            11 => FrameKind::Shutdown,
            _ => return None,
        })
    }
}

/// Errors produced by the wire codec and transports.
#[derive(Debug)]
pub enum WireError {
    /// Underlying socket/pipe error.
    Io(std::io::Error),
    /// Peer closed the connection (clean close between frames, or a
    /// loopback channel whose other end dropped).
    Closed,
    /// First four bytes were not `BMSH`.
    BadMagic([u8; 4]),
    /// Peer speaks a different protocol version. Fatal: a shard exits 2.
    Version { got: u8, want: u8 },
    /// Frame fingerprint does not match the shard's registered run
    /// config. Fatal: a shard exits 2 instead of computing on stale
    /// geometry.
    Fingerprint { got: u64, want: u64 },
    /// Payload ended before a field could be decoded.
    Truncated { need: usize, have: usize },
    /// Unknown frame kind byte.
    BadKind(u8),
    /// Structurally valid frame that violates the request/response
    /// protocol (e.g. a result frame arriving at a shard).
    Mismatch(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
            WireError::Closed => write!(f, "peer closed the shard connection"),
            WireError::BadMagic(m) => {
                write!(f, "bad frame magic {m:02x?} (want {WIRE_MAGIC:02x?})")
            }
            WireError::Version { got, want } => write!(
                f,
                "shard wire protocol version mismatch: peer speaks v{got}, this build speaks v{want}"
            ),
            WireError::Fingerprint { got, want } => write!(
                f,
                "shard config fingerprint mismatch: frame carries {got:#018x}, \
                 shard registered {want:#018x} — refusing to compute on stale geometry"
            ),
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame payload: need {need} bytes, have {have}")
            }
            WireError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::Mismatch(msg) => write!(f, "shard protocol violation: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// A parsed frame: header fields plus raw payload bytes.
#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: FrameKind,
    pub fingerprint: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Total bytes this frame occupies on the wire.
    pub fn wire_len(&self) -> usize {
        HEADER_LEN + self.payload.len()
    }

    /// Serialize header + payload into one buffer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(self.wire_len());
        buf.extend_from_slice(&WIRE_MAGIC);
        buf.push(WIRE_VERSION);
        buf.push(self.kind as u8);
        buf.extend_from_slice(&0u16.to_le_bytes());
        buf.extend_from_slice(&self.fingerprint.to_le_bytes());
        buf.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&self.payload);
        buf
    }

    /// Parse one frame from a byte slice (loopback path). The slice must
    /// hold exactly one frame.
    pub fn from_bytes(buf: &[u8]) -> Result<Frame, WireError> {
        let mut cursor = buf;
        let frame = read_frame(&mut cursor)?;
        if !cursor.is_empty() {
            return Err(WireError::Mismatch(format!(
                "{} trailing bytes after frame payload",
                cursor.len()
            )));
        }
        Ok(frame)
    }
}

/// Write one frame to a stream and flush it.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<(), WireError> {
    w.write_all(&frame.to_bytes())?;
    w.flush()?;
    Ok(())
}

/// Read one frame from a stream. EOF at a frame boundary maps to
/// [`WireError::Closed`]; magic and version are validated here so every
/// receive path rejects foreign or stale-version peers.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut header = [0u8; HEADER_LEN];
    if let Err(e) = r.read_exact(&mut header) {
        return Err(if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Closed
        } else {
            WireError::Io(e)
        });
    }
    let magic: [u8; 4] = header[0..4].try_into().unwrap();
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = header[4];
    if version != WIRE_VERSION {
        return Err(WireError::Version { got: version, want: WIRE_VERSION });
    }
    let kind = FrameKind::from_u8(header[5]).ok_or(WireError::BadKind(header[5]))?;
    let fingerprint = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let payload_len = u32::from_le_bytes(header[16..20].try_into().unwrap()) as usize;
    let mut payload = vec![0u8; payload_len];
    r.read_exact(&mut payload).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Truncated { need: payload_len, have: 0 }
        } else {
            WireError::Io(e)
        }
    })?;
    Ok(Frame { kind, fingerprint, payload })
}

/// Little-endian payload builder.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.buf.reserve(vs.len() * 4);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_f64s(&mut self, vs: &[f64]) {
        self.buf.reserve(vs.len() * 8);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_u32s(&mut self, vs: &[u32]) {
        self.buf.reserve(vs.len() * 4);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_u64s(&mut self, vs: &[u64]) {
        self.buf.reserve(vs.len() * 8);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian payload cursor; every read is bounds-checked and maps
/// overruns to [`WireError::Truncated`].
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::Truncated { need: n, have: self.buf.len() - self.pos });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn get_f64s(&mut self, n: usize) -> Result<Vec<f64>, WireError> {
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn get_u32s(&mut self, n: usize) -> Result<Vec<u32>, WireError> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn get_u64s(&mut self, n: usize) -> Result<Vec<u64>, WireError> {
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn get_str(&mut self) -> Result<String, WireError> {
        let n = self.get_u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| WireError::Mismatch("non-utf8 string field".into()))
    }
}

/// Which kernel pass a [`ShardMsg::Block`] frame requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockPhase {
    /// Step pass: accumulate per-cluster sums/counts/inertia.
    Step = 0,
    /// Assign pass: final labels + inertia.
    Assign = 1,
    /// Local per-block clustering (labels + block centroids + counts).
    Local = 2,
}

impl BlockPhase {
    fn from_u8(v: u8) -> Result<BlockPhase, WireError> {
        match v {
            0 => Ok(BlockPhase::Step),
            1 => Ok(BlockPhase::Assign),
            2 => Ok(BlockPhase::Local),
            other => Err(WireError::Mismatch(format!("unknown block phase {other}"))),
        }
    }
}

/// Centroid drift rider for pruned/lanes/simd kernels: per-centroid
/// movement plus the round max, both f64 (exactly what
/// `CentroidDrift` holds — shipping f64 preserves bit-identity of the
/// Hamerly bound updates).
#[derive(Clone, Debug, PartialEq)]
pub struct WireDrift {
    pub per_centroid: Vec<f64>,
    pub max: f64,
}

/// Typed view of every frame the protocol exchanges.
///
/// Payload layouts (after the 20-byte header; all little-endian):
///
/// | kind          | payload                                                                 |
/// |---------------|-------------------------------------------------------------------------|
/// | `Register`    | job u64, then [`ShardSpec`] (see `spec.rs` for the field table)         |
/// | `RegisterAck` | empty                                                                   |
/// | `Block`       | job u64, block u64, round u64, phase u8, has_drift u8, k u32, c u32, centroids k·c×f32, drift? (k×f64 + max f64) |
/// | `StepResult`  | job u64, block u64, round u64, k u32, c u32, counts k×u64, sums k·c×f64, inertia f64, io_secs f64, compute_secs f64, pixels u64 |
/// | `AssignResult`| job u64, block u64, round u64, inertia f64, io_secs f64, compute_secs f64, pixels u64, n u64, labels n×u32 |
/// | `LocalResult` | job u64, block u64, round u64, k u32, c u32, n u64, labels n×u32, centroids k·c×f32, counts k×u64, inertia f64, io_secs f64, compute_secs f64, pixels u64 |
/// | `ErrorResult` | job u64, block u64, round u64, message (u32 len + utf8)                 |
/// | `Ping`/`Pong` | job u64                                                                 |
/// | `Retire`      | job u64, has_purge u8, purge_content u64                                |
/// | `Shutdown`    | empty                                                                   |
#[derive(Clone, Debug)]
pub enum ShardMsg {
    Register {
        job: u64,
        spec: ShardSpec,
    },
    RegisterAck,
    Block {
        job: u64,
        block: u64,
        round: u64,
        phase: BlockPhase,
        k: u32,
        channels: u32,
        centroids: Vec<f32>,
        drift: Option<WireDrift>,
    },
    StepResult {
        job: u64,
        block: u64,
        round: u64,
        k: u32,
        channels: u32,
        counts: Vec<u64>,
        sums: Vec<f64>,
        inertia: f64,
        io_secs: f64,
        compute_secs: f64,
        pixels: u64,
    },
    AssignResult {
        job: u64,
        block: u64,
        round: u64,
        inertia: f64,
        io_secs: f64,
        compute_secs: f64,
        pixels: u64,
        labels: Vec<u32>,
    },
    LocalResult {
        job: u64,
        block: u64,
        round: u64,
        k: u32,
        channels: u32,
        labels: Vec<u32>,
        centroids: Vec<f32>,
        counts: Vec<u64>,
        inertia: f64,
        io_secs: f64,
        compute_secs: f64,
        pixels: u64,
    },
    ErrorResult {
        job: u64,
        block: u64,
        round: u64,
        message: String,
    },
    Ping {
        job: u64,
    },
    Pong {
        job: u64,
    },
    Retire {
        job: u64,
        purge_content: Option<u64>,
    },
    Shutdown,
}

impl ShardMsg {
    pub fn kind(&self) -> FrameKind {
        match self {
            ShardMsg::Register { .. } => FrameKind::Register,
            ShardMsg::RegisterAck => FrameKind::RegisterAck,
            ShardMsg::Block { .. } => FrameKind::Block,
            ShardMsg::StepResult { .. } => FrameKind::StepResult,
            ShardMsg::AssignResult { .. } => FrameKind::AssignResult,
            ShardMsg::LocalResult { .. } => FrameKind::LocalResult,
            ShardMsg::ErrorResult { .. } => FrameKind::ErrorResult,
            ShardMsg::Ping { .. } => FrameKind::Ping,
            ShardMsg::Pong { .. } => FrameKind::Pong,
            ShardMsg::Retire { .. } => FrameKind::Retire,
            ShardMsg::Shutdown => FrameKind::Shutdown,
        }
    }

    /// Encode into a full frame carrying `fingerprint` in the header.
    pub fn to_frame(&self, fingerprint: u64) -> Frame {
        Frame { kind: self.kind(), fingerprint, payload: self.encode_payload() }
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match self {
            ShardMsg::Register { job, spec } => {
                w.put_u64(*job);
                spec.encode_into(&mut w);
            }
            ShardMsg::RegisterAck | ShardMsg::Shutdown => {}
            ShardMsg::Block { job, block, round, phase, k, channels, centroids, drift } => {
                w.put_u64(*job);
                w.put_u64(*block);
                w.put_u64(*round);
                w.put_u8(*phase as u8);
                w.put_u8(drift.is_some() as u8);
                w.put_u32(*k);
                w.put_u32(*channels);
                w.put_f32s(centroids);
                if let Some(d) = drift {
                    w.put_f64s(&d.per_centroid);
                    w.put_f64(d.max);
                }
            }
            ShardMsg::StepResult {
                job,
                block,
                round,
                k,
                channels,
                counts,
                sums,
                inertia,
                io_secs,
                compute_secs,
                pixels,
            } => {
                w.put_u64(*job);
                w.put_u64(*block);
                w.put_u64(*round);
                w.put_u32(*k);
                w.put_u32(*channels);
                w.put_u64s(counts);
                w.put_f64s(sums);
                w.put_f64(*inertia);
                w.put_f64(*io_secs);
                w.put_f64(*compute_secs);
                w.put_u64(*pixels);
            }
            ShardMsg::AssignResult {
                job,
                block,
                round,
                inertia,
                io_secs,
                compute_secs,
                pixels,
                labels,
            } => {
                w.put_u64(*job);
                w.put_u64(*block);
                w.put_u64(*round);
                w.put_f64(*inertia);
                w.put_f64(*io_secs);
                w.put_f64(*compute_secs);
                w.put_u64(*pixels);
                w.put_u64(labels.len() as u64);
                w.put_u32s(labels);
            }
            ShardMsg::LocalResult {
                job,
                block,
                round,
                k,
                channels,
                labels,
                centroids,
                counts,
                inertia,
                io_secs,
                compute_secs,
                pixels,
            } => {
                w.put_u64(*job);
                w.put_u64(*block);
                w.put_u64(*round);
                w.put_u32(*k);
                w.put_u32(*channels);
                w.put_u64(labels.len() as u64);
                w.put_u32s(labels);
                w.put_f32s(centroids);
                w.put_u64s(counts);
                w.put_f64(*inertia);
                w.put_f64(*io_secs);
                w.put_f64(*compute_secs);
                w.put_u64(*pixels);
            }
            ShardMsg::ErrorResult { job, block, round, message } => {
                w.put_u64(*job);
                w.put_u64(*block);
                w.put_u64(*round);
                w.put_str(message);
            }
            ShardMsg::Ping { job } | ShardMsg::Pong { job } => {
                w.put_u64(*job);
            }
            ShardMsg::Retire { job, purge_content } => {
                w.put_u64(*job);
                w.put_u8(purge_content.is_some() as u8);
                w.put_u64(purge_content.unwrap_or(0));
            }
        }
        w.finish()
    }

    /// Decode a frame's payload according to its kind.
    pub fn decode(frame: &Frame) -> Result<ShardMsg, WireError> {
        let mut r = ByteReader::new(&frame.payload);
        let msg = match frame.kind {
            FrameKind::Register => {
                let job = r.get_u64()?;
                let spec = ShardSpec::decode_from(&mut r)?;
                ShardMsg::Register { job, spec }
            }
            FrameKind::RegisterAck => ShardMsg::RegisterAck,
            FrameKind::Block => {
                let job = r.get_u64()?;
                let block = r.get_u64()?;
                let round = r.get_u64()?;
                let phase = BlockPhase::from_u8(r.get_u8()?)?;
                let has_drift = r.get_u8()? != 0;
                let k = r.get_u32()?;
                let channels = r.get_u32()?;
                let centroids = r.get_f32s(k as usize * channels as usize)?;
                let drift = if has_drift {
                    let per_centroid = r.get_f64s(k as usize)?;
                    let max = r.get_f64()?;
                    Some(WireDrift { per_centroid, max })
                } else {
                    None
                };
                ShardMsg::Block { job, block, round, phase, k, channels, centroids, drift }
            }
            FrameKind::StepResult => {
                let job = r.get_u64()?;
                let block = r.get_u64()?;
                let round = r.get_u64()?;
                let k = r.get_u32()?;
                let channels = r.get_u32()?;
                let counts = r.get_u64s(k as usize)?;
                let sums = r.get_f64s(k as usize * channels as usize)?;
                let inertia = r.get_f64()?;
                let io_secs = r.get_f64()?;
                let compute_secs = r.get_f64()?;
                let pixels = r.get_u64()?;
                ShardMsg::StepResult {
                    job,
                    block,
                    round,
                    k,
                    channels,
                    counts,
                    sums,
                    inertia,
                    io_secs,
                    compute_secs,
                    pixels,
                }
            }
            FrameKind::AssignResult => {
                let job = r.get_u64()?;
                let block = r.get_u64()?;
                let round = r.get_u64()?;
                let inertia = r.get_f64()?;
                let io_secs = r.get_f64()?;
                let compute_secs = r.get_f64()?;
                let pixels = r.get_u64()?;
                let n = r.get_u64()? as usize;
                let labels = r.get_u32s(n)?;
                ShardMsg::AssignResult {
                    job,
                    block,
                    round,
                    inertia,
                    io_secs,
                    compute_secs,
                    pixels,
                    labels,
                }
            }
            FrameKind::LocalResult => {
                let job = r.get_u64()?;
                let block = r.get_u64()?;
                let round = r.get_u64()?;
                let k = r.get_u32()?;
                let channels = r.get_u32()?;
                let n = r.get_u64()? as usize;
                let labels = r.get_u32s(n)?;
                let centroids = r.get_f32s(k as usize * channels as usize)?;
                let counts = r.get_u64s(k as usize)?;
                let inertia = r.get_f64()?;
                let io_secs = r.get_f64()?;
                let compute_secs = r.get_f64()?;
                let pixels = r.get_u64()?;
                ShardMsg::LocalResult {
                    job,
                    block,
                    round,
                    k,
                    channels,
                    labels,
                    centroids,
                    counts,
                    inertia,
                    io_secs,
                    compute_secs,
                    pixels,
                }
            }
            FrameKind::ErrorResult => {
                let job = r.get_u64()?;
                let block = r.get_u64()?;
                let round = r.get_u64()?;
                let message = r.get_str()?;
                ShardMsg::ErrorResult { job, block, round, message }
            }
            FrameKind::Ping => ShardMsg::Ping { job: r.get_u64()? },
            FrameKind::Pong => ShardMsg::Pong { job: r.get_u64()? },
            FrameKind::Retire => {
                let job = r.get_u64()?;
                let has_purge = r.get_u8()? != 0;
                let purge = r.get_u64()?;
                ShardMsg::Retire { job, purge_content: has_purge.then_some(purge) }
            }
            FrameKind::Shutdown => ShardMsg::Shutdown,
        };
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let msg = ShardMsg::Ping { job: 7 };
        let frame = msg.to_frame(0xDEAD_BEEF_CAFE_F00D);
        let bytes = frame.to_bytes();
        assert_eq!(bytes.len(), HEADER_LEN + 8);
        assert_eq!(&bytes[0..4], b"BMSH");
        assert_eq!(bytes[4], WIRE_VERSION);
        let back = Frame::from_bytes(&bytes).unwrap();
        assert_eq!(back.kind, FrameKind::Ping);
        assert_eq!(back.fingerprint, 0xDEAD_BEEF_CAFE_F00D);
        match ShardMsg::decode(&back).unwrap() {
            ShardMsg::Ping { job } => assert_eq!(job, 7),
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn version_mismatch_names_both_versions() {
        let mut bytes = ShardMsg::Shutdown.to_frame(0).to_bytes();
        bytes[4] = 9;
        let err = Frame::from_bytes(&bytes).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("v9") && msg.contains(&format!("v{WIRE_VERSION}")), "{msg}");
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = ShardMsg::Shutdown.to_frame(0).to_bytes();
        bytes[0] = b'X';
        assert!(matches!(Frame::from_bytes(&bytes), Err(WireError::BadMagic(_))));
    }

    #[test]
    fn block_frame_roundtrip_with_drift() {
        let msg = ShardMsg::Block {
            job: 3,
            block: 11,
            round: 4,
            phase: BlockPhase::Step,
            k: 2,
            channels: 3,
            centroids: vec![0.5, 1.0, -2.25, 8.0, 0.125, 3.5],
            drift: Some(WireDrift { per_centroid: vec![0.25, 0.0625], max: 0.25 }),
        };
        let frame = msg.to_frame(42);
        // job+block+round (24) + phase+has_drift (2) + k+c (8) + 6 f32 (24)
        // + 2 f64 + max (24) — the closed form the python checker uses.
        assert_eq!(frame.payload.len(), 24 + 2 + 8 + 6 * 4 + 2 * 8 + 8);
        match ShardMsg::decode(&Frame::from_bytes(&frame.to_bytes()).unwrap()).unwrap() {
            ShardMsg::Block { block, phase, centroids, drift, .. } => {
                assert_eq!(block, 11);
                assert_eq!(phase, BlockPhase::Step);
                assert_eq!(centroids[2].to_bits(), (-2.25f32).to_bits());
                assert_eq!(drift.unwrap().max, 0.25);
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }

    #[test]
    fn step_result_payload_len_matches_closed_form() {
        let (k, c) = (4usize, 3usize);
        let msg = ShardMsg::StepResult {
            job: 0,
            block: 1,
            round: 2,
            k: k as u32,
            channels: c as u32,
            counts: vec![0; k],
            sums: vec![0.0; k * c],
            inertia: 0.0,
            io_secs: 0.0,
            compute_secs: 0.0,
            pixels: 0,
        };
        // 24 + 8 + 8k + 8kc + 32.
        assert_eq!(msg.to_frame(0).payload.len(), 64 + 8 * k + 8 * k * c);
    }

    #[test]
    fn truncated_payload_detected() {
        let frame = ShardMsg::Ping { job: 1 }.to_frame(0);
        let truncated = Frame { kind: frame.kind, fingerprint: 0, payload: vec![0u8; 4] };
        assert!(matches!(ShardMsg::decode(&truncated), Err(WireError::Truncated { .. })));
    }
}
