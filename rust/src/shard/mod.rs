//! Multi-process shard execution: the leader/shard-worker split.
//!
//! The paper's block decomposition is exactly the unit that shards
//! across OS processes: the leader runs the unchanged `RunMachine`
//! round protocol, but its worker pool is a set of [`proxy`] threads
//! that forward each block over a [`transport`] to shard processes
//! hosting the real kernels ([`host`]). Per-block partial sums come
//! back as fixed little-endian [`wire`] frames and merge through the
//! same deterministic block-ordered reduction as solo — labels,
//! centroids, counts, and inertia are **bit-identical** to a
//! single-process run (see `EXPERIMENTS.md` §Distributed for the
//! argument, and `tests/shard_equivalence.rs` for the proof matrix).
//!
//! Module map:
//! - [`wire`] — versioned, fingerprinted frame codec + closed-form
//!   payload layouts;
//! - [`spec`] — the self-contained job description a shard
//!   materializes (config + knobs + pixels);
//! - [`transport`] — `ShardTransport` trait: UDS/TCP streams plus the
//!   in-process loopback the tests and benches use;
//! - [`host`] — shard-side connection handlers around a single-worker
//!   pool (`blockms shard-worker` hosts one);
//! - [`proxy`] — leader-side worker threads that forward instead of
//!   compute.

pub mod host;
pub mod proxy;
pub mod spec;
pub mod transport;
pub mod wire;

use anyhow::{bail, Context, Result};

pub use host::{run_listener, spawn_loopback_shard, LoopbackShard, ShardHost};
pub use proxy::ShardSpecMap;
pub use spec::ShardSpec;
pub use transport::{connect, loopback_pair, LoopbackTransport, ShardTransport};
pub use wire::{wire_stats, ShardMsg, WireError, WIRE_VERSION};

use crate::coordinator::WorkerPool;

/// Where a sharded run's compute lives.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardEndpoints {
    /// In-process shard threads over loopback transports (tests,
    /// benches, and `--shards N` without addresses).
    Loopback { shards: usize },
    /// One `blockms shard-worker` process per address (UDS path or
    /// `host:port`).
    Remote { addrs: Vec<String> },
}

impl ShardEndpoints {
    /// Parse the `--shards N[:addr,...]` argument: a bare count means
    /// in-process loopback shards; with addresses, the count must match
    /// the address list.
    pub fn parse(arg: &str) -> Result<ShardEndpoints> {
        let (count, rest) = match arg.split_once(':') {
            Some((n, rest)) => (n, Some(rest)),
            None => (arg, None),
        };
        let shards: usize = count
            .parse()
            .ok()
            .filter(|&n| n > 0)
            .with_context(|| format!("--shards wants a positive count, got {arg:?}"))?;
        match rest {
            None => Ok(ShardEndpoints::Loopback { shards }),
            Some(list) => {
                let addrs: Vec<String> = list
                    .split(',')
                    .map(str::trim)
                    .filter(|a| !a.is_empty())
                    .map(String::from)
                    .collect();
                if addrs.len() != shards {
                    bail!(
                        "--shards {shards} names {} address(es); want exactly {shards}",
                        addrs.len()
                    );
                }
                Ok(ShardEndpoints::Remote { addrs })
            }
        }
    }

    pub fn shards(&self) -> usize {
        match self {
            ShardEndpoints::Loopback { shards } => *shards,
            ShardEndpoints::Remote { addrs } => addrs.len(),
        }
    }
}

/// Build a sharded worker pool: `conns_per_shard` connections to each
/// shard (so blocks pipeline per shard exactly like `--workers` local
/// threads), one proxy thread per connection. Returns the loopback
/// shard guards — drop them **after** `pool.shutdown()`.
pub fn spawn_shard_pool(
    endpoints: &ShardEndpoints,
    conns_per_shard: usize,
) -> Result<(WorkerPool, Vec<LoopbackShard>)> {
    assert!(conns_per_shard > 0, "need at least one connection per shard");
    let mut transports: Vec<Box<dyn ShardTransport + Send>> = Vec::new();
    let mut guards = Vec::new();
    match endpoints {
        ShardEndpoints::Loopback { shards } => {
            assert!(*shards > 0, "need at least one shard");
            for _ in 0..*shards {
                let (ends, guard) = spawn_loopback_shard(conns_per_shard, None);
                transports.extend(ends);
                guards.push(guard);
            }
        }
        ShardEndpoints::Remote { addrs } => {
            for addr in addrs {
                for _ in 0..conns_per_shard {
                    transports.push(connect(addr)?);
                }
            }
        }
    }
    Ok((WorkerPool::spawn_sharded(transports), guards))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_arg_parses_loopback_and_remote() {
        assert_eq!(ShardEndpoints::parse("3").unwrap(), ShardEndpoints::Loopback { shards: 3 });
        assert_eq!(
            ShardEndpoints::parse("2:/tmp/a.sock,127.0.0.1:9001").unwrap(),
            ShardEndpoints::Remote {
                addrs: vec!["/tmp/a.sock".into(), "127.0.0.1:9001".into()]
            }
        );
        assert!(ShardEndpoints::parse("0").is_err());
        assert!(ShardEndpoints::parse("x").is_err());
        assert!(ShardEndpoints::parse("2:/tmp/only-one.sock").is_err());
    }
}
