//! Transports that move [`Frame`]s between leader and shard.
//!
//! Three implementations of one trait:
//!
//! - [`StreamTransport`] over any `Read + Write` stream — the real
//!   deployment paths, Unix-domain sockets and TCP ([`connect`] picks
//!   by address shape: a `/` means a socket path, otherwise host:port).
//! - [`LoopbackTransport`] over in-process channels — what the
//!   equivalence tests and the `blockms distributed` bench use, so the
//!   full protocol (framing, registration, fingerprint checks, byte
//!   accounting) is exercised without sockets.
//!
//! Every implementation counts bytes both per-instance and into the
//! process-wide [`super::wire::wire_stats`] totals the bench reports.

use std::io::{Read, Write};
use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{Context, Result};

use super::wire::{note_received, note_sent, read_frame, write_frame, Frame, WireError};

/// A bidirectional, frame-oriented link to one peer. Exactly one
/// request is in flight per connection (strict request/response), so
/// implementations need no internal demultiplexing.
pub trait ShardTransport: Send {
    fn send(&mut self, frame: &Frame) -> Result<(), WireError>;
    fn recv(&mut self) -> Result<Frame, WireError>;
    /// Bytes this instance has written to the wire.
    fn bytes_sent(&self) -> u64;
    /// Bytes this instance has read off the wire.
    fn bytes_received(&self) -> u64;
}

/// Frame transport over any byte stream (UnixStream, TcpStream).
pub struct StreamTransport<S> {
    stream: S,
    sent: u64,
    received: u64,
}

impl<S: Read + Write + Send> StreamTransport<S> {
    pub fn new(stream: S) -> StreamTransport<S> {
        StreamTransport { stream, sent: 0, received: 0 }
    }
}

impl<S: Read + Write + Send> ShardTransport for StreamTransport<S> {
    fn send(&mut self, frame: &Frame) -> Result<(), WireError> {
        write_frame(&mut self.stream, frame)?;
        let n = frame.wire_len() as u64;
        self.sent += n;
        note_sent(n);
        Ok(())
    }

    fn recv(&mut self) -> Result<Frame, WireError> {
        let frame = read_frame(&mut self.stream)?;
        let n = frame.wire_len() as u64;
        self.received += n;
        note_received(n);
        Ok(frame)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

/// Open a leader-side connection to a shard worker. Addresses with a
/// `/` are Unix-domain socket paths; anything else is `host:port` TCP.
pub fn connect(addr: &str) -> Result<Box<dyn ShardTransport + Send>> {
    if addr.contains('/') {
        #[cfg(unix)]
        {
            let stream = std::os::unix::net::UnixStream::connect(addr)
                .with_context(|| format!("connect shard socket {addr}"))?;
            return Ok(Box::new(StreamTransport::new(stream)));
        }
        #[cfg(not(unix))]
        anyhow::bail!("unix-domain shard sockets are not supported on this platform: {addr}");
    }
    let stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connect shard address {addr}"))?;
    stream.set_nodelay(true).ok();
    Ok(Box::new(StreamTransport::new(stream)))
}

/// In-process transport: whole frames over unbounded channels. Dropping
/// either end surfaces as [`WireError::Closed`] on the other — which is
/// exactly how the kill-one-shard tests simulate shard death.
pub struct LoopbackTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    sent: u64,
    received: u64,
}

/// A connected pair of loopback ends (leader end, shard end).
pub fn loopback_pair() -> (LoopbackTransport, LoopbackTransport) {
    let (atx, brx) = channel();
    let (btx, arx) = channel();
    (
        LoopbackTransport { tx: atx, rx: arx, sent: 0, received: 0 },
        LoopbackTransport { tx: btx, rx: brx, sent: 0, received: 0 },
    )
}

impl ShardTransport for LoopbackTransport {
    fn send(&mut self, frame: &Frame) -> Result<(), WireError> {
        let bytes = frame.to_bytes();
        let n = bytes.len() as u64;
        self.tx.send(bytes).map_err(|_| WireError::Closed)?;
        self.sent += n;
        note_sent(n);
        Ok(())
    }

    fn recv(&mut self) -> Result<Frame, WireError> {
        let bytes = self.rx.recv().map_err(|_| WireError::Closed)?;
        let n = bytes.len() as u64;
        self.received += n;
        note_received(n);
        Frame::from_bytes(&bytes)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::wire::ShardMsg;

    #[test]
    fn loopback_roundtrip_counts_bytes() {
        let (mut leader, mut shard) = loopback_pair();
        let frame = ShardMsg::Ping { job: 1 }.to_frame(0xAB);
        leader.send(&frame).unwrap();
        let got = shard.recv().unwrap();
        assert_eq!(got.fingerprint, 0xAB);
        assert_eq!(leader.bytes_sent(), frame.wire_len() as u64);
        assert_eq!(shard.bytes_received(), frame.wire_len() as u64);
    }

    #[test]
    fn dropped_peer_reads_as_closed() {
        let (mut leader, shard) = loopback_pair();
        drop(shard);
        assert!(matches!(leader.recv(), Err(WireError::Closed)));
        let frame = ShardMsg::Shutdown.to_frame(0);
        assert!(matches!(leader.send(&frame), Err(WireError::Closed)));
    }
}
