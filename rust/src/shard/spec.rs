//! The shard workload description: everything a `blockms shard-worker`
//! needs to rebuild the leader's exact run — geometry, clustering
//! config, execution knobs, and the raw pixels themselves.
//!
//! The spec rides in every `Register` frame. Shipping pixels (rather
//! than assuming a shared filesystem) keeps the protocol self-contained
//! over plain TCP and makes every shard able to compute **any** block
//! of the job, which is what lets the leader re-queue a dead shard's
//! blocks onto survivors without data movement at failure time. The
//! shard recomputes [`run_fingerprint`] from the decoded spec and
//! refuses the registration (exit 2) if it disagrees with the frame
//! header — satellite hardening against silently computing on stale
//! geometry.
//!
//! Payload layout (little-endian, after the Register frame's job u64):
//!
//! ```text
//! height u64 · width u64 · channels u64 · k u64 · seed u64
//! tol_bits u32 · max_iters u64 · has_fixed u8 · fixed_iters u64
//! init_tag u8 (0 sample | 1 ++ | 2 fixed, then n u64 + n×f32)
//! mode u8 (0 global | 1 local)
//! shape_tag u8 (0 rows | 1 cols | 2 square | 3 custom) · a u64 · b u64
//! kernel u8 (0 naive | 1 pruned | 2 fused | 3 lanes | 4 simd)
//! layout u8 (0 interleaved | 1 soa)
//! arena_mb u64 · prefetch u8 · strip_cache u64
//! simd_level u8 (0 portable | 1 neon | 2 avx2 | 3 avx512) · fma u8
//! strip_rows u64 (0 = direct crops) · file_backed u8
//! pixel_len u64 · pixel_len×f32 interleaved samples
//! ```
//!
//! Fixed part: 118 bytes (+ 8 + 4·len for a Fixed init), so a Register
//! frame is `20 + 8 + 118 + 4·h·w·c` bytes — the closed form
//! `python/check_distributed_schema.py` recomputes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::wire::{ByteReader, ByteWriter, WireError};
use crate::blocks::{BlockPlan, BlockShape};
use crate::coordinator::{
    run_fingerprint, BlockSource, ClusterConfig, ClusterMode, Engine, IoMode, JobId, WorkerContext,
};
use crate::image::Raster;
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::simd::{SimdLevel, SimdMode};
use crate::kmeans::tile::TileLayout;
use crate::kmeans::InitMethod;
use crate::plan::ExecPlan;
use crate::stripstore::{Backing, StripStore};

/// Size of the spec payload minus the pixel block and any Fixed-init
/// centroids (see the module-level layout table).
pub const SPEC_FIXED_BYTES: usize = 118;

// Like the coordinator's solo-store sequence: two shard jobs with
// file-backed strips must never share a backing file, and the pid keeps
// cross-process TMPDIR sharing safe.
static SHARD_STORE_SEQ: AtomicU64 = AtomicU64::new(0);

fn shard_store_dir() -> std::path::PathBuf {
    let seq = SHARD_STORE_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("blockms_shard_p{}_{}", std::process::id(), seq))
}

/// Self-contained description of one sharded job (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardSpec {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub k: usize,
    pub seed: u64,
    /// `ClusterConfig::tol` as raw f32 bits — survives the wire exactly.
    pub tol_bits: u32,
    pub max_iters: usize,
    pub fixed_iters: Option<usize>,
    pub init: InitMethod,
    pub mode: ClusterMode,
    pub shape: BlockShape,
    pub kernel: KernelChoice,
    pub layout: TileLayout,
    pub arena_mb: usize,
    pub prefetch: bool,
    pub strip_cache: usize,
    pub simd: SimdMode,
    /// Strip height of the shard's I/O model (0 = direct crops from the
    /// rebuilt raster).
    pub strip_rows: usize,
    /// Back the shard's strip store with a real file (exercises the
    /// same out-of-core path as solo file backing).
    pub file_backed: bool,
    /// The job's interleaved `h·w·c` samples, shipped verbatim.
    pub pixels: Arc<Vec<f32>>,
}

impl ShardSpec {
    /// Build the spec for a run the leader is about to distribute.
    pub fn from_run(
        img: &Raster,
        ccfg: &ClusterConfig,
        mode: ClusterMode,
        io: &IoMode,
        exec: &ExecPlan,
    ) -> ShardSpec {
        let (strip_rows, file_backed) = match *io {
            IoMode::Direct => (0, false),
            IoMode::Strips { strip_rows, file_backed } => (strip_rows, file_backed),
        };
        ShardSpec {
            height: img.height(),
            width: img.width(),
            channels: img.channels(),
            k: ccfg.k,
            seed: ccfg.seed,
            tol_bits: ccfg.tol.to_bits(),
            max_iters: ccfg.max_iters,
            fixed_iters: ccfg.fixed_iters,
            init: ccfg.init.clone(),
            mode,
            shape: exec.shape,
            kernel: exec.kernel,
            layout: exec.layout,
            arena_mb: exec.arena_mb,
            prefetch: exec.prefetch,
            strip_cache: exec.strip_cache,
            simd: exec.simd,
            strip_rows,
            file_backed,
            pixels: Arc::new(img.as_pixels().to_vec()),
        }
    }

    /// The clustering config this spec round-trips — field-for-field
    /// what the leader ran with, so the fingerprint below reproduces.
    pub fn cluster_config(&self) -> ClusterConfig {
        ClusterConfig {
            k: self.k,
            max_iters: self.max_iters,
            tol: f32::from_bits(self.tol_bits),
            init: self.init.clone(),
            seed: self.seed,
            fixed_iters: self.fixed_iters,
        }
    }

    /// The run fingerprint every frame of this job must carry.
    pub fn fingerprint(&self) -> u64 {
        run_fingerprint(self.height, self.width, self.channels, &self.cluster_config(), self.mode)
    }

    /// The single-worker execution plan a shard connection runs blocks
    /// under (one pool worker per connection; shard-side parallelism is
    /// the leader opening several connections).
    pub fn exec_plan(&self) -> ExecPlan {
        ExecPlan::pinned(self.shape)
            .with_workers(1)
            .with_kernel(self.kernel)
            .with_layout(self.layout)
            .with_arena_mb(self.arena_mb)
            .with_prefetch(self.prefetch)
            .with_strip_cache(self.strip_cache)
            .with_file_backing(self.file_backed)
            .with_simd(self.simd)
    }

    /// Rebuild the worker-facing context: raster from the shipped
    /// pixels, block plan from the shipped shape, strip store per the
    /// shipped I/O mode. Identical inputs produce bit-identical
    /// per-block results on any host (see EXPERIMENTS.md §Distributed).
    pub fn materialize(&self, job: JobId) -> Result<WorkerContext> {
        let raster = Arc::new(Raster::from_vec(
            self.height,
            self.width,
            self.channels,
            self.pixels.as_ref().clone(),
        ));
        let plan = Arc::new(BlockPlan::new(self.height, self.width, self.shape));
        let source = if self.strip_rows > 0 {
            let backing = if self.file_backed {
                Backing::File(shard_store_dir())
            } else {
                Backing::Memory
            };
            let mut store = StripStore::new(&raster, self.strip_rows, backing)
                .context("shard strip store")?;
            store.enable_cache(self.strip_cache);
            BlockSource::Strips(Arc::new(store))
        } else {
            BlockSource::Direct(raster)
        };
        let backend = Engine::Native
            .backend_spec(self.k, self.channels)
            .context("shard backend spec")?;
        Ok(WorkerContext {
            plan,
            source,
            backend,
            fault: None,
            local_mode: self.mode == ClusterMode::Local,
            exec: self.exec_plan(),
            content: job,
        })
    }

    pub fn encode_into(&self, w: &mut ByteWriter) {
        w.put_u64(self.height as u64);
        w.put_u64(self.width as u64);
        w.put_u64(self.channels as u64);
        w.put_u64(self.k as u64);
        w.put_u64(self.seed);
        w.put_u32(self.tol_bits);
        w.put_u64(self.max_iters as u64);
        w.put_u8(self.fixed_iters.is_some() as u8);
        w.put_u64(self.fixed_iters.unwrap_or(0) as u64);
        match &self.init {
            InitMethod::RandomSample => w.put_u8(0),
            InitMethod::PlusPlus => w.put_u8(1),
            InitMethod::Fixed(c) => {
                w.put_u8(2);
                w.put_u64(c.len() as u64);
                w.put_f32s(c);
            }
        }
        w.put_u8(match self.mode {
            ClusterMode::Global => 0,
            ClusterMode::Local => 1,
        });
        let (tag, a, b) = match self.shape {
            BlockShape::Rows { band_rows } => (0u8, band_rows as u64, 0u64),
            BlockShape::Cols { band_cols } => (1, band_cols as u64, 0),
            BlockShape::Square { side } => (2, side as u64, 0),
            BlockShape::Custom { rows, cols } => (3, rows as u64, cols as u64),
        };
        w.put_u8(tag);
        w.put_u64(a);
        w.put_u64(b);
        w.put_u8(match self.kernel {
            KernelChoice::Naive => 0,
            KernelChoice::Pruned => 1,
            KernelChoice::Fused => 2,
            KernelChoice::Lanes => 3,
            KernelChoice::Simd => 4,
        });
        w.put_u8(match self.layout {
            TileLayout::Interleaved => 0,
            TileLayout::Soa => 1,
        });
        w.put_u64(self.arena_mb as u64);
        w.put_u8(self.prefetch as u8);
        w.put_u64(self.strip_cache as u64);
        w.put_u8(match self.simd.level {
            SimdLevel::Portable => 0,
            SimdLevel::Neon => 1,
            SimdLevel::Avx2 => 2,
            SimdLevel::Avx512 => 3,
        });
        w.put_u8(self.simd.fma as u8);
        w.put_u64(self.strip_rows as u64);
        w.put_u8(self.file_backed as u8);
        w.put_u64(self.pixels.len() as u64);
        w.put_f32s(&self.pixels);
    }

    pub fn decode_from(r: &mut ByteReader<'_>) -> Result<ShardSpec, WireError> {
        let height = r.get_u64()? as usize;
        let width = r.get_u64()? as usize;
        let channels = r.get_u64()? as usize;
        let k = r.get_u64()? as usize;
        let seed = r.get_u64()?;
        let tol_bits = r.get_u32()?;
        let max_iters = r.get_u64()? as usize;
        let has_fixed = r.get_u8()? != 0;
        let fixed = r.get_u64()? as usize;
        let init = match r.get_u8()? {
            0 => InitMethod::RandomSample,
            1 => InitMethod::PlusPlus,
            2 => {
                let n = r.get_u64()? as usize;
                InitMethod::Fixed(r.get_f32s(n)?)
            }
            other => return Err(WireError::Mismatch(format!("unknown init tag {other}"))),
        };
        let mode = match r.get_u8()? {
            0 => ClusterMode::Global,
            1 => ClusterMode::Local,
            other => return Err(WireError::Mismatch(format!("unknown mode tag {other}"))),
        };
        let shape_tag = r.get_u8()?;
        let a = r.get_u64()? as usize;
        let b = r.get_u64()? as usize;
        let shape = match shape_tag {
            0 => BlockShape::Rows { band_rows: a },
            1 => BlockShape::Cols { band_cols: a },
            2 => BlockShape::Square { side: a },
            3 => BlockShape::Custom { rows: a, cols: b },
            other => return Err(WireError::Mismatch(format!("unknown shape tag {other}"))),
        };
        let kernel = match r.get_u8()? {
            0 => KernelChoice::Naive,
            1 => KernelChoice::Pruned,
            2 => KernelChoice::Fused,
            3 => KernelChoice::Lanes,
            4 => KernelChoice::Simd,
            other => return Err(WireError::Mismatch(format!("unknown kernel tag {other}"))),
        };
        let layout = match r.get_u8()? {
            0 => TileLayout::Interleaved,
            1 => TileLayout::Soa,
            other => return Err(WireError::Mismatch(format!("unknown layout tag {other}"))),
        };
        let arena_mb = r.get_u64()? as usize;
        let prefetch = r.get_u8()? != 0;
        let strip_cache = r.get_u64()? as usize;
        let level = match r.get_u8()? {
            0 => SimdLevel::Portable,
            1 => SimdLevel::Neon,
            2 => SimdLevel::Avx2,
            3 => SimdLevel::Avx512,
            other => return Err(WireError::Mismatch(format!("unknown simd level tag {other}"))),
        };
        let fma = r.get_u8()? != 0;
        let strip_rows = r.get_u64()? as usize;
        let file_backed = r.get_u8()? != 0;
        let pixel_len = r.get_u64()? as usize;
        if pixel_len != height * width * channels {
            return Err(WireError::Mismatch(format!(
                "pixel payload {pixel_len} does not cover {height}x{width}x{channels}"
            )));
        }
        let pixels = Arc::new(r.get_f32s(pixel_len)?);
        Ok(ShardSpec {
            height,
            width,
            channels,
            k,
            seed,
            tol_bits,
            max_iters,
            fixed_iters: has_fixed.then_some(fixed),
            init,
            mode,
            shape,
            kernel,
            layout,
            arena_mb,
            prefetch,
            strip_cache,
            simd: SimdMode { level, fma },
            strip_rows,
            file_backed,
            pixels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::SyntheticOrtho;

    fn spec() -> ShardSpec {
        let img = SyntheticOrtho::default().with_seed(7).generate(24, 20);
        let ccfg = ClusterConfig {
            k: 3,
            max_iters: 5,
            tol: 0.25,
            init: InitMethod::RandomSample,
            seed: 11,
            fixed_iters: Some(4),
        };
        let io = IoMode::Strips { strip_rows: 8, file_backed: false };
        let exec = ExecPlan::pinned(BlockShape::Square { side: 8 })
            .with_kernel(KernelChoice::Lanes)
            .with_strip_cache(2);
        ShardSpec::from_run(&img, &ccfg, ClusterMode::Global, &io, &exec)
    }

    #[test]
    fn roundtrips_bit_exact() {
        let s = spec();
        let mut w = ByteWriter::new();
        s.encode_into(&mut w);
        let bytes = w.finish();
        assert_eq!(bytes.len(), SPEC_FIXED_BYTES + 4 * s.pixels.len());
        let back = ShardSpec::decode_from(&mut ByteReader::new(&bytes)).unwrap();
        assert_eq!(back, s);
        for (a, b) in s.pixels.iter().zip(back.pixels.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fingerprint_matches_leader_formula() {
        let s = spec();
        assert_eq!(
            s.fingerprint(),
            run_fingerprint(24, 20, 3, &s.cluster_config(), ClusterMode::Global)
        );
        // Any config drift must change the fingerprint.
        let mut other = s.clone();
        other.seed ^= 1;
        assert_ne!(other.fingerprint(), s.fingerprint());
    }

    #[test]
    fn fixed_init_roundtrips() {
        let mut s = spec();
        s.init = InitMethod::Fixed(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        let mut w = ByteWriter::new();
        s.encode_into(&mut w);
        let bytes = w.finish();
        assert_eq!(bytes.len(), SPEC_FIXED_BYTES + 8 + 9 * 4 + 4 * s.pixels.len());
        let back = ShardSpec::decode_from(&mut ByteReader::new(&bytes)).unwrap();
        assert_eq!(back.init, s.init);
    }

    #[test]
    fn materialize_rebuilds_the_exact_raster() {
        let s = spec();
        let ctx = s.materialize(9).unwrap();
        assert_eq!(ctx.content, 9);
        assert_eq!(ctx.plan.len(), BlockPlan::new(24, 20, s.shape).len());
        match &ctx.source {
            BlockSource::Strips(store) => {
                assert_eq!(store.height(), 24);
            }
            other => panic!("expected strip source, got {:?}", std::mem::discriminant(other)),
        }
        assert_eq!(ctx.exec.workers, 1);
        assert_eq!(ctx.exec.kernel, KernelChoice::Lanes);
    }
}
