//! Leader-side shard proxies: worker threads that forward blocks over
//! a transport instead of computing them.
//!
//! [`proxy_main`] is the shard analogue of the in-process
//! `worker_main`: it pops tagged jobs from the same [`JobQueue`],
//! brackets each block with the same [`Watchdog`] heartbeat stamps,
//! and reports [`JobOutcome`]s/[`JobError`]s on the same results
//! channel — so the leader's entire round protocol (retry budgets,
//! stall escalation, speculation, deterministic block-ordered merge)
//! works unchanged on top of remote shards.
//!
//! Registration is **eager and per-connection**: the first thing a
//! proxy does (on the warmup ping every run issues) is ship the job's
//! [`ShardSpec`] and await the ack, so by the time any timed round
//! begins every connection is registered and the bytes-per-round
//! closed form in `python/check_distributed_schema.py` is exact.
//!
//! Failure model: a shard-reported block error ([`ShardMsg::ErrorResult`])
//! fails that block and keeps the connection; a transport error fails
//! the in-flight block and **kills the proxy** — under dynamic
//! scheduling the re-queued block lands on a surviving connection,
//! which is precisely the dead-shard recovery path the kill tests
//! exercise.

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail};

use crate::coordinator::{
    BlockTiming, Job, JobError, JobId, JobOutcome, JobPayload, JobQueue, JobResult,
};
use crate::kmeans::kernel::CentroidDrift;
use crate::kmeans::math::StepAccum;
use crate::resilience::Watchdog;

use super::spec::ShardSpec;
use super::transport::ShardTransport;
use super::wire::{BlockPhase, ShardMsg, WireDrift};

/// Pool-level spec table: what each proxy ships when it first sees a
/// job on its connection. Keyed by job id, holding the precomputed
/// config fingerprint every frame for the job carries.
pub type ShardSpecMap = Mutex<HashMap<JobId, (u64, Arc<ShardSpec>)>>;

/// What this connection has registered with its shard.
#[derive(Clone, Copy)]
struct RegisteredShard {
    fingerprint: u64,
    k: u32,
    channels: u32,
}

/// Body of one leader-side proxy thread (worker slot `proxy_id` of a
/// sharded [`crate::coordinator::WorkerPool`]).
pub fn proxy_main(
    proxy_id: usize,
    queue: Arc<JobQueue>,
    results: Sender<Result<JobOutcome, JobError>>,
    watchdog: Arc<Watchdog>,
    specs: Arc<ShardSpecMap>,
    mut transport: Box<dyn ShardTransport + Send>,
) {
    let mut registered: HashMap<JobId, RegisteredShard> = HashMap::new();
    while let Some(job) = queue.pop(proxy_id) {
        let reply = match &job.payload {
            JobPayload::Retire { purge_content } => {
                // Mirrors the in-process contract: no reply message.
                if let Some(reg) = registered.remove(&job.job) {
                    let retire =
                        ShardMsg::Retire { job: job.job, purge_content: *purge_content };
                    if transport.send(&retire.to_frame(reg.fingerprint)).is_err() {
                        return; // dead transport; nothing to report for a retire
                    }
                }
                continue;
            }
            JobPayload::Ping => {
                ping_roundtrip(&mut *transport, &specs, &mut registered, proxy_id, &job)
                    .map(Ok)
            }
            JobPayload::Step { centroids, drift } => block_roundtrip(
                &mut *transport,
                &specs,
                &mut registered,
                &watchdog,
                proxy_id,
                &job,
                BlockPhase::Step,
                centroids,
                drift.as_ref(),
            ),
            JobPayload::Assign { centroids, drift } => block_roundtrip(
                &mut *transport,
                &specs,
                &mut registered,
                &watchdog,
                proxy_id,
                &job,
                BlockPhase::Assign,
                centroids,
                drift.as_ref(),
            ),
            JobPayload::Local { init } => block_roundtrip(
                &mut *transport,
                &specs,
                &mut registered,
                &watchdog,
                proxy_id,
                &job,
                BlockPhase::Local,
                init,
                None,
            ),
        };
        match reply {
            Ok(Ok(outcome)) => {
                if results.send(Ok(outcome)).is_err() {
                    return; // leader gone
                }
            }
            // The shard reported a block failure but the connection is
            // healthy: fail the block (the leader's retry budget
            // re-queues it) and keep serving.
            Ok(Err(error)) => {
                let _ = results.send(Err(JobError { job: job.job, block: job.block, error }));
            }
            // Transport-level failure: fail the in-flight block, then
            // die — retries drain onto surviving connections.
            Err(error) => {
                let _ = results.send(Err(JobError { job: job.job, block: job.block, error }));
                return;
            }
        }
    }
    // Queue closed: polite shutdown so a remote worker's handler exits
    // promptly instead of waiting for the socket to drop.
    let _ = transport.send(&ShardMsg::Shutdown.to_frame(0));
}

/// Ship the job's spec on first contact; later calls are free.
fn ensure_registered(
    transport: &mut dyn ShardTransport,
    specs: &ShardSpecMap,
    registered: &mut HashMap<JobId, RegisteredShard>,
    job: JobId,
) -> anyhow::Result<RegisteredShard> {
    if let Some(reg) = registered.get(&job) {
        return Ok(*reg);
    }
    let (fingerprint, spec) = {
        let map = specs.lock().unwrap();
        map.get(&job).cloned().ok_or_else(|| {
            anyhow!("no shard spec registered for job {job} (register_shard_spec first)")
        })?
    };
    let reg = RegisteredShard { fingerprint, k: spec.k as u32, channels: spec.channels as u32 };
    let msg = ShardMsg::Register { job, spec: (*spec).clone() };
    transport.send(&msg.to_frame(fingerprint))?;
    match ShardMsg::decode(&transport.recv()?)? {
        ShardMsg::RegisterAck => {
            registered.insert(job, reg);
            Ok(reg)
        }
        other => bail!("expected register ack, shard sent {:?}", other.kind()),
    }
}

fn ping_roundtrip(
    transport: &mut dyn ShardTransport,
    specs: &ShardSpecMap,
    registered: &mut HashMap<JobId, RegisteredShard>,
    proxy_id: usize,
    job: &Job,
) -> anyhow::Result<JobOutcome> {
    // Eager registration: the warmup barrier pays the spec-shipping
    // cost, keeping every timed round's byte count a pure function of
    // the geometry.
    let reg = ensure_registered(transport, specs, registered, job.job)?;
    transport.send(&ShardMsg::Ping { job: job.job }.to_frame(reg.fingerprint))?;
    match ShardMsg::decode(&transport.recv()?)? {
        ShardMsg::Pong { .. } => Ok(JobOutcome {
            job: job.job,
            block: job.block,
            round: job.round,
            worker: proxy_id,
            timing: BlockTiming::default(),
            result: JobResult::Pong,
        }),
        other => bail!("expected pong, shard sent {:?}", other.kind()),
    }
}

/// One strict request/response block exchange. Outer `Err` = the
/// connection is broken (caller dies); inner `Err` = the shard
/// reported a block failure (caller keeps the connection).
#[allow(clippy::too_many_arguments)]
fn block_roundtrip(
    transport: &mut dyn ShardTransport,
    specs: &ShardSpecMap,
    registered: &mut HashMap<JobId, RegisteredShard>,
    watchdog: &Watchdog,
    proxy_id: usize,
    job: &Job,
    phase: BlockPhase,
    centroids: &Arc<Vec<f32>>,
    drift: Option<&Arc<CentroidDrift>>,
) -> anyhow::Result<anyhow::Result<JobOutcome>> {
    let reg = ensure_registered(transport, specs, registered, job.job)?;
    let msg = ShardMsg::Block {
        job: job.job,
        block: job.block as u64,
        round: job.round,
        phase,
        k: reg.k,
        channels: reg.channels,
        centroids: centroids.as_ref().clone(),
        drift: drift
            .map(|d| WireDrift { per_centroid: d.per_centroid.clone(), max: d.max }),
    };
    // Heartbeat brackets the whole roundtrip: a shard that hangs (or
    // dies without closing the stream) shows up as a stalled proxy and
    // the leader's watchdog escalation re-queues the block elsewhere.
    watchdog.begin(proxy_id, job.job, job.block, job.round);
    let reply = transport.send(&msg.to_frame(reg.fingerprint)).and_then(|()| transport.recv());
    watchdog.end(proxy_id);
    msg_to_outcome(proxy_id, job, ShardMsg::decode(&reply?)?)
}

fn msg_to_outcome(
    proxy_id: usize,
    job: &Job,
    msg: ShardMsg,
) -> anyhow::Result<anyhow::Result<JobOutcome>> {
    let check = |j: u64, b: u64, r: u64| -> anyhow::Result<()> {
        if j != job.job || b != job.block as u64 || r != job.round {
            bail!(
                "shard connection out of sync: asked for job {} block {} round {}, \
                 got job {j} block {b} round {r}",
                job.job,
                job.block,
                job.round
            );
        }
        Ok(())
    };
    let outcome = |timing: BlockTiming, result: JobResult| JobOutcome {
        job: job.job,
        block: job.block,
        round: job.round,
        worker: proxy_id,
        timing,
        result,
    };
    match msg {
        ShardMsg::StepResult {
            job: j,
            block,
            round,
            k,
            channels,
            counts,
            sums,
            inertia,
            io_secs,
            compute_secs,
            pixels,
        } => {
            check(j, block, round)?;
            let accum = StepAccum {
                k: k as usize,
                channels: channels as usize,
                sums,
                counts,
                inertia,
            };
            Ok(Ok(outcome(
                BlockTiming { io_secs, compute_secs, pixels: pixels as usize },
                JobResult::Step { accum },
            )))
        }
        ShardMsg::AssignResult {
            job: j,
            block,
            round,
            inertia,
            io_secs,
            compute_secs,
            pixels,
            labels,
        } => {
            check(j, block, round)?;
            Ok(Ok(outcome(
                BlockTiming { io_secs, compute_secs, pixels: pixels as usize },
                JobResult::Assign { labels, inertia },
            )))
        }
        ShardMsg::LocalResult {
            job: j,
            block,
            round,
            labels,
            centroids,
            counts,
            inertia,
            io_secs,
            compute_secs,
            pixels,
            ..
        } => {
            check(j, block, round)?;
            Ok(Ok(outcome(
                BlockTiming { io_secs, compute_secs, pixels: pixels as usize },
                JobResult::Local { labels, centroids, inertia, counts },
            )))
        }
        ShardMsg::ErrorResult { job: j, block, round, message } => {
            check(j, block, round)?;
            Ok(Err(anyhow!("shard reported: {message}")))
        }
        other => bail!("expected a result frame, shard sent {:?}", other.kind()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::coordinator::{ClusterMode, Schedule};
    use crate::image::SyntheticOrtho;
    use crate::kmeans::kernel::KernelChoice;
    use crate::kmeans::math;
    use crate::kmeans::simd::SimdMode;
    use crate::kmeans::tile::TileLayout;
    use crate::kmeans::InitMethod;
    use crate::resilience::DEFAULT_HEARTBEAT_TIMEOUT_MS;
    use crate::shard::host::spawn_loopback_shard;

    fn tiny_spec() -> ShardSpec {
        let img = SyntheticOrtho::default().with_seed(11).generate(16, 12);
        ShardSpec {
            height: 16,
            width: 12,
            channels: 3,
            k: 2,
            seed: 11,
            tol_bits: 0.0f32.to_bits(),
            max_iters: 4,
            fixed_iters: Some(4),
            init: InitMethod::Fixed(vec![0.1, 0.2, 0.3, 0.8, 0.7, 0.6]),
            mode: ClusterMode::Global,
            shape: BlockShape::Square { side: 8 },
            kernel: KernelChoice::Naive,
            layout: TileLayout::Interleaved,
            arena_mb: 0,
            prefetch: false,
            strip_cache: 0,
            simd: SimdMode::default(),
            strip_rows: 0,
            file_backed: false,
            pixels: Arc::new(img.as_pixels().to_vec()),
        }
    }

    #[test]
    fn proxy_drives_blocks_through_a_loopback_shard() {
        let spec = tiny_spec();
        let (h, w, c, k) = (spec.height, spec.width, spec.channels, spec.k);
        let img = SyntheticOrtho::default().with_seed(spec.seed).generate(h, w);
        let (mut ends, shard) = spawn_loopback_shard(1, None);
        let queue = Arc::new(JobQueue::new(1, Schedule::Dynamic));
        let watchdog = Arc::new(Watchdog::new(1, DEFAULT_HEARTBEAT_TIMEOUT_MS));
        let specs: Arc<ShardSpecMap> = Arc::new(Mutex::new(HashMap::new()));
        let fp = spec.fingerprint();
        specs.lock().unwrap().insert(3, (fp, Arc::new(spec)));
        let (tx, rx) = std::sync::mpsc::channel();
        let transport = ends.pop().unwrap();
        let qh = Arc::clone(&queue);
        let handle = std::thread::spawn(move || {
            proxy_main(0, qh, tx, watchdog, specs, transport);
        });
        let cen = Arc::new(vec![0.2f32, 0.3, 0.4, 0.7, 0.6, 0.5]);
        let blocks = 4; // 16x12 in side-8 squares
        queue.push_round(
            (0..blocks)
                .map(|b| Job {
                    job: 3,
                    block: b,
                    round: 1,
                    payload: JobPayload::Step { centroids: Arc::clone(&cen), drift: None },
                })
                .collect(),
        );
        let mut merged = StepAccum::zeros(k, c);
        for _ in 0..blocks {
            let out = rx.recv().unwrap().unwrap();
            match out.result {
                JobResult::Step { accum } => merged.merge(&accum),
                other => panic!("expected step outcome, got {other:?}"),
            }
        }
        queue.close();
        handle.join().unwrap();
        drop(ends);
        drop(shard);
        let want = math::step(img.as_pixels(), &cen, k, c);
        assert_eq!(merged.counts, want.counts);
        assert_eq!(merged.inertia.to_bits(), want.inertia.to_bits());
    }

    #[test]
    fn missing_spec_fails_the_block_and_kills_the_proxy() {
        let (mut ends, shard) = spawn_loopback_shard(1, None);
        let queue = Arc::new(JobQueue::new(1, Schedule::Dynamic));
        let watchdog = Arc::new(Watchdog::new(1, DEFAULT_HEARTBEAT_TIMEOUT_MS));
        let specs: Arc<ShardSpecMap> = Arc::new(Mutex::new(HashMap::new()));
        let (tx, rx) = std::sync::mpsc::channel();
        let transport = ends.pop().unwrap();
        let qh = Arc::clone(&queue);
        let handle = std::thread::spawn(move || {
            proxy_main(0, qh, tx, watchdog, specs, transport);
        });
        queue.push_round(vec![Job {
            job: 9,
            block: 0,
            round: 1,
            payload: JobPayload::Step { centroids: Arc::new(vec![0.0; 6]), drift: None },
        }]);
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.error.to_string().contains("no shard spec registered"), "{err}");
        handle.join().unwrap();
        queue.close();
        drop(ends);
        drop(shard);
    }
}
