//! Timing, the paper's performance algebra (speedup, efficiency), and
//! clustering-quality metrics ([`quality`]).

pub mod quality;

use std::time::Instant;

/// Speedup = T_serial / T_parallel (paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Speedup(pub f64);

impl Speedup {
    pub fn compute(serial_s: f64, parallel_s: f64) -> Speedup {
        assert!(serial_s >= 0.0 && parallel_s > 0.0, "bad times {serial_s}/{parallel_s}");
        Speedup(serial_s / parallel_s)
    }

    /// Efficiency = speedup / workers (paper §4.1).
    pub fn efficiency(&self, workers: usize) -> f64 {
        assert!(workers > 0);
        self.0 / workers as f64
    }
}

/// Wall-clock stopwatch with named laps.
#[derive(Debug)]
pub struct RunTimer {
    start: Instant,
    laps: Vec<(String, f64)>,
    last: Instant,
}

impl Default for RunTimer {
    fn default() -> Self {
        Self::new()
    }
}

impl RunTimer {
    pub fn new() -> RunTimer {
        let now = Instant::now();
        RunTimer {
            start: now,
            laps: Vec::new(),
            last: now,
        }
    }

    /// Record a lap since the previous lap (or start).
    pub fn lap(&mut self, name: impl Into<String>) -> f64 {
        let now = Instant::now();
        let secs = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.laps.push((name.into(), secs));
        secs
    }

    /// Total elapsed seconds since construction.
    pub fn total(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn laps(&self) -> &[(String, f64)] {
        &self.laps
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Repeat a closure `n` times, returning per-run seconds.
pub fn time_n(n: usize, mut f: impl FnMut()) -> Vec<f64> {
    assert!(n > 0);
    (0..n)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_and_efficiency_match_paper_rows() {
        // Table 1 row 1024x768: serial 0.050589, parallel 0.036366 @ 2 cores
        let s = Speedup::compute(0.050589, 0.036366);
        assert!((s.0 - 1.391107078).abs() < 1e-6);
        assert!((s.efficiency(2) - 0.695553539).abs() < 1e-6);
        // Table 2 row 4656x5793 @ 4 cores
        let s = Speedup::compute(1.714137, 0.144857);
        assert!((s.0 - 11.83330457).abs() < 1e-5);
        assert!((s.efficiency(4) - 2.958326142).abs() < 1e-5);
    }

    #[test]
    #[should_panic(expected = "bad times")]
    fn zero_parallel_time_rejected() {
        Speedup::compute(1.0, 0.0);
    }

    #[test]
    fn timer_laps_accumulate() {
        let mut t = RunTimer::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let lap1 = t.lap("a");
        let lap2 = t.lap("b");
        assert!(lap1 >= 0.004, "lap1 {lap1}");
        assert!(lap2 < lap1, "lap2 should be ~0");
        assert_eq!(t.laps().len(), 2);
        assert!(t.total() >= lap1);
    }

    #[test]
    fn time_it_returns_result() {
        let (v, secs) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn time_n_runs_n_times() {
        let mut count = 0;
        let times = time_n(5, || count += 1);
        assert_eq!(count, 5);
        assert_eq!(times.len(), 5);
    }
}
