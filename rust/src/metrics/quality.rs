//! Clustering-quality metrics.
//!
//! The paper evaluates only runtime; these metrics let the reproduction
//! also verify that parallel block processing does not degrade *quality*
//! — in global mode it provably cannot (identical result), but in local
//! mode (independent per-block clusterings) quality genuinely drops, and
//! these scores quantify by how much (see `examples/scaling_study.rs`
//! and the quality rows in EXPERIMENTS.md):
//!
//! - [`davies_bouldin`] — internal index (lower = better separated);
//! - [`purity`] / [`adjusted_rand_sampled`] — external agreement with a
//!   ground-truth map (the synthetic generator emits one);
//! - [`label_agreement`] — permutation-aware fraction of pixels on which
//!   two clusterings agree (greedy max matching).

use std::collections::BTreeMap;

/// Davies–Bouldin index of a clustering over `pixels[P, C]`.
/// Lower is better; 0 for perfectly compact, far-apart clusters.
pub fn davies_bouldin(
    pixels: &[f32],
    labels: &[u32],
    centroids: &[f32],
    k: usize,
    channels: usize,
) -> f64 {
    assert_eq!(pixels.len(), labels.len() * channels);
    assert_eq!(centroids.len(), k * channels);
    // mean intra-cluster distance (to centroid)
    let mut scatter = vec![0.0f64; k];
    let mut counts = vec![0u64; k];
    for (px, &l) in pixels.chunks_exact(channels).zip(labels) {
        let li = l as usize;
        assert!(li < k, "label {l} out of range");
        let c = &centroids[li * channels..(li + 1) * channels];
        let d2: f64 = px
            .iter()
            .zip(c)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        scatter[li] += d2.sqrt();
        counts[li] += 1;
    }
    for i in 0..k {
        if counts[i] > 0 {
            scatter[i] /= counts[i] as f64;
        }
    }
    // R_ij = (s_i + s_j) / d(c_i, c_j); DB = mean_i max_j R_ij
    let centroid_dist = |i: usize, j: usize| -> f64 {
        centroids[i * channels..(i + 1) * channels]
            .iter()
            .zip(&centroids[j * channels..(j + 1) * channels])
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    let mut db = 0.0;
    let mut active = 0;
    for i in 0..k {
        if counts[i] == 0 {
            continue;
        }
        active += 1;
        let mut worst: f64 = 0.0;
        for j in 0..k {
            if j == i || counts[j] == 0 {
                continue;
            }
            let d = centroid_dist(i, j);
            if d > 0.0 {
                worst = worst.max((scatter[i] + scatter[j]) / d);
            }
        }
        db += worst;
    }
    if active == 0 {
        0.0
    } else {
        db / active as f64
    }
}

/// Purity: each cluster votes for its majority truth class; purity is the
/// fraction of pixels in their cluster's majority class. In `[0, 1]`,
/// higher is better; `1/k_truth` ≈ chance.
pub fn purity(labels: &[u32], truth: &[u32]) -> f64 {
    assert_eq!(labels.len(), truth.len());
    assert!(!labels.is_empty());
    let mut votes: BTreeMap<(u32, u32), u64> = BTreeMap::new();
    for (&l, &t) in labels.iter().zip(truth) {
        *votes.entry((l, t)).or_insert(0) += 1;
    }
    let mut best: BTreeMap<u32, u64> = BTreeMap::new();
    for (&(l, _), &n) in &votes {
        let e = best.entry(l).or_insert(0);
        if n > *e {
            *e = n;
        }
    }
    best.values().sum::<u64>() as f64 / labels.len() as f64
}

/// Adjusted Rand Index on a deterministic pixel sample (full ARI is
/// O(n²)-ish in pair counting; the sampled version subsamples `max_n`
/// pixels with a fixed stride). In `[-1, 1]`; 0 ≈ chance, 1 = identical
/// partitions.
pub fn adjusted_rand_sampled(labels: &[u32], truth: &[u32], max_n: usize) -> f64 {
    assert_eq!(labels.len(), truth.len());
    assert!(max_n >= 2);
    let stride = (labels.len() / max_n).max(1);
    let sample: Vec<(u32, u32)> = labels
        .iter()
        .zip(truth)
        .step_by(stride)
        .map(|(&l, &t)| (l, t))
        .collect();
    // contingency table
    let mut table: BTreeMap<(u32, u32), f64> = BTreeMap::new();
    let mut rows: BTreeMap<u32, f64> = BTreeMap::new();
    let mut cols: BTreeMap<u32, f64> = BTreeMap::new();
    for &(l, t) in &sample {
        *table.entry((l, t)).or_insert(0.0) += 1.0;
        *rows.entry(l).or_insert(0.0) += 1.0;
        *cols.entry(t).or_insert(0.0) += 1.0;
    }
    let comb2 = |x: f64| x * (x - 1.0) / 2.0;
    let sum_ij: f64 = table.values().map(|&v| comb2(v)).sum();
    let sum_a: f64 = rows.values().map(|&v| comb2(v)).sum();
    let sum_b: f64 = cols.values().map(|&v| comb2(v)).sum();
    let n = sample.len() as f64;
    let expected = sum_a * sum_b / comb2(n);
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return 1.0; // degenerate: all in one cluster both sides
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Permutation-aware agreement between two label maps: greedily match
/// clusters of `a` to clusters of `b` by overlap, then report the matched
/// fraction. In `[0, 1]`.
pub fn label_agreement(a: &[u32], b: &[u32], k: usize) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    // overlap matrix
    let mut overlap = vec![0u64; k * k];
    for (&x, &y) in a.iter().zip(b) {
        overlap[(x as usize) * k + y as usize] += 1;
    }
    // greedy max matching
    let mut used_a = vec![false; k];
    let mut used_b = vec![false; k];
    let mut matched = 0u64;
    for _ in 0..k {
        let mut best = 0u64;
        let mut pick = None;
        for i in 0..k {
            if used_a[i] {
                continue;
            }
            for j in 0..k {
                if used_b[j] {
                    continue;
                }
                if overlap[i * k + j] > best {
                    best = overlap[i * k + j];
                    pick = Some((i, j));
                }
            }
        }
        match pick {
            Some((i, j)) => {
                used_a[i] = true;
                used_b[j] = true;
                matched += best;
            }
            None => break,
        }
    }
    matched as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn db_zero_for_perfect_clusters() {
        // two point-clusters exactly at their centroids
        let pixels = vec![0.0f32, 0.0, 0.0, 0.0, 0.0, 0.0, 9.0, 9.0, 9.0];
        let labels = vec![0u32, 0, 1];
        let centroids = vec![0.0f32, 0.0, 0.0, 9.0, 9.0, 9.0];
        assert_eq!(davies_bouldin(&pixels, &labels, &centroids, 2, 3), 0.0);
    }

    #[test]
    fn db_grows_with_scatter() {
        let tight = vec![0.0f32, 0.1, 10.0, 10.1]; // 1-channel
        let loose = vec![0.0f32, 3.0, 10.0, 13.0];
        let labels = vec![0u32, 0, 1, 1];
        let cen_tight = vec![0.05f32, 10.05];
        let cen_loose = vec![1.5f32, 11.5];
        let db_t = davies_bouldin(&tight, &labels, &cen_tight, 2, 1);
        let db_l = davies_bouldin(&loose, &labels, &cen_loose, 2, 1);
        assert!(db_t < db_l, "{db_t} !< {db_l}");
    }

    #[test]
    fn purity_perfect_and_chance() {
        let truth = vec![0u32, 0, 1, 1];
        assert_eq!(purity(&[1, 1, 0, 0], &truth), 1.0); // permuted = fine
        assert_eq!(purity(&[0, 0, 0, 0], &truth), 0.5); // one blob
    }

    #[test]
    fn ari_identical_is_one_and_permutation_invariant() {
        let truth: Vec<u32> = (0..1000).map(|i| (i / 250) as u32).collect();
        assert!((adjusted_rand_sampled(&truth, &truth, 500) - 1.0).abs() < 1e-12);
        let permuted: Vec<u32> = truth.iter().map(|&t| 3 - t).collect();
        assert!((adjusted_rand_sampled(&permuted, &truth, 500) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ari_random_labels_near_zero() {
        let mut rng = crate::util::prng::Rng::new(9);
        let truth: Vec<u32> = (0..4000).map(|i| (i / 1000) as u32).collect();
        let random: Vec<u32> = (0..4000).map(|_| rng.next_below(4) as u32).collect();
        let ari = adjusted_rand_sampled(&random, &truth, 2000);
        assert!(ari.abs() < 0.05, "ari {ari}");
    }

    #[test]
    fn agreement_handles_permutations() {
        let a = vec![0u32, 0, 1, 1, 2, 2];
        let b = vec![2u32, 2, 0, 0, 1, 1]; // same partition, relabeled
        assert_eq!(label_agreement(&a, &b, 3), 1.0);
        let c = vec![0u32, 1, 0, 1, 0, 1]; // orthogonal partition
        assert!(label_agreement(&a, &c, 3) < 0.7);
    }

    #[test]
    fn truth_map_scores_well_under_kmeans() {
        // end-to-end: cluster a synthetic scene, score against its truth
        use crate::image::SyntheticOrtho;
        use crate::kmeans::{KMeansConfig, SeqKMeans};
        let gen = SyntheticOrtho::default().with_seed(5).with_classes(3);
        let (img, truth) = gen.generate_with_truth(80, 80);
        let r = SeqKMeans::run(
            img.as_pixels(),
            3,
            &KMeansConfig {
                k: 3,
                ..Default::default()
            },
        );
        let p = purity(&r.labels, &truth);
        assert!(p > 0.7, "k-means should recover synthetic classes: purity {p}");
        let ari = adjusted_rand_sampled(&r.labels, &truth, 2000);
        assert!(ari > 0.4, "ari {ari}");
    }
}
