//! `blockms` — the launcher.
//!
//! Subcommands:
//!
//! - `cluster`       run parallel block K-Means on a synthetic scene (or a
//!                   PPM file) and write the label map;
//! - `paper-tables`  regenerate the paper's Tables 1–19 (+ figure series);
//! - `cases`         regenerate the §4 Cases 1–3 block-size I/O analysis;
//! - `info`          show artifact/manifest status and environment.
//!
//! Run `blockms --help` for options, or drive everything from a config
//! file: `blockms cluster --config run.ini`.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use blockms::bench::tables::{all_table_ids, run_table, SweepOpts};
use blockms::bench::{cases, runner::EngineChoice};
use blockms::blocks::{ApproachKind, BlockPlan, BlockShape};
use blockms::coordinator::{
    ClusterConfig, ClusterMode, Coordinator, CoordinatorConfig, Engine, IoMode, Schedule,
};
use blockms::image::{read_ppm, write_labels_ppm, write_ppm, SyntheticOrtho};
use blockms::kmeans::kernel::KernelChoice;
use blockms::runtime::{find_artifacts_dir, ArtifactSet};
use blockms::util::cli::{Args, Cli, CliError};
use blockms::util::config::Config;
use blockms::util::fmt::duration;

fn cli() -> Cli {
    Cli::new("blockms", "parallel block processing for K-Means clustering")
        .opt("config", None, "INI config file (CLI overrides it)")
        .opt("k", Some("2"), "cluster count")
        .opt("workers", Some("4"), "worker count")
        .opt("approach", Some("column"), "block approach: row|column|square")
        .opt("block-rows", None, "explicit block rows (overrides approach)")
        .opt("block-cols", None, "explicit block cols (overrides approach)")
        .opt("width", Some("1280"), "synthetic image width")
        .opt("height", Some("800"), "synthetic image height")
        .opt("seed", Some("7"), "workload / init seed")
        .opt("input", None, "input PPM instead of synthetic scene")
        .opt("out", None, "output path (cluster: label map PPM; kernels: JSON; sweep: CSV)")
        .opt("out-input", None, "also write the input scene PPM here")
        .opt("engine", Some("native"), "compute engine: native|pjrt")
        .opt("kernel", Some("naive"), "compute kernel: naive|pruned|fused")
        .opt("mode", Some("global"), "clustering mode: global|local")
        .opt("schedule", Some("dynamic"), "job schedule: static|dynamic")
        .opt("iters", None, "fixed Lloyd iterations (default: converge)")
        .opt("max-iters", Some("20"), "max Lloyd iterations")
        .opt("strip-rows", None, "enable strip I/O model with this strip height")
        .opt("table", Some("all"), "paper-tables: table number or 'all'")
        .opt("scale", Some("0.25"), "paper-tables/cases: per-side size scale")
        .opt("bench-iters", Some("6"), "paper-tables/cases: Lloyd iterations")
        .flag("serial", "cluster: also run the sequential baseline and compare")
        .flag("verbose", "more logging")
}

fn main() {
    let c = cli();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match c.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            print!("{}", c.help_text());
            println!("\nSUBCOMMANDS:\n  cluster | paper-tables | cases | sweep | kernels | info");
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand().unwrap_or("cluster") {
        "cluster" => cmd_cluster(&args),
        "paper-tables" => cmd_tables(&args),
        "cases" => cmd_cases(&args),
        "sweep" => cmd_sweep(&args),
        "kernels" => cmd_kernels(&args),
        "info" => cmd_info(),
        other => Err(anyhow::anyhow!("unknown subcommand {other:?} (see --help)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Merge `--config file` under the CLI args for a single typed lookup.
struct Opts<'a> {
    args: &'a Args,
    config: Config,
}

impl<'a> Opts<'a> {
    fn load(args: &'a Args) -> Result<Opts<'a>> {
        let config = match args.get("config") {
            Some(path) => Config::load(Path::new(path))
                .with_context(|| format!("load config {path}"))?,
            None => Config::default(),
        };
        Ok(Opts { args, config })
    }

    /// CLI beats config (`section.key` in the file, `--key` on the CLI).
    fn get(&self, cli_key: &str, cfg_key: &str) -> Option<String> {
        self.args
            .get(cli_key)
            .map(str::to_string)
            .or_else(|| self.config.get(cfg_key).map(str::to_string))
    }

    fn parse<T: std::str::FromStr>(&self, cli_key: &str, cfg_key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(cli_key, cfg_key) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("invalid {cli_key}={raw:?}: {e}")),
        }
    }

    fn require<T: std::str::FromStr>(&self, cli_key: &str, cfg_key: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.parse(cli_key, cfg_key)?
            .ok_or_else(|| anyhow::anyhow!("missing required option --{cli_key}"))
    }
}

fn engine_of(opts: &Opts) -> Result<Engine> {
    Ok(match opts.require::<EngineChoice>("engine", "run.engine")? {
        EngineChoice::Native => Engine::Native,
        EngineChoice::Pjrt => Engine::Pjrt {
            artifacts_dir: None,
        },
    })
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let opts = Opts::load(args)?;
    let k: usize = opts.require("k", "cluster.k")?;
    let workers: usize = opts.require("workers", "run.workers")?;
    let seed: u64 = opts.require("seed", "workload.seed")?;

    // --- image -----------------------------------------------------------
    let img = match opts.get("input", "workload.input") {
        Some(path) => {
            let img = read_ppm(Path::new(&path))?;
            println!("loaded {path}: {}x{} ({} bands)", img.width(), img.height(), img.channels());
            img
        }
        None => {
            let width: usize = opts.require("width", "workload.width")?;
            let height: usize = opts.require("height", "workload.height")?;
            println!("generating synthetic ortho scene {width}x{height} (seed {seed})");
            SyntheticOrtho::default().with_seed(seed).generate(height, width)
        }
    };
    if let Some(p) = opts.get("out-input", "output.input") {
        write_ppm(&img, Path::new(&p))?;
        println!("wrote input scene to {p}");
    }
    let img = Arc::new(img);

    // --- plan --------------------------------------------------------------
    let shape = match (
        opts.parse::<usize>("block-rows", "blocks.rows")?,
        opts.parse::<usize>("block-cols", "blocks.cols")?,
    ) {
        (Some(rows), Some(cols)) => BlockShape::Custom { rows, cols },
        (None, None) => {
            let kind: ApproachKind = opts.require("approach", "blocks.approach")?;
            BlockShape::paper_default(kind, img.height(), img.width())
        }
        _ => bail!("--block-rows and --block-cols must be given together"),
    };
    let plan = Arc::new(BlockPlan::new(img.height(), img.width(), shape));
    println!(
        "plan: {} -> {} blocks of up to {:?}",
        shape,
        plan.len(),
        plan.block_dims()
    );

    // --- run ---------------------------------------------------------------
    let io = match opts.parse::<usize>("strip-rows", "io.strip_rows")? {
        Some(strip_rows) => IoMode::Strips {
            strip_rows,
            file_backed: false,
        },
        None => IoMode::Direct,
    };
    let coord = Coordinator::new(CoordinatorConfig {
        workers,
        engine: engine_of(&opts)?,
        mode: opts.require::<ClusterMode>("mode", "run.mode")?,
        io,
        schedule: opts.require::<Schedule>("schedule", "run.schedule")?,
        kernel: opts.require::<KernelChoice>("kernel", "run.kernel")?,
        fail_block: None,
    });
    let ccfg = ClusterConfig {
        k,
        max_iters: opts.require("max-iters", "cluster.max_iters")?,
        seed,
        fixed_iters: opts.parse("iters", "cluster.iters")?,
        ..Default::default()
    };
    let out = coord.cluster(&img, &plan, &ccfg)?;
    println!(
        "parallel: {} workers, {} blocks, {} iterations{} -> inertia {:.1}, {}",
        out.workers,
        out.blocks,
        out.iterations,
        if out.converged { " (converged)" } else { "" },
        out.inertia,
        duration(out.total_secs)
    );
    if let Some(io) = out.io_stats {
        println!(
            "io: {} block reads, {} strip reads, {} bytes",
            io.block_reads, io.strip_reads, io.bytes_read
        );
    }

    if args.flag("serial") {
        let s = coord.serial(&img, &ccfg)?;
        println!(
            "serial:   1 worker, {} iterations -> inertia {:.1}, {}",
            s.iterations,
            s.inertia,
            duration(s.total_secs)
        );
        // Native engine: bit-identical (tested invariant). PJRT engine:
        // f32 partial sums accumulate per chunk, so different block
        // partitions can differ by float-rounding — report the fraction.
        let agree = s
            .labels
            .iter()
            .zip(&out.labels)
            .filter(|(a, b)| a == b)
            .count() as f64
            / s.labels.len() as f64;
        println!(
            "label agreement with serial: {:.4}% | speedup (wall, 1-core box): {:.3}",
            agree * 100.0,
            s.total_secs / out.total_secs
        );
    }

    if let Some(p) = opts.get("out", "output.labels") {
        write_labels_ppm(&out.labels, img.height(), img.width(), Path::new(&p))?;
        println!("wrote label map to {p}");
    }
    Ok(())
}

fn sweep_opts(args: &Args) -> Result<SweepOpts> {
    let opts = Opts::load(args)?;
    Ok(SweepOpts {
        scale: opts.require("scale", "bench.scale")?,
        seed: opts.require("seed", "workload.seed")?,
        engine: opts.require("engine", "run.engine")?,
        iters: opts.require("bench-iters", "bench.iters")?,
        ..Default::default()
    })
}

fn cmd_tables(args: &Args) -> Result<()> {
    let opts = sweep_opts(args)?;
    let which = args.get("table").unwrap_or("all");
    let ids: Vec<usize> = if which == "all" {
        all_table_ids()
    } else {
        vec![which.parse().context("--table must be a number or 'all'")?]
    };
    for id in ids {
        let text = run_table(id, &opts)?;
        println!("{text}");
    }
    Ok(())
}

fn cmd_cases(args: &Args) -> Result<()> {
    let opts = sweep_opts(args)?;
    let results = cases::run_cases(&opts)?;
    print!("{}", cases::render_cases(&results));
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use blockms::bench::tables::sweep_all;
    use blockms::util::csv::Csv;
    let opts = sweep_opts(args)?;
    let out_path = args.get("out").unwrap_or("sweep.csv").to_string();
    let rows = sweep_all(&opts)?;
    let mut csv = Csv::new(&[
        "table", "approach", "k", "workers", "data_size", "serial_s", "parallel_s", "speedup",
        "efficiency", "blocks", "strip_reads_per_pass", "wall_s",
    ]);
    for (table, r) in &rows {
        csv.row([
            table.to_string(),
            r.approach.to_string(),
            r.k.to_string(),
            r.workers.to_string(),
            r.data_size.clone(),
            format!("{:.6}", r.serial_secs),
            format!("{:.6}", r.parallel_secs),
            format!("{:.4}", r.speedup),
            format!("{:.4}", r.efficiency),
            r.blocks.to_string(),
            r.strip_reads.to_string(),
            format!("{:.4}", r.wall_secs),
        ]);
    }
    csv.write_to(Path::new(&out_path))?;
    println!("wrote {} cells to {out_path}", csv.len());
    Ok(())
}

/// Kernel-layer benchmark: naive vs pruned vs fused step-round
/// throughput, written to `BENCH_kernels.json` (see EXPERIMENTS.md
/// §Kernel architecture for the schema).
fn cmd_kernels(args: &Args) -> Result<()> {
    use blockms::bench::kernels::{render_kernel_bench, write_kernel_bench, KernelBenchOpts};
    let opts = Opts::load(args)?;
    let scale: f64 = opts.require("scale", "bench.scale")?;
    let side = ((1024.0 * scale).round() as usize).max(32);
    let bopts = KernelBenchOpts {
        height: side,
        width: side,
        iters: opts.require("bench-iters", "bench.iters")?,
        seed: opts.require("seed", "workload.seed")?,
        ..Default::default()
    };
    let out = args.get("out").unwrap_or("BENCH_kernels.json").to_string();
    let rows = write_kernel_bench(Path::new(&out), &bopts)?;
    print!("{}", render_kernel_bench(&bopts, &rows));
    println!("wrote {out}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("blockms {}", env!("CARGO_PKG_VERSION"));
    match find_artifacts_dir() {
        Some(dir) => {
            println!("artifacts: {}", dir.display());
            match ArtifactSet::load(&dir) {
                Ok(set) => {
                    let m = &set.manifest;
                    println!(
                        "  manifest ok: chunk={} channels={} ks={:?} local_iters={}",
                        m.chunk, m.channels, m.ks, m.local_iters
                    );
                    for a in m.artifacts() {
                        println!("  {} ({} -> {} tensors)", a.name, a.inputs.len(), a.outputs.len());
                    }
                }
                Err(e) => println!("  INVALID: {e:#}"),
            }
        }
        None => println!("artifacts: NOT FOUND (run `make artifacts`)"),
    }
    println!("cores visible: {}", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0));
    Ok(())
}
