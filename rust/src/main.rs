//! `blockms` — the launcher.
//!
//! Subcommands:
//!
//! - `cluster`       run parallel block K-Means on a synthetic scene (or a
//!                   PPM file) and write the label map; `--auto` lets the
//!                   planner pick unpinned knobs, `--dry-run` prints the
//!                   resolved plan and exits without reading pixels;
//! - `plan`          rank candidate execution plans (shape × kernel ×
//!                   layout × cache × prefetch) by predicted cost —
//!                   the explain table; never touches pixels;
//! - `paper-tables`  regenerate the paper's Tables 1–19 (+ figure series;
//!                   `--out` also exports every cell as one flat CSV);
//! - `cases`         regenerate the §4 Cases 1–3 block-size I/O analysis;
//! - `sweep`         amortized multi-variant sweep: a (k, seed, init) grid
//!                   over one image as a single share group — one decoded
//!                   pass serves every variant, bit-identical to solo runs —
//!                   ranked into an elbow report -> BENCH_sweep.json
//!                   (`--ks 2..8 | 2,4,8`, `--seeds N`, `--inits
//!                   random,plusplus`; `--quick` for the CI smoke size);
//! - `simd`          naive/lanes vs the simd kernel at every supported
//!                   capability level × paper shapes -> BENCH_simd.json
//!                   (`--quick` for the CI smoke size);
//! - `layout`        interleaved-vs-SoA × kernel × block-shape matrix ->
//!                   BENCH_layout.json (`--quick` for the CI smoke size);
//! - `stream`        streamed-vs-in-memory out-of-core pipeline ->
//!                   BENCH_stream.json (`--quick` for the CI smoke size);
//! - `batch`         multi-job service throughput matrix -> BENCH_service.json
//!                   (`--input` benches a real PPM);
//! - `serve`         drive N jobs through one persistent shared pool
//!                   (`--mem-mb` admits jobs by path and streams them);
//! - `shard-worker`  host shard-side block compute: listen on `--listen`
//!                   (UDS path or host:port) for a leader's connections
//!                   (`--once` exits after the first leader disconnects);
//! - `distributed`   multi-process scaling bench: solo vs `--shards N`
//!                   loopback shards, bit-identity checked per row ->
//!                   BENCH_distributed.json (`--quick` for the CI smoke
//!                   size);
//! - `resilience`    fault-tolerance overhead bench: baseline vs retry vs
//!                   checkpoint vs kill/resume -> BENCH_resilience.json
//!                   (`--quick` for the CI smoke size);
//! - `hardening`     liveness-hardening bench: watchdog/speculation
//!                   overhead, recovery under hung workers, QoS shed mix
//!                   under overload -> BENCH_hardening.json (`--quick`
//!                   for the CI smoke size);
//! - `info`          show artifact/manifest status and environment.
//!
//! Distribution rides on `cluster` and `serve`: `--shards N` runs the
//! block protocol over N in-process loopback shards, `--shards
//! N:addr,...` connects to `blockms shard-worker` processes instead
//! (results bit-identical to solo either way), and with `--auto` the
//! planner's wire-cost terms decide whether distributing actually pays.
//! `--heartbeat-ms` tunes the liveness probe both modes share.
//!
//! Fault tolerance rides on `cluster`: `--retries N` re-queues a failed
//! block up to N times per round (bit-identical — a re-queued block is a
//! pure function of the round's centroids), `--checkpoint F
//! --checkpoint-every R` writes an atomic round-boundary checkpoint every
//! R rounds, and `--resume F` continues a killed run bit-identically.
//! `--fault BLOCK[:KIND[:VISITS[:AFTER]]]` injects a deterministic fault
//! for drills (`hang[MS]` parks the worker silently — pair with
//! `--retries` so the heartbeat watchdog can re-queue the block).
//!
//! Liveness hardening rides on `cluster` and `serve`: `--speculate`
//! re-runs straggler blocks on idle workers near the end of a round
//! (first result wins; bit-identical either way), `--deadline-ms N`
//! bounds a job's wall clock — a deadlined global run checkpoints its
//! last round boundary and exits resumable — and `serve` adds
//! `--priority` (overload sheds lowest-priority jobs first) and
//! `--drain-timeout` (graceful drain: finish or checkpoint every open
//! job, then report per-job dispositions).
//!
//! `cluster --mem-mb N` runs the whole pipeline out-of-core: pixels
//! stream from the source (PPM file or synthetic generator) into a
//! strip store under a hard resident budget, and the label map spools
//! to disk; `--dry-run` reports the predicted peak resident bytes.
//!
//! Run `blockms --help` for options, or drive everything from a config
//! file: `blockms cluster --config run.ini`.
//!
//! Exit codes: 0 success, 1 runtime failure, 2 usage error (unknown
//! flag/subcommand or bad value; the message names the flag).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use blockms::bench::service::{render_service_bench, write_service_bench, ServiceBenchOpts};
use blockms::bench::tables::{all_table_ids, run_table, SweepOpts};
use blockms::bench::{cases, runner::EngineChoice};
use blockms::blocks::{ApproachKind, BlockShape};
use blockms::cli::{blockms_cli, parse_usize_list, Opts, SUBCOMMANDS};
use blockms::coordinator::{
    ClusterConfig, ClusterMode, Coordinator, CoordinatorConfig, Engine, IoMode, Schedule,
};
use blockms::image::{
    ppm_dims, read_ppm, write_labels_ppm, write_ppm, PpmSource, Raster, RasterSource,
    SyntheticOrtho, SyntheticSource,
};
use blockms::kmeans::simd::{self, SimdLevel, SimdMode};
use blockms::kmeans::tile::TileLayout;
use blockms::plan::{CostModel, ExecPlan, Explain, Planner, PlanRequest};
use blockms::resilience::{FaultKind, FaultPlan};
use blockms::runtime::{find_artifacts_dir, ArtifactSet};
use blockms::service::{ClusterServer, JobSpec, JobStatus, ServerConfig};
use blockms::shard::{run_listener, ShardEndpoints};
use blockms::util::cli::{Args, CliError};
use blockms::util::fmt::duration;

fn main() {
    let c = blockms_cli();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match c.parse(argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            print!("{}", c.help_text());
            println!("\nSUBCOMMANDS:\n  {}", SUBCOMMANDS.join(" | "));
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand().unwrap_or("cluster") {
        "cluster" => cmd_cluster(&args),
        "plan" => cmd_plan(&args),
        "paper-tables" => cmd_tables(&args),
        "cases" => cmd_cases(&args),
        "sweep" => cmd_sweep(&args),
        "kernels" => cmd_kernels(&args),
        "simd" => cmd_simd(&args),
        "layout" => cmd_layout(&args),
        "stream" => cmd_stream(&args),
        "batch" => cmd_batch(&args),
        "serve" => cmd_serve(&args),
        "shard-worker" => cmd_shard_worker(&args),
        "distributed" => cmd_distributed(&args),
        "resilience" => cmd_resilience(&args),
        "hardening" => cmd_hardening(&args),
        "info" => cmd_info(),
        other => Err(anyhow::Error::new(CliError::UnknownSubcommand(
            other.to_string(),
        ))),
    };
    if let Err(e) = result {
        // Usage mistakes exit 2 with the offending flag named; runtime
        // failures exit 1.
        if let Some(cli_err) = e.downcast_ref::<CliError>() {
            eprintln!("error: {cli_err}");
            std::process::exit(2);
        }
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Strip height the streaming pipeline defaults to when `--mem-mb` is
/// given without an explicit `--strip-rows`.
const DEFAULT_STREAM_STRIP_ROWS: usize = 64;

/// A usage (exit-2) error for flags whose value parsed but is out of
/// range — e.g. `--workers 0` would otherwise panic deep in the pool.
fn positive(v: usize, flag: &str) -> Result<usize> {
    if v == 0 {
        Err(anyhow::Error::new(CliError::BadValue(
            flag.to_string(),
            "0".to_string(),
            "must be at least 1".to_string(),
        )))
    } else {
        Ok(v)
    }
}

/// Parse `--shards N[:addr,...]` into endpoints. A malformed spec —
/// zero shards, or an address list whose length disagrees with N — is
/// a usage error (exit 2).
fn shards_of(opts: &Opts) -> Result<Option<ShardEndpoints>> {
    match opts.get("shards", "run.shards") {
        None => Ok(None),
        Some(raw) => match ShardEndpoints::parse(&raw) {
            Ok(endpoints) => Ok(Some(endpoints)),
            Err(e) => Err(anyhow::Error::new(CliError::BadValue(
                "shards".to_string(),
                raw,
                e.to_string(),
            ))),
        },
    }
}

/// A typed `--shards` composes with neither fault injection (faults
/// target in-process workers) nor `--mem-mb` streaming (shards need the
/// whole raster in the spec). Both pairings are usage errors, exit 2.
fn check_shard_conflicts(opts: &Opts, mem_mb: bool, fault: bool) -> Result<()> {
    let raw = match opts.get("shards", "run.shards") {
        Some(raw) => raw,
        None => return Ok(()),
    };
    let conflict = |why: &str| {
        Err(anyhow::Error::new(CliError::BadValue(
            "shards".to_string(),
            raw.clone(),
            why.to_string(),
        )))
    };
    if mem_mb {
        return conflict("--shards ships the whole raster in the shard spec; drop --mem-mb");
    }
    if fault {
        return conflict("fault injection targets in-process workers; drop --fault");
    }
    Ok(())
}

/// Resolve the run's SIMD mode: hardware detection clamped by the
/// `BLOCKMS_SIMD` override, plus the `--fma` opt-in. Asking for a level
/// this host lacks (or a level the env var cannot name) is a usage
/// error, exit 2.
fn simd_of(args: &Args) -> Result<SimdMode> {
    let level = simd::resolve().map_err(|e| {
        anyhow::Error::new(CliError::BadEnv(
            simd::SIMD_ENV.to_string(),
            std::env::var(simd::SIMD_ENV).unwrap_or_default(),
            e.to_string(),
        ))
    })?;
    Ok(SimdMode {
        level,
        fma: args.flag("fma"),
    })
}

/// Planner for a stamped request. When the kernel axis is live and a
/// native SIMD level was detected, replace that level's prior with a
/// measured simd-over-lanes ratio (a few-ms microbench) so `--auto`
/// picks Simd only where it is actually faster on this host.
fn planner_for(req: &PlanRequest) -> Planner {
    let mut model = CostModel::default();
    if req.kernel.is_none() && req.simd.level != SimdLevel::Portable {
        model.calibrate_simd(req.simd.level, simd::microbench_ratio(req.simd));
    }
    Planner::new(model)
}

fn engine_of(opts: &Opts) -> Result<Engine> {
    Ok(match opts.require::<EngineChoice>("engine", "run.engine")? {
        EngineChoice::Native => Engine::Native,
        EngineChoice::Pjrt => Engine::Pjrt {
            artifacts_dir: None,
        },
    })
}

/// Resolve the I/O mode from `--strip-rows` / `--file-backed`.
fn io_of(opts: &Opts, args: &Args) -> Result<IoMode> {
    Ok(match opts.parse::<usize>("strip-rows", "io.strip_rows")? {
        Some(strip_rows) => IoMode::Strips {
            strip_rows: positive(strip_rows, "strip-rows")?,
            file_backed: args.flag("file-backed"),
        },
        None => IoMode::Direct,
    })
}

/// Workload geometry without touching pixels: the PPM header for
/// `--input`, the size flags for a synthetic scene.
fn workload_dims(opts: &Opts, input: Option<&str>) -> Result<(usize, usize, usize)> {
    match input {
        Some(path) => ppm_dims(Path::new(path)),
        None => {
            let width: usize = positive(opts.require("width", "workload.width")?, "width")?;
            let height: usize = positive(opts.require("height", "workload.height")?, "height")?;
            Ok((height, width, 3))
        }
    }
}

/// Build the [`PlanRequest`] for a run. Pin discipline:
///
/// - without `auto`, every knob pins to its (possibly defaulted) flag
///   value — exactly the pre-planner behaviour;
/// - with `auto`, only knobs the user actually typed (or the config
///   file sets) are pins; the planner chooses the rest.
fn plan_request(
    opts: &Opts,
    args: &Args,
    auto: bool,
    height: usize,
    width: usize,
    channels: usize,
) -> Result<PlanRequest> {
    let k: usize = positive(opts.require("k", "cluster.k")?, "k")?;
    let max_iters: usize = opts.require("max-iters", "cluster.max_iters")?;
    let fixed_iters: Option<usize> = opts.parse("iters", "cluster.iters")?;
    let mem_mb = match opts.parse::<usize>("mem-mb", "run.mem_mb")? {
        Some(m) => Some(positive(m, "mem-mb")?),
        None => None,
    };
    let strip_rows = match opts.parse::<usize>("strip-rows", "io.strip_rows")? {
        Some(v) => Some(positive(v, "strip-rows")?),
        // A budget implies strip I/O: streaming needs strips to stream.
        None if mem_mb.is_some() => Some(DEFAULT_STREAM_STRIP_ROWS),
        None => None,
    };
    let mut req = PlanRequest::new(height, width, channels, k)
        .with_rounds(fixed_iters.unwrap_or(max_iters))
        .with_strip_rows(strip_rows)
        .with_mem_mb(mem_mb);
    // Backing: an explicit --file-backed pins; under a budget the
    // planner chooses (degrading to file when memory cannot fit);
    // otherwise memory — the pre-streaming behaviour.
    req.file_backed = if args.flag("file-backed") {
        Some(true)
    } else if mem_mb.is_some() {
        None
    } else {
        Some(false)
    };

    // Block shape: explicit --block-rows/cols always pin; a typed
    // --approach pins its paper-default sizing.
    req.shape = match (
        opts.parse::<usize>("block-rows", "blocks.rows")?,
        opts.parse::<usize>("block-cols", "blocks.cols")?,
    ) {
        (Some(rows), Some(cols)) => Some(BlockShape::Custom { rows, cols }),
        (None, None) => {
            let kind: Option<ApproachKind> = if auto {
                opts.pinned("approach", "blocks.approach")?
            } else {
                Some(opts.require("approach", "blocks.approach")?)
            };
            kind.map(|kind| BlockShape::paper_default(kind, height, width))
        }
        _ => bail!("--block-rows and --block-cols must be given together"),
    };
    req.workers = match if auto {
        opts.pinned("workers", "run.workers")?
    } else {
        Some(opts.require("workers", "run.workers")?)
    } {
        Some(w) => Some(positive(w, "workers")?),
        None => None,
    };
    req.kernel = if auto {
        opts.pinned("kernel", "run.kernel")?
    } else {
        Some(opts.require("kernel", "run.kernel")?)
    };
    // Layout: an explicit flag pins; otherwise the pinned kernel's
    // native shape (reproducing the pre-planner default) — or free
    // under --auto.
    req.layout = match opts.pinned::<TileLayout>("layout", "run.layout")? {
        Some(l) => Some(l),
        None if auto => None,
        None => req.kernel.map(|k| k.default_layout()),
    };
    req.arena_mb = if auto {
        opts.pinned("arena-mb", "run.arena_mb")?
    } else {
        Some(opts.require("arena-mb", "run.arena_mb")?)
    };
    req.strip_cache = if auto {
        opts.pinned("strip-cache", "io.strip_cache")?
    } else {
        Some(opts.parse("strip-cache", "io.strip_cache")?.unwrap_or(0))
    };
    // A flag cannot be typed as false: --prefetch pins true, absence
    // leaves it free under --auto and pins false otherwise.
    req.prefetch = if args.flag("prefetch") {
        Some(true)
    } else if auto {
        None
    } else {
        Some(false)
    };
    // Distribution: without --auto a typed --shards N pins the shard
    // count; with --auto the same flag opens a solo-vs-N cost race and
    // the planner's wire terms decide whether the freight pays. The
    // heartbeat is a carried-through knob (0 = the pool default), but
    // an explicit zero would disarm the watchdog: usage error, exit 2.
    if let Some(endpoints) = shards_of(opts)? {
        if auto {
            req = req.with_shard_grid(vec![endpoints.shards()]);
        } else {
            req = req.with_shards(Some(endpoints.shards()));
        }
    }
    if let Some(hb) = opts.pinned::<usize>("heartbeat-ms", "run.heartbeat_ms")? {
        req = req.with_heartbeat_ms(Some(positive(hb, "heartbeat-ms")?));
    }
    // SIMD capability is a fact of the host, never a search axis: the
    // env-clamped detected level (and the --fma opt-in) ride on every
    // candidate, and the cost model prices the Simd kernel at it.
    req = req.with_simd(simd_of(args)?);
    // Fault-tolerance knobs are carried-through, never search axes
    // (retries change availability, not values) — so they ride on every
    // candidate regardless of --auto. Defaults are 0 = off.
    req = req
        .with_retries(opts.parse("retries", "run.retries")?)
        .with_checkpoint_every(opts.parse("checkpoint-every", "run.checkpoint_every")?)
        .with_deadline_ms(opts.parse("deadline-ms", "run.deadline_ms")?)
        .with_priority(opts.parse("priority", "run.priority")?)
        .with_speculate(args.flag("speculate"));
    Ok(req)
}

/// A hang fault parks the worker silently: without a retry budget the
/// watchdog has nowhere to re-queue the block and the run can only
/// stall out to a loud error. That pairing is a usage mistake, caught
/// before any pixels move (exit 2).
fn check_hang_retries(fault: &Option<FaultPlan>, retries: usize) -> Result<()> {
    if let Some(f) = fault {
        if matches!(f.kind(), FaultKind::Hang { .. }) && retries == 0 {
            return Err(anyhow::Error::new(CliError::BadValue(
                "fault".to_string(),
                "hang".to_string(),
                "a hang fault needs --retries N so the watchdog can re-queue the block"
                    .to_string(),
            )));
        }
    }
    Ok(())
}

/// Parse `--fault BLOCK[:KIND[:VISITS[:AFTER]]]` into a [`FaultPlan`]:
/// block index, fault kind (`error` default), how many visits fail
/// (`1` default, `always` never heals), and how many visits succeed
/// first (`0` default — with one visit per round, `AFTER` is the round
/// the run dies in). Examples: `2`, `2:panic`, `0:error:always`,
/// `1:reader-io:1:4`. A malformed spec is a usage error (exit 2).
fn fault_of(opts: &Opts) -> Result<Option<FaultPlan>> {
    let raw = match opts.get("fault", "run.fault") {
        Some(raw) => raw,
        None => return Ok(None),
    };
    let bad = |why: String| {
        anyhow::Error::new(CliError::BadValue("fault".to_string(), raw.clone(), why))
    };
    let parts: Vec<&str> = raw.split(':').collect();
    if parts.len() > 4 {
        return Err(bad("too many fields (want BLOCK[:KIND[:VISITS[:AFTER]]])".to_string()));
    }
    let block: usize = parts[0]
        .parse()
        .map_err(|_| bad("block must be a non-negative integer".to_string()))?;
    let kind: FaultKind = match parts.get(1) {
        Some(s) => s.parse().map_err(bad)?,
        None => FaultKind::Error,
    };
    let visits: usize = match parts.get(2) {
        Some(&"always") => usize::MAX,
        Some(s) => {
            let v = s
                .parse()
                .map_err(|_| bad("visits must be an integer or 'always'".to_string()))?;
            if v == 0 {
                return Err(bad("visits must be at least 1".to_string()));
            }
            v
        }
        None => 1,
    };
    let skip: usize = match parts.get(3) {
        Some(s) => s
            .parse()
            .map_err(|_| bad("after must be a non-negative integer".to_string()))?,
        None => 0,
    };
    Ok(Some(FaultPlan::new(block, kind, visits).after(skip)))
}

/// Shared resolve step: request → (plan, explain), printed consistently.
fn resolve_exec(
    opts: &Opts,
    args: &Args,
    auto: bool,
    height: usize,
    width: usize,
    channels: usize,
) -> Result<(ExecPlan, Explain)> {
    let req = plan_request(opts, args, auto, height, width, channels)?;
    let (exec, explain) = planner_for(&req).resolve(&req);
    Ok((exec, explain))
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let opts = Opts::load(args)?;
    let seed: u64 = opts.require("seed", "workload.seed")?;
    let auto = args.flag("auto");
    let input = opts.get("input", "workload.input");

    // --- resolve the execution plan (no pixels touched yet) --------------
    let (height, width, channels) = workload_dims(&opts, input.as_deref())?;
    let (exec, explain) = resolve_exec(&opts, args, auto, height, width, channels)?;
    println!(
        "plan: {} -> {} blocks (grid {}x{})",
        exec.summary(),
        explain.chosen().blocks,
        explain.chosen().grid.0,
        explain.chosen().grid.1
    );
    if auto {
        println!("planner: {}", explain.rationale());
    }
    if exec.mem_mb > 0 {
        let predicted = explain.chosen().resident_bytes as f64 / (1 << 20) as f64;
        println!(
            "memory: predicted peak resident {predicted:.1} MiB (budget {} MiB)",
            exec.mem_mb
        );
        if explain.budget_exceeded() {
            bail!(
                "no feasible plan under --mem-mb {}: the smallest candidate still needs \
                 {predicted:.1} MiB — raise the budget, lower --workers, or shrink the blocks",
                exec.mem_mb
            );
        }
    }
    check_shard_conflicts(&opts, exec.mem_mb > 0, opts.get("fault", "run.fault").is_some())?;
    if exec.checkpoint_every > 0 && opts.get("checkpoint", "run.checkpoint").is_none() {
        // A cadence with nowhere to write is a usage mistake, not a
        // silently-ignored knob.
        return Err(anyhow::Error::new(CliError::MissingRequired(
            "checkpoint".to_string(),
        )));
    }
    if args.flag("dry-run") {
        return Ok(());
    }
    if exec.mem_mb > 0 {
        // Out-of-core: pixels stream from the source into a strip store
        // (never fully resident), labels stream out through the sink.
        return stream_cluster(&opts, args, exec, input.as_deref(), seed, height, width);
    }

    // --- image -----------------------------------------------------------
    let img = match &input {
        Some(path) => {
            let img = read_ppm(Path::new(path))?;
            println!("loaded {path}: {}x{} ({} bands)", img.width(), img.height(), img.channels());
            img
        }
        None => {
            println!("generating synthetic ortho scene {width}x{height} (seed {seed})");
            SyntheticOrtho::default().with_seed(seed).generate(height, width)
        }
    };
    if let Some(p) = opts.get("out-input", "output.input") {
        write_ppm(&img, Path::new(&p))?;
        println!("wrote input scene to {p}");
    }
    let img = Arc::new(img);

    // --- run ---------------------------------------------------------------
    let fault = fault_of(&opts)?;
    check_hang_retries(&fault, exec.retries)?;
    let mut coord = Coordinator::new(CoordinatorConfig {
        exec,
        engine: engine_of(&opts)?,
        mode: opts.require::<ClusterMode>("mode", "run.mode")?,
        io: io_of(&opts, args)?,
        schedule: opts.require::<Schedule>("schedule", "run.schedule")?,
        fault,
        checkpoint: opts.get("checkpoint", "run.checkpoint").map(PathBuf::from),
        resume: opts.get("resume", "run.resume").map(PathBuf::from),
    });
    // exec.shards > 0 only when --shards was typed (the planner may
    // still have picked solo under --auto — then the run stays local).
    if exec.shards > 0 {
        let endpoints = shards_of(&opts)?.expect("exec.shards implies --shards");
        println!(
            "distributed: {} shard(s) × {} connection(s), {}",
            endpoints.shards(),
            exec.workers,
            match &endpoints {
                ShardEndpoints::Loopback { .. } => "in-process loopback".to_string(),
                ShardEndpoints::Remote { addrs } => addrs.join(", "),
            }
        );
        coord = coord.with_shards(endpoints);
    }
    let ccfg = ClusterConfig {
        k: positive(opts.require("k", "cluster.k")?, "k")?,
        max_iters: opts.require("max-iters", "cluster.max_iters")?,
        seed,
        fixed_iters: opts.parse("iters", "cluster.iters")?,
        ..Default::default()
    };
    let out = coord.cluster(&img, &ccfg)?;
    println!(
        "parallel: {} workers, {} blocks, {} iterations{} -> inertia {:.1}, {}",
        out.workers,
        out.blocks,
        out.iterations,
        if out.converged { " (converged)" } else { "" },
        out.inertia,
        duration(out.total_secs)
    );
    // Which plan ran — with predicted vs measured cost when the planner
    // chose it, so bench tables and the io line stay consistent.
    let passes = out.rounds.len().max(1);
    let actual_ns =
        (out.total_secs - out.spawn_secs).max(0.0) * 1e9 / (img.pixels() * passes) as f64;
    if auto {
        println!(
            "ran: {} | predicted {:.2} ns/px/pass, actual {:.2} ns/px/pass",
            exec.summary(),
            explain.chosen().cost.ns_per_pixel_pass,
            actual_ns
        );
    } else {
        println!("ran: {} | actual {:.2} ns/px/pass", exec.summary(), actual_ns);
    }
    if let Some(io) = out.io_stats {
        println!(
            "io: {} block reads, {} strip reads, {} bytes | strip cache: {} hits / {} misses",
            io.block_reads,
            io.strip_reads,
            io.bytes_read,
            io.strip_cache_hits,
            io.strip_cache_misses
        );
    }

    if args.flag("serial") {
        let s = coord.serial(&img, &ccfg)?;
        println!(
            "serial:   1 worker, {} iterations -> inertia {:.1}, {}",
            s.iterations,
            s.inertia,
            duration(s.total_secs)
        );
        // Native engine: bit-identical (tested invariant). PJRT engine:
        // f32 partial sums accumulate per chunk, so different block
        // partitions can differ by float-rounding — report the fraction.
        let agree = s
            .labels
            .iter()
            .zip(&out.labels)
            .filter(|(a, b)| a == b)
            .count() as f64
            / s.labels.len() as f64;
        println!(
            "label agreement with serial: {:.4}% | speedup (wall, 1-core box): {:.3}",
            agree * 100.0,
            s.total_secs / out.total_secs
        );
    }

    if let Some(p) = opts.get("out", "output.labels") {
        write_labels_ppm(&out.labels, img.height(), img.width(), Path::new(&p))?;
        println!("wrote label map to {p}");
    }
    Ok(())
}

/// The `--mem-mb` arm of `blockms cluster`: drive
/// [`Coordinator::cluster_source`] over a streaming source (PPM file or
/// synthetic generator), then report the audited peak resident bytes
/// against the budget. Labels are written strip-by-strip, so even a
/// spooled map goes disk → disk bounded.
fn stream_cluster(
    opts: &Opts,
    args: &Args,
    exec: ExecPlan,
    input: Option<&str>,
    seed: u64,
    height: usize,
    width: usize,
) -> Result<()> {
    if args.flag("serial") {
        bail!("--serial needs the whole image resident; drop --mem-mb to compare (bit-identity \
               of the streamed path is asserted by tests/integration_pipeline.rs)");
    }
    if opts.get("out-input", "output.input").is_some() {
        bail!("--out-input would materialize the scene; drop --mem-mb to dump it");
    }
    let strip_rows = match opts.parse::<usize>("strip-rows", "io.strip_rows")? {
        Some(v) => positive(v, "strip-rows")?,
        None => DEFAULT_STREAM_STRIP_ROWS,
    };
    let fault = fault_of(opts)?;
    check_hang_retries(&fault, exec.retries)?;
    let coord = Coordinator::new(CoordinatorConfig {
        exec,
        engine: engine_of(opts)?,
        mode: opts.require::<ClusterMode>("mode", "run.mode")?,
        io: IoMode::Strips {
            strip_rows,
            file_backed: exec.file_backed,
        },
        schedule: opts.require::<Schedule>("schedule", "run.schedule")?,
        fault,
        checkpoint: opts.get("checkpoint", "run.checkpoint").map(PathBuf::from),
        resume: opts.get("resume", "run.resume").map(PathBuf::from),
    });
    let ccfg = ClusterConfig {
        k: positive(opts.require("k", "cluster.k")?, "k")?,
        max_iters: opts.require("max-iters", "cluster.max_iters")?,
        seed,
        fixed_iters: opts.parse("iters", "cluster.iters")?,
        ..Default::default()
    };
    let mut source: Box<dyn RasterSource> = match input {
        Some(path) => {
            println!("streaming {path} ({width}x{height}, strips of {strip_rows} rows)");
            Box::new(PpmSource::open(Path::new(path))?)
        }
        None => {
            println!(
                "streaming synthetic ortho scene {width}x{height} (seed {seed}, strips of \
                 {strip_rows} rows)"
            );
            Box::new(SyntheticSource::new(
                &SyntheticOrtho::default().with_seed(seed),
                height,
                width,
            ))
        }
    };
    let run = coord.cluster_source(source.as_mut(), &ccfg)?;
    println!(
        "parallel: {} workers, {} blocks, {} iterations{} -> inertia {:.1}, {}",
        run.workers,
        run.blocks,
        run.iterations,
        if run.converged { " (converged)" } else { "" },
        run.inertia,
        duration(run.total_secs)
    );
    let peak = run.peak_resident_bytes as f64 / (1 << 20) as f64;
    let budget = exec.mem_mb as f64;
    println!(
        "memory: peak resident {peak:.1} MiB of {budget:.0} MiB budget ({}) | labels {}",
        if run.peak_resident_bytes <= (exec.mem_mb as u64) << 20 {
            "within budget"
        } else {
            "OVER BUDGET"
        },
        if run.labels.is_spooled() { "spooled to disk" } else { "dense" },
    );
    println!(
        "io: {} block reads, {} strip reads, {} bytes | strip cache: {} hits / {} misses",
        run.io_stats.block_reads,
        run.io_stats.strip_reads,
        run.io_stats.bytes_read,
        run.io_stats.strip_cache_hits,
        run.io_stats.strip_cache_misses
    );
    if let Some(p) = opts.get("out", "output.labels") {
        run.labels.write_labels_ppm(run.height, run.width, Path::new(&p))?;
        println!("wrote label map to {p} (streamed)");
    }
    Ok(())
}

/// Rank candidate execution plans by predicted cost and print the
/// explain table — never reads or generates pixels. `plan` is always an
/// auto resolve (ranking one pinned candidate would be vacuous); typed
/// flags still pin their axes. `--quick` pins the CI smoke geometry.
fn cmd_plan(args: &Args) -> Result<()> {
    let opts = Opts::load(args)?;
    let input = opts.get("input", "workload.input");
    let (height, width, channels) = if args.flag("quick") {
        (128, 128, 3)
    } else {
        workload_dims(&opts, input.as_deref())?
    };
    let mut req = plan_request(&opts, args, true, height, width, channels)?;
    // --quick exercises the I/O axes, and a --out bench always measures
    // through a strip store — in both cases default the strip height
    // BEFORE resolving, so the ranked table and the measured grid
    // describe the same I/O model.
    if req.strip_rows.is_none() && (args.flag("quick") || args.get("out").is_some()) {
        req = req.with_strip_rows(Some(if args.flag("quick") { 16 } else { 64 }));
    }
    let (exec, explain) = planner_for(&req).resolve(&req);
    let top = if args.flag("verbose") {
        explain.candidates.len()
    } else {
        12
    };
    print!("{}", explain.render(top));
    println!("planner: {}", explain.rationale());
    println!("plan: {}", exec.summary());

    // With --out, also run the *measured* plan bench — predicted vs
    // real wall over the candidate grid — and write the
    // `BENCH_plan.json` document. --quick pins the CI geometry;
    // otherwise the bench measures the geometry/workers/strips that
    // were just ranked (a typed --k narrows the sweep to that k;
    // --bench-iters sets the measured Lloyd rounds, like every other
    // bench).
    if let Some(out) = args.get("out") {
        use blockms::bench::plan::{render_plan_bench, write_plan_bench, PlanBenchOpts};
        let bopts = if args.flag("quick") {
            PlanBenchOpts::quick()
        } else {
            let defaults = PlanBenchOpts::default();
            PlanBenchOpts {
                height,
                width,
                ks: match opts.pinned::<usize>("k", "cluster.k")? {
                    Some(k) => vec![positive(k, "k")?],
                    None => defaults.ks.clone(),
                },
                iters: opts.require("bench-iters", "bench.iters")?,
                seed: opts.require("seed", "workload.seed")?,
                workers: req.workers.unwrap_or(defaults.workers),
                strip_rows: req.strip_rows.unwrap_or(defaults.strip_rows),
                ..defaults
            }
        };
        let (model, rows) = write_plan_bench(Path::new(out), &bopts)?;
        print!("{}", render_plan_bench(&bopts, &model, &rows));
        println!("wrote {out}");
    }
    Ok(())
}

fn sweep_opts(args: &Args) -> Result<SweepOpts> {
    let opts = Opts::load(args)?;
    Ok(SweepOpts {
        scale: opts.require("scale", "bench.scale")?,
        seed: opts.require("seed", "workload.seed")?,
        engine: opts.require("engine", "run.engine")?,
        iters: opts.require("bench-iters", "bench.iters")?,
        ..Default::default()
    })
}

fn cmd_tables(args: &Args) -> Result<()> {
    let opts = sweep_opts(args)?;
    let which = args.get("table").unwrap_or("all");
    let ids: Vec<usize> = if which == "all" {
        all_table_ids()
    } else {
        vec![which.parse().map_err(|e: std::num::ParseIntError| {
            anyhow::Error::new(CliError::BadValue(
                "table".to_string(),
                which.to_string(),
                e.to_string(),
            ))
        })?]
    };
    for id in ids {
        let text = run_table(id, &opts)?;
        println!("{text}");
    }
    // --out additionally exports every table cell as one flat CSV (the
    // spreadsheet-side view of the same sweep_all pass).
    if let Some(out) = args.get("out") {
        use blockms::bench::tables::sweep_all;
        use blockms::util::csv::Csv;
        let rows = sweep_all(&opts)?;
        let mut csv = Csv::new(&[
            "table", "approach", "k", "workers", "data_size", "serial_s", "parallel_s", "speedup",
            "efficiency", "blocks", "strip_reads_per_pass", "wall_s",
        ]);
        for (table, r) in &rows {
            csv.row([
                table.to_string(),
                r.approach.to_string(),
                r.k.to_string(),
                r.workers.to_string(),
                r.data_size.clone(),
                format!("{:.6}", r.serial_secs),
                format!("{:.6}", r.parallel_secs),
                format!("{:.4}", r.speedup),
                format!("{:.4}", r.efficiency),
                r.blocks.to_string(),
                r.strip_reads.to_string(),
                format!("{:.4}", r.wall_secs),
            ]);
        }
        csv.write_to(Path::new(out))?;
        println!("wrote {} cells to {out}", csv.len());
    }
    Ok(())
}

fn cmd_cases(args: &Args) -> Result<()> {
    let opts = sweep_opts(args)?;
    let results = cases::run_cases(&opts)?;
    print!("{}", cases::render_cases(&results));
    Ok(())
}

/// Amortized multi-variant sweep: run a `(k, seed, init)` grid over
/// one image as a single share group (one read, many models), rank the
/// variants with the quality metrics, and write `BENCH_sweep.json`
/// (see EXPERIMENTS.md §Sweep for the schema). Grid syntax errors and
/// empty grids (`--ks 8..2`, `--seeds 0`) are usage mistakes: exit 2.
fn cmd_sweep(args: &Args) -> Result<()> {
    use blockms::bench::sweep::{render_sweep_bench, write_sweep_bench, SweepBenchOpts};
    use blockms::sweep::{parse_inits, parse_ks};
    let opts = Opts::load(args)?;
    let bad = |flag: &str, raw: &str, e: &anyhow::Error| {
        anyhow::Error::new(CliError::BadValue(
            flag.to_string(),
            raw.to_string(),
            e.to_string(),
        ))
    };

    // --quick pins the CI geometry (image size, ks, iters); everything
    // the user types explicitly still wins in either mode.
    let mut bopts = if args.flag("quick") {
        SweepBenchOpts::quick()
    } else {
        SweepBenchOpts::default()
    };
    if !args.flag("quick") || args.provided("ks") {
        let raw = opts.require::<String>("ks", "sweep.ks")?;
        bopts.ks = parse_ks(&raw).map_err(|e| bad("ks", &raw, &e))?;
    }
    let raw_inits = opts.require::<String>("inits", "sweep.inits")?;
    bopts.inits = parse_inits(&raw_inits).map_err(|e| bad("inits", &raw_inits, &e))?;
    bopts.n_seeds = positive(opts.require("seeds", "sweep.seeds")?, "seeds")?;
    if let Some(seed) = opts.pinned::<u64>("seed", "workload.seed")? {
        bopts.base_seed = seed;
    }
    if let Some(h) = opts.pinned::<usize>("height", "workload.height")? {
        bopts.height = positive(h, "height")?;
    }
    if let Some(w) = opts.pinned::<usize>("width", "workload.width")? {
        bopts.width = positive(w, "width")?;
    }
    if let Some(iters) = opts.pinned::<usize>("bench-iters", "bench.iters")? {
        bopts.iters = positive(iters, "bench-iters")?;
    }
    if let Some(workers) = opts.pinned::<usize>("workers", "run.workers")? {
        bopts.workers = positive(workers, "workers")?;
    }
    if let Some(rows) = opts.pinned::<usize>("strip-rows", "io.strip_rows")? {
        bopts.strip_rows = positive(rows, "strip-rows")?;
    }
    bopts.input = args.get("input").map(PathBuf::from);

    let out = args.get("out").unwrap_or("BENCH_sweep.json").to_string();
    let res = write_sweep_bench(Path::new(&out), &bopts)?;
    print!("{}", render_sweep_bench(&bopts, &res));
    println!("wrote {out}");
    Ok(())
}

/// Kernel-layer benchmark: naive vs pruned vs fused step-round
/// throughput, written to `BENCH_kernels.json` (see EXPERIMENTS.md
/// §Kernel architecture for the schema).
fn cmd_kernels(args: &Args) -> Result<()> {
    use blockms::bench::kernels::{render_kernel_bench, write_kernel_bench, KernelBenchOpts};
    let opts = Opts::load(args)?;
    let scale: f64 = opts.require("scale", "bench.scale")?;
    let side = ((1024.0 * scale).round() as usize).max(32);
    let bopts = KernelBenchOpts {
        height: side,
        width: side,
        iters: opts.require("bench-iters", "bench.iters")?,
        seed: opts.require("seed", "workload.seed")?,
        ..Default::default()
    };
    let out = args.get("out").unwrap_or("BENCH_kernels.json").to_string();
    let rows = write_kernel_bench(Path::new(&out), &bopts)?;
    print!("{}", render_kernel_bench(&bopts, &rows));
    println!("wrote {out}");
    Ok(())
}

/// SIMD-layer benchmark: naive/lanes anchors vs the simd kernel at
/// every supported capability level, over the paper's three shapes,
/// written to `BENCH_simd.json` (see EXPERIMENTS.md §SIMD for the
/// schema). `--quick` runs the CI smoke size.
fn cmd_simd(args: &Args) -> Result<()> {
    use blockms::bench::simd::{render_simd_bench, write_simd_bench, SimdBenchOpts};
    let opts = Opts::load(args)?;
    let base = if args.flag("quick") {
        SimdBenchOpts::quick()
    } else {
        let scale: f64 = opts.require("scale", "bench.scale")?;
        let side = ((1024.0 * scale).round() as usize).max(32);
        SimdBenchOpts {
            height: side,
            width: side,
            iters: opts.require("bench-iters", "bench.iters")?,
            ..Default::default()
        }
    };
    let bopts = SimdBenchOpts {
        seed: opts.require("seed", "workload.seed")?,
        workers: positive(opts.require("workers", "run.workers")?, "workers")?,
        ..base
    };
    let out = args.get("out").unwrap_or("BENCH_simd.json").to_string();
    let rows = write_simd_bench(Path::new(&out), &bopts)?;
    print!("{}", render_simd_bench(&bopts, &rows));
    println!("wrote {out}");
    Ok(())
}

/// Layout-layer benchmark: interleaved-vs-SoA × {naive, pruned, lanes}
/// × the paper's three block shapes through a strip store, written to
/// `BENCH_layout.json` (see EXPERIMENTS.md §Layout for the schema).
/// `--quick` runs the CI smoke size.
fn cmd_layout(args: &Args) -> Result<()> {
    use blockms::bench::layout::{render_layout_bench, write_layout_bench, LayoutBenchOpts};
    let opts = Opts::load(args)?;
    // --quick pins the matrix size (image side, ks, iters, samples);
    // workers, strip-cache, and seed are honored in both modes.
    let base = if args.flag("quick") {
        LayoutBenchOpts::quick()
    } else {
        let scale: f64 = opts.require("scale", "bench.scale")?;
        let side = ((1024.0 * scale).round() as usize).max(32);
        LayoutBenchOpts {
            height: side,
            width: side,
            iters: opts.require("bench-iters", "bench.iters")?,
            ..Default::default()
        }
    };
    let bopts = LayoutBenchOpts {
        seed: opts.require("seed", "workload.seed")?,
        workers: positive(opts.require("workers", "run.workers")?, "workers")?,
        cache_strips: opts.parse::<usize>("strip-cache", "io.strip_cache")?.unwrap_or(0),
        ..base
    };
    let out = args.get("out").unwrap_or("BENCH_layout.json").to_string();
    let rows = write_layout_bench(Path::new(&out), &bopts)?;
    print!("{}", render_layout_bench(&bopts, &rows));
    println!("wrote {out}");
    Ok(())
}

/// Streaming-layer benchmark: streamed vs in-memory pipeline at the
/// acceptance geometries (1024² and a 4096×1024 tall case), written to
/// `BENCH_stream.json` (see EXPERIMENTS.md §Streaming for the schema).
/// `--quick` runs the CI smoke size.
fn cmd_stream(args: &Args) -> Result<()> {
    use blockms::bench::stream::{render_stream_bench, write_stream_bench, StreamBenchOpts};
    let opts = Opts::load(args)?;
    let base = if args.flag("quick") {
        StreamBenchOpts::quick()
    } else {
        StreamBenchOpts::default()
    };
    let bopts = StreamBenchOpts {
        seed: opts.require("seed", "workload.seed")?,
        workers: positive(opts.require("workers", "run.workers")?, "workers")?,
        ..base
    };
    let out = args.get("out").unwrap_or("BENCH_stream.json").to_string();
    let rows = write_stream_bench(Path::new(&out), &bopts)?;
    print!("{}", render_stream_bench(&bopts, &rows));
    println!("wrote {out}");
    Ok(())
}

/// Service-layer benchmark: multi-job throughput over one shared pool at
/// pool sizes × batch sizes, written to `BENCH_service.json` (see
/// EXPERIMENTS.md §Service for the schema).
fn cmd_batch(args: &Args) -> Result<()> {
    let opts = Opts::load(args)?;
    let scale: f64 = opts.require("scale", "bench.scale")?;
    let side = ((1024.0 * scale).round() as usize).max(32);
    // `--input scene.ppm` benches service throughput over a real file
    // (geometry from the header) instead of synthetic scenes.
    let input = opts.get("input", "workload.input");
    let (bench_h, bench_w) = match &input {
        Some(p) => {
            let (h, w, _) = ppm_dims(Path::new(p))?;
            (h, w)
        }
        None => (side, side),
    };
    let bopts = ServiceBenchOpts {
        height: bench_h,
        width: bench_w,
        input: input.map(std::path::PathBuf::from),
        k: positive(opts.require("k", "cluster.k")?, "k")?,
        iters: opts.require("bench-iters", "bench.iters")?,
        seed: opts.require("seed", "workload.seed")?,
        pool_sizes: parse_usize_list(&opts.require::<String>("pools", "bench.pools")?, "pools")?,
        batch_sizes: parse_usize_list(
            &opts.require::<String>("batches", "bench.batches")?,
            "batches",
        )?,
        kernel: opts.require("kernel", "run.kernel")?,
        schedule: opts.require("schedule", "run.schedule")?,
    };
    let out = args.get("out").unwrap_or("BENCH_service.json").to_string();
    let rows = write_service_bench(Path::new(&out), &bopts)?;
    print!("{}", render_service_bench(&bopts, &rows));
    println!("wrote {out}");
    Ok(())
}

/// Drive N jobs through one persistent shared pool, printing per-job
/// latency and aggregate throughput.
fn cmd_serve(args: &Args) -> Result<()> {
    let opts = Opts::load(args)?;
    let workers: usize = positive(opts.require("workers", "run.workers")?, "workers")?;
    let jobs: usize = positive(opts.require("jobs", "serve.jobs")?, "jobs")?;
    let max_in_flight: usize = positive(
        opts.require("max-in-flight", "serve.max_in_flight")?,
        "max-in-flight",
    )?;
    let k: usize = positive(opts.require("k", "cluster.k")?, "k")?;
    let seed: u64 = opts.require("seed", "workload.seed")?;
    let auto = args.flag("auto");
    let mode = opts.require::<ClusterMode>("mode", "run.mode")?;
    let schedule = opts.require::<Schedule>("schedule", "run.schedule")?;
    let io = io_of(&opts, args)?;
    let engine = engine_of(&opts)?;
    let max_iters: usize = opts.require("max-iters", "cluster.max_iters")?;
    let fixed_iters: Option<usize> = opts.parse("iters", "cluster.iters")?;

    // One shared input image, or a distinct synthetic scene per job.
    // Under --mem-mb nothing is materialized here: jobs are admitted by
    // path (or generator description) and stream at activation.
    let input = opts.get("input", "workload.input");
    let streaming = opts.parse::<usize>("mem-mb", "run.mem_mb")?.is_some();
    let base: Option<Arc<Raster>> = match &input {
        Some(path) if !streaming => {
            let img = read_ppm(Path::new(path))?;
            println!("loaded {path}: {}x{} ({} bands)", img.width(), img.height(), img.channels());
            Some(Arc::new(img))
        }
        Some(path) => {
            let (h, w, c) = ppm_dims(Path::new(path))?;
            println!("admitting {path} by header: {w}x{h} ({c} bands), pixels stream per job");
            None
        }
        None => None,
    };
    // Every job shares one geometry, so the admission path resolves ONE
    // ExecPlan up front and embeds it in every spec — the same resolve
    // the solo coordinator would do (tested identical in
    // tests/plan_resolution.rs).
    let (height, width, channels) = match &base {
        Some(img) => (img.height(), img.width(), img.channels()),
        None => workload_dims(&opts, input.as_deref())?,
    };
    let mut req = plan_request(&opts, args, auto, height, width, channels)?;
    // The shared pool's width is explicit here; the plan must agree.
    req.workers = Some(workers);
    let (exec, explain) = planner_for(&req).resolve(&req);
    println!("plan: {}", exec.summary());
    if auto {
        println!("planner: {}", explain.rationale());
    }
    if exec.mem_mb > 0 && explain.budget_exceeded() {
        bail!(
            "no feasible plan under --mem-mb {} for this geometry (smallest candidate needs \
             {:.1} MiB)",
            exec.mem_mb,
            explain.chosen().resident_bytes as f64 / (1 << 20) as f64
        );
    }
    let stream_strip_rows = match opts.parse::<usize>("strip-rows", "io.strip_rows")? {
        Some(v) => positive(v, "strip-rows")?,
        None => DEFAULT_STREAM_STRIP_ROWS,
    };
    let fault = fault_of(&opts)?;
    check_hang_retries(&fault, exec.retries)?;
    check_shard_conflicts(&opts, streaming, fault.is_some())?;
    let drain_timeout: u64 = opts.require("drain-timeout", "serve.drain_timeout")?;
    // `--checkpoint P` under serve is the deadline escape hatch: a job
    // that hits `--deadline-ms` snapshots its last round boundary to
    // P.jobN and stays resumable via `cluster --resume`.
    let deadline_ckpt = opts.get("checkpoint", "run.checkpoint");

    let shard_endpoints = (exec.shards > 0)
        .then(|| shards_of(&opts))
        .transpose()?
        .flatten();
    let server = ClusterServer::try_start(ServerConfig {
        workers,
        schedule,
        max_in_flight,
        shards: shard_endpoints.clone(),
        heartbeat_ms: exec.heartbeat_ms,
    })?;
    println!(
        "serving {jobs} jobs over a {workers}-worker pool (admission cap {max_in_flight}, {schedule:?} schedule)"
    );
    if let Some(endpoints) = &shard_endpoints {
        println!(
            "distributed: {} shard(s) × {workers} connection(s) each",
            endpoints.shards()
        );
    }
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(jobs);
    for j in 0..jobs {
        let job_seed = seed.wrapping_add(j as u64);
        let ccfg = ClusterConfig {
            k,
            max_iters,
            seed: job_seed,
            fixed_iters,
            ..Default::default()
        };
        let mut spec = if exec.mem_mb > 0 {
            // Streamed admission: path or generator description only;
            // each job's pixels decode at activation, strip by strip.
            let stream_io = IoMode::Strips {
                strip_rows: stream_strip_rows,
                file_backed: exec.file_backed,
            };
            match &input {
                Some(path) => JobSpec::from_ppm(Path::new(path), exec, ccfg)?,
                None => JobSpec::from_synthetic(
                    SyntheticOrtho::default().with_seed(job_seed),
                    height,
                    width,
                    exec,
                    ccfg,
                ),
            }
            .with_mode(mode)
            .with_io(stream_io)
            .with_engine(engine.clone())
        } else {
            let img = match &base {
                Some(img) => Arc::clone(img),
                None => Arc::new(
                    SyntheticOrtho::default()
                        .with_seed(job_seed)
                        .generate(height, width),
                ),
            };
            JobSpec::new(img, exec, ccfg)
                .with_mode(mode)
                .with_io(io.clone())
                .with_engine(engine.clone())
        };
        if let Some(f) = &fault {
            spec = spec.with_fault(f.clone());
        }
        if let Some(base) = &deadline_ckpt {
            spec = spec.with_deadline_checkpoint(PathBuf::from(format!("{base}.job{j}")));
        }
        // Blocks while the admission gate is full — the backpressure path.
        handles.push(server.submit(spec)?);
    }
    for (j, h) in handles.iter().enumerate() {
        match h.wait() {
            JobStatus::Done(out) => println!(
                "job {j:>3}: {} blocks, {} iterations{} -> inertia {:.1}, latency {}",
                out.blocks,
                out.iterations,
                if out.converged { " (converged)" } else { "" },
                out.inertia,
                duration(out.total_secs)
            ),
            JobStatus::Deadline { checkpoint: Some(p) } => println!(
                "job {j:>3}: deadline hit -> checkpointed to {} (resumable)",
                p.display()
            ),
            JobStatus::Deadline { checkpoint: None } => {
                println!("job {j:>3}: deadline hit; progress discarded (no --checkpoint)")
            }
            JobStatus::Cancelled => println!("job {j:>3}: cancelled (shed by admission)"),
            JobStatus::Failed(msg) => bail!("job {j} failed: {msg}"),
            s @ (JobStatus::Queued | JobStatus::Running) => {
                bail!("job {j}: wait() returned non-terminal status {}", s.label())
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!(
        "aggregate: {} jobs in {} -> {:.2} jobs/s | max open jobs {} (cap {}) | shed {} | deadlined {}",
        jobs,
        duration(wall),
        jobs as f64 / wall,
        stats.max_open_jobs,
        max_in_flight,
        stats.shed,
        stats.deadlined
    );
    // Graceful drain instead of a bare shutdown: every still-open job
    // finishes, checkpoints, or is cancelled inside the budget, and each
    // disposition is reported (here all jobs were already waited on, so
    // the report is normally empty — the drill is `tests/hardening.rs`).
    let report = server.drain(std::time::Duration::from_millis(drain_timeout));
    for (id, what) in &report.dispositions {
        println!("drain: job #{id}: {what}");
    }
    Ok(())
}

/// Host shard-side block compute: bind `--listen` (a UDS path or
/// `host:port`) and serve leader connections until killed (`--once`
/// exits after the first leader disconnects — the CI drill mode).
/// A missing `--listen` is a usage error, exit 2.
fn cmd_shard_worker(args: &Args) -> Result<()> {
    let opts = Opts::load(args)?;
    let listen = opts
        .get("listen", "shard.listen")
        .ok_or_else(|| anyhow::Error::new(CliError::MissingRequired("listen".to_string())))?;
    run_listener(&listen, args.flag("once"))
}

/// Distributed-scaling benchmark: solo vs loopback shard counts with
/// per-row bit-identity checks and closed-form wire-byte validation,
/// written to `BENCH_distributed.json` (see EXPERIMENTS.md §Distributed
/// for the schema). `--quick` runs the CI smoke size.
fn cmd_distributed(args: &Args) -> Result<()> {
    use blockms::bench::distributed::{
        render_distributed_bench, write_distributed_bench, DistributedBenchOpts,
    };
    let opts = Opts::load(args)?;
    let base = if args.flag("quick") {
        DistributedBenchOpts::quick()
    } else {
        let scale: f64 = opts.require("scale", "bench.scale")?;
        let side = ((1024.0 * scale).round() as usize).max(32);
        DistributedBenchOpts {
            height: side,
            width: side,
            iters: opts.require("bench-iters", "bench.iters")?,
            ..Default::default()
        }
    };
    let bopts = DistributedBenchOpts {
        seed: opts.require("seed", "workload.seed")?,
        conns_per_shard: positive(opts.require("workers", "run.workers")?, "workers")?,
        ..base
    };
    let out = args.get("out").unwrap_or("BENCH_distributed.json").to_string();
    let rows = write_distributed_bench(Path::new(&out), &bopts)?;
    print!("{}", render_distributed_bench(&bopts, &rows));
    println!("wrote {out}");
    Ok(())
}

/// Resilience-layer benchmark: fault-free baseline vs retry vs
/// checkpoint vs kill/resume overhead and recovery latency, written to
/// `BENCH_resilience.json` (see EXPERIMENTS.md §Resilience for the
/// schema). `--quick` runs the CI smoke size.
fn cmd_resilience(args: &Args) -> Result<()> {
    use blockms::bench::resilience::{
        render_resilience_bench, write_resilience_bench, ResilienceBenchOpts,
    };
    let opts = Opts::load(args)?;
    let base = if args.flag("quick") {
        ResilienceBenchOpts::quick()
    } else {
        ResilienceBenchOpts::default()
    };
    let bopts = ResilienceBenchOpts {
        seed: opts.require("seed", "workload.seed")?,
        workers: positive(opts.require("workers", "run.workers")?, "workers")?,
        // The CLI default --retries 0 would make the retry scenario
        // vacuous; only a typed flag (or config key) overrides the
        // bench's own budget.
        retries: match opts.pinned::<usize>("retries", "run.retries")? {
            Some(r) => positive(r, "retries")?,
            None => base.retries,
        },
        ..base
    };
    let out = args.get("out").unwrap_or("BENCH_resilience.json").to_string();
    let rows = write_resilience_bench(Path::new(&out), &bopts)?;
    print!("{}", render_resilience_bench(&bopts, &rows));
    println!("wrote {out}");
    Ok(())
}

/// Liveness-hardening benchmark: watchdog + speculation overhead when
/// nothing fails, recovery latency with 1/2/4 hung workers, and the QoS
/// shed/served mix under 2× overload, written to `BENCH_hardening.json`
/// (see EXPERIMENTS.md §Hardening for the schema). `--quick` runs the
/// CI smoke size.
fn cmd_hardening(args: &Args) -> Result<()> {
    use blockms::bench::hardening::{
        render_hardening_bench, write_hardening_bench, HardeningBenchOpts,
    };
    let opts = Opts::load(args)?;
    let base = if args.flag("quick") {
        HardeningBenchOpts::quick()
    } else {
        HardeningBenchOpts::default()
    };
    let bopts = HardeningBenchOpts {
        seed: opts.require("seed", "workload.seed")?,
        workers: positive(opts.require("workers", "run.workers")?, "workers")?,
        ..base
    };
    let out = args.get("out").unwrap_or("BENCH_hardening.json").to_string();
    let rows = write_hardening_bench(Path::new(&out), &bopts)?;
    print!("{}", render_hardening_bench(&bopts, &rows));
    println!("wrote {out}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("blockms {}", env!("CARGO_PKG_VERSION"));
    match find_artifacts_dir() {
        Some(dir) => {
            println!("artifacts: {}", dir.display());
            match ArtifactSet::load(&dir) {
                Ok(set) => {
                    let m = &set.manifest;
                    println!(
                        "  manifest ok: chunk={} channels={} ks={:?} local_iters={}",
                        m.chunk, m.channels, m.ks, m.local_iters
                    );
                    for a in m.artifacts() {
                        println!("  {} ({} -> {} tensors)", a.name, a.inputs.len(), a.outputs.len());
                    }
                }
                Err(e) => println!("  INVALID: {e:#}"),
            }
        }
        None => println!("artifacts: NOT FOUND (run `make artifacts`)"),
    }
    println!("cores visible: {}", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0));
    Ok(())
}
