//! Shared, thread-safe access counters for a strip store.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::mem::ResidentGauge;

/// Counters shared by every [`super::StripReader`] of a store.
/// All counters are monotonic; `snapshot()` gives a consistent-enough
/// view for reporting (exact consistency is not needed — these feed
/// tables, not control flow).
///
/// Besides the monotone I/O counters, the stats carry the store's
/// [`ResidentGauge`]: every pixel-holding buffer of the pipeline
/// (ingestion strip, memory-backed store, reader strip/block buffers,
/// decoded-strip cache entries) records against it, and the high-water
/// mark lands in [`AccessSnapshot::peak_resident_bytes`] — the audited
/// side of the `--mem-mb` budget.
#[derive(Debug, Default)]
pub struct AccessStats {
    strip_reads: AtomicU64,
    block_reads: AtomicU64,
    bytes_read: AtomicU64,
    strip_cache_hits: AtomicU64,
    strip_cache_misses: AtomicU64,
    resident: ResidentGauge,
}

/// A point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AccessSnapshot {
    pub strip_reads: u64,
    pub block_reads: u64,
    pub bytes_read: u64,
    /// Strip accesses served from the shared [`super::StripCache`]
    /// without a decode/transfer. Zero when the store has no cache.
    pub strip_cache_hits: u64,
    /// Strip accesses that went to the backing despite the cache.
    pub strip_cache_misses: u64,
    /// High-water mark of tracked pixel-holding bytes (store + buffers
    /// + cache). The accounting side of the `--mem-mb` contract.
    pub peak_resident_bytes: u64,
}

impl AccessStats {
    pub fn new_shared() -> Arc<AccessStats> {
        Arc::new(AccessStats::default())
    }

    pub fn record_strip_read(&self, bytes: usize) {
        self.strip_reads.fetch_add(1, Ordering::Relaxed);
        self.bytes_read.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_block_read(&self) {
        self.block_reads.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_cache_hit(&self) {
        self.strip_cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_cache_miss(&self) {
        self.strip_cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// The shared resident-byte gauge (see [`ResidentGauge`]).
    pub fn resident(&self) -> &ResidentGauge {
        &self.resident
    }

    pub fn snapshot(&self) -> AccessSnapshot {
        AccessSnapshot {
            strip_reads: self.strip_reads.load(Ordering::Relaxed),
            block_reads: self.block_reads.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            strip_cache_hits: self.strip_cache_hits.load(Ordering::Relaxed),
            strip_cache_misses: self.strip_cache_misses.load(Ordering::Relaxed),
            peak_resident_bytes: self.resident.peak(),
        }
    }

    pub fn reset(&self) {
        self.strip_reads.store(0, Ordering::Relaxed);
        self.block_reads.store(0, Ordering::Relaxed);
        self.bytes_read.store(0, Ordering::Relaxed);
        self.strip_cache_hits.store(0, Ordering::Relaxed);
        self.strip_cache_misses.store(0, Ordering::Relaxed);
        self.resident.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let s = AccessStats::default();
        s.record_strip_read(100);
        s.record_strip_read(50);
        s.record_block_read();
        let snap = s.snapshot();
        assert_eq!(snap.strip_reads, 2);
        assert_eq!(snap.block_reads, 1);
        assert_eq!(snap.bytes_read, 150);
    }

    #[test]
    fn reset_zeroes() {
        let s = AccessStats::default();
        s.record_strip_read(10);
        s.record_cache_hit();
        s.record_cache_miss();
        assert_eq!(s.snapshot().strip_cache_hits, 1);
        assert_eq!(s.snapshot().strip_cache_misses, 1);
        s.reset();
        assert_eq!(s.snapshot().strip_reads, 0);
        assert_eq!(s.snapshot().bytes_read, 0);
        assert_eq!(s.snapshot().strip_cache_hits, 0);
        assert_eq!(s.snapshot().strip_cache_misses, 0);
    }

    #[test]
    fn concurrent_counting_is_exact() {
        let s = AccessStats::new_shared();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    s.record_strip_read(8);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.snapshot().strip_reads, 4000);
        assert_eq!(s.snapshot().bytes_read, 32000);
    }
}
