//! The strip store: a raster persisted as full-width row strips.

use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::cache::StripCache;
use super::reader::StripReader;
use super::stats::AccessStats;
use crate::image::{Raster, RasterSource};

/// Where the strip data lives.
#[derive(Clone, Debug)]
pub enum Backing {
    /// Strips held in memory (fast; still counts accesses).
    Memory,
    /// Strips written to a real file of little-endian f32 samples in the
    /// given directory; readers `seek + read` per strip. This is the mode
    /// the Cases 1–3 experiment uses, making read-amplification cost real.
    File(PathBuf),
}

/// Immutable strip-organized image storage. Cheap to clone handles from
/// via [`StripStore::reader`]; all readers share one [`AccessStats`].
pub struct StripStore {
    height: usize,
    width: usize,
    channels: usize,
    strip_rows: usize,
    backing: StoreData,
    stats: Arc<AccessStats>,
    /// Shared decoded-strip LRU (None = every read hits the backing,
    /// the seed behaviour; see [`StripCache`]).
    cache: Option<Arc<StripCache>>,
}

pub(super) enum StoreData {
    Memory(Arc<Vec<f32>>),
    File { path: PathBuf },
}

/// Borrowing row cursor so [`StripStore::new`] can reuse the streaming
/// ingest path without requiring an `Arc` (one write path means the
/// in-memory and out-of-core builds cannot diverge in strip layout).
struct BorrowedRaster<'a> {
    img: &'a Raster,
    next_row: usize,
}

impl RasterSource for BorrowedRaster<'_> {
    fn height(&self) -> usize {
        self.img.height()
    }

    fn width(&self) -> usize {
        self.img.width()
    }

    fn channels(&self) -> usize {
        self.img.channels()
    }

    fn next_strip(&mut self, max_rows: usize, out: &mut Vec<f32>) -> Result<usize> {
        out.clear();
        let rows = max_rows.min(self.img.height() - self.next_row);
        if rows == 0 {
            return Ok(0);
        }
        let per_row = self.img.width() * self.img.channels();
        let start = self.next_row * per_row;
        out.extend_from_slice(&self.img.data()[start..start + rows * per_row]);
        self.next_row += rows;
        Ok(rows)
    }
}

impl StripStore {
    /// Persist `img` as strips of `strip_rows` rows. Equivalent to
    /// [`StripStore::ingest`] over an in-memory cursor — same write
    /// path, same on-disk layout.
    pub fn new(img: &Raster, strip_rows: usize, backing: Backing) -> Result<StripStore> {
        StripStore::ingest(
            &mut BorrowedRaster { img, next_row: 0 },
            strip_rows,
            backing,
            |_, _| {},
        )
    }

    /// Build a store by pulling strips sequentially from any
    /// [`RasterSource`]. With [`Backing::File`] the source's pixels are
    /// written through a bounded buffer — peak resident pixel bytes of
    /// ingestion are ~2 strips (decoded f32 + encode bytes) regardless
    /// of image height; [`Backing::Memory`] necessarily holds the whole
    /// image (the back-compat mode a `--mem-mb` planner avoids for
    /// over-budget images). Every buffer is recorded against the
    /// store's [`crate::util::mem::ResidentGauge`].
    ///
    /// `tap(first_row, samples)` observes each decoded strip exactly
    /// once, in order — the single-pass hook the streaming centroid
    /// init rides on.
    pub fn ingest<S>(
        source: &mut S,
        strip_rows: usize,
        backing: Backing,
        mut tap: impl FnMut(usize, &[f32]),
    ) -> Result<StripStore>
    where
        S: RasterSource + ?Sized,
    {
        assert!(strip_rows > 0, "strip_rows must be positive");
        let (height, width, channels) = (source.height(), source.width(), source.channels());
        assert!(height > 0 && width > 0 && channels > 0, "degenerate source");
        let stats = AccessStats::new_shared();
        let gauge = stats.resident();
        let mut strip: Vec<f32> = Vec::new();
        let mut first_row = 0usize;
        let data = match backing {
            Backing::Memory => {
                let mut all: Vec<f32> = Vec::with_capacity(height * width * channels);
                loop {
                    let rows = source.next_strip(strip_rows, &mut strip)?;
                    if rows == 0 {
                        break;
                    }
                    ensure!(
                        strip.len() == rows * width * channels,
                        "strip at row {first_row}: {} samples, want {}",
                        strip.len(),
                        rows * width * channels
                    );
                    let sb = (strip.len() * 4) as u64;
                    gauge.add(sb); // transient decode buffer
                    tap(first_row, &strip);
                    all.extend_from_slice(&strip);
                    gauge.add(sb); // now resident in the store
                    gauge.sub(sb); // transient buffer recycled
                    first_row += rows;
                }
                ensure!(
                    first_row == height,
                    "source ended at row {first_row} of {height}"
                );
                StoreData::Memory(Arc::new(all))
            }
            Backing::File(dir) => {
                std::fs::create_dir_all(&dir)
                    .with_context(|| format!("create {}", dir.display()))?;
                let path = dir.join(format!(
                    "strips_{height}x{width}x{channels}_{strip_rows}.f32le"
                ));
                let f = std::fs::File::create(&path)
                    .with_context(|| format!("create {}", path.display()))?;
                let mut w = std::io::BufWriter::new(f);
                let mut bytes: Vec<u8> = Vec::new();
                loop {
                    let rows = source.next_strip(strip_rows, &mut strip)?;
                    if rows == 0 {
                        break;
                    }
                    ensure!(
                        strip.len() == rows * width * channels,
                        "strip at row {first_row}: {} samples, want {}",
                        strip.len(),
                        rows * width * channels
                    );
                    let sb = (strip.len() * 4) as u64;
                    gauge.add(2 * sb); // decoded f32 strip + encode bytes
                    tap(first_row, &strip);
                    bytes.clear();
                    bytes.extend(strip.iter().flat_map(|v| v.to_le_bytes()));
                    w.write_all(&bytes)?;
                    gauge.sub(2 * sb); // both buffers recycled
                    first_row += rows;
                }
                ensure!(
                    first_row == height,
                    "source ended at row {first_row} of {height}"
                );
                w.flush()?;
                StoreData::File { path }
            }
        };
        Ok(StripStore {
            height,
            width,
            channels,
            strip_rows,
            backing: data,
            stats,
            cache: None,
        })
    }

    /// Attach a shared decoded-strip LRU of `cap_strips` capacity
    /// (0 = no cache). Call before handing out readers: a reader opened
    /// earlier keeps reading uncached.
    pub fn enable_cache(&mut self, cap_strips: usize) {
        self.cache = (cap_strips > 0).then(|| Arc::new(StripCache::new(cap_strips)));
    }

    /// The shared strip cache, if one was enabled.
    pub fn cache(&self) -> Option<&Arc<StripCache>> {
        self.cache.as_ref()
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    pub fn strip_rows(&self) -> usize {
        self.strip_rows
    }

    /// Total strip count.
    pub fn strips(&self) -> usize {
        self.height.div_ceil(self.strip_rows)
    }

    /// Row extent of strip `s`: `(first_row, rows_in_strip)`.
    pub fn strip_extent(&self, s: usize) -> (usize, usize) {
        let first = s * self.strip_rows;
        assert!(first < self.height, "strip {s} out of range");
        (first, self.strip_rows.min(self.height - first))
    }

    /// Samples (f32 count) in strip `s`.
    pub fn strip_len(&self, s: usize) -> usize {
        let (_, rows) = self.strip_extent(s);
        rows * self.width * self.channels
    }

    /// Byte offset of strip `s` in the file layout.
    pub fn strip_offset_bytes(&self, s: usize) -> u64 {
        (s * self.strip_rows * self.width * self.channels * 4) as u64
    }

    pub fn stats(&self) -> &Arc<AccessStats> {
        &self.stats
    }

    /// Open an independent reader (per worker: own file handle, shared
    /// counters).
    pub fn reader(&self) -> Result<StripReader> {
        StripReader::open(self)
    }

    pub(super) fn data(&self) -> &StoreData {
        &self.backing
    }

    /// Path of the backing file (None for memory backing).
    pub fn file_path(&self) -> Option<&std::path::Path> {
        match &self.backing {
            StoreData::File { path } => Some(path),
            StoreData::Memory(_) => None,
        }
    }
}

impl Drop for StripStore {
    fn drop(&mut self) {
        if let StoreData::File { path } = &self.backing {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::SyntheticOrtho;

    #[test]
    fn strip_geometry() {
        let img = SyntheticOrtho::default().with_seed(1).generate(10, 6);
        let st = StripStore::new(&img, 4, Backing::Memory).unwrap();
        assert_eq!(st.strips(), 3);
        assert_eq!(st.strip_extent(0), (0, 4));
        assert_eq!(st.strip_extent(2), (8, 2)); // partial tail strip
        assert_eq!(st.strip_len(2), 2 * 6 * 3);
        assert_eq!(st.strip_offset_bytes(1), (4 * 6 * 3 * 4) as u64);
    }

    #[test]
    fn file_backing_creates_and_cleans_up() {
        let img = SyntheticOrtho::default().with_seed(2).generate(8, 8);
        let dir = std::env::temp_dir().join("blockms_store_test");
        let path;
        {
            let st = StripStore::new(&img, 4, Backing::File(dir.clone())).unwrap();
            path = st.file_path().unwrap().to_path_buf();
            assert!(path.exists());
            let len = std::fs::metadata(&path).unwrap().len();
            assert_eq!(len, (8 * 8 * 3 * 4) as u64);
        }
        assert!(!path.exists(), "backing file not cleaned up");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn strip_extent_bounds() {
        let img = SyntheticOrtho::default().generate(10, 6);
        let st = StripStore::new(&img, 4, Backing::Memory).unwrap();
        st.strip_extent(3);
    }

    #[test]
    fn ingest_writes_the_same_file_as_new() {
        // One write path is the claim; this pins it byte-for-byte.
        let gen = SyntheticOrtho::default().with_seed(6);
        let img = gen.generate(13, 9);
        let dir_a = std::env::temp_dir().join("blockms_ingest_a");
        let dir_b = std::env::temp_dir().join("blockms_ingest_b");
        let a = StripStore::new(&img, 4, Backing::File(dir_a)).unwrap();
        let mut src = crate::image::SyntheticSource::new(&gen, 13, 9);
        let b = StripStore::ingest(&mut src, 4, Backing::File(dir_b), |_, _| {}).unwrap();
        let bytes_a = std::fs::read(a.file_path().unwrap()).unwrap();
        let bytes_b = std::fs::read(b.file_path().unwrap()).unwrap();
        assert_eq!(bytes_a, bytes_b);
        assert_eq!((b.height(), b.width(), b.channels()), (13, 9, 3));
    }

    #[test]
    fn ingest_tap_sees_every_strip_once_in_order() {
        let gen = SyntheticOrtho::default().with_seed(7);
        let mut src = crate::image::SyntheticSource::new(&gen, 10, 6);
        let mut rows_seen = Vec::new();
        let mut samples = 0usize;
        let st = StripStore::ingest(&mut src, 4, Backing::Memory, |first_row, strip| {
            rows_seen.push(first_row);
            samples += strip.len();
        })
        .unwrap();
        assert_eq!(rows_seen, vec![0, 4, 8]);
        assert_eq!(samples, 10 * 6 * 3);
        assert_eq!(st.strips(), 3);
    }

    #[test]
    fn file_ingest_peak_resident_is_strip_bounded() {
        // The out-of-core promise: a tall image ingests file-backed in
        // ~2 strips of resident pixel bytes, independent of height.
        let gen = SyntheticOrtho::default().with_seed(8);
        let (h, w, strip_rows) = (512usize, 8usize, 8usize);
        let dir = std::env::temp_dir().join("blockms_ingest_peak");
        let mut src = crate::image::SyntheticSource::new(&gen, h, w);
        let st = StripStore::ingest(&mut src, strip_rows, Backing::File(dir), |_, _| {}).unwrap();
        let peak = st.stats().snapshot().peak_resident_bytes;
        let strip_bytes = (strip_rows * w * 3 * 4) as u64;
        let image_bytes = (h * w * 3 * 4) as u64;
        assert!(peak <= 2 * strip_bytes, "peak {peak} > 2 strips {strip_bytes}");
        assert!(peak < image_bytes / 8, "peak {peak} not height-independent");
    }

    #[test]
    fn memory_ingest_accounts_the_whole_image() {
        let gen = SyntheticOrtho::default().with_seed(9);
        let mut src = crate::image::SyntheticSource::new(&gen, 16, 8);
        let st = StripStore::ingest(&mut src, 4, Backing::Memory, |_, _| {}).unwrap();
        let peak = st.stats().snapshot().peak_resident_bytes;
        assert!(peak >= (16 * 8 * 3 * 4) as u64, "memory store must show up: {peak}");
    }
}
