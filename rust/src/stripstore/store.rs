//! The strip store: a raster persisted as full-width row strips.

use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::cache::StripCache;
use super::reader::StripReader;
use super::stats::AccessStats;
use crate::image::Raster;

/// Where the strip data lives.
#[derive(Clone, Debug)]
pub enum Backing {
    /// Strips held in memory (fast; still counts accesses).
    Memory,
    /// Strips written to a real file of little-endian f32 samples in the
    /// given directory; readers `seek + read` per strip. This is the mode
    /// the Cases 1–3 experiment uses, making read-amplification cost real.
    File(PathBuf),
}

/// Immutable strip-organized image storage. Cheap to clone handles from
/// via [`StripStore::reader`]; all readers share one [`AccessStats`].
pub struct StripStore {
    height: usize,
    width: usize,
    channels: usize,
    strip_rows: usize,
    backing: StoreData,
    stats: Arc<AccessStats>,
    /// Shared decoded-strip LRU (None = every read hits the backing,
    /// the seed behaviour; see [`StripCache`]).
    cache: Option<Arc<StripCache>>,
}

pub(super) enum StoreData {
    Memory(Arc<Vec<f32>>),
    File { path: PathBuf },
}

impl StripStore {
    /// Persist `img` as strips of `strip_rows` rows.
    pub fn new(img: &Raster, strip_rows: usize, backing: Backing) -> Result<StripStore> {
        assert!(strip_rows > 0, "strip_rows must be positive");
        let stats = AccessStats::new_shared();
        let data = match backing {
            Backing::Memory => StoreData::Memory(Arc::new(img.data().to_vec())),
            Backing::File(dir) => {
                std::fs::create_dir_all(&dir)
                    .with_context(|| format!("create {}", dir.display()))?;
                let path = dir.join(format!(
                    "strips_{}x{}x{}_{}.f32le",
                    img.height(),
                    img.width(),
                    img.channels(),
                    strip_rows
                ));
                let f = std::fs::File::create(&path)
                    .with_context(|| format!("create {}", path.display()))?;
                let mut w = std::io::BufWriter::new(f);
                // Raster data is already row-major — strips are contiguous
                // runs; write the whole buffer in strip-sized chunks so
                // the on-disk layout *is* the strip layout.
                for chunk in img
                    .data()
                    .chunks(strip_rows * img.width() * img.channels())
                {
                    let bytes: Vec<u8> = chunk.iter().flat_map(|v| v.to_le_bytes()).collect();
                    w.write_all(&bytes)?;
                }
                w.flush()?;
                StoreData::File { path }
            }
        };
        Ok(StripStore {
            height: img.height(),
            width: img.width(),
            channels: img.channels(),
            strip_rows,
            backing: data,
            stats,
            cache: None,
        })
    }

    /// Attach a shared decoded-strip LRU of `cap_strips` capacity
    /// (0 = no cache). Call before handing out readers: a reader opened
    /// earlier keeps reading uncached.
    pub fn enable_cache(&mut self, cap_strips: usize) {
        self.cache = (cap_strips > 0).then(|| Arc::new(StripCache::new(cap_strips)));
    }

    /// The shared strip cache, if one was enabled.
    pub fn cache(&self) -> Option<&Arc<StripCache>> {
        self.cache.as_ref()
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    pub fn strip_rows(&self) -> usize {
        self.strip_rows
    }

    /// Total strip count.
    pub fn strips(&self) -> usize {
        self.height.div_ceil(self.strip_rows)
    }

    /// Row extent of strip `s`: `(first_row, rows_in_strip)`.
    pub fn strip_extent(&self, s: usize) -> (usize, usize) {
        let first = s * self.strip_rows;
        assert!(first < self.height, "strip {s} out of range");
        (first, self.strip_rows.min(self.height - first))
    }

    /// Samples (f32 count) in strip `s`.
    pub fn strip_len(&self, s: usize) -> usize {
        let (_, rows) = self.strip_extent(s);
        rows * self.width * self.channels
    }

    /// Byte offset of strip `s` in the file layout.
    pub fn strip_offset_bytes(&self, s: usize) -> u64 {
        (s * self.strip_rows * self.width * self.channels * 4) as u64
    }

    pub fn stats(&self) -> &Arc<AccessStats> {
        &self.stats
    }

    /// Open an independent reader (per worker: own file handle, shared
    /// counters).
    pub fn reader(&self) -> Result<StripReader> {
        StripReader::open(self)
    }

    pub(super) fn data(&self) -> &StoreData {
        &self.backing
    }

    /// Path of the backing file (None for memory backing).
    pub fn file_path(&self) -> Option<&std::path::Path> {
        match &self.backing {
            StoreData::File { path } => Some(path),
            StoreData::Memory(_) => None,
        }
    }
}

impl Drop for StripStore {
    fn drop(&mut self) {
        if let StoreData::File { path } = &self.backing {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::SyntheticOrtho;

    #[test]
    fn strip_geometry() {
        let img = SyntheticOrtho::default().with_seed(1).generate(10, 6);
        let st = StripStore::new(&img, 4, Backing::Memory).unwrap();
        assert_eq!(st.strips(), 3);
        assert_eq!(st.strip_extent(0), (0, 4));
        assert_eq!(st.strip_extent(2), (8, 2)); // partial tail strip
        assert_eq!(st.strip_len(2), 2 * 6 * 3);
        assert_eq!(st.strip_offset_bytes(1), (4 * 6 * 3 * 4) as u64);
    }

    #[test]
    fn file_backing_creates_and_cleans_up() {
        let img = SyntheticOrtho::default().with_seed(2).generate(8, 8);
        let dir = std::env::temp_dir().join("blockms_store_test");
        let path;
        {
            let st = StripStore::new(&img, 4, Backing::File(dir.clone())).unwrap();
            path = st.file_path().unwrap().to_path_buf();
            assert!(path.exists());
            let len = std::fs::metadata(&path).unwrap().len();
            assert_eq!(len, (8 * 8 * 3 * 4) as u64);
        }
        assert!(!path.exists(), "backing file not cleaned up");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn strip_extent_bounds() {
        let img = SyntheticOrtho::default().generate(10, 6);
        let st = StripStore::new(&img, 4, Backing::Memory).unwrap();
        st.strip_extent(3);
    }
}
