//! Strip-granular image store — MATLAB `blockproc`'s I/O behaviour.
//!
//! The paper's block-shape analysis (§4, Cases 1–3) is entirely about how
//! the image *file* is accessed: the file stores the image in full-width
//! **row strips**, and reading any block touches every strip its row span
//! overlaps — the whole strip is transferred even if the block covers a
//! sliver of it. Consequences the paper measures:
//!
//! - **Row-shaped** blocks `[1200 4656]`: each strip is read exactly once
//!   (best I/O);
//! - **Square** blocks `[1200 1200]` on a 4656-wide image: 4 blocks per
//!   strip row → every strip is read 4 times;
//! - **Column-shaped** blocks `[5793 1000]`: 5 blocks spanning all strips
//!   → the entire file is read 5 times (worst I/O; the paper still finds
//!   column *fastest overall* because compute dominates and its partial
//!   edge blocks are cheapest to balance).
//!
//! [`StripStore`] persists a raster as row strips (in memory or as a real
//! file of little-endian f32 samples), hands out concurrent
//! [`StripReader`]s (one per worker, own file handle), counts every strip
//! access in [`AccessStats`], and offers the closed-form
//! [`read_amplification`] the paper quotes. An optional shared
//! [`StripCache`] (LRU over decoded strips) turns the column case's
//! re-decodes into counted cache hits; memory-backed strips are always
//! served zero-copy from the shared buffer.

mod cache;
mod reader;
mod stats;
mod store;

pub use cache::StripCache;
pub use reader::StripReader;
pub use stats::{AccessSnapshot, AccessStats};
pub use store::{Backing, StripStore};

use crate::blocks::BlockPlan;

/// Closed-form strip-read counts for a plan: how many strip reads a full
/// pass over all blocks performs, and the amplification vs reading the
/// file once.
///
/// Returns `(total_strip_reads, total_strips, amplification)`.
pub fn read_amplification(plan: &BlockPlan, strip_rows: usize) -> (usize, usize, f64) {
    assert!(strip_rows > 0);
    let total_strips = plan.height().div_ceil(strip_rows);
    let mut reads = 0usize;
    for b in plan.iter() {
        let first = b.row0 / strip_rows;
        let last = (b.row_end() - 1) / strip_rows;
        reads += last - first + 1;
    }
    (reads, total_strips, reads as f64 / total_strips as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{BlockPlan, BlockShape};

    /// The paper's Case 1/2/3 numbers on the 4656×5793 image (width 4656,
    /// height 5793; strips are full-width rows).
    #[test]
    fn paper_case1_square_reads_every_strip_4_times() {
        let plan = BlockPlan::new(5793, 4656, BlockShape::Square { side: 1200 });
        let (_, _, amp) = read_amplification(&plan, 8);
        // image is 4 blocks wide -> every strip read ~4x
        assert!((amp - 4.0).abs() < 0.05, "amplification {amp}");
    }

    #[test]
    fn paper_case2_row_reads_every_strip_once() {
        let plan = BlockPlan::new(
            5793,
            4656,
            BlockShape::Custom {
                rows: 1200,
                cols: 4656,
            },
        );
        let (reads, strips, amp) = read_amplification(&plan, 8);
        // strip-aligned row blocks: each strip read exactly once (up to
        // the two boundary strips a non-aligned band can split).
        assert!(amp < 1.01, "amplification {amp}");
        assert!(reads >= strips);
    }

    #[test]
    fn paper_case3_column_reads_file_5_times() {
        let plan = BlockPlan::new(
            5793,
            4656,
            BlockShape::Custom {
                rows: 5793,
                cols: 1000,
            },
        );
        let (_, _, amp) = read_amplification(&plan, 8);
        // 4656/1000 -> 5 column blocks, each spanning every strip
        assert_eq!(amp, 5.0);
    }

    #[test]
    fn amplification_is_at_least_one() {
        for side in [1, 3, 7, 64] {
            let plan = BlockPlan::new(100, 90, BlockShape::Square { side });
            let (_, _, amp) = read_amplification(&plan, 8);
            assert!(amp >= 1.0, "side {side}: amp {amp}");
        }
    }
}
