//! Per-worker strip reader: whole-strip reads, block extraction.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::cache::StripCache;
use super::stats::AccessStats;
use super::store::{StoreData, StripStore};
use crate::blocks::BlockRegion;

/// Reads blocks from a [`StripStore`] with `blockproc` semantics: every
/// strip the block's row span overlaps is read *in full*, then the block
/// rectangle is extracted. One reader per worker thread (own file
/// handle); counters — and the decoded-strip cache, when the store has
/// one — are shared.
pub struct StripReader {
    height: usize,
    width: usize,
    channels: usize,
    strip_rows: usize,
    source: Source,
    stats: Arc<AccessStats>,
    cache: Option<Arc<StripCache>>,
    /// Reusable whole-strip buffer (file reads without a cache).
    strip_buf: Vec<f32>,
    /// Raw byte buffer for file reads.
    byte_buf: Vec<u8>,
    /// Where the most recent [`StripReader::load_strip`] left its data.
    current: StripData,
    /// Bytes of this reader's reusable buffers (strip + raw + the
    /// caller's block buffer) currently recorded on the shared resident
    /// gauge; released on drop.
    tracked_bytes: usize,
    /// f32 capacity of the caller's block buffer as last seen by
    /// [`StripReader::read_block`] (the per-worker `px_buf`).
    out_cap: usize,
}

enum Source {
    Memory(Arc<Vec<f32>>),
    File(File),
}

/// Location of the currently loaded strip's samples. Memory-backed
/// strips are served as zero-copy ranges of the shared buffer (the seed
/// copied every strip into `strip_buf`); cached strips are shared
/// `Arc`s; only uncached file reads land in the private buffer.
enum StripData {
    None,
    /// `source` is `Memory`: samples are `data[start..start + len]`.
    Memory { start: usize, len: usize },
    /// Decoded into `strip_buf`.
    Buffered,
    /// Shared from the strip cache.
    Cached(Arc<Vec<f32>>),
}

impl StripReader {
    pub(super) fn open(store: &StripStore) -> Result<StripReader> {
        let source = match store.data() {
            StoreData::Memory(data) => Source::Memory(Arc::clone(data)),
            StoreData::File { path } => Source::File(
                File::open(path).with_context(|| format!("open {}", path.display()))?,
            ),
        };
        Ok(StripReader {
            height: store.height(),
            width: store.width(),
            channels: store.channels(),
            strip_rows: store.strip_rows(),
            source,
            stats: Arc::clone(store.stats()),
            cache: store.cache().cloned(),
            strip_buf: Vec::new(),
            byte_buf: Vec::new(),
            current: StripData::None,
            tracked_bytes: 0,
            out_cap: 0,
        })
    }

    /// Re-sync the gauge with this reader's reusable buffer footprint.
    /// Buffers are reused across reads, so the tracked number changes
    /// only when a capacity grows (or on drop, when it all releases).
    fn retrack(&mut self) {
        let now = self.strip_buf.capacity() * 4 + self.byte_buf.capacity() + self.out_cap * 4;
        if now != self.tracked_bytes {
            self.stats
                .resident()
                .resize(self.tracked_bytes as u64, now as u64);
            self.tracked_bytes = now;
        }
    }

    /// Raw-transfer chunk for file decodes. Bounding the byte buffer at
    /// 64 KiB keeps a reader's resident footprint at ~one decoded strip
    /// instead of two. `CostModel::resident_bytes` references this
    /// constant so the feasibility model cannot drift from the runtime.
    pub(crate) const DECODE_CHUNK_BYTES: usize = 1 << 16;

    /// Decode a file strip of `samples` f32s at `offset` into `out`
    /// (reusing `byte_buf` for the bounded raw transfer).
    fn decode_file_strip(
        f: &mut File,
        byte_buf: &mut Vec<u8>,
        out: &mut Vec<f32>,
        offset: u64,
        samples: usize,
    ) -> Result<()> {
        f.seek(SeekFrom::Start(offset)).context("seek strip")?;
        out.clear();
        out.reserve(samples);
        let mut remaining = samples * 4;
        while remaining > 0 {
            let take = remaining.min(Self::DECODE_CHUNK_BYTES);
            byte_buf.resize(take, 0);
            f.read_exact(byte_buf).context("read strip")?;
            out.extend(
                byte_buf
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])),
            );
            remaining -= take;
        }
        Ok(())
    }

    /// Make strip `s` the current strip; returns its first row and row
    /// count. Counts one strip read — unless the shared cache serves it,
    /// which counts a cache hit instead.
    fn load_strip(&mut self, s: usize) -> Result<(usize, usize)> {
        let first = s * self.strip_rows;
        assert!(first < self.height, "strip {s} out of range");
        let rows = self.strip_rows.min(self.height - first);
        let samples = rows * self.width * self.channels;
        // Net change in cache-resident f32s this load caused (inserted
        // payload minus evicted payloads); settled on the gauge below.
        let mut cache_delta: i64 = 0;
        match &mut self.source {
            Source::Memory(_) => {
                // Always zero-copy; the cache (if any) only does the
                // hit/miss accounting, modelling resident decoded strips
                // with the same counters as the file backing.
                if let Some(cache) = &self.cache {
                    if cache.get(s).is_some() {
                        self.stats.record_cache_hit();
                    } else {
                        cache.put(s, Arc::new(Vec::new())); // presence marker
                        self.stats.record_cache_miss();
                        self.stats.record_strip_read(samples * 4);
                    }
                } else {
                    self.stats.record_strip_read(samples * 4);
                }
                let start = first * self.width * self.channels;
                self.current = StripData::Memory {
                    start,
                    len: samples,
                };
            }
            Source::File(f) => {
                let offset = (first * self.width * self.channels * 4) as u64;
                if let Some(cache) = &self.cache {
                    if let Some(data) = cache.get(s) {
                        self.stats.record_cache_hit();
                        self.current = StripData::Cached(data);
                    } else {
                        let mut decoded = Vec::new();
                        Self::decode_file_strip(
                            f,
                            &mut self.byte_buf,
                            &mut decoded,
                            offset,
                            samples,
                        )?;
                        let data = Arc::new(decoded);
                        cache_delta = data.len() as i64 - cache.put(s, Arc::clone(&data)) as i64;
                        self.stats.record_cache_miss();
                        self.stats.record_strip_read(samples * 4);
                        self.current = StripData::Cached(data);
                    }
                } else {
                    // Reusable private buffer: the uncached hot path
                    // never allocates per strip.
                    Self::decode_file_strip(
                        f,
                        &mut self.byte_buf,
                        &mut self.strip_buf,
                        offset,
                        samples,
                    )?;
                    self.stats.record_strip_read(samples * 4);
                    self.current = StripData::Buffered;
                }
            }
        }
        match cache_delta.cmp(&0) {
            std::cmp::Ordering::Greater => self.stats.resident().add(cache_delta as u64 * 4),
            std::cmp::Ordering::Less => self.stats.resident().sub((-cache_delta) as u64 * 4),
            std::cmp::Ordering::Equal => {}
        }
        self.retrack();
        Ok((first, rows))
    }

    /// The currently loaded strip's samples.
    fn strip_slice(&self) -> &[f32] {
        match &self.current {
            StripData::None => unreachable!("no strip loaded"),
            StripData::Memory { start, len } => match &self.source {
                Source::Memory(data) => &data[*start..*start + *len],
                Source::File(_) => unreachable!("memory range on file source"),
            },
            StripData::Buffered => &self.strip_buf,
            StripData::Cached(data) => data,
        }
    }

    /// Read one block (`blockproc` semantics) into `out` as a flat
    /// `pixels[P, C]` buffer in row-major region order.
    pub fn read_block(&mut self, region: &BlockRegion, out: &mut Vec<f32>) -> Result<()> {
        assert!(
            region.row_end() <= self.height && region.col_end() <= self.width,
            "block {region} outside {}x{}",
            self.height,
            self.width
        );
        out.clear();
        out.reserve(region.area() * self.channels);
        let first_strip = region.row0 / self.strip_rows;
        let last_strip = (region.row_end() - 1) / self.strip_rows;
        for s in first_strip..=last_strip {
            let (strip_row0, strip_nrows) = self.load_strip(s)?;
            let strip = self.strip_slice();
            // rows of the block inside this strip
            let r_lo = region.row0.max(strip_row0);
            let r_hi = region.row_end().min(strip_row0 + strip_nrows);
            for r in r_lo..r_hi {
                let row_in_strip = r - strip_row0;
                let start = (row_in_strip * self.width + region.col0) * self.channels;
                out.extend_from_slice(&strip[start..start + region.cols() * self.channels]);
            }
        }
        self.out_cap = out.capacity();
        self.retrack();
        self.stats.record_block_read();
        Ok(())
    }
}

impl Drop for StripReader {
    fn drop(&mut self) {
        // Release this reader's reusable-buffer footprint (cache
        // residency stays: entries outlive any one reader).
        self.stats.resident().sub(self.tracked_bytes as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{BlockPlan, BlockShape};
    use crate::image::SyntheticOrtho;
    use crate::stripstore::{read_amplification, Backing, StripStore};

    fn image() -> crate::image::Raster {
        SyntheticOrtho::default().with_seed(5).generate(37, 23)
    }

    fn check_blocks_match_crop(backing: Backing) {
        let img = image();
        let store = StripStore::new(&img, 5, backing).unwrap();
        let mut rd = store.reader().unwrap();
        let plan = BlockPlan::new(37, 23, BlockShape::Square { side: 7 });
        let mut got = Vec::new();
        for region in plan.iter() {
            rd.read_block(region, &mut got).unwrap();
            assert_eq!(got, img.crop(region), "mismatch at {region}");
        }
    }

    #[test]
    fn memory_blocks_match_direct_crop() {
        check_blocks_match_crop(Backing::Memory);
    }

    #[test]
    fn file_blocks_match_direct_crop() {
        let dir = std::env::temp_dir().join("blockms_reader_test");
        check_blocks_match_crop(Backing::File(dir));
    }

    #[test]
    fn strip_read_counts_match_closed_form() {
        let img = image();
        let store = StripStore::new(&img, 5, Backing::Memory).unwrap();
        for shape in [
            BlockShape::Square { side: 7 },
            BlockShape::Rows { band_rows: 9 },
            BlockShape::Cols { band_cols: 6 },
        ] {
            store.stats().reset();
            let plan = BlockPlan::new(37, 23, shape);
            let mut rd = store.reader().unwrap();
            let mut buf = Vec::new();
            for region in plan.iter() {
                rd.read_block(region, &mut buf).unwrap();
            }
            let (expected_reads, _, _) = read_amplification(&plan, 5);
            let snap = store.stats().snapshot();
            assert_eq!(
                snap.strip_reads as usize, expected_reads,
                "shape {shape}: measured != closed form"
            );
            assert_eq!(snap.block_reads as usize, plan.len());
        }
    }

    #[test]
    fn bytes_counted_are_whole_strips() {
        let img = image();
        let store = StripStore::new(&img, 37, Backing::Memory).unwrap(); // 1 strip
        let mut rd = store.reader().unwrap();
        let mut buf = Vec::new();
        // a 1x1 block still transfers the entire strip
        rd.read_block(&BlockRegion::new(0, 0, 1, 1), &mut buf).unwrap();
        assert_eq!(
            store.stats().snapshot().bytes_read as usize,
            37 * 23 * 3 * 4
        );
        assert_eq!(buf.len(), 3);
    }

    #[test]
    fn memory_blocks_are_served_zero_copy() {
        // The memory path must not copy strips into the private buffer:
        // after a full pass the reusable buffer is still untouched.
        let img = image();
        let store = StripStore::new(&img, 5, Backing::Memory).unwrap();
        let mut rd = store.reader().unwrap();
        let mut buf = Vec::new();
        let plan = BlockPlan::new(37, 23, BlockShape::Square { side: 7 });
        for region in plan.iter() {
            rd.read_block(region, &mut buf).unwrap();
        }
        assert!(rd.strip_buf.is_empty(), "memory path copied a strip");
        assert_eq!(rd.byte_buf.len(), 0);
    }

    #[test]
    fn cache_turns_repeat_strip_reads_into_hits() {
        let img = image();
        for file_backed in [false, true] {
            let backing = if file_backed {
                Backing::File(std::env::temp_dir().join("blockms_reader_cache_test"))
            } else {
                Backing::Memory
            };
            let mut store = StripStore::new(&img, 5, backing).unwrap();
            store.enable_cache(store.strips());
            let mut rd = store.reader().unwrap();
            let mut buf = Vec::new();
            // Column plan: every block spans every strip.
            let plan = BlockPlan::new(37, 23, BlockShape::Cols { band_cols: 6 });
            for region in plan.iter() {
                rd.read_block(region, &mut buf).unwrap();
                assert_eq!(buf, img.crop(region), "file_backed={file_backed}: {region}");
            }
            let snap = store.stats().snapshot();
            let strips = store.strips() as u64;
            let blocks = plan.len() as u64;
            assert_eq!(snap.strip_cache_misses, strips, "file_backed={file_backed}");
            assert_eq!(
                snap.strip_cache_hits,
                strips * (blocks - 1),
                "file_backed={file_backed}"
            );
            // Only misses transfer: the file is decoded exactly once.
            assert_eq!(snap.strip_reads, strips, "file_backed={file_backed}");
        }
    }

    #[test]
    fn concurrent_readers_share_counters() {
        let img = image();
        let store = std::sync::Arc::new(StripStore::new(&img, 5, Backing::Memory).unwrap());
        let plan = BlockPlan::new(37, 23, BlockShape::Square { side: 10 });
        let regions: Vec<_> = plan.regions().to_vec();
        let mut handles = Vec::new();
        for chunk in regions.chunks(regions.len().div_ceil(3)) {
            let store = std::sync::Arc::clone(&store);
            let chunk = chunk.to_vec();
            handles.push(std::thread::spawn(move || {
                let mut rd = store.reader().unwrap();
                let mut buf = Vec::new();
                for r in chunk {
                    rd.read_block(&r, &mut buf).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(store.stats().snapshot().block_reads as usize, plan.len());
    }
}
