//! Per-worker strip reader: whole-strip reads, block extraction.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::stats::AccessStats;
use super::store::{StoreData, StripStore};
use crate::blocks::BlockRegion;

/// Reads blocks from a [`StripStore`] with `blockproc` semantics: every
/// strip the block's row span overlaps is read *in full*, then the block
/// rectangle is extracted. One reader per worker thread (own file
/// handle); counters are shared.
pub struct StripReader {
    height: usize,
    width: usize,
    channels: usize,
    strip_rows: usize,
    source: Source,
    stats: Arc<AccessStats>,
    /// Reusable whole-strip buffer (avoids per-read allocation).
    strip_buf: Vec<f32>,
    /// Raw byte buffer for file reads.
    byte_buf: Vec<u8>,
}

enum Source {
    Memory(Arc<Vec<f32>>),
    File(File),
}

impl StripReader {
    pub(super) fn open(store: &StripStore) -> Result<StripReader> {
        let source = match store.data() {
            StoreData::Memory(data) => Source::Memory(Arc::clone(data)),
            StoreData::File { path } => Source::File(
                File::open(path).with_context(|| format!("open {}", path.display()))?,
            ),
        };
        Ok(StripReader {
            height: store.height(),
            width: store.width(),
            channels: store.channels(),
            strip_rows: store.strip_rows(),
            source,
            stats: Arc::clone(store.stats()),
            strip_buf: Vec::new(),
            byte_buf: Vec::new(),
        })
    }

    /// Read one whole strip into the internal buffer; returns the strip's
    /// first row and row count. Counts one strip read.
    fn read_strip(&mut self, s: usize) -> Result<(usize, usize)> {
        let first = s * self.strip_rows;
        assert!(first < self.height, "strip {s} out of range");
        let rows = self.strip_rows.min(self.height - first);
        let samples = rows * self.width * self.channels;
        match &mut self.source {
            Source::Memory(data) => {
                let start = first * self.width * self.channels;
                self.strip_buf.clear();
                self.strip_buf.extend_from_slice(&data[start..start + samples]);
            }
            Source::File(f) => {
                let offset = (first * self.width * self.channels * 4) as u64;
                f.seek(SeekFrom::Start(offset)).context("seek strip")?;
                self.byte_buf.resize(samples * 4, 0);
                f.read_exact(&mut self.byte_buf).context("read strip")?;
                self.strip_buf.clear();
                self.strip_buf.extend(
                    self.byte_buf
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]])),
                );
            }
        }
        self.stats.record_strip_read(samples * 4);
        Ok((first, rows))
    }

    /// Read one block (`blockproc` semantics) into `out` as a flat
    /// `pixels[P, C]` buffer in row-major region order.
    pub fn read_block(&mut self, region: &BlockRegion, out: &mut Vec<f32>) -> Result<()> {
        assert!(
            region.row_end() <= self.height && region.col_end() <= self.width,
            "block {region} outside {}x{}",
            self.height,
            self.width
        );
        out.clear();
        out.reserve(region.area() * self.channels);
        let first_strip = region.row0 / self.strip_rows;
        let last_strip = (region.row_end() - 1) / self.strip_rows;
        for s in first_strip..=last_strip {
            let (strip_row0, strip_nrows) = self.read_strip(s)?;
            // rows of the block inside this strip
            let r_lo = region.row0.max(strip_row0);
            let r_hi = region.row_end().min(strip_row0 + strip_nrows);
            for r in r_lo..r_hi {
                let row_in_strip = r - strip_row0;
                let start = (row_in_strip * self.width + region.col0) * self.channels;
                out.extend_from_slice(
                    &self.strip_buf[start..start + region.cols() * self.channels],
                );
            }
        }
        self.stats.record_block_read();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::{BlockPlan, BlockShape};
    use crate::image::SyntheticOrtho;
    use crate::stripstore::{read_amplification, Backing, StripStore};

    fn image() -> crate::image::Raster {
        SyntheticOrtho::default().with_seed(5).generate(37, 23)
    }

    fn check_blocks_match_crop(backing: Backing) {
        let img = image();
        let store = StripStore::new(&img, 5, backing).unwrap();
        let mut rd = store.reader().unwrap();
        let plan = BlockPlan::new(37, 23, BlockShape::Square { side: 7 });
        let mut got = Vec::new();
        for region in plan.iter() {
            rd.read_block(region, &mut got).unwrap();
            assert_eq!(got, img.crop(region), "mismatch at {region}");
        }
    }

    #[test]
    fn memory_blocks_match_direct_crop() {
        check_blocks_match_crop(Backing::Memory);
    }

    #[test]
    fn file_blocks_match_direct_crop() {
        let dir = std::env::temp_dir().join("blockms_reader_test");
        check_blocks_match_crop(Backing::File(dir));
    }

    #[test]
    fn strip_read_counts_match_closed_form() {
        let img = image();
        let store = StripStore::new(&img, 5, Backing::Memory).unwrap();
        for shape in [
            BlockShape::Square { side: 7 },
            BlockShape::Rows { band_rows: 9 },
            BlockShape::Cols { band_cols: 6 },
        ] {
            store.stats().reset();
            let plan = BlockPlan::new(37, 23, shape);
            let mut rd = store.reader().unwrap();
            let mut buf = Vec::new();
            for region in plan.iter() {
                rd.read_block(region, &mut buf).unwrap();
            }
            let (expected_reads, _, _) = read_amplification(&plan, 5);
            let snap = store.stats().snapshot();
            assert_eq!(
                snap.strip_reads as usize, expected_reads,
                "shape {shape}: measured != closed form"
            );
            assert_eq!(snap.block_reads as usize, plan.len());
        }
    }

    #[test]
    fn bytes_counted_are_whole_strips() {
        let img = image();
        let store = StripStore::new(&img, 37, Backing::Memory).unwrap(); // 1 strip
        let mut rd = store.reader().unwrap();
        let mut buf = Vec::new();
        // a 1x1 block still transfers the entire strip
        rd.read_block(&BlockRegion::new(0, 0, 1, 1), &mut buf).unwrap();
        assert_eq!(
            store.stats().snapshot().bytes_read as usize,
            37 * 23 * 3 * 4
        );
        assert_eq!(buf.len(), 3);
    }

    #[test]
    fn concurrent_readers_share_counters() {
        let img = image();
        let store = std::sync::Arc::new(StripStore::new(&img, 5, Backing::Memory).unwrap());
        let plan = BlockPlan::new(37, 23, BlockShape::Square { side: 10 });
        let regions: Vec<_> = plan.regions().to_vec();
        let mut handles = Vec::new();
        for chunk in regions.chunks(regions.len().div_ceil(3)) {
            let store = std::sync::Arc::clone(&store);
            let chunk = chunk.to_vec();
            handles.push(std::thread::spawn(move || {
                let mut rd = store.reader().unwrap();
                let mut buf = Vec::new();
                for r in chunk {
                    rd.read_block(&r, &mut buf).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(store.stats().snapshot().block_reads as usize, plan.len());
    }
}
