//! Shared LRU cache of decoded strips.
//!
//! The paper's column-shaped blocks are the worst I/O case precisely
//! because every block re-reads (and, file-backed, re-decodes) every
//! strip: a 5-column plan transfers the file 5×. A [`StripCache`] sits
//! between all of a store's readers and the backing: keyed by strip
//! index, capacity counted in strips, LRU-evicted. With capacity for
//! the whole file, the column plan's amplification collapses to 1 — the
//! remaining 4 passes are cache hits counted in
//! [`super::AccessStats::record_cache_hit`].
//!
//! Entries are `Arc<Vec<f32>>` so a reader can keep using a decoded
//! strip after it has been evicted — eviction only drops the cache's
//! reference. For memory-backed stores the payload would be a copy of
//! data that is already resident, so those stores track *presence only*
//! (empty sentinel vectors) and keep serving strip bytes zero-copy from
//! the shared buffer; hit/miss accounting is identical across backings.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// A thread-safe LRU map `strip index → decoded samples`.
pub struct StripCache {
    cap: usize,
    state: Mutex<CacheState>,
}

struct CacheState {
    tick: u64,
    entries: HashMap<usize, (u64, Arc<Vec<f32>>)>,
}

impl StripCache {
    /// Cache holding up to `cap` strips (`cap >= 1`; use no cache at
    /// all instead of a zero-capacity one).
    pub fn new(cap: usize) -> StripCache {
        assert!(cap >= 1, "cache capacity must be at least one strip");
        StripCache {
            cap,
            state: Mutex::new(CacheState {
                tick: 0,
                entries: HashMap::new(),
            }),
        }
    }

    /// Capacity in strips.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Resident strip count.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up strip `s`, refreshing its recency on a hit.
    pub fn get(&self, s: usize) -> Option<Arc<Vec<f32>>> {
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        st.entries.get_mut(&s).map(|(used, data)| {
            *used = tick;
            Arc::clone(data)
        })
    }

    /// Insert strip `s`, evicting the least-recently-used strips down
    /// to capacity. Returns the total f32 count of evicted payloads
    /// (plus any payload `s` replaced) so the caller can release the
    /// bytes from its resident accounting.
    pub fn put(&self, s: usize, data: Arc<Vec<f32>>) -> usize {
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        let mut evicted = st
            .entries
            .insert(s, (tick, data))
            .map(|(_, old)| old.len())
            .unwrap_or(0);
        while st.entries.len() > self.cap {
            let victim = st
                .entries
                .iter()
                .min_by_key(|(_, (used, _))| *used)
                .map(|(&k, _)| k)
                .expect("non-empty over-capacity cache");
            if let Some((_, old)) = st.entries.remove(&victim) {
                evicted += old.len();
            }
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strip(v: f32) -> Arc<Vec<f32>> {
        Arc::new(vec![v; 4])
    }

    #[test]
    fn get_after_put_and_miss_before() {
        let c = StripCache::new(4);
        assert!(c.get(0).is_none());
        c.put(0, strip(1.0));
        assert_eq!(c.get(0).unwrap()[0], 1.0);
        assert!(!c.is_empty() && c.len() == 1);
    }

    #[test]
    fn lru_eviction_order() {
        let c = StripCache::new(2);
        assert_eq!(c.put(0, strip(0.0)), 0);
        assert_eq!(c.put(1, strip(1.0)), 0);
        assert!(c.get(0).is_some()); // 0 now more recent than 1
        assert_eq!(c.put(2, strip(2.0)), 4, "evicting 1 reports its size");
        assert!(c.get(0).is_some());
        assert!(c.get(1).is_none());
        assert!(c.get(2).is_some());
        assert_eq!(c.len(), 2);
        // replacing an existing entry reports the replaced payload
        assert_eq!(c.put(2, strip(9.0)), 4);
    }

    #[test]
    fn evicted_entries_stay_alive_for_holders() {
        let c = StripCache::new(1);
        c.put(0, strip(7.0));
        let held = c.get(0).unwrap();
        c.put(1, strip(8.0)); // evicts 0
        assert!(c.get(0).is_none());
        assert_eq!(held[0], 7.0); // holder unaffected
    }

    #[test]
    fn concurrent_access_is_safe() {
        let c = Arc::new(StripCache::new(8));
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for i in 0..200 {
                    let s = (t * 7 + i) % 16;
                    if c.get(s).is_none() {
                        c.put(s, strip(s as f32));
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= 8);
    }

    #[test]
    #[should_panic(expected = "at least one strip")]
    fn zero_capacity_rejected() {
        StripCache::new(0);
    }
}
