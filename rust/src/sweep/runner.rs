//! Drive a sweep grid through the [`ClusterServer`] as one share
//! group: one strip store, shared decoded tiles, co-scheduled rounds.
//!
//! Every variant is an ordinary [`JobSpec`] — same init draw, same
//! block order, same reduction — so its output is bit-identical to a
//! solo run of the same spec; the share group only changes how many
//! times the image's bytes are decoded (≈ once, instead of once per
//! variant).

use std::sync::Arc;

use anyhow::{Context, Result};

use super::grid::{SweepGrid, SweepVariant};
use crate::coordinator::{ClusterConfig, ClusterOutput, IoMode};
use crate::image::Raster;
use crate::plan::ExecPlan;
use crate::service::{ClusterServer, JobHandle, JobSpec, ServerConfig};
use crate::stripstore::AccessSnapshot;

/// A finished sweep: outputs positionally matched to the expanded
/// grid, plus the group-wide I/O counters.
pub struct SweepOutcome {
    pub variants: Vec<SweepVariant>,
    pub outputs: Vec<ClusterOutput>,
    /// Strip-store counters for the whole sweep. Shared-group members
    /// snapshot one store with monotone counters, so the max over
    /// per-variant snapshots is the last finalizer's view — the sweep
    /// total.
    pub io: Option<AccessSnapshot>,
    pub wall_secs: f64,
}

/// Submit every grid variant to `server` over `image`. With
/// `share = Some(group)` the variants join one share group (amortized
/// I/O); with `None` each runs fully isolated (the serialized
/// baseline the bench compares against). Returns handles in grid
/// expansion order.
pub fn submit_sweep(
    server: &ClusterServer,
    image: &Arc<Raster>,
    exec: ExecPlan,
    base: &ClusterConfig,
    grid: &SweepGrid,
    strip_rows: usize,
    share: Option<u64>,
) -> Result<Vec<JobHandle>> {
    let mut handles = Vec::with_capacity(grid.len());
    for v in grid.expand() {
        let mut cfg = base.clone();
        cfg.k = v.k;
        cfg.seed = v.seed;
        cfg.init = v.init;
        let mut spec = JobSpec::new(Arc::clone(image), exec, cfg).with_io(IoMode::Strips {
            strip_rows,
            file_backed: exec.file_backed,
        });
        if let Some(g) = share {
            spec = spec.with_share_group(g);
        }
        handles.push(
            server
                .submit(spec)
                .with_context(|| format!("submit sweep variant k={}", v.k))?,
        );
    }
    Ok(handles)
}

/// Wait on every handle, failing fast with the variant's position.
pub fn collect_outputs(handles: &[JobHandle]) -> Result<Vec<ClusterOutput>> {
    handles
        .iter()
        .enumerate()
        .map(|(i, h)| h.wait_output().with_context(|| format!("sweep variant #{i}")))
        .collect()
}

/// Run the whole grid on a private server sized so every variant is in
/// flight at once (full co-scheduling). One share group, one store,
/// one set of decoded tiles.
pub fn run_sweep(
    image: &Arc<Raster>,
    exec: ExecPlan,
    base: &ClusterConfig,
    grid: &SweepGrid,
    strip_rows: usize,
    workers: usize,
) -> Result<SweepOutcome> {
    let t0 = std::time::Instant::now();
    let server = ClusterServer::start(ServerConfig {
        workers,
        max_in_flight: grid.len(),
        ..Default::default()
    });
    let handles = submit_sweep(&server, image, exec, base, grid, strip_rows, Some(1))?;
    let outputs = collect_outputs(&handles)?;
    server.shutdown();
    let io = outputs
        .iter()
        .filter_map(|o| o.io_stats)
        .max_by_key(|s| s.bytes_read);
    Ok(SweepOutcome {
        variants: grid.expand(),
        outputs,
        io,
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocks::BlockShape;
    use crate::image::SyntheticOrtho;

    #[test]
    fn sweep_runs_the_whole_grid_once_each() {
        let img = Arc::new(SyntheticOrtho::default().with_seed(19).generate(24, 20));
        let exec = ExecPlan::pinned(BlockShape::Square { side: 8 });
        let grid = SweepGrid::from_args("2..3", 19, 2, "random").unwrap();
        let base = ClusterConfig::default();
        let out = run_sweep(&img, exec, &base, &grid, 8, 2).unwrap();
        assert_eq!(out.outputs.len(), 4);
        assert_eq!(out.variants.len(), 4);
        for (v, o) in out.variants.iter().zip(&out.outputs) {
            assert_eq!(o.labels.len(), 24 * 20, "{}", v.label());
            assert_eq!(o.centroids.len(), v.k * 3, "{}", v.label());
        }
        let io = out.io.expect("strip I/O counters present");
        assert!(io.strip_reads > 0);
    }
}
