//! Variant grid expansion and the sweep CLI's grid syntax.
//!
//! `--ks` accepts an inclusive range (`2..8`) or an explicit list
//! (`2,4,8`); `--seeds N` expands to `base, base+1, …, base+N-1`;
//! `--inits` is a comma list of `random` / `plusplus` (alias `++`).
//! Expansion order is k-major, then seed, then init — deterministic,
//! so reports and tests can index variants positionally.

use anyhow::{bail, ensure, Result};

use crate::kmeans::InitMethod;

/// One point of the sweep grid.
#[derive(Clone, Debug)]
pub struct SweepVariant {
    pub k: usize,
    pub seed: u64,
    pub init: InitMethod,
}

impl SweepVariant {
    /// Stable human-readable tag, e.g. `k4-s31-random`.
    pub fn label(&self) -> String {
        format!("k{}-s{}-{}", self.k, self.seed, init_name(&self.init))
    }
}

/// Short stable name for an init method (report rows, JSON keys).
pub fn init_name(init: &InitMethod) -> &'static str {
    match init {
        InitMethod::RandomSample => "random",
        InitMethod::PlusPlus => "plusplus",
        InitMethod::Fixed(_) => "fixed",
    }
}

/// Parse the `--ks` grid axis: `2..8` (inclusive) or `2,4,8` or `4`.
pub fn parse_ks(raw: &str) -> Result<Vec<usize>> {
    let raw = raw.trim();
    if let Some((lo, hi)) = raw.split_once("..") {
        let lo: usize = lo
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad k range start {lo:?} in {raw:?}"))?;
        let hi: usize = hi
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad k range end {hi:?} in {raw:?}"))?;
        ensure!(lo >= 1, "k must be at least 1 (got {lo})");
        ensure!(lo <= hi, "empty k range {raw:?} (start > end)");
        return Ok((lo..=hi).collect());
    }
    let mut ks = Vec::new();
    for part in raw.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let k: usize = part
            .parse()
            .map_err(|_| anyhow::anyhow!("bad k value {part:?} in {raw:?}"))?;
        ensure!(k >= 1, "k must be at least 1 (got {k})");
        ks.push(k);
    }
    ensure!(!ks.is_empty(), "empty k list {raw:?}");
    Ok(ks)
}

/// Parse the `--inits` axis: comma list of `random` / `plusplus`
/// (alias `++`).
pub fn parse_inits(raw: &str) -> Result<Vec<InitMethod>> {
    let mut inits = Vec::new();
    for part in raw.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.to_ascii_lowercase().as_str() {
            "random" | "randomsample" => inits.push(InitMethod::RandomSample),
            "plusplus" | "++" | "kmeans++" => inits.push(InitMethod::PlusPlus),
            other => bail!("unknown init {other:?} (want random|plusplus)"),
        }
    }
    ensure!(!inits.is_empty(), "empty init list {raw:?}");
    Ok(inits)
}

/// The full `(k, seed, init)` grid of one sweep.
#[derive(Clone, Debug)]
pub struct SweepGrid {
    pub ks: Vec<usize>,
    pub seeds: Vec<u64>,
    pub inits: Vec<InitMethod>,
}

impl SweepGrid {
    /// A validated grid; every axis must be non-empty.
    pub fn new(ks: Vec<usize>, seeds: Vec<u64>, inits: Vec<InitMethod>) -> Result<SweepGrid> {
        ensure!(!ks.is_empty(), "sweep grid has no k values");
        ensure!(!seeds.is_empty(), "sweep grid has no seeds");
        ensure!(!inits.is_empty(), "sweep grid has no init methods");
        Ok(SweepGrid { ks, seeds, inits })
    }

    /// Build from the CLI's raw flags: `--ks` syntax, `--seeds N`
    /// replicas starting at `base_seed`, `--inits` names.
    pub fn from_args(ks: &str, base_seed: u64, n_seeds: usize, inits: &str) -> Result<SweepGrid> {
        ensure!(n_seeds >= 1, "--seeds must be at least 1 (empty grid)");
        SweepGrid::new(
            parse_ks(ks)?,
            (0..n_seeds as u64).map(|i| base_seed + i).collect(),
            parse_inits(inits)?,
        )
    }

    /// Number of variants the grid expands to.
    pub fn len(&self) -> usize {
        self.ks.len() * self.seeds.len() * self.inits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand to the full variant list, k-major then seed then init.
    pub fn expand(&self) -> Vec<SweepVariant> {
        let mut out = Vec::with_capacity(self.len());
        for &k in &self.ks {
            for &seed in &self.seeds {
                for init in &self.inits {
                    out.push(SweepVariant {
                        k,
                        seed,
                        init: init.clone(),
                    });
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_and_list_syntax() {
        assert_eq!(parse_ks("2..5").unwrap(), vec![2, 3, 4, 5]);
        assert_eq!(parse_ks("2,4,8").unwrap(), vec![2, 4, 8]);
        assert_eq!(parse_ks("4").unwrap(), vec![4]);
        assert_eq!(parse_ks(" 3 .. 3 ").unwrap(), vec![3]);
        assert!(parse_ks("8..2").is_err(), "inverted range is empty");
        assert!(parse_ks("0..3").is_err(), "k=0 is invalid");
        assert!(parse_ks("a,b").is_err());
        assert!(parse_ks("").is_err());
    }

    #[test]
    fn init_names_round_trip() {
        let inits = parse_inits("random,plusplus").unwrap();
        assert_eq!(inits.len(), 2);
        assert_eq!(init_name(&inits[0]), "random");
        assert_eq!(init_name(&inits[1]), "plusplus");
        assert!(matches!(
            parse_inits("++").unwrap()[0],
            InitMethod::PlusPlus
        ));
        assert!(parse_inits("kohonen").is_err());
        assert!(parse_inits("").is_err());
    }

    #[test]
    fn expansion_is_k_major_and_sized() {
        let grid = SweepGrid::from_args("2..4", 7, 2, "random").unwrap();
        assert_eq!(grid.len(), 6);
        let v = grid.expand();
        assert_eq!(v.len(), 6);
        assert_eq!(
            v.iter().map(|v| (v.k, v.seed)).collect::<Vec<_>>(),
            vec![(2, 7), (2, 8), (3, 7), (3, 8), (4, 7), (4, 8)]
        );
        assert_eq!(v[0].label(), "k2-s7-random");
    }

    #[test]
    fn empty_axes_rejected() {
        assert!(SweepGrid::from_args("2..4", 7, 0, "random").is_err());
        assert!(SweepGrid::new(vec![], vec![1], vec![InitMethod::RandomSample]).is_err());
        assert!(SweepGrid::new(vec![2], vec![], vec![InitMethod::RandomSample]).is_err());
        assert!(SweepGrid::new(vec![2], vec![1], vec![]).is_err());
    }
}
