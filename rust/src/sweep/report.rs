//! Model selection over a finished sweep: per-variant quality rows,
//! Davies-Bouldin ranking, and inertia-elbow knee detection.
//!
//! Two complementary answers to "which k?":
//! - **DB ranking** — lower [`crate::metrics::quality::davies_bouldin`]
//!   is better (tight clusters, far apart). Degenerate results (≤ 1
//!   non-empty cluster, where the index collapses to 0.0) are ranked
//!   *last*, not first — an all-one-cluster fit must never win.
//! - **Inertia elbow** — inertia decreases monotonically in k, so its
//!   minimum is useless; the *knee* (max perpendicular distance to the
//!   first→last chord of the normalized curve) marks where extra
//!   clusters stop paying. Hand-computed cases live in
//!   `tests/quality_metrics.rs`.

use anyhow::{ensure, Result};

use super::grid::SweepVariant;
use crate::coordinator::ClusterOutput;
use crate::metrics::quality::davies_bouldin;

/// One variant's quality row.
#[derive(Clone, Debug)]
pub struct VariantResult {
    pub variant: SweepVariant,
    pub iterations: usize,
    pub inertia: f64,
    /// Davies-Bouldin index at the final assignment (0.0 = degenerate:
    /// at most one non-empty cluster).
    pub db_index: f64,
    pub wall_secs: f64,
}

impl VariantResult {
    /// Degenerate fit: the DB index had ≤ 1 non-empty cluster to work
    /// with and carries no ranking signal.
    pub fn is_degenerate(&self) -> bool {
        self.db_index == 0.0
    }
}

/// The sweep's model-selection report.
#[derive(Clone, Debug, Default)]
pub struct SweepReport {
    pub rows: Vec<VariantResult>,
}

impl SweepReport {
    /// Score each variant's output against the image it clustered.
    /// `variants` and `outputs` are positionally matched (the runner
    /// preserves grid expansion order).
    pub fn build(
        variants: &[SweepVariant],
        outputs: &[ClusterOutput],
        pixels: &[f32],
        channels: usize,
    ) -> Result<SweepReport> {
        ensure!(
            variants.len() == outputs.len(),
            "variant/output count mismatch: {} vs {}",
            variants.len(),
            outputs.len()
        );
        let rows = variants
            .iter()
            .zip(outputs)
            .map(|(v, out)| VariantResult {
                variant: v.clone(),
                iterations: out.iterations,
                inertia: out.inertia,
                db_index: davies_bouldin(pixels, &out.labels, &out.centroids, v.k, channels),
                wall_secs: out.total_secs,
            })
            .collect();
        Ok(SweepReport { rows })
    }

    /// Row indices ranked best-first by Davies-Bouldin (ascending),
    /// degenerate fits last. Ties break toward the smaller k (the
    /// simpler model), then submission order — fully deterministic.
    pub fn ranked_by_db(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.rows.len()).collect();
        idx.sort_by(|&a, &b| {
            let (ra, rb) = (&self.rows[a], &self.rows[b]);
            ra.is_degenerate()
                .cmp(&rb.is_degenerate())
                .then(ra.db_index.total_cmp(&rb.db_index))
                .then(ra.variant.k.cmp(&rb.variant.k))
                .then(a.cmp(&b))
        });
        idx
    }

    /// The best non-degenerate row, if any.
    pub fn best(&self) -> Option<&VariantResult> {
        self.ranked_by_db()
            .first()
            .map(|&i| &self.rows[i])
            .filter(|r| !r.is_degenerate())
    }

    /// The elbow curve: distinct k ascending, with mean inertia over
    /// every (seed, init) replicate at that k.
    pub fn elbow(&self) -> (Vec<usize>, Vec<f64>) {
        let mut ks: Vec<usize> = self.rows.iter().map(|r| r.variant.k).collect();
        ks.sort_unstable();
        ks.dedup();
        let means = ks
            .iter()
            .map(|&k| {
                let vals: Vec<f64> = self
                    .rows
                    .iter()
                    .filter(|r| r.variant.k == k)
                    .map(|r| r.inertia)
                    .collect();
                vals.iter().sum::<f64>() / vals.len() as f64
            })
            .collect();
        (ks, means)
    }

    /// The k at the inertia curve's knee (see [`knee_index`]); `None`
    /// when the grid has no rows.
    pub fn knee_k(&self) -> Option<usize> {
        let (ks, inertia) = self.elbow();
        if ks.is_empty() {
            return None;
        }
        Some(ks[knee_index(&inertia)])
    }
}

/// Knee of a monotone-ish curve by max distance to the first→last
/// chord: both axes are normalized to [0, 1] (so the answer is
/// invariant to units), and the index with the greatest perpendicular
/// distance to the chord wins; ties go to the earliest index. Curves
/// with fewer than 3 points have no interior — index 0 is returned.
pub fn knee_index(values: &[f64]) -> usize {
    if values.len() < 3 {
        return 0;
    }
    let n = values.len();
    let (y0, y1) = (values[0], values[n - 1]);
    let span = y1 - y0;
    // Flat curve: every point sits on the chord; keep the first.
    if span == 0.0 {
        return 0;
    }
    let mut best = (0usize, f64::NEG_INFINITY);
    for (i, &v) in values.iter().enumerate() {
        let x = i as f64 / (n - 1) as f64;
        let y = (v - y0) / span;
        // Distance to the chord y = x (normalized endpoints are (0,0)
        // and (1,1)); the 1/√2 factor is rank-invariant and dropped.
        // For decreasing curves `span < 0` flips y's sign consistently,
        // so the same |x - y| measures the sag either way.
        let d = (x - y).abs();
        if d > best.1 {
            best = (i, d);
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::InitMethod;

    fn row(k: usize, db: f64, inertia: f64) -> VariantResult {
        VariantResult {
            variant: SweepVariant {
                k,
                seed: 1,
                init: InitMethod::RandomSample,
            },
            iterations: 3,
            inertia,
            db_index: db,
            wall_secs: 0.0,
        }
    }

    #[test]
    fn db_ranking_prefers_low_and_demotes_degenerate() {
        let report = SweepReport {
            rows: vec![row(2, 0.9, 10.0), row(3, 0.4, 6.0), row(4, 0.0, 5.0)],
        };
        assert_eq!(report.ranked_by_db(), vec![1, 0, 2]);
        assert_eq!(report.best().unwrap().variant.k, 3);
    }

    #[test]
    fn db_ties_break_to_smaller_k() {
        let report = SweepReport {
            rows: vec![row(5, 0.5, 4.0), row(2, 0.5, 9.0)],
        };
        assert_eq!(report.ranked_by_db(), vec![1, 0]);
    }

    #[test]
    fn all_degenerate_has_no_best() {
        let report = SweepReport {
            rows: vec![row(2, 0.0, 1.0), row(3, 0.0, 1.0)],
        };
        assert!(report.best().is_none());
    }

    #[test]
    fn elbow_averages_replicates_per_k() {
        let mut rows = vec![row(2, 0.5, 10.0), row(2, 0.5, 12.0), row(3, 0.5, 4.0)];
        rows[1].variant.seed = 2;
        let report = SweepReport { rows };
        let (ks, means) = report.elbow();
        assert_eq!(ks, vec![2, 3]);
        assert_eq!(means, vec![11.0, 4.0]);
    }

    #[test]
    fn knee_finds_the_bend() {
        // Sharp elbow at index 1: 100 → 10 → 8 → 6
        assert_eq!(knee_index(&[100.0, 10.0, 8.0, 6.0]), 1);
        // Later elbow: 100 → 60 → 20 → 18 → 16 bends at index 2
        assert_eq!(knee_index(&[100.0, 60.0, 20.0, 18.0, 16.0]), 2);
        // Straight line has no interior winner: first index
        assert_eq!(knee_index(&[4.0, 3.0, 2.0, 1.0]), 0);
        // Flat and tiny curves degrade to 0
        assert_eq!(knee_index(&[5.0, 5.0, 5.0]), 0);
        assert_eq!(knee_index(&[1.0, 2.0]), 0);
        assert_eq!(knee_index(&[]), 0);
    }
}
