//! Amortized multi-variant sweeps: one read, many models.
//!
//! The paper clusters one image at one `k`; the practical workload is
//! the *model-selection sweep* — a grid of `(k, seed, init)` variants
//! over the same image (cf. the multi-k batched K-Means++ workloads in
//! PAPERS.md). Run naively, N variants cost N full reads. This module
//! runs them as one **share group** on the [`ClusterServer`]: a single
//! strip store, decoded SoA tiles keyed by *content* instead of job
//! (one decode serves every variant), and rotation co-scheduling so a
//! freshly filled tile is consumed by all siblings while hot. Variant
//! results stay bit-identical to solo runs — sharing changes where
//! bytes come from, never the arithmetic (`tests/sweep_equivalence.rs`
//! holds the full kernel × shape × backing matrix to that contract).
//!
//! The pieces:
//! - [`SweepGrid`] — grid expansion + the CLI's `--ks 2..8` /
//!   `--seeds N` / `--inits random,plusplus` parsers;
//! - [`run_sweep`] / [`submit_sweep`] — drive a grid through one
//!   server under one share group and collect outputs;
//! - [`SweepReport`] — per-variant quality rows (Davies-Bouldin,
//!   inertia), DB ranking, and the inertia-elbow knee
//!   ([`knee_index`]) for the "which k?" answer.

mod grid;
mod report;
mod runner;

pub use grid::{init_name, parse_inits, parse_ks, SweepGrid, SweepVariant};
pub use report::{knee_index, SweepReport, VariantResult};
pub use runner::{collect_outputs, run_sweep, submit_sweep, SweepOutcome};
