//! The execution-planning subsystem: one resolved plan per run.
//!
//! The paper's central observation is that the *best* execution
//! strategy — block shape, kernel, tile layout, cache sizing — depends
//! on workload geometry and the balance of I/O vs compute. Before this
//! subsystem every knob was threaded by hand through
//! `CoordinatorConfig`, `JobSpec`, and the CLI; now every entry point
//! resolves its inputs into one [`ExecPlan`] up front and consumes only
//! that:
//!
//! ```text
//!   pins (CLI flags / config / caller)          workload geometry
//!                  │                                   │
//!                  ▼                                   ▼
//!            [`PlanRequest`] ──▶ [`Planner`] + [`CostModel`]
//!                                     │
//!                      ┌──────────────┴──────────────┐
//!                      ▼                             ▼
//!                 [`ExecPlan`]                  [`Explain`]
//!            (the one resolved run          (every candidate with
//!             description everything         its predicted cost —
//!             downstream consumes)           `blockms plan` prints it)
//! ```
//!
//! A fully-pinned request resolves to exactly its pins (the planner
//! never overrides an explicit choice); unpinned knobs are chosen by
//! minimizing the [`CostModel`]'s predicted wall time over the
//! candidate grid. Resolution is **deterministic**: candidates are
//! enumerated in a fixed order and ties break toward the earlier
//! candidate, so the same request and priors always yield the same
//! plan. The planner only *selects among* bit-identical kernels and
//! layouts, so auto-planning can never change results — only speed.

mod cost;
mod explain;

pub use cost::{CostModel, PlanCost, Workload, CALIB_KS, REF_WORKERS};
pub use explain::{Candidate, Explain};

use crate::blocks::{ApproachKind, BlockPlan, BlockShape};
use crate::kmeans::kernel::KernelChoice;
use crate::kmeans::tile::TileLayout;

/// Worker count the planner assumes when nothing pins it.
pub const DEFAULT_WORKERS: usize = 4;

/// Tile-arena budget (MiB) when nothing pins it and the planner has no
/// reason to size it to the workload.
pub const DEFAULT_ARENA_MB: usize = 256;

/// The single resolved description of one run: everything the
/// coordinator, the service, the workers, and the benches need to
/// execute — no `Option`s, no "resolve later".
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExecPlan {
    /// Concrete block geometry (already sized, not an approach kind).
    pub shape: BlockShape,
    /// Worker thread count (paper: 2, 4, 8).
    pub workers: usize,
    /// Compute kernel for step/assign rounds — bit-identical results
    /// across all choices (see [`crate::kmeans::kernel`]).
    pub kernel: KernelChoice,
    /// How block pixels are held across rounds (see
    /// [`crate::kmeans::tile`]). Always concrete: construction resolves
    /// "kernel native" immediately.
    pub layout: TileLayout,
    /// Per-worker tile-arena byte budget in MiB (SoA layout).
    pub arena_mb: usize,
    /// Overlap next-block reads with compute (double buffering).
    pub prefetch: bool,
    /// Shared decoded-strip LRU capacity in strips (0 = no cache);
    /// meaningful only under strip I/O.
    pub strip_cache: usize,
}

impl Default for ExecPlan {
    /// A neutral pinned plan for direct construction in tests and
    /// examples: square 256-tiles, naive kernel, its native interleaved
    /// layout. Real entry points resolve through [`Planner::resolve`].
    fn default() -> Self {
        ExecPlan::pinned(BlockShape::Square { side: 256 })
    }
}

impl ExecPlan {
    /// A fully-pinned plan with the repo's historical defaults for
    /// everything but the shape. Chain the `with_*` builders to pin the
    /// rest.
    pub fn pinned(shape: BlockShape) -> ExecPlan {
        ExecPlan {
            shape,
            workers: DEFAULT_WORKERS,
            kernel: KernelChoice::Naive,
            layout: KernelChoice::Naive.default_layout(),
            arena_mb: DEFAULT_ARENA_MB,
            prefetch: false,
            strip_cache: 0,
        }
    }

    pub fn with_shape(mut self, shape: BlockShape) -> ExecPlan {
        self.shape = shape;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> ExecPlan {
        assert!(workers > 0, "need at least one worker");
        self.workers = workers;
        self
    }

    /// Pin the kernel; the layout follows to the kernel's native shape
    /// (call [`ExecPlan::with_layout`] *after* this to override).
    pub fn with_kernel(mut self, kernel: KernelChoice) -> ExecPlan {
        self.kernel = kernel;
        self.layout = kernel.default_layout();
        self
    }

    pub fn with_layout(mut self, layout: TileLayout) -> ExecPlan {
        self.layout = layout;
        self
    }

    pub fn with_arena_mb(mut self, arena_mb: usize) -> ExecPlan {
        self.arena_mb = arena_mb;
        self
    }

    pub fn with_prefetch(mut self, prefetch: bool) -> ExecPlan {
        self.prefetch = prefetch;
        self
    }

    pub fn with_strip_cache(mut self, strips: usize) -> ExecPlan {
        self.strip_cache = strips;
        self
    }

    /// Per-worker arena budget in bytes.
    pub fn arena_bytes(&self) -> usize {
        self.arena_mb << 20
    }

    /// Materialize the block tiling for an image (deterministic — the
    /// solo coordinator and the service derive identical plans from
    /// identical specs by construction).
    pub fn block_plan(&self, height: usize, width: usize) -> BlockPlan {
        BlockPlan::new(height, width, self.shape)
    }

    /// Resolved block-grid extent for an image.
    pub fn grid(&self, height: usize, width: usize) -> (usize, usize) {
        self.block_plan(height, width).grid_dims()
    }

    /// One-line human rendering ("what ran"), used by the `blockms
    /// cluster` summary and the explain table.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} · {} · {} · {}w",
            self.shape, self.kernel, self.layout, self.workers
        );
        if self.strip_cache > 0 {
            s.push_str(&format!(" · cache {}", self.strip_cache));
        }
        if self.prefetch {
            s.push_str(" · prefetch");
        }
        s
    }
}

/// A planning request: workload geometry plus a pin for every knob the
/// planner may otherwise choose. `None` = the planner decides.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PlanRequest {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub k: usize,
    /// Expected Lloyd iterations (fixed_iters, or max_iters as bound).
    pub rounds: usize,
    /// Strip height of the I/O model (`None` = direct crops).
    pub strip_rows: Option<usize>,
    pub shape: Option<BlockShape>,
    pub workers: Option<usize>,
    pub kernel: Option<KernelChoice>,
    pub layout: Option<TileLayout>,
    pub arena_mb: Option<usize>,
    pub prefetch: Option<bool>,
    pub strip_cache: Option<usize>,
}

impl PlanRequest {
    pub fn new(height: usize, width: usize, channels: usize, k: usize) -> PlanRequest {
        PlanRequest {
            height,
            width,
            channels,
            k,
            rounds: crate::kmeans::KMeansConfig::default().max_iters,
            ..Default::default()
        }
    }

    /// The workload geometry slice the cost model consumes.
    pub fn workload(&self) -> Workload {
        Workload {
            height: self.height,
            width: self.width,
            channels: self.channels,
            k: self.k,
            rounds: self.rounds,
            strip_rows: self.strip_rows,
        }
    }

    /// Pin every knob from an existing plan — the resulting request
    /// round-trips through [`Planner::resolve`] unchanged (a tested
    /// property).
    pub fn pin_all(mut self, plan: &ExecPlan) -> PlanRequest {
        self.shape = Some(plan.shape);
        self.workers = Some(plan.workers);
        self.kernel = Some(plan.kernel);
        self.layout = Some(plan.layout);
        self.arena_mb = Some(plan.arena_mb);
        self.prefetch = Some(plan.prefetch);
        self.strip_cache = Some(plan.strip_cache);
        self
    }

    pub fn with_rounds(mut self, rounds: usize) -> PlanRequest {
        self.rounds = rounds.max(1);
        self
    }

    pub fn with_strip_rows(mut self, strip_rows: Option<usize>) -> PlanRequest {
        self.strip_rows = strip_rows;
        self
    }

    /// True when every knob is pinned (the planner has nothing to do).
    pub fn fully_pinned(&self) -> bool {
        self.shape.is_some()
            && self.workers.is_some()
            && self.kernel.is_some()
            && self.layout.is_some()
            && self.arena_mb.is_some()
            && self.prefetch.is_some()
            && self.strip_cache.is_some()
    }
}

/// The planner: candidate enumeration + cost-model argmin. See module
/// docs for the determinism and never-override-a-pin contracts.
#[derive(Clone, Debug, Default)]
pub struct Planner {
    model: CostModel,
}

impl Planner {
    pub fn new(model: CostModel) -> Planner {
        Planner { model }
    }

    pub fn model(&self) -> &CostModel {
        &self.model
    }

    pub fn model_mut(&mut self) -> &mut CostModel {
        &mut self.model
    }

    /// Every candidate the request admits, in the fixed enumeration
    /// order (shapes, then kernels, then layouts, then cache, then
    /// prefetch), each with its predicted cost. Pins collapse an axis
    /// to the pinned value.
    pub fn candidates(&self, req: &PlanRequest) -> Vec<Candidate> {
        assert!(
            req.height > 0 && req.width > 0 && req.channels > 0 && req.k > 0,
            "degenerate plan request {}x{} c={} k={}",
            req.height,
            req.width,
            req.channels,
            req.k
        );
        let w = req.workload();
        let shapes: Vec<BlockShape> = match req.shape {
            Some(s) => vec![s],
            None => ApproachKind::ALL
                .iter()
                .map(|&a| BlockShape::paper_default(a, req.height, req.width))
                .collect(),
        };
        let kernels: Vec<KernelChoice> = match req.kernel {
            Some(k) => vec![k],
            None => KernelChoice::ALL.to_vec(),
        };
        let layouts: Vec<TileLayout> = match req.layout {
            Some(l) => vec![l],
            None => vec![TileLayout::Interleaved, TileLayout::Soa],
        };
        let caches: Vec<usize> = match req.strip_cache {
            Some(c) => vec![c],
            // A cache only matters when strips can be re-decoded.
            None if req.strip_rows.is_some() => vec![0, w.unique_strips()],
            None => vec![0],
        };
        let prefetches: Vec<bool> = match req.prefetch {
            Some(p) => vec![p],
            None if req.strip_rows.is_some() => vec![false, true],
            None => vec![false],
        };
        let workers = req.workers.unwrap_or(DEFAULT_WORKERS);
        let arena_mb = req.arena_mb.unwrap_or_else(|| self.auto_arena_mb(&w, workers));

        let mut out = Vec::new();
        for &shape in &shapes {
            let plan = BlockPlan::new(req.height, req.width, shape);
            for &kernel in &kernels {
                for &layout in &layouts {
                    for &strip_cache in &caches {
                        for &prefetch in &prefetches {
                            let cost = self.model.predict(
                                &w,
                                &plan,
                                kernel,
                                layout,
                                workers,
                                strip_cache,
                                prefetch,
                            );
                            out.push(Candidate {
                                plan: ExecPlan {
                                    shape,
                                    workers,
                                    kernel,
                                    layout,
                                    arena_mb,
                                    prefetch,
                                    strip_cache,
                                },
                                blocks: plan.len(),
                                grid: plan.grid_dims(),
                                cost,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Resolve a request into the one plan to run, plus the explain
    /// report over everything that was considered.
    pub fn resolve(&self, req: &PlanRequest) -> (ExecPlan, Explain) {
        let candidates = self.candidates(req);
        // Deterministic argmin: strictly-less keeps the earliest of a
        // tie, and enumeration order is fixed.
        let mut best = 0usize;
        for (i, c) in candidates.iter().enumerate() {
            if c.cost.wall_secs < candidates[best].cost.wall_secs {
                best = i;
            }
        }
        let plan = candidates[best].plan;
        let explain = Explain::new(req.clone(), candidates, best, self.model.error_bound);
        (plan, explain)
    }

    /// Arena sizing when unpinned: big enough that every SoA tile of
    /// the job fits its worker's share with deinterleave padding slack,
    /// floored at the historical default.
    fn auto_arena_mb(&self, w: &Workload, workers: usize) -> usize {
        let per_worker = (w.image_bytes() as usize * 5 / 4) / workers.max(1);
        DEFAULT_ARENA_MB.max(per_worker.div_ceil(1 << 20))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> PlanRequest {
        PlanRequest::new(1024, 1024, 3, 4)
            .with_rounds(4)
            .with_strip_rows(Some(64))
    }

    #[test]
    fn fully_pinned_request_round_trips() {
        let pinned = ExecPlan::pinned(BlockShape::Cols { band_cols: 205 })
            .with_workers(2)
            .with_kernel(KernelChoice::Pruned)
            .with_layout(TileLayout::Soa)
            .with_arena_mb(64)
            .with_prefetch(true)
            .with_strip_cache(7);
        let r = req().pin_all(&pinned);
        assert!(r.fully_pinned());
        let (resolved, explain) = Planner::default().resolve(&r);
        assert_eq!(resolved, pinned);
        assert_eq!(explain.candidates.len(), 1);
    }

    #[test]
    fn auto_explores_the_full_grid() {
        let (plan, explain) = Planner::default().resolve(&req());
        // 3 shapes x 4 kernels x 2 layouts x 2 caches x 2 prefetch
        assert_eq!(explain.candidates.len(), 96);
        // the model's lanes floors dominate: auto must not pick naive
        assert_eq!(plan.kernel, KernelChoice::Lanes);
        // picked plan is the explain's chosen row
        assert_eq!(explain.chosen().plan, plan);
    }

    #[test]
    fn pick_is_no_regret_under_its_own_model() {
        let planner = Planner::default();
        for k in [1, 2, 3, 5, 8, 13] {
            let mut r = req();
            r.k = k;
            let (plan, explain) = planner.resolve(&r);
            let chosen = explain.chosen();
            assert_eq!(chosen.plan, plan);
            for c in &explain.candidates {
                assert!(
                    chosen.cost.wall_secs <= c.cost.wall_secs,
                    "k={k}: picked {:?} but {:?} predicts cheaper",
                    chosen.plan,
                    c.plan
                );
            }
        }
    }

    #[test]
    fn resolution_is_deterministic() {
        let planner = Planner::default();
        let (a, ea) = planner.resolve(&req());
        let (b, eb) = planner.resolve(&req());
        assert_eq!(a, b);
        assert_eq!(
            ea.candidates.iter().map(|c| c.plan).collect::<Vec<_>>(),
            eb.candidates.iter().map(|c| c.plan).collect::<Vec<_>>()
        );
    }

    #[test]
    fn pins_constrain_the_search() {
        let planner = Planner::default();
        let mut r = req();
        r.kernel = Some(KernelChoice::Naive);
        r.prefetch = Some(false);
        let (plan, explain) = planner.resolve(&r);
        assert_eq!(plan.kernel, KernelChoice::Naive);
        assert!(!plan.prefetch);
        assert!(explain.candidates.iter().all(|c| c.plan.kernel == KernelChoice::Naive));
        // 3 shapes x 1 kernel x 2 layouts x 2 caches x 1 prefetch
        assert_eq!(explain.candidates.len(), 12);
    }

    #[test]
    fn direct_io_skips_cache_and_prefetch_axes() {
        let planner = Planner::default();
        let r = PlanRequest::new(512, 512, 3, 2).with_rounds(3);
        let (plan, explain) = planner.resolve(&r);
        assert_eq!(plan.strip_cache, 0);
        assert!(!plan.prefetch);
        // 3 shapes x 4 kernels x 2 layouts
        assert_eq!(explain.candidates.len(), 24);
    }

    #[test]
    fn auto_arena_scales_with_image() {
        let planner = Planner::default();
        let small = PlanRequest::new(256, 256, 3, 2);
        let (p_small, _) = planner.resolve(&small);
        assert_eq!(p_small.arena_mb, DEFAULT_ARENA_MB);
        let huge = PlanRequest::new(16384, 16384, 3, 2);
        let (p_huge, _) = planner.resolve(&huge);
        // 16384^2 x 3 x 4 bytes x 1.25 / 4 workers = 960 MiB
        assert!(p_huge.arena_mb > DEFAULT_ARENA_MB, "{}", p_huge.arena_mb);
    }

    #[test]
    fn with_kernel_follows_native_layout_then_override() {
        let p = ExecPlan::default().with_kernel(KernelChoice::Lanes);
        assert_eq!(p.layout, TileLayout::Soa);
        let p = p.with_layout(TileLayout::Interleaved);
        assert_eq!(p.layout, TileLayout::Interleaved);
        assert_eq!(p.kernel, KernelChoice::Lanes);
    }

    #[test]
    fn summary_names_the_strategy() {
        let s = ExecPlan::pinned(BlockShape::Square { side: 459 })
            .with_kernel(KernelChoice::Lanes)
            .with_strip_cache(16)
            .with_prefetch(true)
            .summary();
        for part in ["square[459 459]", "lanes", "soa", "4w", "cache 16", "prefetch"] {
            assert!(s.contains(part), "{part} missing from {s:?}");
        }
    }
}
